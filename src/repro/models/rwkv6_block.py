"""RWKV-6 "Finch" block: time-mix (WKV6 recurrence, data-dependent decay)
+ channel-mix, with token-shift interpolation.

Time-mix (per head, dk = dv = head_dim):
    xs        = token_shift(x)                      (x_{t-1})
    xk,xv,... = lerp(x, xs, mu_*)                   per-channel mixing
    r,k,v,g   = projections;  g gated with silu
    w_t       = exp(-exp(w0 + tanh(xw @ A) @ B))    low-rank dynamic decay
    y         = WKV6(r,k,v,w,u)                     <- Pallas kernel
    out       = (groupnorm(y) * g) @ W_o

Channel-mix:
    k   = relu(lerp(x, xs, mu_k) @ W_k)^2
    out = sigmoid(lerp(x, xs, mu_r) @ W_r) * (k @ W_v)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels import rwkv6 as wkv6_op
from .common import dense_init, rms_norm, split_keys

DECAY_RANK = 64


def init_rwkv6_block(key, d_model: int, n_heads: int, d_ff: int | None = None,
                     dtype=jnp.float32) -> dict:
    d_ff = d_ff or 4 * d_model
    ks = split_keys(key, ["wr", "wk", "wv", "wg", "wo", "wd1", "wd2",
                          "cm_r", "cm_k", "cm_v"])
    def zeros(*shape):
        return jnp.zeros(shape, dtype)

    return {
        "mu": zeros(5, d_model) + 0.5,       # r,k,v,g,w mixing coefficients
        "wr": dense_init(ks["wr"], (d_model, d_model), dtype),
        "wk": dense_init(ks["wk"], (d_model, d_model), dtype),
        "wv": dense_init(ks["wv"], (d_model, d_model), dtype),
        "wg": dense_init(ks["wg"], (d_model, d_model), dtype),
        "wo": dense_init(ks["wo"], (d_model, d_model), dtype),
        "w0": zeros(d_model) - 1.0,          # base decay ~ exp(-exp(-1))
        "wd1": dense_init(ks["wd1"], (d_model, DECAY_RANK), dtype),
        "wd2": dense_init(ks["wd2"], (DECAY_RANK, d_model), dtype,
                          fan_in=DECAY_RANK),
        "u": zeros(d_model) + 0.1,           # per-channel bonus
        "ln_y": zeros(d_model),              # groupnorm scale
        "cm_mu": zeros(2, d_model) + 0.5,
        "cm_r": dense_init(ks["cm_r"], (d_model, d_model), dtype),
        "cm_k": dense_init(ks["cm_k"], (d_model, d_ff), dtype),
        "cm_v": dense_init(ks["cm_v"], (d_ff, d_model), dtype, fan_in=d_ff),
    }


def _token_shift(x: jax.Array, last: jax.Array | None = None) -> jax.Array:
    """x_{t-1} along seq; ``last`` (B,1,D) supplies history for decode."""
    if last is None:
        last = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([last, x[:, :-1]], axis=1)


def _heads(x: jax.Array, n_heads: int) -> jax.Array:
    b, s, d = x.shape
    return jnp.transpose(x.reshape(b, s, n_heads, d // n_heads),
                         (0, 2, 1, 3)).reshape(b * n_heads, s,
                                               d // n_heads)


def _unheads(x: jax.Array, b: int, n_heads: int) -> jax.Array:
    bh, s, hd = x.shape
    return jnp.transpose(x.reshape(b, n_heads, s, hd),
                         (0, 2, 1, 3)).reshape(b, s, n_heads * hd)


def _tm_projections(params, x, xs, compute_dtype):
    mu = params["mu"].astype(jnp.float32)
    def mix(i):
        return (x * (1 - mu[i]) + xs * mu[i]).astype(compute_dtype)

    r = mix(0) @ params["wr"].astype(compute_dtype)
    k = mix(1) @ params["wk"].astype(compute_dtype)
    v = mix(2) @ params["wv"].astype(compute_dtype)
    g = jax.nn.silu((mix(3) @ params["wg"].astype(compute_dtype))
                    .astype(jnp.float32))
    xw = mix(4)
    dyn = jnp.tanh((xw @ params["wd1"].astype(compute_dtype))
                   .astype(jnp.float32))
    dyn = dyn.astype(compute_dtype) @ params["wd2"].astype(compute_dtype)
    logw = params["w0"].astype(jnp.float32) + dyn.astype(jnp.float32)
    w = jnp.exp(-jnp.exp(logw))                       # decay in (0,1)
    return r, k, v, g, w


def time_mix(params: dict, x: jax.Array, n_heads: int,
             compute_dtype=jnp.bfloat16) -> jax.Array:
    out, _ = time_mix_with_state(params, x, n_heads, compute_dtype)
    return out


def time_mix_with_state(params: dict, x: jax.Array, n_heads: int,
                        compute_dtype=jnp.bfloat16) \
        -> tuple[jax.Array, dict]:
    """Parallel (prefill) form that also returns tm_last + wkv state."""
    b, s, d = x.shape
    x32 = x.astype(jnp.float32)
    xs = _token_shift(x32)
    r, k, v, g, w = _tm_projections(params, x32, xs, compute_dtype)
    hd = d // n_heads
    u = jnp.broadcast_to(
        params["u"].astype(jnp.float32).reshape(n_heads, hd)[None],
        (b, n_heads, hd)).reshape(b * n_heads, hd)
    y, wkv_state = wkv6_op(_heads(r.astype(jnp.float32), n_heads),
                           _heads(k.astype(jnp.float32), n_heads),
                           _heads(v.astype(jnp.float32), n_heads),
                           _heads(w, n_heads), u, return_state=True)
    y = _unheads(y, b, n_heads)
    y = rms_norm(y, params["ln_y"])
    out = (y * g).astype(compute_dtype) @ params["wo"].astype(compute_dtype)
    state = {"tm_last": x32[:, -1:], "wkv": wkv_state}
    return out.astype(x.dtype), state


def channel_mix(params: dict, x: jax.Array,
                compute_dtype=jnp.bfloat16) -> jax.Array:
    x32 = x.astype(jnp.float32)
    xs = _token_shift(x32)
    mu = params["cm_mu"].astype(jnp.float32)
    xr = (x32 * (1 - mu[0]) + xs * mu[0]).astype(compute_dtype)
    xk = (x32 * (1 - mu[1]) + xs * mu[1]).astype(compute_dtype)
    k = jnp.square(jax.nn.relu(
        (xk @ params["cm_k"].astype(compute_dtype)).astype(jnp.float32)))
    r = jax.nn.sigmoid(
        (xr @ params["cm_r"].astype(compute_dtype)).astype(jnp.float32))
    out = r * (k.astype(compute_dtype)
               @ params["cm_v"].astype(compute_dtype)).astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# decode (single token, carried state)
# ---------------------------------------------------------------------------
def init_rwkv6_state(batch: int, d_model: int, n_heads: int) -> dict:
    hd = d_model // n_heads
    return {
        "tm_last": jnp.zeros((batch, 1, d_model), jnp.float32),
        "cm_last": jnp.zeros((batch, 1, d_model), jnp.float32),
        "wkv": jnp.zeros((batch * n_heads, hd, hd), jnp.float32),
    }


def time_mix_decode(params: dict, x: jax.Array, state: dict, n_heads: int,
                    compute_dtype=jnp.bfloat16) -> tuple[jax.Array, dict]:
    b, _, d = x.shape
    hd = d // n_heads
    x32 = x.astype(jnp.float32)
    xs = state["tm_last"]
    r, k, v, g, w = _tm_projections(params, x32, xs, compute_dtype)
    rh = _heads(r.astype(jnp.float32), n_heads)[:, 0]     # (BH, hd)
    kh = _heads(k.astype(jnp.float32), n_heads)[:, 0]
    vh = _heads(v.astype(jnp.float32), n_heads)[:, 0]
    wh = _heads(w, n_heads)[:, 0]
    u = jnp.broadcast_to(
        params["u"].astype(jnp.float32).reshape(n_heads, hd)[None],
        (b, n_heads, hd)).reshape(b * n_heads, hd)
    S = state["wkv"]
    kv = kh[:, :, None] * vh[:, None, :]
    y = jnp.einsum("bk,bkv->bv", rh, S + u[:, :, None] * kv)
    S = wh[:, :, None] * S + kv
    y = _unheads(y[:, None], b, n_heads)
    y = rms_norm(y, params["ln_y"])
    out = (y * g).astype(compute_dtype) @ params["wo"].astype(compute_dtype)
    return out.astype(x.dtype), \
        {**state, "tm_last": x32, "wkv": S}


def channel_mix_decode(params: dict, x: jax.Array, state: dict,
                       compute_dtype=jnp.bfloat16) -> tuple[jax.Array, dict]:
    x32 = x.astype(jnp.float32)
    xs = state["cm_last"]
    mu = params["cm_mu"].astype(jnp.float32)
    xr = (x32 * (1 - mu[0]) + xs * mu[0]).astype(compute_dtype)
    xk = (x32 * (1 - mu[1]) + xs * mu[1]).astype(compute_dtype)
    k = jnp.square(jax.nn.relu(
        (xk @ params["cm_k"].astype(compute_dtype)).astype(jnp.float32)))
    r = jax.nn.sigmoid(
        (xr @ params["cm_r"].astype(compute_dtype)).astype(jnp.float32))
    out = r * (k.astype(compute_dtype)
               @ params["cm_v"].astype(compute_dtype)).astype(jnp.float32)
    return out.astype(x.dtype), {**state, "cm_last": x32}
