"""Feed-forward blocks: SwiGLU, GeLU, and capacity-based top-k MoE.

The MoE uses sort-based capacity dispatch (GShard-style, no (E,C,T) one-hot
tensors): tokens are argsorted by expert, scattered into an (E, C, d) buffer
(capacity overflow dropped — the standard trade), pushed through batched
expert FFNs, and combined with their gates.  Expert dim E is the
expert-parallel sharding axis; under pjit the scatter/gather lower to
all-to-alls across the model axis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import dense_init, split_keys


# ---------------------------------------------------------------------------
# dense FFNs
# ---------------------------------------------------------------------------
def init_swiglu(key, d_model: int, d_ff: int, dtype=jnp.float32) -> dict:
    ks = split_keys(key, ["w1", "w3", "w2"])
    return {
        "w1": dense_init(ks["w1"], (d_model, d_ff), dtype),
        "w3": dense_init(ks["w3"], (d_model, d_ff), dtype),
        "w2": dense_init(ks["w2"], (d_ff, d_model), dtype, fan_in=d_ff),
    }


def swiglu(params: dict, x: jax.Array, compute_dtype=jnp.bfloat16,
           act_f32: bool = True) -> jax.Array:
    xc = x.astype(compute_dtype)
    a = xc @ params["w1"].astype(compute_dtype)
    g = xc @ params["w3"].astype(compute_dtype)
    # act_f32=False keeps the activation in compute dtype: under 2D weight
    # sharding the partial-sum all-reduce of ``a`` then rides the wire in
    # bf16 instead of f32 (§Perf collective lever).
    h = jax.nn.silu(a.astype(jnp.float32)).astype(compute_dtype) * g \
        if act_f32 else jax.nn.silu(a) * g
    return (h @ params["w2"].astype(compute_dtype)).astype(x.dtype)


def init_gelu(key, d_model: int, d_ff: int, dtype=jnp.float32) -> dict:
    ks = split_keys(key, ["w1", "w2"])
    return {
        "w1": dense_init(ks["w1"], (d_model, d_ff), dtype),
        "w2": dense_init(ks["w2"], (d_ff, d_model), dtype, fan_in=d_ff),
    }


def gelu_mlp(params: dict, x: jax.Array, compute_dtype=jnp.bfloat16,
             act_f32: bool = True) -> jax.Array:
    xc = x.astype(compute_dtype)
    a = xc @ params["w1"].astype(compute_dtype)
    h = jax.nn.gelu(a.astype(jnp.float32)) if act_f32 else jax.nn.gelu(a)
    return (h.astype(compute_dtype)
            @ params["w2"].astype(compute_dtype)).astype(x.dtype)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------
def init_moe(key, d_model: int, d_ff: int, n_experts: int,
             dtype=jnp.float32) -> dict:
    ks = split_keys(key, ["router", "w1", "w3", "w2"])
    return {
        "router": dense_init(ks["router"], (d_model, n_experts), dtype),
        "w1": dense_init(ks["w1"], (n_experts, d_model, d_ff), dtype,
                         fan_in=d_model),
        "w3": dense_init(ks["w3"], (n_experts, d_model, d_ff), dtype,
                         fan_in=d_model),
        "w2": dense_init(ks["w2"], (n_experts, d_ff, d_model), dtype,
                         fan_in=d_ff),
    }


def moe_ffn(params: dict, x: jax.Array, *, top_k: int,
            capacity_factor: float = 1.25,
            compute_dtype=jnp.bfloat16, act_f32: bool = True) -> jax.Array:
    """Sort-based capacity MoE.  x (B, S, D) -> (B, S, D)."""
    b, s, d = x.shape
    t = b * s
    n_experts = params["router"].shape[1]
    xt = x.reshape(t, d)

    logits = (xt.astype(compute_dtype)
              @ params["router"].astype(compute_dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, sel = jax.lax.top_k(probs, top_k)                  # (T, k)
    gates = gates / jnp.maximum(
        jnp.sum(gates, axis=-1, keepdims=True), 1e-9)

    if capacity_factor == float("inf"):
        capacity = t * top_k
    else:
        capacity = int(max(1, -(-t * top_k * capacity_factor
                                // n_experts)))
        capacity = min(capacity, t * top_k)

    flat_e = sel.reshape(-1)                                  # (T*k,)
    order = jnp.argsort(flat_e, stable=True)
    se = flat_e[order]
    tok = (jnp.arange(t * top_k) // top_k)[order]
    starts = jnp.searchsorted(se, jnp.arange(n_experts), side="left")
    pos = jnp.arange(t * top_k) - starts[se]
    overflow = pos >= capacity
    slot = jnp.where(overflow, n_experts * capacity, se * capacity + pos)

    buf = jnp.zeros((n_experts * capacity + 1, d), compute_dtype)
    buf = buf.at[slot].set(xt[tok].astype(compute_dtype), mode="drop")
    h = buf[:n_experts * capacity].reshape(n_experts, capacity, d)

    w1 = params["w1"].astype(compute_dtype)
    w3 = params["w3"].astype(compute_dtype)
    w2 = params["w2"].astype(compute_dtype)
    a = jnp.einsum("ecd,edf->ecf", h, w1)
    g = jnp.einsum("ecd,edf->ecf", h, w3)
    hh = jax.nn.silu(a.astype(jnp.float32)).astype(compute_dtype) * g \
        if act_f32 else jax.nn.silu(a) * g
    y = jnp.einsum("ecf,efd->ecd", hh, w2)

    y_slots = jnp.concatenate(
        [y.reshape(n_experts * capacity, d),
         jnp.zeros((1, d), compute_dtype)], axis=0)
    y_tok = y_slots[slot]                                     # (T*k, d)
    # combine dtype follows act_f32: an f32 combine forces every backward
    # partial-sum through the expert einsums onto the wire in f32 (the
    # dominant all-reduce bytes of MoE training); bf16 halves them.
    comb_dtype = jnp.float32 if act_f32 else compute_dtype
    gate_sorted = gates.reshape(-1)[order].astype(comb_dtype)
    contrib = y_tok.astype(comb_dtype) * gate_sorted[:, None]
    out = jnp.zeros((t, d), comb_dtype).at[tok].add(contrib)
    return out.reshape(b, s, d).astype(x.dtype)


def moe_ffn_reference(params: dict, x: jax.Array, *, top_k: int) \
        -> jax.Array:
    """Oracle: dense all-experts compute, gather the top-k outputs."""
    b, s, d = x.shape
    xt = x.reshape(-1, d).astype(jnp.float32)
    logits = xt @ params["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, sel = jax.lax.top_k(probs, top_k)
    gates = gates / jnp.maximum(
        jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    # all experts on all tokens
    a = jnp.einsum("td,edf->etf", xt, params["w1"].astype(jnp.float32))
    g = jnp.einsum("td,edf->etf", xt, params["w3"].astype(jnp.float32))
    h = jax.nn.silu(a) * g
    y_all = jnp.einsum("etf,efd->etd", h, params["w2"].astype(jnp.float32))
    picked = jnp.take_along_axis(
        jnp.swapaxes(y_all, 0, 1), sel[:, :, None], axis=1)   # (T,k,d)
    out = jnp.sum(picked * gates[:, :, None], axis=1)
    return out.reshape(b, s, d).astype(x.dtype)
