"""Attention with selectable implementations (the solver's choice axis).

Implementations (``AttnImpl``):

  ``naive``      full (S,S) masked logits — the oracle; O(S^2) memory.
  ``chunked``    scan over query chunks against full K/V — O(S*C) memory,
                 full S^2 FLOPs (masked blocks still computed).  The
                 paper-faithful tiling baseline: blocking without domain
                 pruning.
  ``recursive``  recursive-halving causal attention: the strictly-causal
                 part decomposes into log2(S/C) levels of *unmasked*
                 rectangular attention (upper-half Q vs lower-half K/V,
                 batched across sub-blocks) plus masked diagonal base
                 blocks.  ~S^2/2 + S*C FLOPs with static shapes — the
                 XLA-visible analogue of flash-attention block skipping;
                 a beyond-paper optimization measured in §Perf.
  ``windowed``   sliding-window attention in O(S*(W+C)) via per-chunk
                 dynamic KV slices (mixtral SWA, recurrentgemma local).
  ``pallas``     the flash-attention Pallas kernel (TPU; interpret in
                 tests).

All paths share fp32 softmax statistics and merge via the online-softmax
(acc, m, l) triple.
"""
from __future__ import annotations

from typing import Literal

import jax
import jax.numpy as jnp

from ..kernels.flash_attention import ops as flash_ops

AttnImpl = Literal["naive", "chunked", "recursive", "windowed", "pallas"]

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# online-softmax piece algebra: a piece is (acc, m, l) with
#   out = acc / l,  acc = sum_j exp(s_j - m) v_j,  l = sum_j exp(s_j - m)
# ---------------------------------------------------------------------------
def _piece(q, k, v, *, scale: float, masked: bool = True,
           row0=0, col0=0, causal: bool = True, window: int | None = None,
           score_dtype=jnp.float32, gqa_grouped: bool = False):
    """Attention piece of q (B,Sq,H,D) against k/v (B,Sk,Hkv,D).

    Masking uses absolute positions: row = row0 + r, col = col0 + c;
    valid iff (col <= row if causal) and (col > row - window) and col >= 0.
    ``masked=False`` skips masking entirely (unmasked cross blocks in the
    recursive decomposition).

    §Perf levers (beyond-paper; baseline keeps the faithful defaults):
      score_dtype=bf16   keeps the O(S^2) score/prob maps in bf16 — the
                         row statistics (max, sum) stay fp32, which is
                         what a fused TPU kernel holds in registers;
      gqa_grouped=True   grouped einsum over (Hkv, G) instead of
                         materialising repeated KV heads.
    """
    b, sq, h, d = q.shape
    sk = k.shape[1]
    hkv = k.shape[2]
    group = h // hkv
    if group > 1 and not gqa_grouped:
        k = jnp.repeat(k, group, axis=2)
        v = jnp.repeat(v, group, axis=2)
    if group > 1 and gqa_grouped:
        qg = q.reshape(b, sq, hkv, group, d)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                       preferred_element_type=score_dtype) * scale
        sm_axes = (0, 1, 2)        # (b, hkv, g) leading axes
    else:
        s = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                       preferred_element_type=score_dtype) * scale
        sm_axes = (0, 1)
    lead = (1,) * len(sm_axes)
    if masked:
        rows = row0 + jnp.arange(sq)[:, None]
        cols = col0 + jnp.arange(sk)[None, :]
        valid = cols >= 0
        if causal:
            valid &= cols <= rows
        if window is not None:
            valid &= cols > rows - window
        s = jnp.where(valid.reshape(lead + (sq, sk)), s,
                      jnp.asarray(NEG_INF, s.dtype))
    m = jnp.max(s, axis=-1, keepdims=True).astype(jnp.float32)
    p = jnp.exp((s - m.astype(s.dtype)))
    if masked:
        p = jnp.where(valid.reshape(lead + (sq, sk)), p,
                      jnp.asarray(0.0, p.dtype))
    l = jnp.sum(p.astype(jnp.float32), axis=-1, keepdims=True)
    if group > 1 and gqa_grouped:
        acc = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v,
                         preferred_element_type=jnp.float32)
        acc = acc.reshape(b, sq, h, d)
        m = jnp.transpose(m, (0, 3, 1, 2, 4)).reshape(b, sq, h, 1)
        l = jnp.transpose(l, (0, 3, 1, 2, 4)).reshape(b, sq, h, 1)
    else:
        acc = jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v,
                         preferred_element_type=jnp.float32)
        m = jnp.transpose(m, (0, 2, 1, 3))                      # (B,Sq,H,1)
        l = jnp.transpose(l, (0, 2, 1, 3))
    return acc.astype(jnp.float32), m, l


def _merge(p1, p2):
    acc1, m1, l1 = p1
    acc2, m2, l2 = p2
    m = jnp.maximum(m1, m2)
    c1 = jnp.exp(m1 - m)
    c2 = jnp.exp(m2 - m)
    return acc1 * c1 + acc2 * c2, m, l1 * c1 + l2 * c2


def _finalize(piece, dtype):
    acc, _, l = piece
    return (acc / jnp.maximum(l, 1e-30)).astype(dtype)


# ---------------------------------------------------------------------------
def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              impl: AttnImpl = "chunked", window: int | None = None,
              chunk: int = 512, scale: float | None = None,
              unroll: bool = False, score_dtype=jnp.float32,
              gqa_grouped: bool = False) -> jax.Array:
    """Causal (optionally sliding-window) self attention.

    q (B,S,H,D); k,v (B,S,Hkv,D) with H % Hkv == 0.  Returns (B,S,H,D).
    ``window`` counts the current token (window=1 sees only itself).
    ``unroll`` python-unrolls the chunk maps (dry-run cost fidelity:
    HloCostAnalysis counts a loop body once; unrolled bodies count fully).
    """
    b, s, h, d = q.shape
    if scale is None:
        scale = d ** -0.5
    if impl == "pallas":
        return flash_ops.flash_attention(q, k, v, causal=True, window=window,
                                         scale=scale)
    kw = dict(score_dtype=score_dtype, gqa_grouped=gqa_grouped)
    if window is not None and impl != "naive":
        if s > window:
            return _windowed(q, k, v, window, min(chunk, s), scale, unroll,
                             **kw)
        # window covers everything: plain causal
        window = None
    if impl == "naive" or s <= chunk:
        return _finalize(
            _piece(q, k, v, scale=scale, causal=True, window=window, **kw),
            q.dtype)
    if impl == "chunked":
        return _chunked(q, k, v, chunk, scale, unroll, **kw)
    if impl == "recursive":
        return _recursive(q, k, v, chunk, scale, **kw)
    raise ValueError(f"unknown attention impl {impl!r}")


def _map(fn, args, unroll: bool):
    """lax.map, or a python loop when ``unroll`` (cost-visible HLO)."""
    if not unroll:
        return jax.lax.map(fn, args)
    n = args[0].shape[0]
    outs = [fn(tuple(a[i] for a in args)) for i in range(n)]
    return jnp.stack(outs)


def _chunked(q, k, v, chunk, scale, unroll=False, **kw):
    """Scan over q chunks vs full K/V — bounded memory, full FLOPs."""
    b, s, h, d = q.shape
    pad = (-s) % chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n = q.shape[1] // chunk
    qc = jnp.moveaxis(q.reshape(b, n, chunk, h, d), 1, 0)

    def one(args):
        i, q_i = args
        return _finalize(
            _piece(q_i, k, v, scale=scale, row0=i * chunk, causal=True,
                   **kw),
            q.dtype)

    out = _map(one, (jnp.arange(n), qc), unroll)
    return jnp.moveaxis(out, 0, 1).reshape(b, n * chunk, h, d)[:, :s]


def _recursive(q, k, v, base, scale, **kw):
    """Recursive halving: FLOPs ~ S^2/2 + S*base, static shapes."""
    b, s, h, d = q.shape

    def rec(q_, k_, v_):
        bb, ss = q_.shape[0], q_.shape[1]
        if ss <= base:
            return _piece(q_, k_, v_, scale=scale, causal=True, **kw)
        half = ss // 2
        q1, q2 = q_[:, :half], q_[:, half:]
        k1, k2 = k_[:, :half], k_[:, half:]
        v1, v2 = v_[:, :half], v_[:, half:]
        # both halves recurse together as a doubled batch
        qs = jnp.concatenate([q1, q2], axis=0)
        ks = jnp.concatenate([k1, k2], axis=0)
        vs = jnp.concatenate([v1, v2], axis=0)
        acc, m, l = rec(qs, ks, vs)
        piece1 = (acc[:bb], m[:bb], l[:bb])
        piece2 = (acc[bb:], m[bb:], l[bb:])
        # upper-half queries also see the whole lower half — unmasked
        cross = _piece(q2, k1, v1, scale=scale, masked=False, **kw)
        acc2, m2, l2 = _merge(piece2, cross)
        return (jnp.concatenate([piece1[0], acc2], axis=1),
                jnp.concatenate([piece1[1], m2], axis=1),
                jnp.concatenate([piece1[2], l2], axis=1))

    # pad to a power-of-two multiple of base (computation padding);
    # padded KEY rows sit at positions >= s, masked by causality for all
    # real rows; padded QUERY rows are sliced off.
    target = base
    while target < s:
        target *= 2
    if target != s:
        pad = target - s
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    out = _finalize(rec(q, k, v), q.dtype)
    return out[:, :s]


def _windowed(q, k, v, window, chunk, scale, unroll=False, **kw):
    """Sliding window: each q chunk gathers a (window+chunk) KV slice.

    KV is left-padded by ``span`` so slices are fixed-size; masking uses
    absolute positions so the padding (col < 0) is excluded exactly."""
    b, s, h, d = q.shape
    pad_s = (-s) % chunk
    if pad_s:
        q = jnp.pad(q, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
    n = q.shape[1] // chunk
    span = window + chunk
    kp = jnp.pad(k, ((0, 0), (span, 0), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (span, 0), (0, 0), (0, 0)))
    qc = jnp.moveaxis(q.reshape(b, n, chunk, h, d), 1, 0)

    def one(args):
        i, q_i = args
        # original-coordinate slice [ (i+1)*chunk - span, (i+1)*chunk )
        lo = (i + 1) * chunk              # in padded coords (shift +span)
        k_i = jax.lax.dynamic_slice_in_dim(kp, lo, span, axis=1)
        v_i = jax.lax.dynamic_slice_in_dim(vp, lo, span, axis=1)
        piece = _piece(q_i, k_i, v_i, scale=scale,
                       row0=i * chunk, col0=(i + 1) * chunk - span,
                       causal=True, window=window, **kw)
        return _finalize(piece, q.dtype)

    out = _map(one, (jnp.arange(n), qc), unroll)
    return jnp.moveaxis(out, 0, 1).reshape(b, n * chunk, h, d)[:, :s]


# ---------------------------------------------------------------------------
# decode: one new token against a KV cache
# ---------------------------------------------------------------------------
def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     length, *, scale: float | None = None) -> jax.Array:
    """q (B,1,H,D); caches (B,Sc,Hkv,D); ``length`` (B,) or scalar = number
    of valid cache entries.  For ring-buffer (windowed) caches the caller
    passes length = cache size once full."""
    b, _, h, d = q.shape
    sc = k_cache.shape[1]
    hkv = k_cache.shape[2]
    group = h // hkv
    if scale is None:
        scale = d ** -0.5
    kk = jnp.repeat(k_cache, group, axis=2) if group > 1 else k_cache
    vv = jnp.repeat(v_cache, group, axis=2) if group > 1 else v_cache
    sct = jnp.einsum("bqhd,bkhd->bhqk", q, kk,
                     preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(sc)[None, None, None, :]
    length = jnp.asarray(length)
    valid = pos < length.reshape(-1, 1, 1, 1)
    sct = jnp.where(valid, sct, NEG_INF)
    p = jax.nn.softmax(sct.astype(jnp.float32), axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p.astype(vv.dtype), vv,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


def decode_attention_blocks(q: jax.Array, read_chunk, n_chunks: int,
                            chunk: int, length, *,
                            scale: float | None = None,
                            unroll: bool = False) -> jax.Array:
    """Sequence-blocked decode attention (paged-attention-lite).

    ``read_chunk(i)`` returns the (k, v) block (B, C, Hkv, D) for chunk i
    — dequantisation happens per block, so the live working set is one
    block instead of the whole (possibly int8-packed) cache (the temp
    that blows HBM for 32k x batch-128 decode cells).  Pieces merge by
    online softmax; fully-masked chunks contribute l = 0.
    """
    b, _, h, d = q.shape
    if scale is None:
        scale = d ** -0.5

    def piece_of(i):
        kk, vv = read_chunk(i)
        kk = kk.astype(q.dtype)
        vv = vv.astype(q.dtype)
        sk = kk.shape[1]
        hkv = kk.shape[2]
        group = h // hkv
        if group > 1:
            kk = jnp.repeat(kk, group, axis=2)
            vv = jnp.repeat(vv, group, axis=2)
        sco = jnp.einsum("bqhd,bkhd->bhqk", q, kk,
                         preferred_element_type=jnp.float32) * scale
        pos = i * chunk + jnp.arange(sk)
        valid = pos < length
        sco = jnp.where(valid[None, None, None, :], sco, NEG_INF)
        m = jnp.max(sco, axis=-1, keepdims=True)
        p = jnp.where(valid[None, None, None, :], jnp.exp(sco - m), 0.0)
        l = jnp.sum(p, axis=-1, keepdims=True)
        acc = jnp.einsum("bhqk,bkhd->bqhd", p.astype(vv.dtype), vv,
                         preferred_element_type=jnp.float32)
        m = jnp.transpose(m, (0, 2, 1, 3))
        l = jnp.transpose(l, (0, 2, 1, 3))
        # fully-masked chunk: force m to NEG_INF so _merge ignores it
        m = jnp.where(jnp.any(valid), m, NEG_INF)
        return acc.astype(jnp.float32), m, l

    if unroll:
        out = piece_of(0)
        for i in range(1, n_chunks):
            out = _merge(out, piece_of(jnp.asarray(i)))
        return _finalize(out, q.dtype)
    acc, m, l = jax.lax.map(piece_of, jnp.arange(n_chunks))
    out = (acc[0], m[0], l[0])
    for i in range(1, n_chunks):
        out = _merge(out, (acc[i], m[i], l[i]))
    return _finalize(out, q.dtype)
