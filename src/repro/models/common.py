"""Shared model building blocks: norms, RoPE, initializers, dtype policy."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DTypePolicy:
    """Params in ``param_dtype`` (fp32 master), compute in ``compute_dtype``
    (bf16 on the MXU), softmax/norm/loss accumulation in fp32."""

    param_dtype: jnp.dtype = jnp.float32
    compute_dtype: jnp.dtype = jnp.bfloat16

    def cast(self, x: jax.Array) -> jax.Array:
        return x.astype(self.compute_dtype)


DEFAULT_POLICY = DTypePolicy()


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype)


def rope_angles(positions: jax.Array, head_dim: int,
                theta: float = 1e4) -> tuple[jax.Array, jax.Array]:
    """positions (...,) int32 -> cos/sin (..., head_dim//2) fp32."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x (B, S, H, D); cos/sin (B or 1, S, D//2). Rotate-half convention."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    cos = cos[:, :, None, :].astype(x.dtype)
    sin = sin[:, :, None, :].astype(x.dtype)
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def dense_init(key: jax.Array, shape: tuple[int, ...],
               dtype=jnp.float32, fan_in: int | None = None) -> jax.Array:
    fan_in = fan_in if fan_in is not None else shape[0]
    std = 1.0 / np.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key: jax.Array, shape: tuple[int, ...],
               dtype=jnp.float32) -> jax.Array:
    return (jax.random.normal(key, shape, jnp.float32)).astype(dtype) * 0.02


def split_keys(key: jax.Array, names: list[str]) -> dict[str, jax.Array]:
    keys = jax.random.split(key, len(names))
    return dict(zip(names, keys))
