"""Decoder model assembly: config, init, forward / prefill / decode.

Layer stacking uses ``lax.scan`` over *groups* (one group = one repetition
of the mixer ``pattern``, e.g. RecurrentGemma's (rglru, rglru, attn)), with
the non-dividing remainder unrolled as ``tail`` layers.  Scan keeps the HLO
O(1) in depth — required for the 512-device dry-run compiles — and is remat
boundary.

Mixers: ``attn`` (full causal), ``swa`` (sliding window), ``rglru``
(RecurrentGemma recurrent block), ``rwkv6`` (Finch time-mix).
FFNs:   ``swiglu``, ``gelu``, ``moe``, ``rwkv_cm`` (channel-mix).

Head-count padding (``pad_heads_to``/``pad_kv_heads_to``) applies the
paper's padding-for-computation to tensor-parallel divisibility (yi-34b
56->64 q heads etc.); the padded heads are real parameters — extra compute
traded for legal parallelism, exactly the Listing 1 trade.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from . import attention as attn_mod
from . import ffn as ffn_mod
from . import rglru_block as rg_mod
from . import rwkv6_block as rwkv_mod
from .common import (apply_rope, dense_init, embed_init, rms_norm,
                     rope_angles, split_keys)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    pattern: tuple[str, ...] = ("attn",)
    ffn: str = "swiglu"
    n_experts: int = 0
    moe_top_k: int = 0
    window: int | None = None            # for "swa" mixers
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1e6
    embed_input: bool = True             # False: stub frontend feeds embeds
    attn_impl: str = "recursive"
    attn_chunk: int = 512
    loss_chunk: int = 1024
    capacity_factor: float = 1.25
    d_rnn: int = 0                       # rglru recurrence width
    remat: bool = True
    # Dry-run fidelity: python-unroll the layer/loss scans so XLA's
    # HloCostAnalysis (which counts while bodies ONCE) sees every layer.
    unroll_layers: bool = False
    compute_dtype: str = "bfloat16"      # or "float32" (tests/debug)
    # Parameter storage dtype.  "bfloat16" stores model weights in bf16
    # (casts vanish from the forward pass; gradients and their DP
    # all-reduce go bf16) with an fp32 master copy living in the
    # optimizer state — the standard mixed-precision recipe.  §Perf lever.
    param_dtype: str = "float32"
    kv_cache_dtype: str = "bfloat16"     # or "int8" / "float32"
    # §Perf levers (beyond-paper; defaults are the faithful baseline):
    attn_score_dtype: str = "float32"    # "bfloat16": bf16 score maps
    gqa_grouped: bool = False            # grouped GQA einsum (no KV repeat)
    ffn_act_f32: bool = True             # False: bf16 FFN activations
    # Sequence-blocked decode attention (paged-attention-lite): the KV
    # cache is read/dequantised one block at a time — the live working
    # set shrinks from the whole cache to one block.  None = unblocked.
    decode_chunk: int | None = None
    pad_heads_to: int | None = None      # computation padding for TP
    pad_kv_heads_to: int | None = None

    @property
    def q_heads(self) -> int:
        return self.pad_heads_to or self.n_heads

    @property
    def kv_heads(self) -> int:
        return self.pad_kv_heads_to or self.n_kv_heads

    @property
    def n_groups(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def tail_pattern(self) -> tuple[str, ...]:
        return self.pattern[:self.n_layers % len(self.pattern)]

    @property
    def rnn_width(self) -> int:
        return self.d_rnn or self.d_model

    def mixer_at(self, layer: int) -> str:
        return self.pattern[layer % len(self.pattern)]


def _cd(cfg: "ModelConfig"):
    return jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32


def _sd(cfg: "ModelConfig"):
    return jnp.bfloat16 if cfg.attn_score_dtype == "bfloat16" \
        else jnp.float32


def _group_slice(stacked, g: int):
    return tuple(jax.tree.map(lambda a: a[g], pos) for pos in stacked)


def _stack_groups(per_group: list):
    # list over groups of tuples over positions -> tuple of stacked trees
    n_pos = len(per_group[0])
    return tuple(
        jax.tree.map(lambda *xs: jnp.stack(xs),
                     *[per_group[g][p] for g in range(len(per_group))])
        for p in range(n_pos))


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _init_attn(key, cfg: ModelConfig, dtype) -> dict:
    ks = split_keys(key, ["wq", "wk", "wv", "wo"])
    d, hq, hkv, hd = cfg.d_model, cfg.q_heads, cfg.kv_heads, cfg.head_dim
    p = {
        "wq": dense_init(ks["wq"], (d, hq * hd), dtype),
        "wk": dense_init(ks["wk"], (d, hkv * hd), dtype),
        "wv": dense_init(ks["wv"], (d, hkv * hd), dtype),
        "wo": dense_init(ks["wo"], (hq * hd, d), dtype, fan_in=hq * hd),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * hd,), dtype)
        p["bk"] = jnp.zeros((hkv * hd,), dtype)
        p["bv"] = jnp.zeros((hkv * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


def _init_ffn(key, cfg: ModelConfig, dtype) -> dict:
    if cfg.ffn == "swiglu":
        return ffn_mod.init_swiglu(key, cfg.d_model, cfg.d_ff, dtype)
    if cfg.ffn == "gelu":
        return ffn_mod.init_gelu(key, cfg.d_model, cfg.d_ff, dtype)
    if cfg.ffn == "moe":
        return ffn_mod.init_moe(key, cfg.d_model, cfg.d_ff, cfg.n_experts,
                                dtype)
    if cfg.ffn == "rwkv_cm":
        return {}                         # lives inside the mixer params
    raise ValueError(cfg.ffn)


def _init_layer(key, cfg: ModelConfig, mixer: str, dtype) -> dict:
    ks = split_keys(key, ["mix", "ffn"])
    d = cfg.d_model
    layer: dict[str, Any] = {
        "norm1": jnp.zeros((d,), dtype),
        "norm2": jnp.zeros((d,), dtype),
    }
    if mixer in ("attn", "swa"):
        layer["attn"] = _init_attn(ks["mix"], cfg, dtype)
    elif mixer == "rglru":
        layer["rec"] = rg_mod.init_rglru_block(ks["mix"], d, cfg.rnn_width,
                                               dtype)
    elif mixer == "rwkv6":
        layer["rwkv"] = rwkv_mod.init_rwkv6_block(ks["mix"], d, cfg.n_heads,
                                                  cfg.d_ff, dtype)
    else:
        raise ValueError(mixer)
    if cfg.ffn != "rwkv_cm":
        layer["ffn"] = _init_ffn(ks["ffn"], cfg, dtype)
    return layer


def init_params(cfg: ModelConfig, key: jax.Array,
                dtype=None) -> dict:
    if dtype is None:
        dtype = jnp.bfloat16 if cfg.param_dtype == "bfloat16" \
            else jnp.float32
    ks = split_keys(key, ["embed", "layers", "tail", "head"])
    params: dict[str, Any] = {}
    if cfg.embed_input:
        params["embed"] = embed_init(ks["embed"], (cfg.vocab, cfg.d_model),
                                     dtype)
    # scanned groups: one stacked pytree per pattern position
    lkeys = jax.random.split(ks["layers"],
                             max(cfg.n_groups, 1) * len(cfg.pattern))
    stacked = []
    for p, mixer in enumerate(cfg.pattern):
        per_group = [
            _init_layer(lkeys[g * len(cfg.pattern) + p], cfg, mixer, dtype)
            for g in range(cfg.n_groups)]
        stacked.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per_group)
                       if per_group else None)
    params["layers"] = stacked
    tkeys = jax.random.split(ks["tail"], max(len(cfg.tail_pattern), 1))
    params["tail"] = [
        _init_layer(tkeys[i], cfg, mixer, dtype)
        for i, mixer in enumerate(cfg.tail_pattern)]
    params["final_norm"] = jnp.zeros((cfg.d_model,), dtype)
    params["lm_head"] = dense_init(ks["head"], (cfg.d_model, cfg.vocab),
                                   dtype)
    return params


def param_count(params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# forward (training / prefill)
# ---------------------------------------------------------------------------
def _attn_apply(layer: dict, cfg: ModelConfig, mixer: str, x: jax.Array,
                cos, sin) -> jax.Array:
    compute_dtype = _cd(cfg)
    b, s, d = x.shape
    p = layer["attn"]
    h = rms_norm(x, layer["norm1"]).astype(compute_dtype)
    hq, hkv, hd = cfg.q_heads, cfg.kv_heads, cfg.head_dim
    q = h @ p["wq"].astype(compute_dtype)
    k = h @ p["wk"].astype(compute_dtype)
    v = h @ p["wv"].astype(compute_dtype)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(compute_dtype)
        k = k + p["bk"].astype(compute_dtype)
        v = v + p["bv"].astype(compute_dtype)
    q = q.reshape(b, s, hq, hd)
    k = k.reshape(b, s, hkv, hd)
    v = v.reshape(b, s, hkv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    window = cfg.window if mixer == "swa" else None
    o = attn_mod.attention(q, k, v, impl=cfg.attn_impl, window=window,
                           chunk=cfg.attn_chunk, unroll=cfg.unroll_layers,
                           score_dtype=_sd(cfg), gqa_grouped=cfg.gqa_grouped)
    o = o.reshape(b, s, hq * hd) @ p["wo"].astype(compute_dtype)
    return x + o.astype(x.dtype)


def _ffn_apply(layer: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    compute_dtype = _cd(cfg)
    if cfg.ffn == "rwkv_cm":
        h = rms_norm(x, layer["norm2"])
        return x + rwkv_mod.channel_mix(layer["rwkv"], h, compute_dtype)
    h = rms_norm(x, layer["norm2"])
    if cfg.ffn == "swiglu":
        out = ffn_mod.swiglu(layer["ffn"], h, compute_dtype,
                             act_f32=cfg.ffn_act_f32)
    elif cfg.ffn == "gelu":
        out = ffn_mod.gelu_mlp(layer["ffn"], h, compute_dtype,
                               act_f32=cfg.ffn_act_f32)
    elif cfg.ffn == "moe":
        out = ffn_mod.moe_ffn(layer["ffn"], h, top_k=cfg.moe_top_k,
                              capacity_factor=cfg.capacity_factor,
                              compute_dtype=compute_dtype,
                              act_f32=cfg.ffn_act_f32)
    else:
        raise ValueError(cfg.ffn)
    return x + out


def _layer_apply(layer: dict, cfg: ModelConfig, mixer: str, x: jax.Array,
                 cos, sin) -> jax.Array:
    if mixer in ("attn", "swa"):
        x = _attn_apply(layer, cfg, mixer, x, cos, sin)
    elif mixer == "rglru":
        h = rms_norm(x, layer["norm1"])
        x = x + rg_mod.rglru_block(layer["rec"], h, _cd(cfg))
    elif mixer == "rwkv6":
        h = rms_norm(x, layer["norm1"])
        x = x + rwkv_mod.time_mix(layer["rwkv"], h, cfg.n_heads, _cd(cfg))
    else:
        raise ValueError(mixer)
    return _ffn_apply(layer, cfg, x)


def embed_tokens(params: dict, cfg: ModelConfig,
                 tokens: jax.Array) -> jax.Array:
    compute_dtype = _cd(cfg)
    if cfg.embed_input:
        return params["embed"][tokens].astype(compute_dtype)
    # stub frontend: tokens already are embeddings (B, S, D)
    return tokens.astype(compute_dtype)


def forward(params: dict, cfg: ModelConfig, tokens: jax.Array,
            positions: jax.Array | None = None) -> jax.Array:
    """tokens (B,S) int32 (or (B,S,D) embeddings for stub-frontend archs)
    -> final hidden states (B,S,D) after the last norm."""
    x = embed_tokens(params, cfg, tokens)
    b, s = x.shape[0], x.shape[1]
    if positions is None:
        positions = jnp.arange(s, dtype=jnp.int32)[None]
    cos, sin = rope_angles(positions, cfg.head_dim, cfg.rope_theta)

    def group_body(carry, group_params):
        h = carry
        for p, mixer in enumerate(cfg.pattern):
            h = _layer_apply(
                jax.tree.map(lambda a: a, group_params[p]), cfg, mixer, h,
                cos, sin)
        return h, None

    body = group_body
    if cfg.remat:
        body = jax.checkpoint(group_body, prevent_cse=False)
    if cfg.n_groups > 0:
        if cfg.unroll_layers:
            for g in range(cfg.n_groups):
                x, _ = body(x, _group_slice(tuple(params["layers"]), g))
        else:
            x, _ = jax.lax.scan(body, x, tuple(params["layers"]))
    for i, mixer in enumerate(cfg.tail_pattern):
        x = _layer_apply(params["tail"][i], cfg, mixer, x, cos, sin)
    return rms_norm(x, params["final_norm"])


def logits_fn(params: dict, cfg: ModelConfig,
              hidden: jax.Array) -> jax.Array:
    compute_dtype = _cd(cfg)
    return (hidden.astype(compute_dtype)
            @ params["lm_head"].astype(compute_dtype)).astype(jnp.float32)


def lm_loss(params: dict, cfg: ModelConfig, hidden: jax.Array,
            labels: jax.Array) -> jax.Array:
    """Chunked softmax cross-entropy: logits are never materialised for the
    whole sequence (vocab 256k x 4k tokens would not fit HBM)."""
    b, s, d = hidden.shape
    t = b * s
    h = hidden.reshape(t, d)
    y = labels.reshape(t)
    chunk = min(cfg.loss_chunk, t)
    pad = (-t) % chunk
    if pad:
        h = jnp.pad(h, ((0, pad), (0, 0)))
        y = jnp.concatenate([y, -jnp.ones((pad,), y.dtype)])
    n = h.shape[0] // chunk
    h = h.reshape(n, chunk, d)
    y = y.reshape(n, chunk)

    def chunk_loss(carry, hy):
        h_c, y_c = hy
        logits = logits_fn(params, cfg, h_c)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(y_c, 0)[:, None], axis=-1)[:, 0]
        valid = (y_c >= 0).astype(jnp.float32)
        nll = (logz - gold) * valid
        return (carry[0] + jnp.sum(nll), carry[1] + jnp.sum(valid)), None

    if cfg.unroll_layers:
        carry = (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32))
        for i in range(n):
            carry, _ = chunk_loss(carry, (h[i], y[i]))
        total, count = carry
    else:
        (total, count), _ = jax.lax.scan(chunk_loss, (0.0, 0.0), (h, y))
    return total / jnp.maximum(count, 1.0)


# ---------------------------------------------------------------------------
# KV / recurrent caches
# ---------------------------------------------------------------------------
def _cache_len(cfg: ModelConfig, mixer: str, max_len: int) -> int:
    if mixer == "swa" and cfg.window is not None:
        return min(cfg.window, max_len)
    return max_len


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    """Stacked caches mirroring the scanned/tail param structure."""
    cache_dtype = {"bfloat16": jnp.bfloat16, "int8": jnp.int8,
                   "float32": jnp.float32}[cfg.kv_cache_dtype]

    def one(mixer: str) -> dict:
        if mixer in ("attn", "swa"):
            sc = _cache_len(cfg, mixer, max_len)
            c = {"k": jnp.zeros((batch, sc, cfg.kv_heads, cfg.head_dim),
                                cache_dtype),
                 "v": jnp.zeros((batch, sc, cfg.kv_heads, cfg.head_dim),
                                cache_dtype)}
            if cfg.kv_cache_dtype == "int8":
                c["k_scale"] = jnp.zeros(
                    (batch, sc, cfg.kv_heads, 1), jnp.float32)
                c["v_scale"] = jnp.zeros(
                    (batch, sc, cfg.kv_heads, 1), jnp.float32)
            return c
        if mixer == "rglru":
            return rg_mod.init_rglru_state(batch, cfg.rnn_width)
        if mixer == "rwkv6":
            return rwkv_mod.init_rwkv6_state(batch, cfg.d_model,
                                             cfg.n_heads)
        raise ValueError(mixer)

    stacked = []
    for mixer in cfg.pattern:
        per_group = [one(mixer) for _ in range(cfg.n_groups)]
        stacked.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per_group)
                       if per_group else None)
    return {
        "layers": stacked,
        "tail": [one(m) for m in cfg.tail_pattern],
        "pos": jnp.zeros((), jnp.int32),
    }


def _store_kv(cfg: ModelConfig, cache_layer: dict, k, v, idx):
    """Write k/v (B, S, Hkv, hd) at positions ``idx`` (S,), quantizing for
    int8 caches."""
    if cfg.kv_cache_dtype == "int8":
        def quant(x):
            amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1,
                           keepdims=True)
            scale = jnp.maximum(amax, 1e-12) / 127.0
            q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale),
                         -127, 127).astype(jnp.int8)
            return q, scale
        kq, ks = quant(k)
        vq, vs = quant(v)
        return {
            "k": cache_layer["k"].at[:, idx].set(kq),
            "v": cache_layer["v"].at[:, idx].set(vq),
            "k_scale": cache_layer["k_scale"].at[:, idx].set(ks),
            "v_scale": cache_layer["v_scale"].at[:, idx].set(vs),
        }
    return {
        "k": cache_layer["k"].at[:, idx].set(k.astype(cache_layer["k"].dtype)),
        "v": cache_layer["v"].at[:, idx].set(v.astype(cache_layer["v"].dtype)),
    }


def _read_kv(cfg: ModelConfig, cache_layer: dict):
    if cfg.kv_cache_dtype == "int8":
        k = cache_layer["k"].astype(jnp.float32) * cache_layer["k_scale"]
        v = cache_layer["v"].astype(jnp.float32) * cache_layer["v_scale"]
        return k.astype(jnp.bfloat16), v.astype(jnp.bfloat16)
    return cache_layer["k"], cache_layer["v"]


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------
def _attn_decode(layer: dict, cfg: ModelConfig, mixer: str, x: jax.Array,
                 cache_layer: dict, pos: jax.Array):
    compute_dtype = _cd(cfg)
    b = x.shape[0]
    p = layer["attn"]
    h = rms_norm(x, layer["norm1"]).astype(compute_dtype)
    hq, hkv, hd = cfg.q_heads, cfg.kv_heads, cfg.head_dim
    q = h @ p["wq"].astype(compute_dtype)
    k = h @ p["wk"].astype(compute_dtype)
    v = h @ p["wv"].astype(compute_dtype)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(compute_dtype)
        k = k + p["bk"].astype(compute_dtype)
        v = v + p["bv"].astype(compute_dtype)
    q = q.reshape(b, 1, hq, hd)
    k = k.reshape(b, 1, hkv, hd)
    v = v.reshape(b, 1, hkv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    cos, sin = rope_angles(pos[None, None], cfg.head_dim, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    sc = cache_layer["k"].shape[1]
    idx = (pos % sc)[None]
    new_cache = {**cache_layer, **_store_kv(cfg, cache_layer, k, v, idx)}
    length = jnp.minimum(pos + 1, sc)
    if cfg.decode_chunk and sc > cfg.decode_chunk:
        chunk = cfg.decode_chunk
        n_chunks = sc // chunk

        def read_chunk(i):
            lay = {kk: jax.lax.dynamic_slice_in_dim(
                new_cache[kk], i * chunk, chunk, axis=1)
                for kk in new_cache}
            return _read_kv(cfg, lay)

        o = attn_mod.decode_attention_blocks(
            q.astype(compute_dtype), read_chunk, n_chunks, chunk, length,
            unroll=cfg.unroll_layers)
    else:
        kk, vv = _read_kv(cfg, new_cache)
        o = attn_mod.decode_attention(q, kk, vv, length)
    o = o.reshape(b, 1, hq * hd) @ p["wo"].astype(compute_dtype)
    return x + o.astype(x.dtype), new_cache


def _layer_decode(layer: dict, cfg: ModelConfig, mixer: str, x: jax.Array,
                  cache_layer: dict, pos: jax.Array):
    if mixer in ("attn", "swa"):
        x, new_cache = _attn_decode(layer, cfg, mixer, x, cache_layer, pos)
    elif mixer == "rglru":
        h = rms_norm(x, layer["norm1"])
        out, new_cache = rg_mod.rglru_block_decode(
            layer["rec"], h, cache_layer, _cd(cfg))
        x = x + out
    elif mixer == "rwkv6":
        h = rms_norm(x, layer["norm1"])
        out, new_cache = rwkv_mod.time_mix_decode(
            layer["rwkv"], h, cache_layer, cfg.n_heads, _cd(cfg))
        x = x + out
    else:
        raise ValueError(mixer)
    if cfg.ffn == "rwkv_cm":
        h = rms_norm(x, layer["norm2"])
        out, new_cache = rwkv_mod.channel_mix_decode(
            layer["rwkv"], h, new_cache, _cd(cfg))
        x = x + out
    else:
        x = _ffn_apply(layer, cfg, x)
    return x, new_cache


def decode_step(params: dict, cfg: ModelConfig, cache: dict,
                tokens: jax.Array) -> tuple[jax.Array, dict]:
    """One decoding step.  tokens (B,) int32 (or (B,D) embeddings for stub
    archs) -> (logits (B,V), new cache)."""
    pos = cache["pos"]
    if cfg.embed_input:
        x = params["embed"][tokens][:, None].astype(_cd(cfg))
    else:
        x = tokens[:, None].astype(_cd(cfg))

    def group_body(carry, scanned):
        h = carry
        group_params, group_cache = scanned
        new_caches = []
        for p, mixer in enumerate(cfg.pattern):
            h, nc = _layer_decode(group_params[p], cfg, mixer, h,
                                  group_cache[p], pos)
            new_caches.append(nc)
        return h, tuple(new_caches)

    new_cache: dict[str, Any] = {"pos": pos + 1}
    if cfg.n_groups > 0:
        if cfg.unroll_layers:
            collected = []
            for g in range(cfg.n_groups):
                x, ncg = group_body(
                    x, (_group_slice(tuple(params["layers"]), g),
                        _group_slice(tuple(cache["layers"]), g)))
                collected.append(ncg)
            new_cache["layers"] = list(_stack_groups(collected))
        else:
            x, ncl = jax.lax.scan(group_body, x,
                                  (tuple(params["layers"]),
                                   tuple(cache["layers"])))
            new_cache["layers"] = list(ncl)
    else:
        new_cache["layers"] = cache["layers"]
    new_tail = []
    for i, mixer in enumerate(cfg.tail_pattern):
        x, nc = _layer_decode(params["tail"][i], cfg, mixer, x,
                              cache["tail"][i], pos)
        new_tail.append(nc)
    new_cache["tail"] = new_tail
    h = rms_norm(x, params["final_norm"])
    logits = logits_fn(params, cfg, h)[:, 0]
    return logits, new_cache


# ---------------------------------------------------------------------------
# prefill: parallel forward that also fills caches / recurrent states
# ---------------------------------------------------------------------------
def _attn_prefill(layer: dict, cfg: ModelConfig, mixer: str, x: jax.Array,
                  cos, sin, cache_layer: dict):
    compute_dtype = _cd(cfg)
    """Full attention layer computing q/k/v once: returns (x', cache')."""
    b, s, d = x.shape
    p = layer["attn"]
    h = rms_norm(x, layer["norm1"]).astype(compute_dtype)
    hq, hkv, hd = cfg.q_heads, cfg.kv_heads, cfg.head_dim
    q = h @ p["wq"].astype(compute_dtype)
    k = h @ p["wk"].astype(compute_dtype)
    v = h @ p["wv"].astype(compute_dtype)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(compute_dtype)
        k = k + p["bk"].astype(compute_dtype)
        v = v + p["bv"].astype(compute_dtype)
    q = q.reshape(b, s, hq, hd)
    k = k.reshape(b, s, hkv, hd)
    v = v.reshape(b, s, hkv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    sc = cache_layer["k"].shape[1]
    if sc < s:   # ring buffer: only the last sc positions survive
        idx = jnp.arange(s - sc, s) % sc
        nc = {**cache_layer,
              **_store_kv(cfg, cache_layer, k[:, -sc:], v[:, -sc:], idx)}
    else:
        nc = {**cache_layer,
              **_store_kv(cfg, cache_layer, k, v, jnp.arange(s))}
    window = cfg.window if mixer == "swa" else None
    o = attn_mod.attention(q, k, v, impl=cfg.attn_impl, window=window,
                           chunk=cfg.attn_chunk, unroll=cfg.unroll_layers,
                           score_dtype=_sd(cfg), gqa_grouped=cfg.gqa_grouped)
    o = o.reshape(b, s, hq * hd) @ p["wo"].astype(compute_dtype)
    return x + o.astype(x.dtype), nc


def _layer_prefill(layer: dict, cfg: ModelConfig, mixer: str, x: jax.Array,
                   cos, sin, cache_layer: dict):
    if mixer in ("attn", "swa"):
        x, nc = _attn_prefill(layer, cfg, mixer, x, cos, sin, cache_layer)
    elif mixer == "rglru":
        h = rms_norm(x, layer["norm1"])
        out, nc = rg_mod.rglru_block_with_state(layer["rec"], h, _cd(cfg))
        x = x + out
    elif mixer == "rwkv6":
        h = rms_norm(x, layer["norm1"])
        out, tm_state = rwkv_mod.time_mix_with_state(
            layer["rwkv"], h, cfg.n_heads, _cd(cfg))
        x = x + out
        nc = {**cache_layer, **tm_state}
    else:
        raise ValueError(mixer)
    if cfg.ffn == "rwkv_cm":
        h = rms_norm(x, layer["norm2"])
        nc = {**nc, "cm_last": h.astype(jnp.float32)[:, -1:]}
        x = x + rwkv_mod.channel_mix(layer["rwkv"], h, _cd(cfg))
    else:
        x = _ffn_apply(layer, cfg, x)
    return x, nc


def prefill(params: dict, cfg: ModelConfig, tokens: jax.Array,
            max_len: int | None = None) -> tuple[jax.Array, dict]:
    """Run the prompt in parallel, returning (last-position logits (B,V),
    filled cache).  Recurrent mixers return their final states from the
    scan kernels; attention mixers bulk-write (ring-buffered) KV caches."""
    x = embed_tokens(params, cfg, tokens)
    b, s = x.shape[0], x.shape[1]
    max_len = max_len or s
    cache = init_cache(cfg, b, max_len)
    positions = jnp.arange(s, dtype=jnp.int32)[None]
    cos, sin = rope_angles(positions, cfg.head_dim, cfg.rope_theta)

    def group_body(carry, scanned):
        h = carry
        group_params, group_cache = scanned
        new_caches = []
        for p, mixer in enumerate(cfg.pattern):
            h, nc = _layer_prefill(group_params[p], cfg, mixer, h,
                                   cos, sin, group_cache[p])
            new_caches.append(nc)
        return h, tuple(new_caches)

    body = group_body
    if cfg.remat:
        body = jax.checkpoint(group_body, prevent_cse=False)
    new_cache: dict[str, Any] = {"pos": jnp.asarray(s, jnp.int32)}
    if cfg.n_groups > 0:
        if cfg.unroll_layers:
            collected = []
            for g in range(cfg.n_groups):
                x, ncg = body(
                    x, (_group_slice(tuple(params["layers"]), g),
                        _group_slice(tuple(cache["layers"]), g)))
                collected.append(ncg)
            new_cache["layers"] = list(_stack_groups(collected))
        else:
            x, ncl = jax.lax.scan(body, x,
                                  (tuple(params["layers"]),
                                   tuple(cache["layers"])))
            new_cache["layers"] = list(ncl)
    else:
        new_cache["layers"] = cache["layers"]
    new_tail = []
    for i, mixer in enumerate(cfg.tail_pattern):
        x, nc = _layer_prefill(params["tail"][i], cfg, mixer, x, cos, sin,
                               cache["tail"][i])
        new_tail.append(nc)
    new_cache["tail"] = new_tail
    h = rms_norm(x, params["final_norm"])
    logits = logits_fn(params, cfg, h[:, -1:])[:, 0]
    return logits, new_cache
