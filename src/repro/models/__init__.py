"""Model zoo: generic decoder with pluggable mixers/FFNs."""
from .model import (ModelConfig, init_params, forward, lm_loss, logits_fn,
                    prefill, decode_step, init_cache, param_count)
from . import attention, common, ffn, rglru_block, rwkv6_block

__all__ = [
    "ModelConfig", "init_params", "forward", "lm_loss", "logits_fn",
    "prefill", "decode_step", "init_cache", "param_count",
    "attention", "common", "ffn", "rglru_block", "rwkv6_block",
]
