"""RecurrentGemma / Griffin recurrent block (RG-LRU + causal conv1d).

    y   = norm(x)
    gate = gelu(y @ W_gate)                    (D -> Dr)
    u0   = y @ W_in                            (D -> Dr)
    c    = causal_conv1d(u0, width=4, depthwise)
    r    = sigmoid(c @ W_a + b_a)              recurrence gate
    i    = sigmoid(c @ W_x + b_x)              input gate
    a    = exp(-8 * softplus(Lambda) * r)      data-dependent decay
    h_t  = a_t h_{t-1} + sqrt(1 - a_t^2) * (i * c)      <- Pallas kernel
    out  = (gate * h) @ W_out                  (Dr -> D)

The sequential hot loop is ``kernels.rglru``; everything else is dense
matmul the solver tiles like any other task.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels import rglru as rglru_op
from .common import dense_init, split_keys

CONV_WIDTH = 4
C_SCALE = 8.0


def init_rglru_block(key, d_model: int, d_rnn: int,
                     dtype=jnp.float32) -> dict:
    ks = split_keys(key, ["w_gate", "w_in", "conv", "w_a", "w_x", "lam",
                          "w_out"])
    return {
        "w_gate": dense_init(ks["w_gate"], (d_model, d_rnn), dtype),
        "w_in": dense_init(ks["w_in"], (d_model, d_rnn), dtype),
        "conv_w": dense_init(ks["conv"], (CONV_WIDTH, d_rnn), dtype,
                             fan_in=CONV_WIDTH),
        "w_a": dense_init(ks["w_a"], (d_rnn, d_rnn), dtype),
        "b_a": jnp.zeros((d_rnn,), dtype),
        "w_x": dense_init(ks["w_x"], (d_rnn, d_rnn), dtype),
        "b_x": jnp.zeros((d_rnn,), dtype),
        # Lambda init so a^8·softplus spans slow/fast decays (Griffin A.2)
        "lam": jnp.linspace(-2.0, 2.0, d_rnn).astype(dtype),
        "w_out": dense_init(ks["w_out"], (d_rnn, d_model), dtype,
                            fan_in=d_rnn),
    }


def _causal_conv(u: jax.Array, w: jax.Array,
                 state: jax.Array | None = None) -> jax.Array:
    """Depthwise causal conv along seq; u (B,S,Dr), w (W,Dr).

    ``state`` (B, W-1, Dr) prepends history (decode); else zero history."""
    b, s, dr = u.shape
    width = w.shape[0]
    if state is None:
        state = jnp.zeros((b, width - 1, dr), u.dtype)
    ext = jnp.concatenate([state, u], axis=1)
    out = jnp.zeros_like(u)
    for i in range(width):
        out = out + ext[:, i:i + s, :] * w[width - 1 - i][None, None, :]
    return out


def _gates(params, c):
    r = jax.nn.sigmoid((c @ params["w_a"] + params["b_a"])
                       .astype(jnp.float32))
    i = jax.nn.sigmoid((c @ params["w_x"] + params["b_x"])
                       .astype(jnp.float32))
    log_a = -C_SCALE * jax.nn.softplus(
        params["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    u = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * c.astype(jnp.float32))
    return a, u


def rglru_block(params: dict, x: jax.Array,
                compute_dtype=jnp.bfloat16) -> jax.Array:
    out, _ = rglru_block_with_state(params, x, compute_dtype)
    return out


def rglru_block_with_state(params: dict, x: jax.Array,
                           compute_dtype=jnp.bfloat16) \
        -> tuple[jax.Array, dict]:
    """Parallel (prefill) form that also returns the recurrent state."""
    xc = x.astype(compute_dtype)
    gate = jax.nn.gelu(
        (xc @ params["w_gate"].astype(compute_dtype)).astype(jnp.float32))
    u0 = xc @ params["w_in"].astype(compute_dtype)
    c = _causal_conv(u0, params["conv_w"].astype(compute_dtype))
    a, u = _gates(params, c)
    h = rglru_op(a.astype(jnp.float32), u)            # (B,S,Dr) fp32
    out = (gate * h).astype(compute_dtype) \
        @ params["w_out"].astype(compute_dtype)
    state = {
        "h": h[:, -1].astype(jnp.float32),
        "conv": u0[:, -(CONV_WIDTH - 1):].astype(jnp.float32),
    }
    return out.astype(x.dtype), state


def init_rglru_state(batch: int, d_rnn: int) -> dict:
    return {
        "h": jnp.zeros((batch, d_rnn), jnp.float32),
        "conv": jnp.zeros((batch, CONV_WIDTH - 1, d_rnn), jnp.float32),
    }


def rglru_block_decode(params: dict, x: jax.Array, state: dict,
                       compute_dtype=jnp.bfloat16) \
        -> tuple[jax.Array, dict]:
    """Single-token step. x (B,1,D); state {h (B,Dr), conv (B,W-1,Dr)}."""
    xc = x.astype(compute_dtype)
    gate = jax.nn.gelu(
        (xc @ params["w_gate"].astype(compute_dtype)).astype(jnp.float32))
    u0 = xc @ params["w_in"].astype(compute_dtype)
    conv_state = state["conv"].astype(compute_dtype)
    c = _causal_conv(u0, params["conv_w"].astype(compute_dtype),
                     state=conv_state)
    new_conv = jnp.concatenate([conv_state[:, 1:], u0], axis=1)
    a, u = _gates(params, c)
    h = a[:, 0] * state["h"] + u[:, 0]                 # (B, Dr)
    out = (gate[:, 0] * h).astype(compute_dtype) \
        @ params["w_out"].astype(compute_dtype)
    return out[:, None].astype(x.dtype), \
        {"h": h, "conv": new_conv.astype(jnp.float32)}
