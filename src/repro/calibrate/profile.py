"""``CalibratedHardware``: measured host rates the cost model consumes.

The static constants in ``repro.core.resources`` describe a TPU v5e; the
host actually running the executables (a CPU container, a different TPU
generation, a shared dev box) has different ratios of compute to bandwidth
to dispatch overhead — and those *ratios* are what the solver's slice
assignment and streaming decisions turn on.  A profile holds the four
measured quantities the microbenchmark suite (``repro.calibrate.
microbench``) produces:

* ``dispatch_s``   — per-dispatch overhead of a jitted call (a);
* ``ici_bw``       — effective cross-slice transfer bandwidth (b);
* ``hbm_bw`` / ``hbm_share`` — solo streaming bandwidth and the per-slice
  share of it under k concurrently-active slices (c);
* ``gflops``       — steady-state GFLOP/s of small/medium/large
  contractions (d); ``peak_flops`` is the best sustained rate.

Profiles are JSON-serializable and cached under ``REPRO_CALIBRATION_DIR``
(one file per host identity) so calibration runs once per host, not once
per process.  ``hardware()`` turns a profile into the ``Hardware`` board
the solver consumes in place of the static constants.

This module is import-light (no JAX): the solver can *load* a profile
without touching the runtime; only measuring needs ``microbench``.
"""
from __future__ import annotations

import dataclasses
import os

SCHEMA_VERSION = 1

#: Contraction sizes (n, for an n x n x n matmul) behind the ``gflops``
#: entries — small is dispatch/latency-bound, large is steady-state MXU/FPU
#: throughput.  Keys are the profile's ``gflops`` dict keys.
CONTRACTION_SIZES: dict[str, int] = {"small": 128, "medium": 256,
                                     "large": 512}


def calibration_dir() -> str:
    """Directory holding cached profiles (``REPRO_CALIBRATION_DIR``)."""
    return os.environ.get("REPRO_CALIBRATION_DIR") or os.path.join(
        os.path.expanduser("~"), ".cache", "repro-calibration")


@dataclasses.dataclass(frozen=True)
class CalibratedHardware:
    """Measured rates of the running host, in cost-model units."""

    backend: str                    # jax backend name ("cpu", "tpu", ...)
    n_devices: int
    cpu_count: int
    dispatch_s: float               # (a) seconds per jitted dispatch
    ici_bw: float                   # (b) bytes/s across slices
    hbm_bw: float                   # (c) bytes/s solo streaming
    hbm_share: tuple[float, ...]    # (c) share[k-1]: per-slice fraction
    gflops: dict[str, float]        # (d) size class -> measured GFLOP/s
    quick: bool = False             # smoke-quality measurement fidelity
    elapsed_s: float = 0.0          # how long calibration took
    schema: int = SCHEMA_VERSION

    @property
    def peak_flops(self) -> float:
        """Best sustained FLOP/s across the contraction size classes."""
        return max(self.gflops.values()) * 1e9

    @property
    def host_key(self) -> str:
        """Cache-file identity of the host this profile describes."""
        return f"{self.backend}-{self.n_devices}dev-{self.cpu_count}cpu"

    def fingerprint(self) -> str:
        """Stable content hash of the *measured rates* — the plan store's
        notion of "which hardware profile priced this plan".  Excludes
        ``elapsed_s`` (wall time of the calibration run, not a rate) and
        ``quick`` so a full re-measurement that lands on identical rates
        keeps stored plans valid; any drift in the rates changes it."""
        from ..ft.artifacts import payload_checksum
        d = self.to_jsonable()
        d.pop("elapsed_s", None)
        d.pop("quick", None)
        return payload_checksum(d)[:16]

    # -- serialization ----------------------------------------------------
    def to_jsonable(self) -> dict:
        d = dataclasses.asdict(self)
        d["hbm_share"] = list(self.hbm_share)
        return d

    @staticmethod
    def from_jsonable(d: dict) -> "CalibratedHardware":
        if d.get("schema", 0) != SCHEMA_VERSION:
            raise ValueError(
                f"calibration schema {d.get('schema')!r} != "
                f"{SCHEMA_VERSION} — re-run calibration")
        fields = {f.name for f in dataclasses.fields(CalibratedHardware)}
        kw = {k: v for k, v in d.items() if k in fields}
        kw["hbm_share"] = tuple(kw.get("hbm_share", ()))
        kw["gflops"] = dict(kw.get("gflops", {}))
        return CalibratedHardware(**kw)

    def save(self, path: str) -> str:
        # atomic (tmp + rename, concurrent calibrators race safe) AND
        # checksummed: a torn/bit-rotted profile is detected at load time
        # instead of silently feeding garbage rates to the solver
        from ..ft.artifacts import atomic_write_json
        return atomic_write_json(path, self.to_jsonable(), checksum=True)

    @staticmethod
    def load(path: str) -> "CalibratedHardware":
        """Load + validate a profile; raises ``ValueError`` (via
        ``ArtifactError``) on unparsable content, a checksum mismatch, or
        a stale schema.  Pre-checksum profiles (no embedded digest) still
        load — the schema field gates their shape."""
        from ..ft.artifacts import load_json
        return CalibratedHardware.from_jsonable(load_json(path))

    # -- consumption ------------------------------------------------------
    def hardware(self, n_slices: int = 3, chips_per_slice: int = 1,
                 compute_frac: float = 1.0, vmem_frac: float = 1.0):
        """The measured board: a ``Hardware`` whose rates are this profile.

        The cost model then prices compute with measured FLOP/s, transfers
        with measured HBM/ICI bandwidth, concurrent waves with the measured
        share curve, and task launches with the measured dispatch overhead
        — so slice assignment and stream decisions answer to this host, not
        to the static TPU constants.
        """
        from ..core.resources import Hardware
        return Hardware.make(
            n_slices=n_slices, chips_per_slice=chips_per_slice,
            compute_frac=compute_frac, vmem_frac=vmem_frac,
            peak_flops=self.peak_flops, hbm_bw=self.hbm_bw,
            ici_bw=self.ici_bw, dispatch_s=self.dispatch_s,
            hbm_share=self.hbm_share or None)
