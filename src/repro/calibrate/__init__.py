"""Host calibration: microbenchmarks -> ``CalibratedHardware`` profiles.

The paper's NLP solver balances computation against communication *because
its cost model reflects the hardware*; static constants reflect a TPU v5e
spec sheet, not the host actually executing the plans.  This package
measures the four rates the solver's concurrency decisions turn on — see
``microbench.py`` — and caches them as a JSON profile under
``REPRO_CALIBRATION_DIR`` so every solve on this host can consume measured
numbers:

    from repro.calibrate import calibrate
    hw = calibrate().hardware(n_slices=3)       # measured board
    plan = solve(graph, hw)

or, once a profile is cached, simply ``solve(graph, None)`` — the solver
falls back to the cached calibrated board, and to the static constants only
when no profile exists.

``calibrate(bench=...)`` accepts any object with the ``Microbench``
surface; tests inject deterministic fakes so CI never times real hardware.
"""
from __future__ import annotations

import json
import os
import time

from .profile import (CONTRACTION_SIZES, CalibratedHardware,
                      calibration_dir)

__all__ = [
    "CONTRACTION_SIZES", "CalibratedHardware", "calibrate",
    "calibrated_hardware", "calibration_dir", "cached_profile",
    "cached_hardware", "profile_path",
]


def profile_path(backend: str, n_devices: int, cpu_count: int,
                 base_dir: str | None = None) -> str:
    """Cache file for one host identity under the calibration dir."""
    name = f"{backend}-{n_devices}dev-{cpu_count}cpu.json"
    return os.path.join(base_dir or calibration_dir(), name)


def calibrate(*, force: bool = False, bench=None, path: str | None = None,
              save: bool = True, quick: bool = False) -> CalibratedHardware:
    """Load the host's cached profile, measuring (and caching) if absent.

    ``force=True`` re-measures even with a cache hit; ``bench`` swaps the
    measurement backend (tests pass a deterministic fake); ``quick=True``
    shrinks the real microbenchmarks for smoke runs.
    """
    if bench is None:
        from .microbench import Microbench
        bench = Microbench(quick=quick)
    backend, n_devices, cpu_count = bench.identity()
    if path is None:
        path = profile_path(backend, n_devices, cpu_count)
    if not force and os.path.exists(path):
        try:
            cached = CalibratedHardware.load(path)
        except (ValueError, OSError, json.JSONDecodeError) as exc:
            # stale schema / corrupt file (checksum mismatch, torn write):
            # move it aside and re-measure — startup never crashes on it
            from ..ft.artifacts import quarantine_file
            quarantine_file(path, reason=repr(exc))
        else:
            # a cached smoke-quality (quick) profile must not satisfy a
            # full-fidelity request — re-measure and overwrite it
            if quick or not cached.quick:
                return cached

    from ..core.resources import BOARD_SLICES
    t0 = time.monotonic()
    dispatch_s = bench.measure_dispatch_s()
    ici_bw = bench.measure_ici_bw()
    solo_bw = bench.measure_hbm_bw(1)
    share = [1.0]
    for k in range(2, BOARD_SLICES + 1):
        per_thread = bench.measure_hbm_bw(k)
        share.append(max(min(per_thread / solo_bw, 1.0), 1e-3))
    gflops = {name: bench.measure_gflops(n)
              for name, n in CONTRACTION_SIZES.items()}
    profile = CalibratedHardware(
        backend=backend, n_devices=n_devices, cpu_count=cpu_count,
        dispatch_s=dispatch_s, ici_bw=ici_bw, hbm_bw=solo_bw,
        hbm_share=tuple(share), gflops=gflops, quick=bool(quick),
        elapsed_s=time.monotonic() - t0)
    if save:
        profile.save(path)
    return profile


def calibrated_hardware(n_slices: int = 3, **kw):
    """Measured ``Hardware`` board for this host (calibrating on demand)."""
    return calibrate().hardware(n_slices=n_slices, **kw)


# cached_profile memo: (path -> (mtime, profile)) so solve(graph, None)
# does not re-read + re-parse the JSON on every solve.
_PROFILE_MEMO: dict[str, tuple[float, CalibratedHardware]] = {}


def cached_profile(path: str | None = None) -> CalibratedHardware | None:
    """The host's cached profile, or ``None`` — never measures.

    This is the solver's quiet default path (``solve(graph, None)``):
    loading must not spend seconds timing hardware mid-solve.  On a host
    with no calibration dir it returns ``None`` before touching JAX at
    all; otherwise the host identity needs the backend name, imported
    lazily (and by then the caller is about to run JAX anyway).
    """
    if path is None:
        base = calibration_dir()
        if not os.path.isdir(base) or not os.listdir(base):
            return None             # uncalibrated host: stay JAX-free
        try:
            import jax
            backend = jax.default_backend()
            n_devices = jax.device_count()
            cpu_count = os.cpu_count() or 1
        except Exception:
            return None
        path = profile_path(backend, n_devices, cpu_count, base_dir=base)
    try:
        mtime = os.path.getmtime(path)
    except OSError:
        return None
    hit = _PROFILE_MEMO.get(path)
    if hit is not None and hit[0] == mtime:
        return hit[1]
    try:
        profile = CalibratedHardware.load(path)
    except (ValueError, OSError, json.JSONDecodeError) as exc:
        # corrupt on the quiet path too: quarantine so the next explicit
        # calibrate() regenerates instead of tripping over it again
        from ..ft.artifacts import quarantine_file
        quarantine_file(path, reason=repr(exc))
        return None
    _PROFILE_MEMO[path] = (mtime, profile)
    return profile


def cached_hardware(n_slices: int = 3, **kw):
    """Measured board from the cache, or ``None`` when uncalibrated."""
    profile = cached_profile()
    if profile is None:
        return None
    return profile.hardware(n_slices=n_slices, **kw)
