"""Microbenchmarks measuring the running host's actual rates.

Four measurements, mirroring the profile fields (see ``profile.py``):

(a) **dispatch** — per-call overhead of a warmed jitted no-op, timed with a
    block per call (the host-side serialization cost a task launch pays);
(b) **ici** — effective cross-slice transfer bandwidth: a device-to-device
    copy when the host has several devices, else a jitted full-buffer pass
    (the on-fabric copy a single-device "slice" stream degenerates to);
(c) **hbm share** — streaming bandwidth of a memory-bound pass, solo and
    with ``k`` host threads concurrently streaming their own buffers — the
    measured counterpart of the cost model's per-wave ``bw_share``;
(d) **contraction GFLOP/s** — steady-state matmul throughput at the sizes
    in ``CONTRACTION_SIZES``.

Every measurement is best-of-N over timed batches (the repo's standard
steady-state methodology: batching amortizes scheduler noise, best-of
filters interference).  ``Microbench`` is a plain object so tests inject a
deterministic fake with the same surface — CI never times real hardware.
"""
from __future__ import annotations

import threading
import time

from .profile import CONTRACTION_SIZES


def _best_rate(fn, *, units: float, inner: int, samples: int) -> float:
    """Best ``units``-per-second over ``samples`` batches of ``inner``
    back-to-back calls of ``fn`` (``fn`` must block before returning)."""
    fn()                                        # warm up / compile
    best = float("inf")
    for _ in range(samples):
        t0 = time.perf_counter()
        for _ in range(inner):
            fn()
        best = min(best, (time.perf_counter() - t0) / inner)
    return units / best


class Microbench:
    """The real measurement backend (imports JAX at construction).

    ``quick=True`` shrinks buffers and repeat counts for smoke tests; the
    defaults aim at a few seconds per measurement on a small CPU host.
    """

    def __init__(self, quick: bool = False):
        import jax                              # deferred: profile loading
        import jax.numpy as jnp                 # must not require jax
        self._jax = jax
        self._jnp = jnp
        self.quick = quick
        self._stream_elems = (1 << 21) if quick else (1 << 23)  # f32 elems
        self._samples = 3 if quick else 5
        self._inner = 4 if quick else 10

    # -- host identity ----------------------------------------------------
    def identity(self) -> tuple[str, int, int]:
        """(backend, n_devices, cpu_count) — the profile cache key."""
        import os
        return (self._jax.default_backend(), self._jax.device_count(),
                os.cpu_count() or 1)

    # -- (a) dispatch overhead --------------------------------------------
    def measure_dispatch_s(self) -> float:
        jax, jnp = self._jax, self._jnp
        x = jnp.zeros((8,), jnp.float32)
        f = jax.jit(lambda v: v + 1.0)

        def call():
            f(x).block_until_ready()

        rate = _best_rate(call, units=1.0, inner=50 if self.quick else 200,
                          samples=self._samples)
        return 1.0 / rate                       # seconds per dispatch

    # -- (b) cross-slice transfer bandwidth -------------------------------
    def measure_ici_bw(self) -> float:
        jax, jnp = self._jax, self._jnp
        n = self._stream_elems
        x = jnp.zeros((n,), jnp.float32)
        nbytes = float(n * 4)
        devices = jax.devices()
        if len(devices) > 1:
            # real inter-device hop: place on device 1 from device 0
            src = jax.device_put(x, devices[0])
            src.block_until_ready()

            def call():
                jax.device_put(src, devices[1]).block_until_ready()
        else:
            # single-device host: a cross-slice stream degenerates to an
            # on-fabric buffer pass; a jitted whole-buffer op measures it
            f = jax.jit(lambda v: v + 0.0)

            def call():
                f(x).block_until_ready()

        return _best_rate(call, units=nbytes, inner=self._inner,
                          samples=self._samples)

    # -- (c) HBM bandwidth under concurrently-active slices ---------------
    def measure_hbm_bw(self, n_concurrent: int = 1) -> float:
        """Per-thread achieved streaming bytes/s with ``n_concurrent``
        threads each streaming a private buffer (k=1 is the solo rate the
        share curve normalizes against)."""
        jax, jnp = self._jax, self._jnp
        n = self._stream_elems
        nbytes = float(n * 4)
        f = jax.jit(lambda v: v + 1.0)
        bufs = [jnp.full((n,), float(i), jnp.float32)
                for i in range(max(n_concurrent, 1))]
        for b in bufs:
            f(b).block_until_ready()            # compile once, fault in
        inner = self._inner
        barrier = threading.Barrier(len(bufs))
        rates = [0.0] * len(bufs)

        def worker(i: int) -> None:
            buf = bufs[i]
            barrier.wait()
            t0 = time.perf_counter()
            for _ in range(inner):
                f(buf).block_until_ready()
            rates[i] = nbytes * inner / (time.perf_counter() - t0)

        best = 0.0
        for _ in range(self._samples):
            threads = [threading.Thread(target=worker, args=(i,))
                       for i in range(len(bufs))]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            best = max(best, sum(rates) / len(rates))
        return best

    # -- (d) steady-state contraction GFLOP/s -----------------------------
    def measure_gflops(self, n: int) -> float:
        jax, jnp = self._jax, self._jnp
        if self.quick:
            n = min(n, CONTRACTION_SIZES["medium"])
        key = jax.random.PRNGKey(0)
        ka, kb = jax.random.split(key)
        a = jax.random.normal(ka, (n, n), jnp.float32)
        b = jax.random.normal(kb, (n, n), jnp.float32)
        f = jax.jit(lambda x, y: x @ y)

        def call():
            f(a, b).block_until_ready()

        flops = 2.0 * n * n * n
        return _best_rate(call, units=flops, inner=self._inner,
                          samples=self._samples) / 1e9
