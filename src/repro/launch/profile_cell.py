import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Profile one dry-run cell: roofline terms + per-opcode HLO breakdown.

    python -m repro.launch.profile_cell --arch yi-34b --shape train_4k \
        [--attn-impl chunked] [--extra-cfg '{"remat": false}'] [--groups 2]

Profiles the (unrolled, cost-exact) calibration module — the same numbers
the roofline table is built from — and prints where the bytes live.
"""
import argparse
import dataclasses
import json

from repro.configs import get_config
from repro.launch import hlo_breakdown, hlo_parse
from repro.launch.dryrun import SHAPES, _measure, lower_cell
from repro.launch.mesh import make_production_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=list(SHAPES))
    ap.add_argument("--attn-impl", default=None)
    ap.add_argument("--extra-cfg", default=None)
    ap.add_argument("--groups", type=int, default=2,
                    help="unrolled groups to profile (cost module)")
    ap.add_argument("--top-op", default=None,
                    help="also print the largest shapes of this opcode")
    ap.add_argument("--shard-override", default=None)
    args = ap.parse_args()

    if args.shard_override:
        from repro.distributed import sharding as sh
        sh.set_overrides(json.loads(args.shard_override))
    cfg = get_config(args.arch)
    extra = json.loads(args.extra_cfg) if args.extra_cfg else {}
    if args.attn_impl:
        extra["attn_impl"] = args.attn_impl
    n_pat = len(cfg.pattern)
    cal = dataclasses.replace(
        cfg, **extra, n_layers=args.groups * n_pat, unroll_layers=True,
        loss_chunk=1 << 30)
    mesh = make_production_mesh()
    lowered, aux = lower_cell(cal, args.shape, mesh)
    compiled = lowered.compile()
    m = _measure(compiled)
    groups_eff = cfg.n_layers / n_pat
    print(f"== {args.arch} x {args.shape} ({args.groups} unrolled groups; "
          f"full model = {groups_eff:.1f} groups) ==")
    print(f"per-chip (this module): flops={m['flops']:.3e} "
          f"bytes={m['bytes']:.3e} coll={m['coll_bytes']:.3e}")
    hlo = compiled.as_text()
    print(hlo_breakdown.pretty(hlo_breakdown.by_opcode(hlo)))
    print("collectives:", json.dumps(hlo_parse.collective_summary(hlo)
                                     ["bytes_by_op"]))
    if args.top_op:
        print(f"largest {args.top_op} results:")
        for b, s in hlo_breakdown.top_shapes(hlo, args.top_op):
            print(f"  {b / 2**20:10.1f} MiB  {s}")


if __name__ == "__main__":
    main()
