"""HLO byte/instruction breakdown — the dry-run 'profiler' (§Perf).

With no real TPU, the profile is the optimized HLO itself: aggregate the
RESULT bytes of every instruction by opcode (a proxy for the per-op memory
traffic XLA's HloCostAnalysis charges) and count instructions.  The §Perf
hypothesis loop reads this to find which operator class dominates the
memory term (attention score maps?  loss logits?  optimizer state?).

Usage:
    from repro.launch import hlo_breakdown
    top = hlo_breakdown.by_opcode(compiled.as_text())
    hlo_breakdown.pretty(top)
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

# "  %name = bf16[8,128]{1,0} opcode(...)"  (also tuple results)
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\(?[a-z0-9]+\[[^A-Z(]*?)\s+"
    r"([a-z][\w\-]*)\(", re.M)
_SHAPE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def by_opcode(hlo_text: str) -> dict[str, dict]:
    """opcode -> {'bytes': result bytes, 'count': instructions}."""
    agg: dict[str, dict] = defaultdict(lambda: {"bytes": 0, "count": 0})
    for m in _INSTR.finditer(hlo_text):
        shapes, op = m.groups()
        agg[op]["bytes"] += _shape_bytes(shapes)
        agg[op]["count"] += 1
    return dict(agg)


def top_shapes(hlo_text: str, opcode: str, k: int = 10) \
        -> list[tuple[int, str]]:
    """The k largest result shapes of one opcode (where the bytes live)."""
    out: list[tuple[int, str]] = []
    for m in _INSTR.finditer(hlo_text):
        shapes, op = m.groups()
        if op == opcode:
            out.append((_shape_bytes(shapes), shapes.strip()))
    out.sort(reverse=True)
    dedup: list[tuple[int, str]] = []
    seen = set()
    for b, s in out:
        if s not in seen:
            dedup.append((b, s))
            seen.add(s)
        if len(dedup) >= k:
            break
    return dedup


def pretty(agg: dict[str, dict], k: int = 15) -> str:
    rows = sorted(agg.items(), key=lambda kv: -kv[1]["bytes"])[:k]
    total = sum(v["bytes"] for v in agg.values())
    lines = [f"{'opcode':24s} {'GiB':>9s} {'%':>6s} {'count':>7s}"]
    for op, v in rows:
        lines.append(f"{op:24s} {v['bytes'] / 2**30:9.2f} "
                     f"{100 * v['bytes'] / max(total, 1):6.1f} "
                     f"{v['count']:7d}")
    lines.append(f"{'TOTAL':24s} {total / 2**30:9.2f}")
    return "\n".join(lines)
