"""Collective-traffic extraction from compiled/lowered HLO text.

``cost_analysis()`` has no collective-bytes entry, so the roofline's
collective term is derived here: sum the operand sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
instruction in the (post-SPMD) HLO module.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                  "all-to-all", "collective-permute")

# shape tokens like  bf16[8,128]{1,0}  or  f32[] (scalar)
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
# instruction lines:  %name = <result shape(s)> <op>(operands), attrs
# (optimized HLO prints operand NAMES without shapes, so traffic is
# derived from the result shape + replica-group size)
_INSTR_RE = re.compile(
    r"=\s*(\(?[a-z0-9]+\[[^=]*?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(([^)]*)\)([^\n]*)")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-op-kind bytes moved (per participating device), summed over the
    module.

    result-shape semantics per op:
      all-gather          result = gathered tensor = bytes received
      all-reduce          result = reduced tensor  = operand bytes
      reduce-scatter      result = one shard -> x group_size = input bytes
      all-to-all          result = exchanged tensor
      collective-permute  result = forwarded tensor
    ``-done`` halves of async pairs are skipped so traffic is not
    double-counted.
    """
    out: dict[str, int] = defaultdict(int)
    for m in _INSTR_RE.finditer(hlo_text):
        result_shapes, op, startdone, _operands, attrs = m.groups()
        if startdone == "-start":
            # async pair: the -done half carries the clean result shape
            continue
        nbytes = _shape_bytes(result_shapes)
        if op == "reduce-scatter":
            g = _GROUPS_RE.search(attrs)
            if g:
                nbytes *= int(g.group(2))
        out[op] += nbytes
    return dict(out)


def total_collective_bytes(hlo_text: str) -> int:
    return sum(collective_bytes(hlo_text).values())


def count_ops(hlo_text: str, opname: str) -> int:
    return len(re.findall(rf"\b{re.escape(opname)}\(", hlo_text))


def collective_summary(hlo_text: str) -> dict:
    per = collective_bytes(hlo_text)
    counts = {op: count_ops(hlo_text, op) for op in COLLECTIVE_OPS}
    return {"bytes_by_op": per,
            "counts": {k: v for k, v in counts.items() if v},
            "total_bytes": sum(per.values())}
