import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: ``.lower().compile()`` every (arch x shape x mesh).

The two lines above run before ANY other import (jax locks the device
count on first init) — 512 placeholder CPU devices stand in for the
production meshes: single-pod 16x16 = 256 chips, multi-pod 2x16x16 = 512.

Per cell this script:
  1. builds abstract params/opt-state/caches via jax.eval_shape (no
     allocation anywhere),
  2. jits the train_step / prefill_step / decode_step with the production
     shardings and lowers + compiles it,
  3. records memory_analysis(), cost_analysis(), the HLO collective
     traffic (launch.hlo_parse) and the three roofline terms to JSON for
     EXPERIMENTS.md §Dry-run / §Roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k \
      --mesh single --out experiments/dryrun
  python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import dataclasses
import functools
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.configs import get_config, list_archs
from repro.distributed import sharding as sh
from repro.launch import hlo_parse
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import RooflineReport, model_flops
from repro.models import model as M
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.train_step import make_train_step

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}

# long_500k needs sub-quadratic attention / bounded state (DESIGN.md §5).
LONG_OK = {"recurrentgemma-9b", "rwkv6-1.6b", "mixtral-8x7b"}


def cells():
    for arch in list_archs():
        for shape in SHAPES:
            if shape == "long_500k" and arch not in LONG_OK:
                continue
            yield arch, shape


def input_specs(cfg, shape: str):
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    spec = SHAPES[shape]
    b, s = spec["batch"], spec["seq"]
    if cfg.embed_input:
        tokens = jax.ShapeDtypeStruct((b, s), jnp.int32)
    else:
        tokens = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.float32)
    labels = jax.ShapeDtypeStruct((b, s), jnp.int32)
    return {"tokens": tokens, "labels": labels, "batch": b, "seq": s,
            "kind": spec["kind"]}


def abstract_params(cfg):
    return jax.eval_shape(
        functools.partial(M.init_params, cfg), jax.random.PRNGKey(0))


def _sds(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def lower_cell(cfg, shape: str, mesh, attn_impl: str | None = None,
               extra_cfg: dict | None = None, microbatches: int = 1):
    """Returns (lowered, aux) for one cell."""
    if attn_impl:
        cfg = dataclasses.replace(cfg, attn_impl=attn_impl)
    if extra_cfg:
        cfg = dataclasses.replace(cfg, **extra_cfg)
    ins = input_specs(cfg, shape)
    b, s, kind = ins["batch"], ins["seq"], ins["kind"]
    params = abstract_params(cfg)
    p_shard = sh.shard_params(mesh, params)

    if kind == "train":
        opt_cfg = AdamWConfig()
        lowered_fn, _ = make_train_step(mesh, cfg, opt_cfg, params, b, s,
                                        microbatches=microbatches)
        opt = jax.eval_shape(init_opt_state, params)
        lowered = lowered_fn.lower(params, opt, ins["tokens"],
                                   ins["labels"])
        n_tokens = b * s
    elif kind == "prefill":
        t_shard = sh.tokens_sharding(
            mesh, b, extra_dims=(1 if cfg.embed_input else 2))

        def prefill_step(p, t):
            return M.prefill(p, cfg, t, max_len=s)

        fn = jax.jit(prefill_step, in_shardings=(p_shard, t_shard))
        lowered = fn.lower(params, ins["tokens"])
        n_tokens = b * s
    elif kind == "decode":
        cache = jax.eval_shape(
            functools.partial(M.init_cache, cfg, b, s))
        c_shard = sh.shard_cache(mesh, cache, b)
        if cfg.embed_input:
            tok = jax.ShapeDtypeStruct((b,), jnp.int32)
            t_sh = NamedSharding(mesh, sh.batch_spec(mesh, b))
        else:
            tok = jax.ShapeDtypeStruct((b, cfg.d_model), jnp.float32)
            t_sh = sh.tokens_sharding(mesh, b, extra_dims=1)

        def serve_step(p, c, t):
            return M.decode_step(p, cfg, c, t)

        fn = jax.jit(serve_step,
                     in_shardings=(p_shard, c_shard, t_sh),
                     donate_argnums=(1,))
        lowered = fn.lower(params, cache, tok)
        n_tokens = b
    else:
        raise ValueError(kind)
    return lowered, {"n_tokens": n_tokens, "kind": kind, "cfg": cfg,
                     "microbatches": microbatches}


def _cost_analysis(compiled) -> dict:
    """Normalize ``cost_analysis()`` across jax versions: newer releases
    return one dict, older ones a list with one dict per partition."""
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def _measure(compiled) -> dict[str, float]:
    """flops / bytes / collective bytes of one compiled executable."""
    cost = _cost_analysis(compiled)
    coll = hlo_parse.total_collective_bytes(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll_bytes": float(coll)}


def calibrated_cost(cfg, shape: str, mesh) -> dict[str, float]:
    """Scan-corrected per-chip cost terms (dry-run fidelity).

    ``HloCostAnalysis`` counts a while-loop body ONCE, so the layer-group
    scan, the loss-chunk scan and the attention chunk maps all undercount.
    Fix: compile two *python-unrolled* shallow variants — 1 group and 2
    groups of the layer pattern (loss in one chunk; attention maps
    unrolled) — whose HLO counts are exact.  Cost is affine in group count
    (groups are structurally identical), so

        F(n_groups) = F(1g) + (n_groups - 1) * (F(2g) - F(1g))

    is exact for the scan part; the tail (n_layers % pattern) is covered
    by the fractional group count.  Sequence-step recurrences (rglru /
    rwkv6 lax.scan over time) remain counted once — their per-step work is
    O(d) vs the layer's O(d^2) matmuls (<1%), noted in EXPERIMENTS.md.

    Validated against a full python-unroll on archs small enough to
    compile (tests/test_dryrun.py)."""
    n_pat = len(cfg.pattern)
    groups_eff = cfg.n_layers / n_pat

    def unrolled(n_groups: int) -> dict[str, float]:
        cal_cfg = dataclasses.replace(
            cfg, n_layers=n_groups * n_pat, unroll_layers=True,
            loss_chunk=1 << 30)
        lowered, _ = lower_cell(cal_cfg, shape, mesh)
        return _measure(lowered.compile())

    f1 = unrolled(1)
    f2 = unrolled(2)
    # Per-group deltas are non-negative by construction; tiny cells can
    # measure f2 < f1 on the 'bytes' proxy (XLA fuses the two programs
    # differently) — clamp so extrapolation never goes below the
    # 1-group measurement.
    return {k: f1[k] + (groups_eff - 1.0) * max(f2[k] - f1[k], 0.0)
            for k in f1}


def analyze(lowered, compiled, *, arch: str, shape: str, mesh_name: str,
            n_chips: int, cfg, n_tokens: float, kind: str,
            corrected: dict[str, float] | None = None) -> dict:
    cost = _cost_analysis(compiled)
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))
    try:
        mem = compiled.memory_analysis()
        mem_d = {k: int(getattr(mem, k)) for k in (
            "argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes",
            "alias_size_in_bytes") if hasattr(mem, k)}
    except Exception:   # noqa: BLE001 — backend-dependent
        mem_d = {}
    hlo = compiled.as_text()
    coll = hlo_parse.collective_summary(hlo)
    use = corrected or {"flops": flops, "bytes": bytes_accessed,
                        "coll_bytes": float(coll["total_bytes"])}
    rep = RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, n_chips=n_chips,
        flops_per_chip=use["flops"],
        hbm_bytes_per_chip=use["bytes"],
        coll_bytes_per_chip=use["coll_bytes"],
        model_flops_total=model_flops(cfg, n_tokens, kind))
    return {**rep.to_dict(), "memory_analysis": mem_d,
            "collectives": coll, "cost_analysis_keys": sorted(cost),
            "raw_scanned": {"flops": flops, "bytes": bytes_accessed,
                            "coll_bytes": float(coll["total_bytes"])},
            "scan_corrected": corrected is not None}


HBM_BUDGET = 16 * 2 ** 30       # v5e HBM per chip


def _hbm_use(compiled, kind: str = "") -> float:
    """Per-chip HBM estimate from memory_analysis.

    For decode cells the donated KV cache updates in place on TPU
    (dynamic-update-slice aliases the donated buffer); the CPU backend
    does not implement while-loop/donation aliasing and materialises one
    extra cache copy in temp (verified: scan vs unrolled both carry it;
    tests/test_distributed).  Subtract that phantom copy — bounded by the
    alias (donated) size — from the decode temp estimate."""
    try:
        mem = compiled.memory_analysis()
        args = float(mem.argument_size_in_bytes)
        temp = float(mem.temp_size_in_bytes)
        out = float(mem.output_size_in_bytes)
        alias = float(mem.alias_size_in_bytes)
        if kind == "decode":
            temp = max(temp - alias, 0.0)
        return args + temp + out - alias
    except Exception:   # noqa: BLE001
        return 0.0


def regeneration_ladder(kind: str):
    """Paper §5.7 automated: when a design does not fit, re-solve with
    tightened constraints.  Each rung is (label, extra_cfg_patch,
    microbatches).  Rungs compose left-to-right."""
    if kind == "train":
        return [("mb4", {}, 4), ("mb16", {}, 16),
                ("mb16+chunked", {"attn_impl": "chunked"}, 16),
                ("mb16+chunked256", {"attn_impl": "chunked",
                                     "attn_chunk": 256}, 16)]
    if kind == "prefill":
        return [("chunked", {"attn_impl": "chunked"}, 1),
                ("chunked256", {"attn_impl": "chunked",
                                "attn_chunk": 256}, 1)]
    # decode: int8 KV halves the cache; blocked reads shrink the
    # dequantisation temp from the whole cache to one block
    return [("kv_int8", {"kv_cache_dtype": "int8"}, 1),
            ("kv_int8+blocked", {"kv_cache_dtype": "int8",
                                 "decode_chunk": 2048}, 1)]


def run_cell(arch: str, shape: str, mesh_name: str, out_dir: str,
             attn_impl: str | None = None,
             extra_cfg: dict | None = None, tag: str = "",
             calibrate: bool = True,
             shard_override: dict | None = None,
             auto_regenerate: bool = True) -> dict:
    sh.set_overrides(shard_override or {})
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    n_chips = mesh.devices.size
    t0 = time.time()
    lowered, aux = lower_cell(cfg, shape, mesh, attn_impl, extra_cfg)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    # ----- §5.7 design regeneration: tighten until the design fits -----
    regenerations: list[dict] = []
    hbm = _hbm_use(compiled, aux["kind"])
    if auto_regenerate and hbm > HBM_BUDGET:
        base_extra = dict(extra_cfg or {})
        best = (hbm, lowered, compiled, aux, extra_cfg)
        for label, patch, mb in regeneration_ladder(aux["kind"]):
            trial_extra = {**base_extra, **patch}
            lowered, aux = lower_cell(cfg, shape, mesh, attn_impl,
                                      trial_extra, microbatches=mb)
            compiled = lowered.compile()
            new_hbm = _hbm_use(compiled, aux["kind"])
            regenerations.append(
                {"rung": label, "hbm_gib": new_hbm / 2 ** 30,
                 "fits": bool(new_hbm <= HBM_BUDGET)})
            if new_hbm < best[0]:
                best = (new_hbm, lowered, compiled, aux, trial_extra)
            if new_hbm <= HBM_BUDGET:
                break
        # keep the best rung seen (a later rung may regress)
        hbm, lowered, compiled, aux, extra_cfg = best

    corrected = calibrated_cost(aux["cfg"], shape, mesh) if calibrate \
        else None
    result = analyze(lowered, compiled, arch=arch, shape=shape,
                     mesh_name=mesh_name, n_chips=n_chips, cfg=aux["cfg"],
                     n_tokens=aux["n_tokens"], kind=aux["kind"],
                     corrected=corrected)
    result.update({"lower_s": t_lower, "compile_s": t_compile,
                   "status": "ok", "tag": tag,
                   "extra_cfg": extra_cfg or {},
                   "shard_override": shard_override or {},
                   "microbatches": aux.get("microbatches", 1),
                   "hbm_gib": hbm / 2 ** 30,
                   "fits_hbm": bool(hbm <= HBM_BUDGET),
                   "regenerations": regenerations})
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        suffix = f"_{tag}" if tag else ""
        path = os.path.join(out_dir,
                            f"{arch}_{shape}_{mesh_name}{suffix}.json")
        with open(path, "w") as f:
            json.dump(result, f, indent=2)
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--attn-impl", default=None)
    ap.add_argument("--tag", default="")
    ap.add_argument("--extra-cfg", default=None,
                    help="JSON dict of ModelConfig overrides")
    ap.add_argument("--shard-override", default=None,
                    help='JSON dict of sharding-rule overrides, e.g. '
                         '{"lm_head$": [null, "model"]}')
    args = ap.parse_args()

    extra = json.loads(args.extra_cfg) if args.extra_cfg else None
    shard_ov = json.loads(args.shard_override) if args.shard_override \
        else None
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    todo = list(cells()) if args.all else [(args.arch, args.shape)]
    failures = 0
    for arch, shape in todo:
        for mesh_name in meshes:
            try:
                # cost calibration on the single-pod mesh only (the
                # roofline table is single-pod; multi checks feasibility)
                r = run_cell(arch, shape, mesh_name, args.out,
                             args.attn_impl, extra, args.tag,
                             calibrate=(mesh_name == "single"),
                             shard_override=shard_ov)
                print(f"OK   {arch:24s} {shape:12s} {mesh_name:6s} "
                      f"bound={r['bound']:10s} "
                      f"t=({r['t_compute_s']:.2e},{r['t_memory_s']:.2e},"
                      f"{r['t_collective_s']:.2e})s "
                      f"useful={r['useful_ratio']:.2f} "
                      f"roofline={r['roofline_fraction']:.3f} "
                      f"hbm={r['hbm_gib']:.1f}G"
                      f"{'' if r['fits_hbm'] else '(!)'} "
                      f"regen={len(r['regenerations'])} "
                      f"compile={r['compile_s']:.0f}s", flush=True)
            except Exception as exc:    # noqa: BLE001
                failures += 1
                print(f"FAIL {arch} {shape} {mesh_name}: {exc}",
                      flush=True)
                traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
