"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first jax
init; tests and benches see the real (single) device.
"""
from __future__ import annotations

import numpy as np

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for the production mesh, have "
            f"{len(devices)} — run under dryrun.py which sets "
            f"--xla_force_host_platform_device_count=512")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(f"need {n} devices, have {len(devices)}")
    return jax.make_mesh(shape, axes, devices=devices[:n])
