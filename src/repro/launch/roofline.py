"""Three-term roofline analysis from dry-run compile artifacts.

    compute    = HLO_FLOPs_per_chip / peak_FLOP/s
    memory     = HLO_bytes_per_chip / HBM_bw
    collective = collective_bytes_per_chip / link_bw

Hardware constants per the assignment: 197 TFLOP/s bf16, 819 GB/s HBM,
50 GB/s/link ICI (TPU v5e).  ``cost_analysis()`` of an SPMD-partitioned
executable reports the per-partition (per-chip) module, so its flops/bytes
feed the formulas directly (verified in tests/test_dryrun).

MODEL_FLOPS is the analytic useful work (6·N·D dense, 6·N_active·D MoE);
the ratio MODEL_FLOPS / HLO_FLOPs exposes remat/attention/padding
overheads.
"""
from __future__ import annotations

import dataclasses

from ..core.resources import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from ..models.model import ModelConfig


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    n_chips: int
    flops_per_chip: float
    hbm_bytes_per_chip: float
    coll_bytes_per_chip: float
    model_flops_total: float

    @property
    def t_compute(self) -> float:
        return self.flops_per_chip / PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes_per_chip / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_chip / ICI_BW

    @property
    def bound(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """Roofline step time: overlapped terms -> the max dominates."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_ratio(self) -> float:
        hlo_total = self.flops_per_chip * self.n_chips
        return self.model_flops_total / hlo_total if hlo_total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the chips' peak the useful model FLOPs achieve at
        the roofline step time — the §Perf score."""
        t = self.step_time
        if t <= 0:
            return 0.0
        return self.model_flops_total / (t * self.n_chips * PEAK_FLOPS_BF16)

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "n_chips": self.n_chips,
            "flops_per_chip": self.flops_per_chip,
            "hbm_bytes_per_chip": self.hbm_bytes_per_chip,
            "coll_bytes_per_chip": self.coll_bytes_per_chip,
            "model_flops_total": self.model_flops_total,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bound": self.bound,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def active_params(cfg: ModelConfig) -> float:
    """Parameters touched per token (experts scaled by top_k/n_experts),
    embeddings excluded (lookup, not matmul); lm_head included."""
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab
    hq, hkv, hd = cfg.q_heads, cfg.kv_heads, cfg.head_dim
    per_layer = {}
    per_layer["attn"] = d * hq * hd + 2 * d * hkv * hd + hq * hd * d
    per_layer["swa"] = per_layer["attn"]
    dr = cfg.rnn_width
    per_layer["rglru"] = 2 * d * dr + 2 * dr * dr + dr * d
    per_layer["rwkv6"] = 5 * d * d + 2 * d * 64 + 2 * d * f + d * d
    if cfg.ffn == "swiglu":
        ffn = 3 * d * f
    elif cfg.ffn == "gelu":
        ffn = 2 * d * f
    elif cfg.ffn == "moe":
        dense_frac = cfg.moe_top_k / max(cfg.n_experts, 1)
        ffn = 3 * d * f * cfg.n_experts * dense_frac + d * cfg.n_experts
    else:   # rwkv_cm counted in the mixer entry
        ffn = 0
    total = 0.0
    for i in range(cfg.n_layers):
        total += per_layer[cfg.mixer_at(i)] + ffn
    total += d * v          # lm_head
    return total


def model_flops(cfg: ModelConfig, n_tokens: float,
                kind: str) -> float:
    """6·N_active·tokens (fwd+bwd) for training, 2·N_active·tokens for
    inference (fwd only)."""
    n = active_params(cfg)
    mult = 6.0 if kind == "train" else 2.0
    return mult * n * n_tokens
