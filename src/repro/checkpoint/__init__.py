from . import io
from .manager import CheckpointManager

__all__ = ["io", "CheckpointManager"]
