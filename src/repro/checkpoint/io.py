"""Sharded, atomic checkpoint I/O (offline container: numpy files).

Layout:  <dir>/step_<N>/
            manifest.json      {step, paths, shapes, dtypes, tree}
            <flat-path>.npy    one file per leaf (host-gathered)
            COMMIT             written last — presence marks integrity

Atomicity: leaves + manifest land in ``step_<N>.tmp`` which is renamed
after COMMIT is written, so a crash mid-save never corrupts the latest
checkpoint.  Restore reads full arrays and ``device_put``s them under the
*target* sharding — which is how elastic rescale works: the new mesh's
shardings are applied at load time regardless of the saving topology.
"""
from __future__ import annotations

import json
import os
import re
import shutil

import jax
import jax.numpy as jnp
import numpy as np


def _flat(tree) -> list[tuple[str, np.ndarray]]:
    out = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        parts = []
        for k in path:
            if hasattr(k, "key"):
                parts.append(str(k.key))
            elif hasattr(k, "idx"):
                parts.append(str(k.idx))
            else:
                parts.append(str(k))
        out.append(("__".join(parts), np.asarray(leaf)))
    return out


_BIT_DTYPES = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
               "float8_e5m2": np.uint8}


def _to_savable(arr: np.ndarray) -> tuple[np.ndarray, str]:
    """numpy cannot serialise ml_dtypes (bfloat16, fp8) natively — store
    the raw bits as uintN; the logical dtype lives in the manifest."""
    name = str(arr.dtype)
    if name in _BIT_DTYPES:
        return arr.view(_BIT_DTYPES[name]), name
    return arr, name


def _from_saved(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _BIT_DTYPES:
        import ml_dtypes
        return arr.view(np.dtype(getattr(ml_dtypes, dtype_name)))
    return arr


def save(directory: str, step: int, tree) -> str:
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    leaves = _flat(tree)
    manifest = {"step": step, "leaves": []}
    for name, arr in leaves:
        bits, dtype_name = _to_savable(arr)
        np.save(os.path.join(tmp, name + ".npy"), bits)
        manifest["leaves"].append(
            {"name": name, "shape": list(arr.shape), "dtype": dtype_name})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp, "COMMIT"), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    best = None
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(directory, name, "COMMIT")):
            s = int(m.group(1))
            best = s if best is None else max(best, s)
    return best


def restore(directory: str, step: int, like, shardings=None):
    """Load into the structure of ``like``; apply ``shardings`` if given
    (pytree of NamedSharding matching ``like``) — elastic resharding."""
    path = os.path.join(directory, f"step_{step:08d}")
    if not os.path.exists(os.path.join(path, "COMMIT")):
        raise FileNotFoundError(f"no committed checkpoint at {path}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    dtype_of = {e["name"]: e["dtype"] for e in manifest["leaves"]}
    names = [n for n, _ in _flat(like)]
    arrays = [_from_saved(np.load(os.path.join(path, n + ".npy")),
                          dtype_of.get(n, ""))
              for n in names]
    flat_like, treedef = jax.tree_util.tree_flatten(like)
    assert len(arrays) == len(flat_like), "checkpoint/model structure differ"
    if shardings is not None:
        flat_sh = treedef.flatten_up_to(shardings)
        arrays = [jax.device_put(a, s) for a, s in zip(arrays, flat_sh)]
    else:
        arrays = [jnp.asarray(a) for a in arrays]
    return treedef.unflatten(arrays)


def remove(directory: str, step: int) -> None:
    path = os.path.join(directory, f"step_{step:08d}")
    if os.path.exists(path):
        shutil.rmtree(path)
