"""Checkpoint manager: rotation + async save thread.

The async path snapshots leaves to host memory synchronously (cheap —
device->host copy) and writes files on a daemon thread, so the train loop
resumes immediately; ``wait()`` joins before exit or before a dependent
restore.  At scale this is the standard trick to hide multi-GB writes
behind compute.
"""
from __future__ import annotations

import threading

import jax
import numpy as np

from . import io


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3,
                 async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        self._saved: list[int] = []
        existing = io.latest_step(directory)
        if existing is not None:
            self._saved.append(existing)

    def save(self, step: int, tree) -> None:
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        if self.async_save:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host_tree), daemon=True)
            self._thread.start()
        else:
            self._write(step, host_tree)

    def _write(self, step: int, host_tree) -> None:
        io.save(self.directory, step, host_tree)
        self._saved.append(step)
        while len(self._saved) > self.keep:
            io.remove(self.directory, self._saved.pop(0))

    def wait(self) -> None:
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()
        self._thread = None

    def latest_step(self) -> int | None:
        self.wait()
        return io.latest_step(self.directory)

    def restore(self, like, shardings=None, step: int | None = None):
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            return None, None
        return io.restore(self.directory, step, like, shardings), step
