"""Lowering pass: one fused task + its TaskConfig -> one jitted callable.

This is the paper's §5 code generation, per fused task:

* the task's statements are grouped into *units*: an init statement followed
  by an accumulating contraction collapses into ONE kernel invocation (the
  init value seeds the accumulator on the first reduction step) — fusion
  decisions become real kernel fusion, not just shared scheduling;
* each unit becomes a :class:`ContractionSpec` — grid order from the plan's
  loop permutation (``TaskConfig.perm``, reduction loops innermost), block
  shapes from the plan's tile sizes (``TaskConfig.tiles``, with the
  computation padding applied by the kernel wrapper and sliced back), and
  pipelining semantics from the placement's buffer counts;
* statements outside the affine-contraction subset fall back to the
  statement-level einsum evaluator (identical semantics, no plan tiling);
* the whole task body — all units in order — is exposed as one raw
  traceable callable; the whole-plan engine inlines every task body into a
  single program-wide ``jax.jit`` (``repro.codegen.program``), while the
  per-task debug executor jits each body on its own.

Tile sizes for loops the plan left unspecified are clamped to the loop's
(padded) extent instead of a blanket 128 so small graphs are not over-padded.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax

from ..core.fusion import FusedGraph, FusedTask
from ..core.padding import pad_to_multiple
from ..core.plan import TaskConfig
from ..core.taskgraph import Statement
from ..kernels.contraction import (ACC, ContractionSpec, EpiOp, LoopDim,
                                   Operand)
from ..kernels.contraction import ops as contraction_ops
from .reference import OPAQUE_PREFIX, eval_statement


@dataclasses.dataclass(frozen=True)
class LoweredUnit:
    """One kernel invocation inside a task body."""

    kind: str                           # "contraction" | "einsum" | "opaque"
    spec: ContractionSpec | None        # set when kind == "contraction"
    statements: tuple[Statement, ...]   # source statements (1 or 2)
    operands: tuple[str, ...]           # env arrays, spec operand order
    out_array: str


@dataclasses.dataclass
class TaskLowering:
    """A fused task lowered against one plan config + kernel impl.

    ``body`` is the raw traceable callable — the whole-plan engine
    (:mod:`repro.codegen.program`) inlines it into one program-wide
    ``jax.jit`` so XLA sees every task kernel at once.  ``fn`` wraps the
    same body in a per-task ``jax.jit`` for the debug/validation executor;
    it is built lazily so the fused path never pays for it.
    """

    tid: int
    name: str
    units: tuple[LoweredUnit, ...]
    in_arrays: tuple[str, ...]          # env arrays the task consumes
    out_array: str
    slice_id: int
    body: Callable[..., jax.Array]      # raw: (*in_arrays) -> out array
    _fn: Callable[..., jax.Array] | None = dataclasses.field(
        default=None, repr=False)

    @property
    def fn(self) -> Callable[..., jax.Array]:
        """Per-task jitted entry point (debug/per-task executor path)."""
        if self._fn is None:
            self._fn = jax.jit(self.body)
        return self._fn

    @property
    def kind(self) -> str:
        kinds = {u.kind for u in self.units}
        if kinds == {"contraction"}:
            return "contraction"
        return "opaque" if "opaque" in kinds else "einsum"

    @property
    def grid(self) -> tuple[int, ...] | None:
        """Pallas grid of the dominant (largest-domain) contraction unit."""
        specs = [u.spec for u in self.units if u.spec is not None]
        if not specs:
            return None
        return max(specs, key=lambda s: len(s.loops)).grid


# ---------------------------------------------------------------------------
# Spec construction
# ---------------------------------------------------------------------------
def _loop_dim(cfg: TaskConfig, loop: str, tc: int) -> LoopDim:
    opt = cfg.tiles.get(loop)
    if opt is not None and opt.ori_tc == tc:
        return LoopDim(loop, opt.tile, opt.padded_tc, tc)
    # Plan did not tile this loop (or tiled a different extent): clamp the
    # block to the loop extent rather than defaulting to 128 and over-padding.
    tile = min(128, tc)
    return LoopDim(loop, tile, pad_to_multiple(tc, tile), tc)


def _affine(stmt: Statement) -> bool:
    """Within the kernel's subset: dense, unique non-None iters per access.

    Rank-0 accesses (scalar operands of traced elementwise statements,
    opaque-segment reads) stay on the einsum/eval fallback: a 0-d BlockSpec
    has no tile for the grid pipeline to carry.  Opaque ops are evaluated
    through their registered callables, never a contraction kernel."""
    if stmt.density != 1.0:
        return False
    if stmt.op not in ("mul", "add", "sub"):
        return False
    for acc in tuple(stmt.reads) + tuple(stmt.writes):
        if len(acc.iters) == 0:
            return False
        if any(it is None for it in acc.iters):
            return False
        if len(set(acc.iters)) != len(acc.iters):
            return False
    return True


def _acc_reads(stmt: Statement):
    out = stmt.writes[0]
    return [a for a in stmt.reads if a.array == out.array]


def _is_plain_accumulation(stmt: Statement) -> bool:
    """Reads its own output exactly at the write's iterators (``+=``)."""
    out = stmt.writes[0]
    accs = _acc_reads(stmt)
    return bool(accs) and all(tuple(a.iters) == tuple(out.iters)
                              for a in accs)


def _is_pointwise_def(stmt: Statement) -> bool:
    """A definition with no reduction and no self-read — fusable as init."""
    return not _acc_reads(stmt) and not stmt.reduction_loops


def _ordered_loops(cfg: TaskConfig, used: set[str], red: set[str],
                   tcs: dict[str, int]) -> list[str]:
    """Grid order: the plan permutation restricted to the unit's loops, with
    reduction loops kept innermost (the solver pins them there; enforce it
    for robustness)."""
    in_perm = [l for l in cfg.perm if l in used]
    extra = [l for l in tcs if l in used and l not in cfg.perm]
    seq = in_perm + extra
    return [l for l in seq if l not in red] + [l for l in seq if l in red]


def _unit_spec(cfg: TaskConfig, main: Statement,
               init: Statement | None, prior: bool) -> ContractionSpec:
    out = main.writes[0]
    reads = [a for a in main.reads if a.array != out.array]
    init_reads: list = []
    init_op = "mul"
    if init is not None:
        init_reads = list(init.reads)
        init_op = init.op
    elif prior:
        init_reads = [out]          # previous value of the output array
        init_op = "mul"

    init_coeff, init_offset = 1.0, 0.0
    if init is not None:
        init_coeff, init_offset = init.coeff, init.offset

    tcs = dict(main.trip_counts)
    if init is not None:
        for l, n in init.trip_counts.items():
            tcs.setdefault(l, n)
    # Grid loops = loops some operand or the output actually indexes.  A
    # reduction loop touched by no access contributes nothing in the
    # reference einsum semantics, so it must not enter the grid either.
    used = {it for a in reads + init_reads + [out] for it in a.iters}
    red = set(main.reduction_loops) & used
    loops = _ordered_loops(cfg, used, red, tcs)

    overlapped = all(
        cfg.placements[a.array].buffers >= 2
        for a in reads if a.array in cfg.placements) if reads else True
    return ContractionSpec(
        loops=tuple(_loop_dim(cfg, l, tcs[l]) for l in loops),
        reduction=tuple(l for l in loops if l in red),
        op=main.op,
        reads=tuple(Operand(a.array, tuple(a.iters)) for a in reads),
        out_iters=tuple(out.iters),
        init_reads=tuple(Operand(a.array, tuple(a.iters))
                         for a in init_reads),
        init_op=init_op,
        buffers=2 if overlapped else 1,
        coeff=main.coeff,
        offset=main.offset,
        init_coeff=init_coeff,
        init_offset=init_offset,
    )


# ---------------------------------------------------------------------------
# Task lowering
# ---------------------------------------------------------------------------
def _build_units(fg: FusedGraph, task: FusedTask,
                 cfg: TaskConfig) -> list[LoweredUnit]:
    g = fg.graph
    names = [s.name for s in g.statements]
    units: list[LoweredUnit] = []
    pending_init: Statement | None = None
    produced = False       # the task has already written its output array

    def flush_init() -> None:
        nonlocal pending_init, produced
        if pending_init is not None:
            units.append(_make_unit(cfg, pending_init, None, prior=False))
            pending_init = None
            produced = True

    for stmt in task.statements:
        if stmt.density != 1.0:
            raise NotImplementedError(
                f"{stmt.name}: triangular-density statements are "
                "cost-modeled only (rectangular execution would compute a "
                "different function)")
        if not _affine(stmt):
            # outside the kernel subset: eval fallback, one statement —
            # "opaque" marks frontend passthrough segments (registered
            # residual callables), "einsum" the affine-but-untileable rest
            flush_init()
            srcs = tuple(dict.fromkeys(a.array for a in stmt.reads))
            kind = "opaque" if stmt.op.startswith(OPAQUE_PREFIX) \
                else "einsum"
            units.append(LoweredUnit(kind=kind, spec=None,
                                     statements=(stmt,), operands=srcs,
                                     out_array=stmt.writes[0].array))
            produced = True
            continue
        if _acc_reads(stmt) and not _is_plain_accumulation(stmt):
            # A self-read at iterators other than the write's (e.g. a
            # transposed in-place update) carries a loop-borne dependence
            # neither the kernel nor the reference executes faithfully —
            # refuse loudly rather than mis-lower.
            raise NotImplementedError(
                f"{stmt.name}: reads its own output at non-write "
                "iterators; only plain '+=' accumulation is executable")
        if _is_plain_accumulation(stmt):
            fusable = pending_init is not None and \
                tuple(pending_init.writes[0].iters) == \
                tuple(stmt.writes[0].iters)
            if fusable:
                # init + accumulate -> ONE kernel (the fusion payoff)
                units.append(_make_unit(cfg, stmt, pending_init,
                                        prior=False))
                pending_init = None
                produced = True
                continue
            flush_init()
            # Accumulation with no in-task init: seed from the array's prior
            # value when one exists (earlier task / external input) —
            # matching the reference, which only adds env values it finds.
            out = stmt.writes[0].array
            idx = names.index(stmt.name)
            prior = produced or g.producer_of(out, idx) is not None \
                or out in g.external_inputs()
            units.append(_make_unit(cfg, stmt, None, prior=prior))
            produced = True
            continue
        if _is_pointwise_def(stmt):
            # hold: it may seed the accumulator of the next statement
            flush_init()
            pending_init = stmt
            continue
        # a non-accumulating contraction definition (e.g. gesummv y_sum)
        flush_init()
        units.append(_make_unit(cfg, stmt, None, prior=False))
        produced = True
    flush_init()
    return units


def _make_unit(cfg: TaskConfig, main: Statement, init: Statement | None,
               prior: bool) -> LoweredUnit:
    spec = _unit_spec(cfg, main, init, prior)
    out = main.writes[0].array
    operands = tuple(o.array for o in spec.reads + spec.init_reads)
    stmts = (init, main) if init is not None else (main,)
    return LoweredUnit(kind="contraction", spec=spec, statements=stmts,
                       operands=operands, out_array=out)


# ---------------------------------------------------------------------------
# Epilogue folding (traced graphs): elementwise tails ride inside the kernel
# ---------------------------------------------------------------------------
def _epi_stmt_ok(stmt: Statement) -> bool:
    """A statement foldable as one EpiOp: pointwise over its write domain
    (no reduction or broadcast ``z`` loops), no self-read, an op from the
    kernel's elementwise families."""
    if not (stmt.op in ("mul", "add", "sub")
            or stmt.op.startswith(("unary:", "binary:"))):
        return False
    if stmt.density != 1.0 or len(stmt.writes) != 1:
        return False
    w = stmt.writes[0]
    if any(it is None for it in w.iters) or \
            len(set(w.iters)) != len(w.iters):
        return False
    if set(stmt.loops) != set(w.iters):
        return False
    return not any(r.array == w.array for r in stmt.reads)


def _fold_epilogues(fg: FusedGraph, task: FusedTask,
                    units: list[LoweredUnit]) -> list[LoweredUnit]:
    """Fold single-consumer elementwise units into the contraction unit that
    produces their input: the tail becomes a :class:`EpiOp` on the producer's
    spec, applied to the finished output tile at store time — one kernel,
    no intermediate buffer.  Iterators are renamed onto the producer's
    ``out_iters`` via the positional map of the tail's read of the producer
    output; a tail that transposes, reduces, broadcasts, or whose input is
    consumed anywhere else stays a separate unit."""
    g = fg.graph
    outside = set(g.final_outputs())
    for t in fg.tasks:
        if t.tid != task.tid:
            for s in t.statements:
                outside.update(a.array for a in s.reads)

    def unit_reads(u: LoweredUnit) -> set[str]:
        if u.kind == "contraction":
            return set(u.operands)
        return {a.array for s in u.statements for a in s.reads}

    changed = True
    while changed:
        changed = False
        for vi, V in enumerate(units):
            if len(V.statements) != 1 or V.kind == "opaque":
                continue
            s = V.statements[0]
            if not _epi_stmt_ok(s):
                continue
            fold = _try_fold(units, vi, s, outside, unit_reads)
            if fold is not None:
                ui, new_unit = fold
                units[ui] = new_unit
                del units[vi]
                changed = True
                break
    return units


def _try_fold(units: list[LoweredUnit], vi: int, s: Statement, outside,
              unit_reads) -> tuple[int, LoweredUnit] | None:
    read_arrays = {r.array for r in s.reads}
    for ui in range(vi - 1, -1, -1):
        U = units[ui]
        if U.kind != "contraction" or U.spec is None:
            continue
        if U.out_array not in read_arrays or U.out_array in outside:
            continue
        if any(U.out_array in unit_reads(w)
               for wi, w in enumerate(units) if wi != vi):
            continue
        spec = U.spec
        # Positional rename: the tail's read of the producer output maps its
        # iterators onto the spec's out_iters (must be consistent if read
        # more than once).
        m: dict[str, str] | None = None
        ok = True
        for r in s.reads:
            if r.array != U.out_array:
                continue
            if len(r.iters) != len(spec.out_iters) \
                    or any(it is None for it in r.iters) \
                    or len(set(r.iters)) != len(r.iters):
                ok = False
                break
            mm = dict(zip(r.iters, spec.out_iters))
            if m is None:
                m = mm
            elif mm != m:
                ok = False
                break
        if not ok or m is None or set(s.loops) != set(m):
            continue
        w = s.writes[0]
        if tuple(m[it] for it in w.iters) != tuple(spec.out_iters):
            continue                      # transposed store — keep separate
        if any(s.trip_counts[it] != spec.dim(oit).ori
               for it, oit in m.items()):
            continue
        # Extra operands must be elementwise-aligned and already available
        # when the producer unit runs (task inputs or earlier units' outs).
        later_outs = {units[k].out_array for k in range(ui, len(units))}
        epi_ok = True
        reads: list[Operand] = []
        for r in s.reads:
            if r.array == U.out_array:
                reads.append(Operand(ACC, tuple(spec.out_iters)))
                continue
            if any(it is None or it not in m for it in r.iters) \
                    or r.array in later_outs:
                epi_ok = False
                break
            reads.append(Operand(r.array, tuple(m[it] for it in r.iters)))
        if not epi_ok:
            continue
        new_spec = dataclasses.replace(
            spec, epilogue=spec.epilogue + (EpiOp(
                op=s.op, reads=tuple(reads),
                coeff=s.coeff, offset=s.offset),))
        return ui, LoweredUnit(
            kind="contraction", spec=new_spec,
            statements=U.statements + (s,),
            operands=tuple(o.array for o in new_spec.all_reads),
            out_array=w.array)
    return None


def lower_task(fg: FusedGraph, task: FusedTask, cfg: TaskConfig,
               impl: str) -> TaskLowering:
    """Lower one fused task to a single jitted callable honouring the plan."""
    units = _build_units(fg, task, cfg)
    if fg.graph.traced:
        units = _fold_epilogues(fg, task, units)
    out_array = task.output_array

    # Environment arrays consumed (external to the task body): everything an
    # einsum unit reads plus every contraction operand, minus arrays the
    # task itself produced before that unit runs.
    in_arrays: list[str] = []
    written: set[str] = set()
    for u in units:
        srcs = u.operands if u.kind == "contraction" else tuple(
            dict.fromkeys([a.array for s in u.statements for a in s.reads]))
        for a in srcs:
            if a not in written and a not in in_arrays:
                in_arrays.append(a)
        written.add(u.out_array)

    def body(*arrays: jax.Array) -> jax.Array:
        env = dict(zip(in_arrays, arrays))
        val = None
        for u in units:
            if u.kind == "contraction":
                operands = [env[a] for a in u.operands]
                val = contraction_ops.contract(u.spec, *operands, impl=impl)
            else:
                for s in u.statements:
                    val = eval_statement(s, env)
            env[u.out_array] = val
        return env[out_array]

    return TaskLowering(
        tid=task.tid,
        name=task.name,
        units=tuple(units),
        in_arrays=tuple(in_arrays),
        out_array=out_array,
        slice_id=cfg.slice_id,
        body=body,
    )
