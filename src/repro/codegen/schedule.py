"""Wave schedule: topological levels x slice assignment for a plan.

The paper's generated host code launches independent tasks concurrently and
overlaps inter-task communication with compute (§5, "concurrent task
execution" + "computation-communication overlap").  This module derives the
static schedule that makes both explicit for a (fused graph, execution plan)
pair:

* **waves** — topological levels of the dataflow DAG.  Every task in wave
  ``w`` has all producers in waves ``< w``, so same-wave tasks are mutually
  independent; tasks of one wave assigned to *different* slices are the
  concurrency the plan paid for.
* **transfers** — cross-slice dataflow edges, annotated with the wave after
  which the producer's output is ready and the wave at which the consumer
  needs it.  Issuing the transfer at ``ready_wave`` (production time) instead
  of ``need_wave`` (consumption time) is what lets it ride under the next
  wave's compute — the overlap-aware dispatch the executors implement.
* **liveness** — the last consumer of every intermediate array.  A buffer
  that dies at its last consumer can be *donated* to that consumer's kernel
  (the accumulate-in-place / buffer-reuse payoff); external inputs and final
  outputs are never donatable (the caller owns them).

Everything here is derived from static graph structure + the plan's
``slice_id`` assignment — no JAX, no devices — so it is unit-testable and
shared by both the whole-program path and the per-task debug path.
"""
from __future__ import annotations

import dataclasses

from ..core.costmodel import topo_waves
from ..core.fusion import FusedGraph
from ..core.plan import ExecutionPlan


@dataclasses.dataclass(frozen=True)
class Transfer:
    """One cross-slice dataflow edge, scheduled for overlapped dispatch."""

    array: str
    src: int            # producer tid
    dst: int            # consumer tid
    src_slice: int
    dst_slice: int
    ready_wave: int     # producer's wave — issue the transfer right after it
    need_wave: int      # consumer's wave — must have landed by then

    @property
    def overlap_waves(self) -> int:
        """Waves of compute the transfer can hide under (>= 1 by topology)."""
        return self.need_wave - self.ready_wave


@dataclasses.dataclass(frozen=True)
class WaveSchedule:
    """Static execution schedule for one (fused graph, plan) pair."""

    waves: tuple[tuple[int, ...], ...]      # wave -> tids (sorted)
    wave_of: dict[int, int]                 # tid -> wave index
    slice_of: dict[int, int]                # tid -> plan slice id
    transfers: tuple[Transfer, ...]         # cross-slice edges, by ready_wave
    last_reader: dict[str, int]             # array -> tid of last consumer
    dead_after: dict[int, tuple[str, ...]]  # tid -> arrays dying at this task

    @property
    def order(self) -> list[int]:
        """Wave-major execution order (a valid topological order)."""
        return [tid for wave in self.waves for tid in wave]

    @property
    def multi_slice(self) -> bool:
        """Whether the plan actually spans slices — the shared gate for
        device placement in both executor modes (single-slice plans must
        not pay per-argument device_put even on multi-device hosts)."""
        return len(set(self.slice_of.values())) > 1

    @property
    def max_width(self) -> int:
        return max(len(w) for w in self.waves) if self.waves else 0

    @property
    def wave_slice_counts(self) -> tuple[int, ...]:
        """Distinct slices concurrently active in each wave — the counts the
        cost model's per-wave HBM share uses (``costmodel.plan_latency``)
        and what a calibrated ``hbm_share`` curve is indexed by."""
        return tuple(len({self.slice_of[t] for t in wave})
                     for wave in self.waves)

    def concurrent_groups(self, wave: int) -> dict[int, tuple[int, ...]]:
        """Tasks of ``wave`` keyed by slice — distinct keys run concurrently."""
        out: dict[int, list[int]] = {}
        for tid in self.waves[wave]:
            out.setdefault(self.slice_of[tid], []).append(tid)
        return {s: tuple(ts) for s, ts in sorted(out.items())}

    def donatable(self, tid: int, in_arrays: tuple[str, ...],
                  protected: frozenset[str]) -> tuple[int, ...]:
        """Argument positions of ``in_arrays`` whose buffers die at ``tid``.

        ``protected`` holds arrays the caller still owns (external inputs,
        final outputs) — never donated.
        """
        dead = set(self.dead_after.get(tid, ()))
        return tuple(i for i, a in enumerate(in_arrays)
                     if a in dead and a not in protected)


def wave_schedule(fg: FusedGraph, plan: ExecutionPlan) -> WaveSchedule:
    """Derive the wave schedule of ``plan`` over the fused DAG ``fg``.

    Waves come from :func:`repro.core.costmodel.topo_waves` — the same
    levels the cost model prices, so what the solver optimized is what the
    executors run."""
    wave_of = topo_waves(fg)
    n_waves = 1 + max(wave_of.values()) if wave_of else 0
    waves = tuple(tuple(sorted(t for t, w in wave_of.items() if w == wi))
                  for wi in range(n_waves))

    slice_of = {t.tid: plan.configs[t.tid].slice_id for t in fg.tasks}

    transfers = tuple(sorted(
        (Transfer(array=a, src=u, dst=v,
                  src_slice=slice_of[u], dst_slice=slice_of[v],
                  ready_wave=wave_of[u], need_wave=wave_of[v])
         for (u, v, a) in fg.edges if slice_of[u] != slice_of[v]),
        key=lambda tr: (tr.ready_wave, tr.array, tr.dst)))

    # Liveness over the wave-major order: the last task reading an array is
    # where its buffer dies (external inputs / final outputs are excluded at
    # donation time, not here — the schedule records pure graph liveness).
    order = [tid for wave in waves for tid in wave]
    pos = {tid: i for i, tid in enumerate(order)}
    last_reader: dict[str, int] = {}
    for t in fg.tasks:
        consumed = set(t.read_arrays())
        # incoming edges also cover the prior version of the task's own
        # output array (a cross-task accumulation seed), which
        # read_arrays() deliberately excludes
        for (_, v, a) in fg.edges:
            if v == t.tid:
                consumed.add(a)
        for a in consumed:
            cur = last_reader.get(a)
            if cur is None or pos[t.tid] > pos[cur]:
                last_reader[a] = t.tid
    dead_after: dict[int, list[str]] = {}
    for a, tid in last_reader.items():
        dead_after.setdefault(tid, []).append(a)

    return WaveSchedule(
        waves=waves,
        wave_of=wave_of,
        slice_of=slice_of,
        transfers=transfers,
        last_reader=last_reader,
        dead_after={t: tuple(sorted(v)) for t, v in dead_after.items()},
    )
