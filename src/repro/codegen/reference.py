"""Statement-level reference oracle (naive einsum, program order).

This is the bit-level ground truth every lowered executable is validated
against (in ``pallas_interpret`` mode the Pallas kernel bodies themselves run
against it).  Deliberately independent of the lowering pass: it never looks
at an ExecutionPlan, only at the statement semantics.

Two statement families exist:

* affine ops (``"mul"``/``"add"``/``"sub"``) evaluate through the shared
  :func:`repro.kernels.contraction.ref.combine_terms` semantics — one
  definition for this oracle, the ``xla`` impl and the Pallas kernel;
* **opaque** ops (``"opaque:<digest>"``) are passthrough segments the
  frontend carved out of a traced jaxpr around unsupported primitives.
  Their semantics live in a process-wide registry of traceable callables
  (:func:`register_opaque`); the graph only records the digest, so graph
  fingerprints (and therefore program-cache keys) stay content-addressed.
"""
from __future__ import annotations

import string
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..core.taskgraph import Statement, TaskGraph
from ..kernels.contraction.ref import combine_terms, scale_offset

#: Marker prefix of opaque statement ops (the rest is a content digest).
OPAQUE_PREFIX = "opaque:"

# digest -> traceable callable taking the statement's read arrays (in
# ``Statement.reads`` order) and returning the output array.  Process-wide:
# entries are registered at trace time (repro.frontend) and looked up at
# lowering/trace time; compiled programs no longer need them.
_OPAQUE_FNS: dict[str, Callable] = {}


def register_opaque(op: str, fn: Callable) -> str:
    """Register the callable behind an ``opaque:<digest>`` statement op.

    Idempotent per digest (the digest is content-derived, so re-tracing the
    same segment re-registers the same semantics)."""
    if not op.startswith(OPAQUE_PREFIX):
        raise ValueError(f"opaque op must start with {OPAQUE_PREFIX!r}: "
                         f"{op!r}")
    _OPAQUE_FNS[op] = fn
    return op


def unregister_opaque(ops) -> None:
    """Drop registered opaque callables (trace-cache eviction hook — the
    registry's lifetime follows the bounded trace cache, so a long-lived
    serving process does not retain jaxpr closures for functions every
    other cache already evicted)."""
    for op in ops:
        _OPAQUE_FNS.pop(op, None)


def opaque_fn(op: str) -> Callable:
    fn = _OPAQUE_FNS.get(op)
    if fn is None:
        raise KeyError(
            f"opaque op {op!r} is not registered in this process — "
            "re-trace the source function (repro.frontend.trace) to "
            "rebuild its passthrough segments")
    return fn


def reference_executor(graph: TaskGraph) -> Callable[..., dict]:
    """Naive executor: statements in program order via einsum (oracle)."""

    def run(inputs: dict[str, jax.Array]) -> dict[str, jax.Array]:
        env = dict(inputs)
        for stmt in graph.statements:
            env[stmt.writes[0].array] = eval_statement(stmt, env)
        return {a: env[a] for a in graph.final_outputs()}

    return run


def eval_statement(stmt: Statement, env: dict) -> jax.Array:
    """Evaluate one statement against an array environment (einsum)."""
    if stmt.density != 1.0:
        raise NotImplementedError(
            f"{stmt.name}: triangular-density statements are cost-modeled "
            "only (rectangular execution would compute a different function)")
    if stmt.op.startswith(OPAQUE_PREFIX):
        fn = opaque_fn(stmt.op)
        return fn(*[env[a.array] for a in stmt.reads])
    out_acc = stmt.writes[0]
    reads = [a for a in stmt.reads if a.array != out_acc.array]
    accumulate = any(a.array == out_acc.array for a in stmt.reads)
    out_shape = tuple(stmt.trip_counts[it] for it in out_acc.iters)

    if not reads:
        val = jnp.zeros(out_shape, jnp.float32)
    else:
        letters = {it: string.ascii_letters[i]
                   for i, it in enumerate(stmt.loops)}
        subs = ["".join(letters[i] for i in acc.iters) for acc in reads]
        out_sub = "".join(letters[i] for i in out_acc.iters)
        val = combine_terms(subs, out_sub, stmt.op,
                            [env[acc.array] for acc in reads], out_shape)
    val = scale_offset(val, stmt.coeff, stmt.offset)
    if accumulate and out_acc.array in env:
        val = env[out_acc.array] + val
    return val


def allclose(out, ref, rtol: float = 2e-4) -> bool:
    """Scale-aware comparison against the oracle.

    The absolute tolerance is ``rtol`` of the oracle's largest magnitude:
    blocked f32 accumulation reorders sums, so near-zero entries of a
    large-scale output carry absolute noise proportional to the *output
    scale*, not to the entry (e.g. 3mm's G has entries O(1e4) produced by
    cancellation; the lowered kernel is routinely closer to the f64 truth
    than the reference there).
    """
    o = np.asarray(out, dtype=np.float64)
    r = np.asarray(ref, dtype=np.float64)
    return np.allclose(o, r, rtol=rtol, atol=_scale_atol(r, rtol))


def _scale_atol(r: np.ndarray, rtol: float) -> float:
    """The oracle tolerance contract: absolute noise proportional to the
    output scale (shared by allclose and assert_close — one definition)."""
    return rtol * max(1.0, float(np.abs(r).max()) if r.size else 1.0)


def assert_close(out, ref, rtol: float = 2e-4, name: str = "") -> None:
    o = np.asarray(out, dtype=np.float64)
    r = np.asarray(ref, dtype=np.float64)
    np.testing.assert_allclose(o, r, rtol=rtol, atol=_scale_atol(r, rtol),
                               err_msg=f"{name}: mismatch vs oracle")


def random_inputs(graph: TaskGraph, seed: int = 0) -> dict[str, jax.Array]:
    rng = np.random.default_rng(seed)
    out = {}
    for name in graph.external_inputs():
        arr = graph.arrays[name]
        out[name] = jnp.asarray(
            rng.normal(size=arr.shape).astype(np.float32))
    return out
