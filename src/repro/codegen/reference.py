"""Statement-level reference oracle (naive einsum, program order).

This is the bit-level ground truth every lowered executable is validated
against (in ``pallas_interpret`` mode the Pallas kernel bodies themselves run
against it).  Deliberately independent of the lowering pass: it never looks
at an ExecutionPlan, only at the statement semantics.
"""
from __future__ import annotations

import string
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..core.taskgraph import Statement, TaskGraph


def reference_executor(graph: TaskGraph) -> Callable[..., dict]:
    """Naive executor: statements in program order via einsum (oracle)."""

    def run(inputs: dict[str, jax.Array]) -> dict[str, jax.Array]:
        env = dict(inputs)
        for stmt in graph.statements:
            env[stmt.writes[0].array] = eval_statement(stmt, env)
        return {a: env[a] for a in graph.final_outputs()}

    return run


def eval_statement(stmt: Statement, env: dict) -> jax.Array:
    """Evaluate one statement against an array environment (einsum)."""
    if stmt.density != 1.0:
        raise NotImplementedError(
            f"{stmt.name}: triangular-density statements are cost-modeled "
            "only (rectangular execution would compute a different function)")
    out_acc = stmt.writes[0]
    reads = [a for a in stmt.reads if a.array != out_acc.array]
    accumulate = any(a.array == out_acc.array for a in stmt.reads)
    out_shape = tuple(stmt.trip_counts[it] for it in out_acc.iters)

    if not reads:
        val = jnp.zeros(out_shape, jnp.float32)
    elif stmt.op == "add":
        letters = {it: string.ascii_letters[i]
                   for i, it in enumerate(stmt.loops)}
        val = None
        for acc in reads:
            spec = "".join(letters[i] for i in acc.iters) + "->" + \
                "".join(letters[i] for i in out_acc.iters)
            term = jnp.einsum(spec, env[acc.array])
            val = term if val is None else val + term
    else:  # "mul": product of reads contracted over reduction loops
        letters = {it: string.ascii_letters[i]
                   for i, it in enumerate(stmt.loops)}
        in_specs = ",".join("".join(letters[i] for i in acc.iters)
                            for acc in reads)
        out_spec = "".join(letters[i] for i in out_acc.iters)
        val = jnp.einsum(f"{in_specs}->{out_spec}",
                         *[env[acc.array] for acc in reads])
    if accumulate and out_acc.array in env:
        val = env[out_acc.array] + val
    return val


def allclose(out, ref, rtol: float = 2e-4) -> bool:
    """Scale-aware comparison against the oracle.

    The absolute tolerance is ``rtol`` of the oracle's largest magnitude:
    blocked f32 accumulation reorders sums, so near-zero entries of a
    large-scale output carry absolute noise proportional to the *output
    scale*, not to the entry (e.g. 3mm's G has entries O(1e4) produced by
    cancellation; the lowered kernel is routinely closer to the f64 truth
    than the reference there).
    """
    o = np.asarray(out, dtype=np.float64)
    r = np.asarray(ref, dtype=np.float64)
    return np.allclose(o, r, rtol=rtol, atol=_scale_atol(r, rtol))


def _scale_atol(r: np.ndarray, rtol: float) -> float:
    """The oracle tolerance contract: absolute noise proportional to the
    output scale (shared by allclose and assert_close — one definition)."""
    return rtol * max(1.0, float(np.abs(r).max()) if r.size else 1.0)


def assert_close(out, ref, rtol: float = 2e-4, name: str = "") -> None:
    o = np.asarray(out, dtype=np.float64)
    r = np.asarray(ref, dtype=np.float64)
    np.testing.assert_allclose(o, r, rtol=rtol, atol=_scale_atol(r, rtol),
                               err_msg=f"{name}: mismatch vs oracle")


def random_inputs(graph: TaskGraph, seed: int = 0) -> dict[str, jax.Array]:
    rng = np.random.default_rng(seed)
    out = {}
    for name in graph.external_inputs():
        arr = graph.arrays[name]
        out[name] = jnp.asarray(
            rng.normal(size=arr.shape).astype(np.float32))
    return out
