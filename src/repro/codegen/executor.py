"""Dataflow graph executor: lowered tasks in topo order, slice-aware.

The single-host analogue of the paper's generated host code: fused tasks run
in topological order over the dataflow DAG; each task executes on the JAX
device standing in for its plan slice (``TaskConfig.slice_id``).

* same-slice edge   -> the producer's output is already resident on the
                       consumer's device: shared-buffer handoff, no copy;
* cross-slice edge  -> when several JAX devices exist the operand is moved
                       with ``jax.device_put`` (the ICI transfer analogue);
* single device     -> sequential fallback, all placement is a no-op.
"""
from __future__ import annotations

from typing import Callable

import jax

from ..core.fusion import fuse
from ..core.plan import ExecutionPlan
from ..core.taskgraph import TaskGraph
from ..kernels import dispatch
from .lower import TaskLowering, lower_task


class PlanExecutable:
    """Callable executing ``graph`` as lowered from ``plan``.

    Lowerings are built lazily per kernel impl (``xla`` /
    ``pallas_interpret`` / ``pallas``) so the same executable can be
    validated in interpret mode and deployed compiled.
    """

    def __init__(self, graph: TaskGraph, plan: ExecutionPlan,
                 impl: str | None = None):
        self.graph = graph
        self.plan = plan
        self.fg = fuse(graph)
        self.order = self.fg.topo_order()
        self._impl = impl
        self._lowered: dict[str, dict[int, TaskLowering]] = {}

    # -- lowering ----------------------------------------------------------
    def _resolve_impl(self, impl: str | None = None) -> str:
        return impl or self._impl or dispatch.current_impl()

    def lowerings(self, impl: str | None = None) -> dict[int, TaskLowering]:
        impl = self._resolve_impl(impl)
        if impl not in self._lowered:
            self._lowered[impl] = {
                t.tid: lower_task(self.fg, t, self.plan.configs[t.tid], impl)
                for t in self.fg.tasks
            }
        return self._lowered[impl]

    # -- execution ---------------------------------------------------------
    def __call__(self, inputs: dict[str, jax.Array],
                 impl: str | None = None) -> dict[str, jax.Array]:
        lowered = self.lowerings(impl)
        devices = jax.devices()
        multi = len(devices) > 1
        env = dict(inputs)
        for tid in self.order:
            lw = lowered[tid]
            args = [env[a] for a in lw.in_arrays]
            if multi:
                dev = devices[lw.slice_id % len(devices)]
                args = [_place(x, dev) for x in args]
            env[lw.out_array] = lw.fn(*args)
        outs = {a: env[a] for a in self.graph.final_outputs()}
        if multi:
            outs = {a: _place(v, devices[0]) for a, v in outs.items()}
        return outs


def _place(x: jax.Array, dev) -> jax.Array:
    """Move ``x`` to ``dev`` unless already resident (shared-buffer edge)."""
    try:
        if dev in x.devices():
            return x
    except (AttributeError, TypeError):
        pass
    return jax.device_put(x, dev)


def plan_executor(graph: TaskGraph, plan: ExecutionPlan,
                  impl: str | None = None) -> Callable[..., dict]:
    """Lower ``plan`` for ``graph`` into a plan-faithful executable."""
    return PlanExecutable(graph, plan, impl=impl)
