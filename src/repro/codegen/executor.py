"""Plan executables: whole-program fused path + per-task debug path.

``PlanExecutable`` is the callable handed out by :func:`plan_executor`.  It
executes a graph as lowered from a plan in one of two modes:

* ``mode="program"`` (default) — the whole fused DAG is compiled into ONE
  ``jax.jit`` program per kernel impl (:mod:`repro.codegen.program`): XLA
  sees every task kernel at once, schedules independent same-wave tasks
  concurrently, elides host round-trips on inter-task edges, and cross-slice
  transfers are emitted at the producer's wave so they overlap the next
  wave's compute.  Programs come from a process-wide cache keyed by
  (graph fingerprint, plan fingerprint, impl) — repeated construction and
  repeated calls with identical shapes re-lower and re-trace nothing.

* ``mode="per_task"`` — the PR-1 style host loop, kept as the
  debug/validation mode: one jitted callable per task, dispatched wave by
  wave.  Unlike PR 1 it is overlap-aware (cross-slice edges are issued the
  moment the producing wave is dispatched, riding under the next wave's
  compute thanks to JAX's async dispatch, instead of blocking at consume
  time) and donation-aware (an intermediate buffer dying at its last
  consumer is donated to that consumer's kernel when shapes allow reuse).

Device handles and impl resolution are cached at construction — no
``jax.devices()`` query per call.
"""
from __future__ import annotations

import os

import jax

from ..core.fusion import fuse
from ..core.plan import ExecutionPlan
from ..core.taskgraph import TaskGraph
from ..kernels import dispatch
from .lower import TaskLowering, lower_task
from .program import PlanProgram, compiled_program
from .schedule import WaveSchedule, wave_schedule

MODES = ("program", "per_task")


class PlanExecutable:
    """Callable executing ``graph`` as lowered from ``plan``.

    Lowerings/programs are built lazily per kernel impl (``xla`` /
    ``pallas_interpret`` / ``pallas``) so the same executable can be
    validated in interpret mode and deployed compiled.
    """

    def __init__(self, graph: TaskGraph, plan: ExecutionPlan,
                 impl: str | None = None, mode: str = "program",
                 pool_size: int | None = None):
        if mode not in MODES:
            raise ValueError(f"bad mode {mode!r}; want one of {MODES}")
        self.graph = graph
        self.plan = plan
        self.mode = mode
        self.pool_size = pool_size
        self.fg = fuse(graph)
        self.schedule: WaveSchedule = wave_schedule(self.fg, plan)
        self.order = self.schedule.order
        self._impl = impl
        # cached once: device handles and the never-donated arrays
        self._devices = tuple(jax.devices())
        self._multi = len(self._devices) > 1 and self.schedule.multi_slice
        self._protected = frozenset(graph.external_inputs()) \
            | frozenset(graph.final_outputs())
        self._lowered: dict[str, dict[int, TaskLowering]] = {}
        self._task_fns: dict[str, dict[int, object]] = {}
        self._programs: dict[str, PlanProgram] = {}

    # -- lowering ----------------------------------------------------------
    def _resolve_impl(self, impl: str | None = None) -> str:
        # the explicit impl (argument or constructor) is already resolved;
        # only the contextual default (`kernel_impl` scope / env var) needs
        # a dispatch query, and that must stay per-call to honour scoping
        return impl or self._impl or dispatch.current_impl()

    def program(self, impl: str | None = None) -> PlanProgram:
        """The whole-plan compiled program for ``impl`` (cached)."""
        impl = self._resolve_impl(impl)
        if impl not in self._programs:
            self._programs[impl] = compiled_program(
                self.graph, self.plan, impl,
                fg=self.fg, schedule=self.schedule,
                pool_size=self.pool_size)
        return self._programs[impl]

    def lowerings(self, impl: str | None = None) -> dict[int, TaskLowering]:
        impl = self._resolve_impl(impl)
        if impl not in self._lowered:
            if self.mode == "program":
                # share the program's lowerings instead of re-lowering
                self._lowered[impl] = self.program(impl).lowered
            else:
                self._lowered[impl] = {
                    t.tid: lower_task(self.fg, t, self.plan.configs[t.tid],
                                      impl)
                    for t in self.fg.tasks
                }
        return self._lowered[impl]

    def _donating_fns(self, impl: str) -> dict[int, object]:
        """Per-task jitted fns, donating dying intermediate buffers whose
        shape matches the task output (predictable in-place reuse).

        The CPU runtime declines these donations with a warning, so
        donation is applied only where the backend honours it (TPU/GPU),
        or when forced via ``REPRO_DONATE=1``.
        """
        if impl in self._task_fns:
            return self._task_fns[impl]
        fns: dict[int, object] = {}
        arrays = self.graph.arrays
        supported = jax.default_backend() in ("tpu", "gpu") \
            or os.environ.get("REPRO_DONATE") == "1"
        for tid, lw in self.lowerings(impl).items():
            out_shape = arrays[lw.out_array].shape
            donate = tuple(
                i for i in self.schedule.donatable(tid, lw.in_arrays,
                                                   self._protected)
                if arrays[lw.in_arrays[i]].shape == out_shape) \
                if supported else ()
            fns[tid] = jax.jit(lw.body, donate_argnums=donate) if donate \
                else lw.fn
        self._task_fns[impl] = fns
        return fns

    # -- execution ---------------------------------------------------------
    def __call__(self, inputs: dict[str, jax.Array],
                 impl: str | None = None) -> dict[str, jax.Array]:
        if self.mode == "program":
            return self.program(impl)(inputs)
        return self._run_per_task(inputs, impl)

    def _run_per_task(self, inputs: dict[str, jax.Array],
                      impl: str | None) -> dict[str, jax.Array]:
        impl = self._resolve_impl(impl)
        lowered = self.lowerings(impl)
        fns = self._donating_fns(impl)
        devices = self._devices
        ndev = len(devices)
        multi = self._multi
        env = dict(inputs)
        placed: dict[tuple[str, int], jax.Array] = {}
        for wi, wave in enumerate(self.schedule.waves):
            for tid in wave:
                lw = lowered[tid]
                if multi:
                    d = lw.slice_id % ndev
                    args = []
                    for a in lw.in_arrays:
                        v = placed.get((a, d))
                        if v is None:
                            # cache the placement: shared operands are
                            # copied once per device, not once per consumer
                            v = placed[(a, d)] = _place(env[a], devices[d])
                        args.append(v)
                else:
                    args = [env[a] for a in lw.in_arrays]
                out = fns[tid](*args)
                if multi:
                    for key in [k for k in placed if k[0] == lw.out_array]:
                        del placed[key]
                env[lw.out_array] = out
                # drop buffers that died at this task (their last consumer)
                for a in self.schedule.dead_after.get(tid, ()):
                    if a not in self._protected and a != lw.out_array:
                        env.pop(a, None)
                        for key in [k for k in placed if k[0] == a]:
                            del placed[key]
            if multi:
                # overlap-aware dispatch: enqueue cross-slice transfers as
                # soon as the producing wave is in flight — async dispatch
                # lets them ride under wave wi+1's compute
                for tr in self.schedule.transfers:
                    if tr.ready_wave == wi:
                        d = tr.dst_slice % ndev
                        if (tr.array, d) not in placed \
                                and tr.array in env:
                            placed[(tr.array, d)] = jax.device_put(
                                env[tr.array], devices[d])
        outs = {a: env[a] for a in self.graph.final_outputs()}
        if multi:
            outs = {a: _place(v, devices[0]) for a, v in outs.items()}
        return outs


def _place(x: jax.Array, dev) -> jax.Array:
    """Move ``x`` to ``dev`` unless already resident (shared-buffer edge)."""
    try:
        if dev in x.devices():
            return x
    except (AttributeError, TypeError):
        pass
    return jax.device_put(x, dev)


def plan_executor(graph: TaskGraph, plan: ExecutionPlan,
                  impl: str | None = None,
                  mode: str = "program",
                  pool_size: int | None = None) -> PlanExecutable:
    """Lower ``plan`` for ``graph`` into a plan-faithful executable.

    ``mode="program"`` (default) compiles the whole DAG into one program per
    impl; ``mode="per_task"`` keeps the host-driven per-task dispatch as a
    debug/validation path.  ``pool_size`` clones the program's segment
    executables into a round-robin pool (default: the
    ``REPRO_PROGRAM_POOL_SIZE`` env knob, 1).
    """
    return PlanExecutable(graph, plan, impl=impl, mode=mode,
                          pool_size=pool_size)
