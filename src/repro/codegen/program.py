"""Whole-plan compiled programs: the fused DAG as few ``jax.jit`` segments.

PR 1's executor walked the DAG in a Python loop — one ``jax.jit`` call per
task — so independent tasks serialized on the host dispatch path and every
inter-task edge round-tripped through HBM.  PR 2 lowered the *whole*
dataflow program into a single jitted callable.  This module is the serving
generation of that engine, with three production mechanisms on top:

* **materialization segments** — XLA CPU's fusion pass *clones* a cheap-to-
  recompute producer into every consumer fusion, even through
  ``optimization_barrier`` and even when the producer is a program output
  (measured on gemver: the rank-2 update ran once per consumer dot, turning
  the fusion win into a 0.55x loss).  The program is therefore split at
  multi-consumer producer boundaries: each segment is its own executable, so
  the producer's buffer is materialized exactly once and duplication is
  structurally impossible.  Graphs without multi-consumer intermediates
  (most of PolyBench) keep the original single-program lowering.
* **executable pool** — each program optionally holds ``pool_size`` cloned
  sets of its segment executables, served round-robin, so concurrent callers
  (or cross-call pipelining on memory-bound graphs) never contend on one
  executable instance.  ``REPRO_PROGRAM_POOL_SIZE`` sets the default.
* **bounded LRU program cache** — programs are cached process-wide, keyed by
  (graph fingerprint, plan fingerprint, impl), with per-entry hit/last-use/
  size stats and LRU eviction at ``REPRO_PROGRAM_CACHE_SIZE`` entries, so a
  replica serving many distinct plans has a bounded footprint.  Cache and
  pool are thread-safe: concurrent servers hit under the cache lock,
  misses for the same key compile once behind a per-key build lock, and
  the round-robin cursor never hands two callers the same clone index.
  A persistent AOT cache (``jax_compilation_cache_dir``, exposed as
  :func:`enable_persistent_cache` / ``REPRO_COMPILATION_CACHE_DIR``) lets
  replicas share lowered XLA artifacts across processes: a warm replica's
  first compile of a known program deserializes instead of re-lowering.

The input shapes/dtypes dimension of the cache key is carried by
``jax.jit``'s own aval cache underneath, so a repeated call with identical
shapes re-traces nothing — that is what makes the serving path
(`repro.serve.PlanEngine`) zero-overhead after the first request.

Graphs need not come from the polybench builders: the frontend
(`repro.frontend`) lowers traced jaxprs into graphs whose unsupported
regions are **opaque passthrough segments** — statements whose bodies are
registered residual callables (``repro.codegen.reference.register_opaque``)
evaluated inline while the segment executable traces.  They inline into the
same per-segment ``jax.jit`` programs as contraction kernels (XLA CSE
collapses a multi-output segment's repeated prefix into one computation),
participate in wave scheduling and multi-consumer materialization splits,
and cost nothing at execution time beyond the residual computation itself.
``unit_kinds()`` reports how much of a program is plan-tiled contraction
versus einsum/opaque fallback.
"""
from __future__ import annotations

import dataclasses
import os
import threading
import time
from collections import OrderedDict
from typing import Callable

import jax

from ..core.fusion import FusedGraph, fuse
from ..core.plan import ExecutionPlan
from ..core.taskgraph import TaskGraph
from ..obs import profiler as _obs_profiler
from .lower import TaskLowering, lower_task
from .schedule import WaveSchedule, wave_schedule

#: Default LRU capacity of the process-wide program cache.
DEFAULT_CACHE_SIZE = 64
#: Default executable-pool size per cache entry (1 = no cloning).
DEFAULT_POOL_SIZE = 1


def _env_int(name: str, default: int) -> int:
    try:
        return max(1, int(os.environ.get(name, default)))
    except ValueError:
        return default


# ---------------------------------------------------------------------------
# Fingerprints (cache keys) — canonical definitions live in
# core/fingerprint.py (import-light, shared with the plan store); these
# re-exports keep the historical import site working.
# ---------------------------------------------------------------------------
from ..core.fingerprint import graph_fingerprint, plan_fingerprint  # noqa: E402


def program_key(graph: TaskGraph, plan: ExecutionPlan,
                impl: str) -> tuple[str, str, str]:
    """The process-wide cache key of a (graph, plan, impl) triple."""
    return (graph_fingerprint(graph), plan_fingerprint(plan), impl)


# ---------------------------------------------------------------------------
# Persistent AOT compilation cache (cross-process artifact sharing)
# ---------------------------------------------------------------------------
_persistent_dir: str | None = None


def enable_persistent_cache(path: str) -> str:
    """Point JAX's persistent compilation cache at ``path`` and open it up
    to every program this engine compiles (no min-size / min-compile-time
    cutoffs — plan programs are small but re-lowered by every replica).

    Returns the directory so callers can log/inspect it.  Safe to call more
    than once; the last directory wins process-wide.
    """
    global _persistent_dir
    # Crash hygiene before trusting the directory: a replica killed
    # mid-write leaves zero-byte entries / orphaned temp files that would
    # otherwise surface as deserialization errors on the next warm start.
    # Scrubbed entries are simply recompiled (logged by the scrubber).
    from ..ft.artifacts import (ArtifactError, atomic_write_json,
                                load_json, quarantine_file, scrub_cache_dir)
    scrub_cache_dir(path)
    # Checksummed ownership metadata rides alongside the cache entries: a
    # torn/corrupt metadata file is quarantined and rewritten (never fatal
    # at startup), and a jax-version change is recorded — entries are keyed
    # by jax's own compilation fingerprint, so stale ones are merely dead
    # weight, not a correctness hazard.
    meta_path = os.path.join(path, "repro-cache-metadata.json")
    meta = {"schema": 1, "jax": jax.__version__}
    try:
        seen = load_json(meta_path, require_checksum=True)
        if seen != meta:
            atomic_write_json(meta_path, meta)
    except FileNotFoundError:
        atomic_write_json(meta_path, meta)
    except (ArtifactError, OSError) as exc:
        quarantine_file(meta_path, reason=repr(exc))
        atomic_write_json(meta_path, meta)
    jax.config.update("jax_compilation_cache_dir", path)
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0)
    if _persistent_dir != path:
        # jax latches the cache backend on first compile; a process that
        # already compiled anything would otherwise silently never persist
        try:
            from jax._src import compilation_cache as _cc
            _cc.reset_cache()
        except (ImportError, AttributeError):
            pass
    _persistent_dir = path
    return path


def persistent_cache_dir() -> str | None:
    """The active persistent-cache directory, if any."""
    return _persistent_dir


def _auto_enable_persistent_cache() -> None:
    if _persistent_dir is None:
        path = os.environ.get("REPRO_COMPILATION_CACHE_DIR")
        if path:
            enable_persistent_cache(path)


# ---------------------------------------------------------------------------
# Program segments
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Segment:
    """A contiguous run of tasks compiled into one executable.

    ``in_arrays`` are the env arrays the segment reads (external inputs or
    earlier segments' outputs); ``out_arrays`` are what later segments or
    the caller consume — materialized buffers at the executable boundary.
    """

    index: int
    tids: tuple[int, ...]
    in_arrays: tuple[str, ...]
    out_arrays: tuple[str, ...]


def _split_segments(schedule: WaveSchedule, lowered: dict[int, TaskLowering],
                    materialize: frozenset[str], out_names: tuple[str, ...],
                    ) -> list[Segment]:
    """Split the wave-major task order at multi-consumer producers.

    A task whose output feeds >= 2 consumer tasks closes its segment, so the
    output crosses an executable boundary and XLA cannot clone the producer
    into each consumer (see module docstring).  With no such producers the
    whole plan stays one segment, i.e. one executable.
    """
    order = schedule.order
    groups: list[list[int]] = []
    cur: list[int] = []
    for tid in order:
        cur.append(tid)
        if lowered[tid].out_array in materialize:
            groups.append(cur)
            cur = []
    if cur:
        groups.append(cur)

    segments: list[Segment] = []
    for gi, group in enumerate(groups):
        # external reads: arrays consumed before any in-segment write (an
        # in-segment write earlier in the group satisfies later reads, and
        # a task reading its own output array is a cross-task accumulation
        # seed, external only for the segment's first writer)
        seen: set[str] = set()
        ext: list[str] = []
        for tid in group:
            lw = lowered[tid]
            for a in lw.in_arrays:
                if a not in seen and a not in ext:
                    ext.append(a)
            seen.add(lw.out_array)
        later_reads = {a for g2 in groups[gi + 1:] for tid in g2
                       for a in lowered[tid].in_arrays}
        outs: list[str] = []
        for tid in group:
            a = lowered[tid].out_array
            if (a in later_reads or a in out_names) and a not in outs:
                outs.append(a)
        segments.append(Segment(index=gi, tids=tuple(group),
                                in_arrays=tuple(ext),
                                out_arrays=tuple(outs)))
    return segments


# ---------------------------------------------------------------------------
# The compiled program
# ---------------------------------------------------------------------------
class PlanProgram:
    """One plan, one impl, one compiled executable per segment.

    Most plans have a single segment (the PR-2 whole-program lowering); a
    plan with multi-consumer intermediates is split at those boundaries.
    ``pool_size`` > 1 clones the segment executables into a round-robin
    pool so repeated/concurrent calls spread over distinct executables.
    """

    def __init__(self, graph: TaskGraph, plan: ExecutionPlan, impl: str,
                 fg: FusedGraph | None = None,
                 schedule: WaveSchedule | None = None,
                 pool_size: int | None = None):
        self.graph = graph
        self.plan = plan
        self.impl = impl
        self.fg = fg if fg is not None else fuse(graph)
        self.schedule = schedule if schedule is not None \
            else wave_schedule(self.fg, plan)
        self.lowered: dict[int, TaskLowering] = {
            t.tid: lower_task(self.fg, t, plan.configs[t.tid], impl)
            for t in self.fg.tasks
        }
        self.in_names = tuple(graph.external_inputs())
        self.out_names = tuple(graph.final_outputs())
        # Task outputs feeding >= 2 consumer tasks: XLA CPU clones such
        # producers into every consumer fusion (observed on gemver — the
        # rank-2 update recomputed per consumer dot), through optimization
        # barriers and even past explicit outputs.  These arrays define the
        # segment boundaries where materialization is structural.
        consumers: dict[str, set[int]] = {}
        for (_, v, a) in self.fg.edges:
            consumers.setdefault(a, set()).add(v)
        self._materialize = frozenset(
            a for a, vs in consumers.items() if len(vs) >= 2)
        self._devices = tuple(jax.devices())
        self._multi = len(self._devices) > 1 and self.schedule.multi_slice
        self._traces = 0
        # one lock for the serving counters: concurrent submit threads
        # round-robin onto distinct clones (every call gets a unique
        # index) and `calls`/`trace_count` never lose updates
        self._counter_lock = threading.Lock()
        self._calls = 0
        # clones rotated out of round-robin (straggler mitigation): the
        # serving layer disables a persistently slow clone so requests
        # stop landing on it; at least one clone always stays enabled
        self._disabled: set[int] = set()
        if os.environ.get("REPRO_PROGRAM_SEGMENT", "1") == "0":
            # debug escape hatch: single-executable lowering, barrier-pinned
            self.segments = [Segment(0, tuple(self.schedule.order),
                                     self.in_names, self.out_names)]
        else:
            self.segments = _split_segments(
                self.schedule, self.lowered, self._materialize,
                self.out_names)
        self.pool_size = pool_size if pool_size is not None \
            else _env_int("REPRO_PROGRAM_POOL_SIZE", DEFAULT_POOL_SIZE)
        self._pool: list[tuple[Callable, ...]] = [
            tuple(jax.jit(self._segment_body(seg)) for seg in self.segments)
            for _ in range(self.pool_size)
        ]
        self._single = len(self.segments) == 1

    # -- introspection ----------------------------------------------------
    @property
    def trace_count(self) -> int:
        """How many times any segment body has been (re-)traced."""
        return self._traces

    @property
    def n_segments(self) -> int:
        return len(self.segments)

    @property
    def calls(self) -> int:
        """Requests served by this program (pool round-robin position is
        ``calls % pool_size``)."""
        return self._calls

    def entry(self):
        """Direct single-dispatch call info for single-segment,
        single-device programs: ``(in_arrays, out_arrays, body)`` where
        ``body(*vals)`` is the *untraced* segment body.

        Latency-critical wrappers (``TracedExecutable``) inline the body
        into their own single ``jax.jit`` together with const binding and
        output restoration, so one call costs exactly one jit dispatch —
        the per-call env dict, counter lock and pool rotation of
        ``__call__`` measured ~9us on the frontend benchmark, most of the
        remaining traced-vs-jit gap.  Returns ``None`` for multi-segment
        or multi-device programs (those need the env/transfer machinery).
        """
        if not self._single or self._multi:
            return None
        seg = self.segments[0]
        return seg.in_arrays, seg.out_arrays, self._segment_body(seg)

    def unit_kinds(self) -> dict[str, int]:
        """Lowered-unit census: plan-tiled ``contraction`` kernels vs
        ``einsum`` fallback vs frontend ``opaque`` passthrough segments —
        the program-side counterpart of a trace's coverage ratio."""
        out: dict[str, int] = {}
        for lw in self.lowered.values():
            for u in lw.units:
                out[u.kind] = out.get(u.kind, 0) + 1
        return out

    def est_bytes(self) -> int:
        """Rough resident-size estimate of this cache entry: the graph's
        array footprint once (intermediate buffers live inside the
        executables) plus a fixed per-task code estimate per pool clone."""
        arrays = sum(a.bytes for a in self.graph.arrays.values())
        code = 64 * 1024 * len(self.lowered) * self.pool_size
        return arrays + code

    def _dev(self, slice_id: int) -> int:
        return slice_id % len(self._devices)

    # -- traced bodies ----------------------------------------------------
    def _segment_body(self, seg: Segment):
        """Build the traceable body of one segment (closure per pool clone,
        so every ``jax.jit`` wrapper compiles its own executable)."""
        tids = frozenset(seg.tids)

        def body(*flat: jax.Array):
            with self._counter_lock:
                self._traces += 1
            env: dict[str, jax.Array] = dict(zip(seg.in_arrays, flat))
            placed: dict[tuple[str, int], jax.Array] = {}

            def on_device(array: str, d: int) -> jax.Array:
                key = (array, d)
                if key not in placed:
                    placed[key] = jax.device_put(env[array],
                                                 self._devices[d])
                return placed[key]

            for wi, wave in enumerate(self.schedule.waves):
                for tid in wave:
                    if tid not in tids:
                        continue
                    lw = self.lowered[tid]
                    if self._multi:
                        d = self._dev(self.schedule.slice_of[tid])
                        args = [on_device(a, d) for a in lw.in_arrays]
                    else:
                        args = [env[a] for a in lw.in_arrays]
                    out = lw.body(*args)
                    if self._single and lw.out_array in self._materialize \
                            and lw.out_array not in seg.out_arrays:
                        # unsegmented fallback: barrier-pin multi-consumer
                        # producers (best effort — see module docstring)
                        out = jax.lax.optimization_barrier(out)
                    if self._multi:
                        # the array has a new version: stale placements die
                        for k in [k for k in placed
                                  if k[0] == lw.out_array]:
                            del placed[k]
                    env[lw.out_array] = out
                if self._multi:
                    # Overlap-aware dispatch: cross-slice edges whose
                    # producer AND consumer live in this segment are issued
                    # at the producer's wave so the transfer rides under
                    # wave wi+1's compute.  Edges crossing a segment
                    # boundary are materialized there and placed at use.
                    for tr in self.schedule.transfers:
                        if tr.ready_wave == wi and tr.src in tids \
                                and tr.dst in tids:
                            on_device(tr.array, self._dev(tr.dst_slice))
            if self._multi:
                # final outputs land on device 0 (the PR-2 contract, kept
                # for every segment — a multi-consumer intermediate can
                # itself be a final output produced mid-program)
                outs = [jax.device_put(env[a], self._devices[0])
                        if a in self.out_names else env[a]
                        for a in seg.out_arrays]
            else:
                outs = [env[a] for a in seg.out_arrays]
            return tuple(outs)

        return body

    # -- pool-clone health (straggler rotation) ---------------------------
    def disable_clone(self, clone: int) -> bool:
        """Rotate a pool clone out of round-robin (persistently slow —
        see ``repro.ft.StragglerMonitor``).  Refuses to disable the last
        enabled clone; returns whether the clone is now disabled."""
        with self._counter_lock:
            if not 0 <= clone < self.pool_size:
                return False
            if len(self._disabled) >= self.pool_size - 1 \
                    and clone not in self._disabled:
                return False
            self._disabled.add(clone)
            return True

    def enable_clone(self, clone: int) -> None:
        with self._counter_lock:
            self._disabled.discard(clone)

    @property
    def disabled_clones(self) -> tuple[int, ...]:
        with self._counter_lock:
            return tuple(sorted(self._disabled))

    def _next_clone(self) -> int:
        with self._counter_lock:
            i = self._calls
            self._calls = i + 1
            if not self._disabled:
                return i % self.pool_size
            enabled = [c for c in range(self.pool_size)
                       if c not in self._disabled]
            return enabled[i % len(enabled)]

    # -- execution --------------------------------------------------------
    def run(self, inputs: dict[str, jax.Array]
            ) -> tuple[dict[str, jax.Array], int]:
        """Execute one request and report which pool clone served it —
        the serving layer's entry (clone-attributed timing feeds the
        straggler monitor)."""
        clone = self._next_clone()
        prof = _obs_profiler()
        if prof.enabled and prof.should_sample(self.graph.name):
            return self._run_profiled(inputs, self._pool[clone], prof), clone
        return self._run_on(inputs, self._pool[clone]), clone

    def _run_on(self, inputs: dict[str, jax.Array],
                fns: tuple[Callable, ...]) -> dict[str, jax.Array]:
        if self._single:
            seg = self.segments[0]
            outs = fns[0](*[inputs[a] for a in seg.in_arrays])
            return dict(zip(seg.out_arrays, outs))
        env = dict(inputs)
        for seg, fn in zip(self.segments, fns):
            res = fn(*[env[a] for a in seg.in_arrays])
            env.update(zip(seg.out_arrays, res))
        return {a: env[a] for a in self.out_names}

    def _run_profiled(self, inputs: dict[str, jax.Array],
                      fns: tuple[Callable, ...],
                      prof) -> dict[str, jax.Array]:
        """Sampled execution: segment-by-segment with a device sync after
        each, so host clocks bracket real work (``REPRO_OBS_SAMPLE``).
        The sync defeats async-dispatch pipelining, which is exactly why
        this path is sampled instead of always-on."""
        env = dict(inputs)
        for seg, fn in zip(self.segments, fns):
            seg_tids = set(seg.tids)
            t0 = time.perf_counter()
            res = fn(*[env[a] for a in seg.in_arrays])
            jax.block_until_ready(res)
            prof.record_segment(
                self.graph.name, self.impl, seg.index,
                time.perf_counter() - t0, n_tasks=len(seg.tids),
                waves=tuple(n for n in (
                    sum(1 for t in wave if t in seg_tids)
                    for wave in self.schedule.waves) if n))
            env.update(zip(seg.out_arrays, res))
        return {a: env[a] for a in self.out_names}

    def __call__(self, inputs: dict[str, jax.Array]) -> dict[str, jax.Array]:
        return self.run(inputs)[0]


# ---------------------------------------------------------------------------
# Process-wide bounded LRU program cache
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class CacheEntry:
    """One cached program plus its serving statistics."""

    program: PlanProgram
    hits: int = 0
    last_use: float = 0.0
    est_bytes: int = 0

    def stats(self) -> dict:
        return {"hits": self.hits, "last_use": self.last_use,
                "est_bytes": self.est_bytes,
                "pool_size": self.program.pool_size,
                "n_segments": self.program.n_segments,
                "calls": self.program.calls}


class ProgramCache:
    """Bounded LRU cache of compiled plan programs — thread-safe.

    Keys are (graph fingerprint, plan fingerprint, impl).  A ``get`` moves
    the entry to the MRU position; inserting beyond ``capacity`` evicts the
    LRU entry (its jitted executables die with it once callers drop their
    references).

    Every operation holds ``lock`` (an RLock): concurrent ``submit``
    threads used to race the OrderedDict mutation in get/put (move_to_end
    during iteration, double evictions, lost hit counts).  Compilation
    itself happens *outside* this lock — see :func:`compiled_program` —
    so a slow build never stalls unrelated hits.
    """

    def __init__(self, capacity: int = DEFAULT_CACHE_SIZE):
        self.lock = threading.RLock()
        self.capacity = max(1, capacity)
        self._entries: OrderedDict[tuple, CacheEntry] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        with self.lock:
            return len(self._entries)

    def __contains__(self, key: tuple) -> bool:
        with self.lock:
            return key in self._entries

    def keys(self) -> list[tuple]:
        """LRU -> MRU order (eviction order is the front of this list)."""
        with self.lock:
            return list(self._entries)

    def entry(self, key: tuple) -> CacheEntry | None:
        """Peek an entry without touching LRU order or hit counts."""
        with self.lock:
            return self._entries.get(key)

    def get(self, key: tuple) -> PlanProgram | None:
        """Hit path: O(1), no fingerprinting — serving engines resolve a
        precomputed key here on every request."""
        with self.lock:
            entry = self._entries.get(key)
            if entry is None:
                return None
            self._entries.move_to_end(key)
            entry.hits += 1
            entry.last_use = time.monotonic()
            self.hits += 1
            return entry.program

    def get_if(self, key: tuple, pool_size: int | None) -> PlanProgram | None:
        """Hit only when the cached program satisfies the caller's pool
        contract (``pool_size=None`` accepts any); a contract mismatch is
        not a hit — the caller will rebuild."""
        with self.lock:
            entry = self._entries.get(key)
            if entry is None or (pool_size is not None
                                 and entry.program.pool_size != pool_size):
                return None
            return self.get(key)

    def count_miss(self) -> None:
        with self.lock:
            self.misses += 1

    def put(self, key: tuple, program: PlanProgram) -> PlanProgram:
        with self.lock:
            self._entries[key] = CacheEntry(
                program=program, last_use=time.monotonic(),
                est_bytes=program.est_bytes())
            self._entries.move_to_end(key)
            while len(self._entries) > self.capacity:
                self._entries.popitem(last=False)
                self.evictions += 1
            return program

    def invalidate(self, key: tuple) -> bool:
        """Drop one entry (quarantine path: a program whose outputs failed
        canary validation must not be served again — the next resolve
        rebuilds from scratch).  Not counted as an eviction; returns
        whether the key was present."""
        with self.lock:
            return self._entries.pop(key, None) is not None

    def resize(self, capacity: int) -> None:
        with self.lock:
            self.capacity = max(1, capacity)
            while len(self._entries) > self.capacity:
                self._entries.popitem(last=False)
                self.evictions += 1

    def clear(self) -> None:
        with self.lock:
            self._entries.clear()
            self.hits = 0
            self.misses = 0
            self.evictions = 0

    def stats(self, detail: bool = False) -> dict:
        with self.lock:
            out = {
                "size": len(self._entries),
                "capacity": self.capacity,
                "hits": self.hits,
                "misses": self.misses,
                "evictions": self.evictions,
                "est_bytes": sum(e.est_bytes
                                 for e in self._entries.values()),
            }
            if detail:
                out["entries"] = {"/".join(k): e.stats()
                                  for k, e in self._entries.items()}
            return out


_CACHE = ProgramCache(_env_int("REPRO_PROGRAM_CACHE_SIZE",
                               DEFAULT_CACHE_SIZE))


def program_cache() -> ProgramCache:
    """The process-wide program cache (shared by solver measurement, the
    executors and every ``PlanEngine`` replica in this process)."""
    return _CACHE


def set_program_cache_size(capacity: int) -> None:
    """Re-bound the process-wide cache, evicting LRU overflow."""
    _CACHE.resize(capacity)


# Per-key build locks: concurrent misses for the SAME program compile once
# (the second thread blocks, then hits), while different keys build in
# parallel.  The registry itself is guarded and bounded; clearing it only
# risks one duplicate build per cleared key, never corruption.
_BUILD_LOCKS: dict[tuple, threading.Lock] = {}
_BUILD_LOCKS_GUARD = threading.Lock()
_BUILD_LOCKS_MAX = 1024


def _build_lock(key: tuple) -> threading.Lock:
    with _BUILD_LOCKS_GUARD:
        lock = _BUILD_LOCKS.get(key)
        if lock is None:
            if len(_BUILD_LOCKS) >= _BUILD_LOCKS_MAX:
                _BUILD_LOCKS.clear()
            lock = _BUILD_LOCKS.setdefault(key, threading.Lock())
        return lock


def compiled_program(graph: TaskGraph, plan: ExecutionPlan, impl: str,
                     fg: FusedGraph | None = None,
                     schedule: WaveSchedule | None = None,
                     pool_size: int | None = None) -> PlanProgram:
    """Cache lookup/build: same (graph, plan, impl) -> same PlanProgram.

    A hit re-uses the program's lowerings AND its ``jax.jit`` trace caches,
    so a repeated call with identical input shapes/dtypes re-lowers and
    re-traces nothing.  An explicit ``pool_size`` differing from the cached
    entry rebuilds it (the pool is part of the execution contract).

    Thread-safe: cache bookkeeping happens under the cache lock, the build
    under a per-key lock (N threads missing the same cold program compile
    it once; distinct programs still compile concurrently).
    """
    _auto_enable_persistent_cache()
    key = program_key(graph, plan, impl)
    prog = _CACHE.get_if(key, pool_size)
    if prog is not None:
        return prog
    with _build_lock(key):
        prog = _CACHE.get_if(key, pool_size)    # built while we waited?
        if prog is not None:
            return prog
        _CACHE.count_miss()
        built = PlanProgram(graph, plan, impl, fg=fg, schedule=schedule,
                            pool_size=pool_size)
        return _CACHE.put(key, built)


def cache_stats(detail: bool = False) -> dict:
    """Global program-cache statistics (one source of truth for the bench
    gate and ``PlanEngine.stats()``): size/capacity, hits/misses/evictions,
    estimated bytes, and per-entry detail on request."""
    return _CACHE.stats(detail=detail)


def clear_program_cache() -> None:
    _CACHE.clear()
