"""Whole-plan compiled programs: the entire fused DAG in ONE ``jax.jit``.

PR 1's executor walked the DAG in a Python loop — one ``jax.jit`` call per
task, blocking placement between slices — so independent tasks serialized on
the host dispatch path and every inter-task edge round-tripped through HBM.
Here the *whole* dataflow program is lowered into a single jitted callable:

* task bodies are inlined wave by wave (:mod:`repro.codegen.schedule`), so
  XLA sees every kernel at once, schedules same-wave tasks concurrently and
  elides host round-trips between producers and consumers;
* with several devices, each task's operands are committed to its slice's
  device with ``jax.device_put`` *inside* the traced program, and cross-slice
  edges are issued at the producer's wave (not the consumer's) so the
  transfer overlaps the next wave's compute;
* intermediate buffers are internal to the one XLA program — liveness-based
  reuse is the compiler's job here, while the per-task debug path donates
  dying buffers explicitly (see ``executor.py``).

Programs are cached process-wide, keyed by (graph fingerprint, plan
fingerprint, kernel impl); the input shapes/dtypes dimension of the key is
carried by ``jax.jit``'s own aval cache underneath, so a repeated call with
identical shapes re-traces nothing — that is what makes the serving path
(`repro.serve.PlanEngine`) zero-overhead after the first request.
"""
from __future__ import annotations

import hashlib

import jax

from ..core.fusion import FusedGraph, fuse
from ..core.plan import ExecutionPlan
from ..core.taskgraph import TaskGraph
from .lower import TaskLowering, lower_task
from .schedule import WaveSchedule, wave_schedule


# ---------------------------------------------------------------------------
# Fingerprints (cache keys)
# ---------------------------------------------------------------------------
def graph_fingerprint(graph: TaskGraph) -> str:
    """Stable content hash of a task graph (structure, shapes, semantics)."""
    items = (
        graph.name,
        tuple(sorted((a.name, a.shape, a.dtype_bytes, a.offchip)
                     for a in graph.arrays.values())),
        tuple(s.content_key() for s in graph.statements),
    )
    return hashlib.sha256(repr(items).encode()).hexdigest()[:16]


def plan_fingerprint(plan: ExecutionPlan) -> str:
    """Stable content hash of the plan decisions codegen consumes."""
    items = (plan.graph_name,
             tuple(sorted((tid, repr(cfg.to_jsonable()))
                          for tid, cfg in plan.configs.items())))
    return hashlib.sha256(repr(items).encode()).hexdigest()[:16]


# ---------------------------------------------------------------------------
# The compiled program
# ---------------------------------------------------------------------------
class PlanProgram:
    """One plan, one impl, ONE compiled program over the whole DAG."""

    def __init__(self, graph: TaskGraph, plan: ExecutionPlan, impl: str,
                 fg: FusedGraph | None = None,
                 schedule: WaveSchedule | None = None):
        self.graph = graph
        self.plan = plan
        self.impl = impl
        self.fg = fg if fg is not None else fuse(graph)
        self.schedule = schedule if schedule is not None \
            else wave_schedule(self.fg, plan)
        self.lowered: dict[int, TaskLowering] = {
            t.tid: lower_task(self.fg, t, plan.configs[t.tid], impl)
            for t in self.fg.tasks
        }
        self.in_names = tuple(graph.external_inputs())
        self.out_names = tuple(graph.final_outputs())
        # Task outputs feeding >= 2 consumer tasks are pinned behind an
        # optimization barrier: XLA CPU otherwise *clones* the producer
        # computation into every consumer fusion (observed on gemver — Ah
        # recomputed per consumer), turning the fusion win into a loss.
        consumers: dict[str, set[int]] = {}
        for (_, v, a) in self.fg.edges:
            consumers.setdefault(a, set()).add(v)
        self._materialize = frozenset(
            a for a, vs in consumers.items() if len(vs) >= 2)
        self._devices = tuple(jax.devices())
        self._multi = len(self._devices) > 1 and self.schedule.multi_slice
        self._traces = 0
        self._jit = jax.jit(self._body)

    # -- introspection ----------------------------------------------------
    @property
    def trace_count(self) -> int:
        """How many times the program body has been (re-)traced."""
        return self._traces

    def _dev(self, slice_id: int) -> int:
        return slice_id % len(self._devices)

    # -- traced body ------------------------------------------------------
    def _body(self, *flat: jax.Array):
        self._traces += 1
        env: dict[str, jax.Array] = dict(zip(self.in_names, flat))
        placed: dict[tuple[str, int], jax.Array] = {}

        def on_device(array: str, d: int) -> jax.Array:
            key = (array, d)
            if key not in placed:
                placed[key] = jax.device_put(env[array], self._devices[d])
            return placed[key]

        for wi, wave in enumerate(self.schedule.waves):
            for tid in wave:
                lw = self.lowered[tid]
                if self._multi:
                    d = self._dev(self.schedule.slice_of[tid])
                    args = [on_device(a, d) for a in lw.in_arrays]
                else:
                    args = [env[a] for a in lw.in_arrays]
                out = lw.body(*args)
                if lw.out_array in self._materialize:
                    out = jax.lax.optimization_barrier(out)
                if self._multi:
                    # the array has a new version: stale placements die
                    for key in [k for k in placed if k[0] == lw.out_array]:
                        del placed[key]
                env[lw.out_array] = out
            if self._multi:
                # Overlap-aware dispatch: cross-slice edges are issued the
                # moment their producing wave is emitted, so the transfer
                # rides under wave wi+1's compute instead of stalling the
                # consumer at use time.
                for tr in self.schedule.transfers:
                    if tr.ready_wave == wi:
                        on_device(tr.array, self._dev(tr.dst_slice))
        outs = [env[a] for a in self.out_names]
        if self._multi:
            outs = [jax.device_put(v, self._devices[0]) for v in outs]
        return tuple(outs)

    # -- execution --------------------------------------------------------
    def __call__(self, inputs: dict[str, jax.Array]) -> dict[str, jax.Array]:
        outs = self._jit(*[inputs[a] for a in self.in_names])
        return dict(zip(self.out_names, outs))


# ---------------------------------------------------------------------------
# Process-wide program cache
# ---------------------------------------------------------------------------
_CACHE: dict[tuple[str, str, str], PlanProgram] = {}
_HITS = 0
_MISSES = 0


def compiled_program(graph: TaskGraph, plan: ExecutionPlan, impl: str,
                     fg: FusedGraph | None = None,
                     schedule: WaveSchedule | None = None) -> PlanProgram:
    """Cache lookup/build: same (graph, plan, impl) -> same PlanProgram.

    A hit re-uses the program's lowerings AND its ``jax.jit`` trace cache, so
    a repeated call with identical input shapes/dtypes re-lowers and
    re-traces nothing.
    """
    global _HITS, _MISSES
    key = (graph_fingerprint(graph), plan_fingerprint(plan), impl)
    prog = _CACHE.get(key)
    if prog is not None:
        _HITS += 1
        return prog
    _MISSES += 1
    prog = PlanProgram(graph, plan, impl, fg=fg, schedule=schedule)
    _CACHE[key] = prog
    return prog


def cache_stats() -> dict:
    return {"hits": _HITS, "misses": _MISSES, "size": len(_CACHE)}


def clear_program_cache() -> None:
    global _HITS, _MISSES
    _CACHE.clear()
    _HITS = 0
    _MISSES = 0
