"""Code generation subsystem: ExecutionPlan -> plan-faithful executables.

The paper (§5) emits HLS-C++ + OpenCL host code from the NLP solution; here
the same lowering targets JAX/Pallas:

* ``lower.py``      per-fused-task lowering: statements -> ContractionSpecs
                    (grid = plan permutation, blocks = plan tiles, fused
                    init+accumulate, buffering semantics), one raw traceable
                    body per task;
* ``schedule.py``   wave schedule: topological levels x slice assignment,
                    cross-slice transfer timing, buffer liveness/donation;
* ``program.py``    whole-plan engine: the entire fused DAG in ONE
                    ``jax.jit`` program per impl, with a process-wide cache
                    keyed by (graph fingerprint, plan fingerprint, impl);
* ``executor.py``   ``PlanExecutable``: program mode (default, fused) and
                    per-task mode (debug/validation, overlap- and
                    donation-aware host dispatch);
* ``reference.py``  naive statement-order einsum oracle for bit-level
                    validation (run the executable under
                    ``kernel_impl("pallas_interpret")`` to validate the
                    actual kernel bodies against it).

``repro.core.apply`` remains as a deprecation shim over this package.
"""
from .executor import PlanExecutable, plan_executor
from .lower import LoweredUnit, TaskLowering, lower_task
from .program import (PlanProgram, ProgramCache, cache_stats,
                      clear_program_cache, compiled_program,
                      enable_persistent_cache, graph_fingerprint,
                      persistent_cache_dir, plan_fingerprint, program_cache,
                      program_key, set_program_cache_size)
from .reference import (OPAQUE_PREFIX, allclose, assert_close,
                        eval_statement, opaque_fn, random_inputs,
                        reference_executor, register_opaque,
                        unregister_opaque)
from .schedule import Transfer, WaveSchedule, wave_schedule

__all__ = [
    "PlanExecutable", "plan_executor",
    "LoweredUnit", "TaskLowering", "lower_task",
    "PlanProgram", "ProgramCache", "compiled_program", "cache_stats",
    "clear_program_cache", "graph_fingerprint", "plan_fingerprint",
    "program_cache", "program_key", "set_program_cache_size",
    "enable_persistent_cache", "persistent_cache_dir",
    "Transfer", "WaveSchedule", "wave_schedule",
    "allclose", "assert_close", "eval_statement",
    "random_inputs", "reference_executor",
    "OPAQUE_PREFIX", "opaque_fn", "register_opaque", "unregister_opaque",
]
