"""Code generation subsystem: ExecutionPlan -> plan-faithful executables.

The paper (§5) emits HLS-C++ + OpenCL host code from the NLP solution; here
the same lowering targets JAX/Pallas:

* ``lower.py``      per-fused-task lowering: statements -> ContractionSpecs
                    (grid = plan permutation, blocks = plan tiles, fused
                    init+accumulate, buffering semantics), one jitted
                    callable per task;
* ``executor.py``   dataflow executor: topo order + slice-aware dispatch
                    (shared-buffer handoff vs device transfer);
* ``reference.py``  naive statement-order einsum oracle for bit-level
                    validation (run the executable under
                    ``kernel_impl("pallas_interpret")`` to validate the
                    actual kernel bodies against it).

``repro.core.apply`` remains as a deprecation shim over this package.
"""
from .executor import PlanExecutable, plan_executor
from .lower import LoweredUnit, TaskLowering, lower_task
from .reference import (allclose, assert_close, eval_statement,
                        random_inputs, reference_executor)

__all__ = [
    "PlanExecutable", "plan_executor",
    "LoweredUnit", "TaskLowering", "lower_task",
    "allclose", "assert_close", "eval_statement",
    "random_inputs", "reference_executor",
]
