"""Fault-tolerance supervisor: checkpoint-restart with failure injection.

``run_with_restarts`` drives a step function under a restart budget: any
exception (injected or real — preemption, XLA device loss) rolls the run
back to the newest committed checkpoint and replays.  The data pipeline is
deterministic per step, so replayed steps reproduce the identical stream.
This is the single-process skeleton of the multi-pod supervisor: at scale
the same state machine runs per-host with a coordinator election.
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Callable

log = logging.getLogger("repro.ft")


class InjectedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class FailurePlan:
    """Deterministic failure injection for tests: fail at these steps
    (each fires once)."""
    at_steps: tuple[int, ...] = ()

    def __post_init__(self):
        self._pending = set(self.at_steps)

    def maybe_fail(self, step: int) -> None:
        if step in self._pending:
            self._pending.discard(step)
            raise InjectedFailure(f"injected failure at step {step}")


@dataclasses.dataclass
class RestartStats:
    restarts: int = 0
    replayed_steps: int = 0
    failures: list = dataclasses.field(default_factory=list)


def run_with_restarts(*, total_steps: int, state, step_fn: Callable,
                      save_fn: Callable, restore_fn: Callable,
                      checkpoint_every: int, max_restarts: int = 5,
                      failure_plan: FailurePlan | None = None
                      ) -> tuple[object, RestartStats]:
    """Generic restartable loop.

    step_fn(state, step) -> state      (raises on failure)
    save_fn(state, step) -> None
    restore_fn() -> (state, step) | (None, None)
    """
    stats = RestartStats()
    step = 0
    restored, rstep = restore_fn()
    if restored is not None:
        state, step = restored, rstep + 1
    while step < total_steps:
        try:
            if failure_plan is not None:
                failure_plan.maybe_fail(step)
            state = step_fn(state, step)
            if (step + 1) % checkpoint_every == 0 or step + 1 == total_steps:
                save_fn(state, step)
            step += 1
        except Exception as exc:      # noqa: BLE001 — restart on anything
            stats.restarts += 1
            stats.failures.append((step, repr(exc)))
            log.warning("step %d failed (%s); restart %d/%d",
                        step, exc, stats.restarts, max_restarts)
            if stats.restarts > max_restarts:
                raise
            restored, rstep = restore_fn()
            if restored is None:
                stats.replayed_steps += step
                step = 0
            else:
                state = restored
                stats.replayed_steps += step - (rstep + 1)
                step = rstep + 1
    return state, stats
