"""Straggler detection & mitigation policy.

At multi-pod scale individual hosts intermittently run slow (thermal
throttling, network incast, background daemons).  The monitor keeps an EMA
of per-host step times; a host exceeding ``threshold x EMA`` for
``patience`` consecutive steps is flagged.  Mitigation = reassign its data
shard across the remaining hosts (the synchronous-SGD-safe mitigation:
identical math, smaller stragglers' share) and optionally trigger an
elastic rescale if the host stays degraded.

Single-host container: exercised in tests by feeding synthetic timing
traces; the launcher threads per-host timings through ``observe``.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class StragglerConfig:
    threshold: float = 1.8       # x EMA to flag
    patience: int = 3            # consecutive slow steps before action
    ema: float = 0.9
    min_steps: int = 5           # warmup before flagging


class StragglerMonitor:
    def __init__(self, n_hosts: int, cfg: StragglerConfig | None = None):
        self.cfg = cfg or StragglerConfig()
        self.n_hosts = n_hosts
        self._ema: list[float | None] = [None] * n_hosts
        self._slow_streak = [0] * n_hosts
        self._steps = 0
        self.reassigned: set[int] = set()

    def observe(self, host_times: list[float]) -> list[int]:
        """Feed one step's per-host wall times; returns hosts to demote."""
        assert len(host_times) == self.n_hosts
        self._steps += 1
        fleet = sorted(t for i, t in enumerate(host_times)
                       if i not in self.reassigned)
        median = fleet[len(fleet) // 2] if fleet else 0.0
        flagged = []
        for i, t in enumerate(host_times):
            if i in self.reassigned:
                continue
            prev = self._ema[i]
            self._ema[i] = t if prev is None else \
                self.cfg.ema * prev + (1 - self.cfg.ema) * t
            if self._steps > self.cfg.min_steps \
                    and t > self.cfg.threshold * max(median, 1e-9):
                self._slow_streak[i] += 1
            else:
                self._slow_streak[i] = 0
            if self._slow_streak[i] >= self.cfg.patience:
                flagged.append(i)
        return flagged

    def observe_one(self, host: int, t: float) -> bool:
        """Single-host observation — the serving-side entry point, where
        each request lands on ONE executable-pool clone ("host") and only
        that clone's wall time is known.

        EMA/streak update for ``host`` alone, flagged against the median
        EMA of its *peers* (other live clones) rather than the whole-fleet
        step median ``observe`` uses — with one sample per step there is
        no fleet snapshot, and excluding the observed clone keeps a
        2-clone pool flaggable (its own slow EMA cannot drag the median
        up to hide it).  Returns True once the clone crosses the patience
        bar; the caller rotates it out (``PlanProgram.disable_clone``).
        """
        if host in self.reassigned:
            return False
        self._steps += 1
        prev = self._ema[host]
        self._ema[host] = t if prev is None else \
            self.cfg.ema * prev + (1 - self.cfg.ema) * t
        peers = sorted(e for i, e in enumerate(self._ema)
                       if e is not None and i != host
                       and i not in self.reassigned)
        if not peers or self._steps <= self.cfg.min_steps:
            self._slow_streak[host] = 0
            return False
        median = peers[len(peers) // 2]
        if t > self.cfg.threshold * max(median, 1e-9):
            self._slow_streak[host] += 1
        else:
            self._slow_streak[host] = 0
        return self._slow_streak[host] >= self.cfg.patience

    def demote(self, host: int) -> dict[int, float]:
        """Remove a host from the data assignment; returns the new shard
        fractions per remaining host."""
        self.reassigned.add(host)
        alive = [i for i in range(self.n_hosts) if i not in self.reassigned]
        if not alive:
            raise RuntimeError("all hosts demoted")
        share = 1.0 / len(alive)
        return {i: share for i in alive}
