"""Serve-side fault tolerance: chaos injection, breakers, backoff.

The training-side skeleton (``repro.ft.supervisor``) restarts a step loop
from checkpoints; the *serving* layer needs a different contract — a
request must be answered now, correctly, even while the optimized path is
broken.  This module holds the pieces :class:`repro.serve.PlanEngine`
threads through its request path:

* :class:`ChaosPlan` — deterministic serve-side failure injection (the
  ``FailurePlan`` idea extended to the request path): compile failures,
  kernel-output corruption ("miscompiles"), slow executions pinned to a
  pool clone, whole-batch failures in the continuous-batching tier, and
  corrupted persistent artifacts.  Every degradation path in the engine is
  exercised by tests and ``benchmarks/bench_chaos.py`` through this one
  object, so chaos runs are reproducible bit-for-bit.

The batching front door (``repro.serve.batching``) sits *above* this
contract: a coalesced batch that fails — injected via ``batch_fail_at``,
or poisoned by one request's data — is re-submitted **per request**
through ``PlanEngine.submit``, so each batchmate passes through its own
breaker/fallback path and one poisoned request can never fail the others.
* :class:`CircuitBreaker` — per-entry closed → open → half-open state
  machine.  Consecutive optimized-path failures open the breaker
  (quarantine); after ``reset_s`` one probe request is allowed through
  (half-open); a success closes it again.  The clock is injectable so
  transition tests are deterministic.
* :class:`BackoffPolicy` — the deterministic exponential schedule the
  background re-solve loop sleeps on between recovery attempts.
* The serving **error taxonomy**: admission rejections
  (:class:`EngineOverloaded`), deadline rejections
  (:class:`DeadlineExceeded`) and canary-detected miscompiles
  (:class:`MiscompileError`), all rooted at :class:`ServingError` so
  callers can distinguish "the engine said no" from a workload bug.
"""
from __future__ import annotations

import dataclasses
import enum
import logging
import os
import threading
import time
from typing import Callable

from .supervisor import InjectedFailure

log = logging.getLogger("repro.ft.serve")


# ---------------------------------------------------------------------------
# Error taxonomy
# ---------------------------------------------------------------------------
class ServingError(RuntimeError):
    """Root of engine-originated request failures (vs workload bugs)."""


class EngineOverloaded(ServingError):
    """Admission control rejected the request: the bounded in-flight depth
    stayed full past the admission timeout (backpressure)."""


class DeadlineExceeded(ServingError):
    """The request's deadline budget expired before it was admitted."""


class MiscompileError(ServingError):
    """Canary validation caught the optimized path producing wrong values
    (corrupted kernel output / NaN / inf) — the entry is quarantined."""


# ---------------------------------------------------------------------------
# Deterministic serve-side chaos injection
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class ChaosPlan:
    """Deterministic fault injection for the serving request path.

    Sites are counted **per entry name** (every engine hook passes the
    entry's name), and each configured index fires exactly once — the same
    determinism contract as ``repro.ft.FailurePlan``:

    * ``compile_fail_at`` — the i-th program resolution for an entry
      raises :class:`InjectedFailure` (an XLA compile error stand-in);
    * ``execute_fail_at`` — the i-th execution raises before dispatch
      (device loss / runtime error stand-in);
    * ``corrupt_at`` — the i-th execution's outputs are silently replaced
      with garbage (NaN) *after* the kernel ran — a miscompile the engine
      can only catch with canary validation / NaN guards;
    * ``slow_at`` — the i-th execution sleeps ``slow_s`` seconds (a
      degraded kernel / thermal throttle stand-in); ``slow_clone`` instead
      pins the delay to one executable-pool clone index, whatever the
      request index (the straggler-rotation scenario);
    * ``batch_fail_at`` — the i-th coalesced batch for an entry raises
      before the batched program is submitted, forcing the batcher's
      per-request fallback path (every batchmate re-submitted alone
      through its own breaker);
    * ``refresh_fail_at`` — the i-th background stale-plan refresh
      attempt for an entry raises before re-solving (a solver/store
      failure stand-in), exercising the refresh loop's backoff while the
      stale plan keeps serving.

    ``only`` restricts injection to one entry name so multi-entry engines
    can break a single workload.  ``events`` records every injection as
    ``(site, name, index)`` for test/bench introspection.
    """

    compile_fail_at: tuple[int, ...] = ()
    execute_fail_at: tuple[int, ...] = ()
    corrupt_at: tuple[int, ...] = ()
    slow_at: tuple[int, ...] = ()
    batch_fail_at: tuple[int, ...] = ()
    refresh_fail_at: tuple[int, ...] = ()
    slow_s: float = 0.0
    slow_clone: int | None = None
    only: str | None = None

    def __post_init__(self):
        self._lock = threading.Lock()
        self._counts: dict[tuple[str, str], int] = {}
        self._pending = {
            "compile": set(self.compile_fail_at),
            "execute": set(self.execute_fail_at),
            "corrupt": set(self.corrupt_at),
            "slow": set(self.slow_at),
            "batch": set(self.batch_fail_at),
            "refresh": set(self.refresh_fail_at),
        }
        self.events: list[tuple[str, str, int]] = []

    def _fires(self, site: str, name: str) -> bool:
        if self.only is not None and name != self.only:
            return False
        with self._lock:
            idx = self._counts.get((site, name), 0)
            self._counts[(site, name)] = idx + 1
            if idx in self._pending[site]:
                self._pending[site].discard(idx)
                self.events.append((site, name, idx))
                return True
        return False

    # -- engine hooks -----------------------------------------------------
    def on_compile(self, name: str) -> None:
        """Hook before program resolution; raises on an injected compile
        failure."""
        if self._fires("compile", name):
            raise InjectedFailure(f"injected compile failure for {name!r}")

    def on_execute(self, name: str) -> None:
        """Hook before program execution; raises on an injected runtime
        failure."""
        if self._fires("execute", name):
            raise InjectedFailure(f"injected execute failure for {name!r}")

    def on_batch(self, name: str) -> None:
        """Hook before a coalesced batch is submitted (the continuous-
        batching tier passes the *batched* entry name, e.g. ``mlp@b4``);
        raises on an injected batch failure — the batcher must then
        re-submit every batchmate individually through its own breaker."""
        if self._fires("batch", name):
            raise InjectedFailure(f"injected batch failure for {name!r}")

    def on_refresh(self, name: str) -> None:
        """Hook before a background stale-plan refresh attempt re-solves;
        raises on an injected refresh failure — the engine must keep
        serving the stale plan and retry with backoff."""
        if self._fires("refresh", name):
            raise InjectedFailure(
                f"injected plan-refresh failure for {name!r}")

    def corrupt_outputs(self, name: str, outputs: dict) -> dict:
        """Hook after execution: on an injected miscompile, return the
        output dict with every value poisoned to NaN (same shapes/dtypes,
        so only value validation can catch it)."""
        if not self._fires("corrupt", name):
            return outputs
        import jax.numpy as jnp
        return {k: jnp.full_like(v, float("nan")) if jnp.issubdtype(
                    v.dtype, jnp.floating) else v
                for k, v in outputs.items()}

    def execute_delay(self, name: str, clone: int | None = None) -> float:
        """Seconds of injected slowness for this execution (0.0 = none)."""
        if self.slow_clone is not None and clone == self.slow_clone \
                and (self.only is None or name == self.only):
            with self._lock:
                self.events.append(("slow_clone", name, clone))
            return self.slow_s
        if self._fires("slow", name):
            return self.slow_s
        return 0.0

    # -- persistent-artifact corruption -----------------------------------
    @staticmethod
    def corrupt_file(path: str, mode: str = "garbage") -> str:
        """Corrupt a persistent artifact on disk (calibration profile,
        compilation-cache entry, metadata file): ``garbage`` overwrites
        with non-JSON bytes that keep the old length, ``truncate`` leaves
        a zero-byte file — the two corruption shapes crash recovery has to
        survive."""
        if mode == "truncate":
            with open(path, "wb"):
                pass
        else:
            try:
                size = max(os.path.getsize(path), 16)
            except OSError:
                size = 16
            with open(path, "wb") as f:
                f.write(b"\x00CORRUPT" * (size // 8 + 1))
        return path


# ---------------------------------------------------------------------------
# Circuit breaker (per served entry)
# ---------------------------------------------------------------------------
class BreakerState(enum.Enum):
    CLOSED = "closed"          # healthy: optimized path serves
    OPEN = "open"              # quarantined: every request falls back
    HALF_OPEN = "half_open"    # probing: one request tries the plan again


class CircuitBreaker:
    """Consecutive-failure breaker guarding one entry's optimized path.

    ``threshold`` consecutive failures open it; after ``reset_s`` the next
    :meth:`allow` transitions to half-open and admits exactly one probe
    (others fall back until the probe reports).  ``record_success`` closes
    from any state; ``record_failure`` re-opens.  ``clock`` is injectable
    for deterministic transition tests.  Thread-safe.
    """

    def __init__(self, threshold: int = 3, reset_s: float = 30.0,
                 clock: Callable[[], float] = time.monotonic,
                 on_transition: Callable[[str], None] | None = None):
        self.threshold = max(1, threshold)
        self.reset_s = reset_s
        self.clock = clock
        self._lock = threading.Lock()
        self._state = BreakerState.CLOSED
        self._failures = 0          # consecutive
        self._opened_at = 0.0
        self._probing = False
        self.transitions: list[str] = []
        # observability seam: the engine hangs a metrics-registry counter
        # here (repro_breaker_transitions_total{entry,state}) so breaker
        # flips are scrapeable, not only visible in stats()
        self._on_transition = on_transition

    @property
    def state(self) -> BreakerState:
        with self._lock:
            return self._state

    def _set(self, state: BreakerState) -> None:
        if state != self._state:
            self._state = state
            self.transitions.append(state.value)
            if self._on_transition is not None:
                self._on_transition(state.value)

    def allow(self) -> bool:
        """May this request try the optimized path?"""
        with self._lock:
            if self._state is BreakerState.CLOSED:
                return True
            if self._state is BreakerState.OPEN:
                if self.clock() - self._opened_at < self.reset_s:
                    return False
                self._set(BreakerState.HALF_OPEN)
                self._probing = True
                return True
            # HALF_OPEN: exactly one in-flight probe
            if self._probing:
                return False
            self._probing = True
            return True

    def record_success(self) -> None:
        with self._lock:
            self._failures = 0
            self._probing = False
            self._set(BreakerState.CLOSED)

    def record_failure(self) -> bool:
        """Returns True when this failure (re-)opened the breaker."""
        with self._lock:
            was_open = self._state is BreakerState.OPEN
            self._failures += 1
            self._probing = False
            if self._state is BreakerState.HALF_OPEN \
                    or self._failures >= self.threshold:
                self._set(BreakerState.OPEN)
                self._opened_at = self.clock()
                return not was_open
            return False

    def force_open(self) -> None:
        """Quarantine immediately (registration-time failures)."""
        with self._lock:
            self._failures = max(self._failures, self.threshold)
            self._set(BreakerState.OPEN)
            self._opened_at = self.clock()

    def stats(self) -> dict:
        with self._lock:
            return {"state": self._state.value,
                    "consecutive_failures": self._failures,
                    "transitions": list(self.transitions)}


# ---------------------------------------------------------------------------
# Backoff schedule (background re-solve)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class BackoffPolicy:
    """Deterministic exponential backoff: ``base_s * mult**i`` capped at
    ``max_s``, for ``retries`` attempts.  Pure — the schedule is a
    function of the policy alone, so recovery timing is testable."""

    base_s: float = 0.05
    mult: float = 2.0
    max_s: float = 5.0
    retries: int = 8

    def delays(self) -> list[float]:
        return [min(self.base_s * self.mult ** i, self.max_s)
                for i in range(self.retries)]
