from .straggler import StragglerConfig, StragglerMonitor
from .supervisor import (FailurePlan, InjectedFailure, RestartStats,
                         run_with_restarts)

__all__ = ["StragglerConfig", "StragglerMonitor", "FailurePlan",
           "InjectedFailure", "RestartStats", "run_with_restarts"]
