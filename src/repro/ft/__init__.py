"""Fault tolerance: training-side restarts + serve-side degradation.

``supervisor``/``straggler`` are the training-loop skeleton (checkpoint
restart, per-host straggler demotion); ``serve`` carries the request-path
contract (chaos injection, circuit breakers, backoff, the serving error
taxonomy) that ``repro.serve.PlanEngine`` threads through every submit;
``artifacts`` validates the persistent files both sides trust at startup.
"""
from .artifacts import (ArtifactError, atomic_write_json, load_json,
                        payload_checksum, quarantine_file, scrub_cache_dir)
from .serve import (BackoffPolicy, BreakerState, ChaosPlan, CircuitBreaker,
                    DeadlineExceeded, EngineOverloaded, MiscompileError,
                    ServingError)
from .straggler import StragglerConfig, StragglerMonitor
from .supervisor import (FailurePlan, InjectedFailure, RestartStats,
                         run_with_restarts)

__all__ = [
    "StragglerConfig", "StragglerMonitor", "FailurePlan",
    "InjectedFailure", "RestartStats", "run_with_restarts",
    "ChaosPlan", "CircuitBreaker", "BreakerState", "BackoffPolicy",
    "ServingError", "EngineOverloaded", "DeadlineExceeded",
    "MiscompileError",
    "ArtifactError", "atomic_write_json", "load_json", "payload_checksum",
    "quarantine_file", "scrub_cache_dir",
]
