"""Pallas TPU kernels for the perf-critical compute hot spots.

Each kernel package has kernel.py (pl.pallas_call + BlockSpec), ops.py
(jit'd public wrapper with padding + dispatch) and ref.py (pure-jnp oracle).
Validated in interpret mode on CPU; compiled via Mosaic on TPU.
"""
from . import dispatch
from .dispatch import kernel_impl, current_impl
from .matmul import matmul
from .contraction import ContractionSpec, LoopDim, Operand, contract
from .flash_attention import flash_attention
from .rglru import rglru
from .rwkv6 import rwkv6
from .quant import quantize, dequantize

__all__ = [
    "dispatch", "kernel_impl", "current_impl",
    "matmul", "ContractionSpec", "LoopDim", "Operand", "contract",
    "flash_attention", "rglru", "rwkv6",
    "quantize", "dequantize",
]
