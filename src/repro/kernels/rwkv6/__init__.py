from .ops import rwkv6
from . import kernel, ops, ref

__all__ = ["rwkv6", "kernel", "ops", "ref"]
