"""Pure-jnp oracle for the WKV6 recurrence.

Sequence-chunked with a rematerialised (checkpointed) inner scan: the
backward pass stores only chunk-boundary states ((BH, dk, dv) every
``chunk`` steps) instead of every per-step state — without this, training
rwkv6-1.6b at 4k context materialises TBs of per-step (dk, dv) states
(observed: 1.66 TB/chip temp in the dry-run memory analysis).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def _chunk_scan(state, rkvw, u):
    """Scan one chunk; returns (final state, ys)."""
    def step(st, x):
        r_t, k_t, v_t, w_t = x
        kv = k_t[:, :, None] * v_t[:, None, :]            # (BH, dk, dv)
        y = jnp.einsum("bk,bkv->bv", r_t, st + u[:, :, None] * kv)
        return w_t[:, :, None] * st + kv, y

    return jax.lax.scan(step, state, rkvw)


def rwkv6(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
          u: jax.Array, return_state: bool = False, chunk: int = 128):
    """r,k,w (BH,S,dk), v (BH,S,dv), u (BH,dk) -> y (BH,S,dv)
    (+ final state (BH,dk,dv) when ``return_state``)."""
    bh, s, dk = r.shape
    dv = v.shape[-1]
    args = tuple(jnp.swapaxes(x.astype(jnp.float32), 0, 1)
                 for x in (r, k, v, w))
    state0 = jnp.zeros((bh, dk, dv), jnp.float32)
    u32 = u.astype(jnp.float32)
    body = jax.checkpoint(functools.partial(_chunk_scan, u=u32))

    c = min(chunk, s)
    if s % c:            # irregular length: single checkpointed scan
        state, ys = body(state0, args)
        y = jnp.swapaxes(ys, 0, 1).astype(r.dtype)
        return (y, state) if return_state else y

    n = s // c
    chunked = tuple(x.reshape((n, c) + x.shape[1:]) for x in args)
    state, ys = jax.lax.scan(body, state0, chunked)
    ys = ys.reshape((s,) + ys.shape[2:])
    y = jnp.swapaxes(ys, 0, 1).astype(r.dtype)
    if return_state:
        return y, state
    return y
