"""Public wrapper for the WKV6 kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import dispatch
from . import kernel, ref


def rwkv6(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
          u: jax.Array, *, bs: int = 128, impl: str | None = None,
          return_state: bool = False):
    impl = impl or dispatch.current_impl()
    if impl == "xla":
        return ref.rwkv6(r, k, v, w, u, return_state=return_state)
    bh, s, dk = r.shape
    bs_ = min(bs, s)
    pad = (-s) % bs_
    if pad:
        pad_spec = ((0, 0), (0, pad), (0, 0))
        r = jnp.pad(r, pad_spec)
        k = jnp.pad(k, pad_spec)
        v = jnp.pad(v, pad_spec)
        # padded steps must leave the state unchanged: w = 1, k = 0
        w = jnp.pad(w, pad_spec, constant_values=1.0)
    out, state = kernel.rwkv6(r, k, v, w, u, bs=bs_,
                              interpret=(impl == "pallas_interpret"))
    out = out[:, :s]
    if return_state:
        return out, state
    return out
