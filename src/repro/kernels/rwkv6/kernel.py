"""RWKV-6 (Finch) WKV recurrence Pallas kernel — data-dependent decay.

Per (batch, head) the recurrent state is a (dk, dv) matrix:

    y_t = r_t . (S_{t-1} + (u * k_t) v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T

with per-channel data-dependent decay w_t in (0,1) (Finch's contribution vs
RWKV-5's static decay) and a per-head bonus u for the current token.  The
state matrix lives in a VMEM scratch carried across sequence blocks (grid is
sequential over the S dimension).

Layouts: r,k,w (BH, S, dk), v (BH, S, dv), u (BH, dk) -> y (BH, S, dv).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rwkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, st_ref, s_ref, *,
                  bs: int, n_blocks: int):
    @pl.when(pl.program_id(1) == 0)
    def _reset():
        s_ref[...] = jnp.zeros_like(s_ref)

    def body(t, state):
        r_t = r_ref[0, t, :].astype(jnp.float32)      # (dk,)
        k_t = k_ref[0, t, :].astype(jnp.float32)      # (dk,)
        v_t = v_ref[0, t, :].astype(jnp.float32)      # (dv,)
        w_t = w_ref[0, t, :].astype(jnp.float32)      # (dk,)
        u = u_ref[0, :].astype(jnp.float32)           # (dk,)
        kv = k_t[:, None] * v_t[None, :]              # (dk, dv)
        y = jnp.sum((state + u[:, None] * kv) * r_t[:, None], axis=0)
        o_ref[0, t, :] = y.astype(o_ref.dtype)
        return w_t[:, None] * state + kv

    state = jax.lax.fori_loop(0, bs, body, s_ref[...])
    s_ref[...] = state

    @pl.when(pl.program_id(1) == n_blocks - 1)
    def _emit_state():
        st_ref[0] = state


@functools.partial(jax.jit, static_argnames=("bs", "interpret"))
def rwkv6(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
          u: jax.Array, *, bs: int = 128,
          interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """Returns (y (BH,S,dv), final state (BH,dk,dv))."""
    bh, s, dk = r.shape
    dv = v.shape[-1]
    assert s % bs == 0, (s, bs)
    n_blocks = s // bs
    return pl.pallas_call(
        functools.partial(_rwkv6_kernel, bs=bs, n_blocks=n_blocks),
        grid=(bh, n_blocks),
        in_specs=[
            pl.BlockSpec((1, bs, dk), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, bs, dk), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, bs, dv), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, bs, dk), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, dk), lambda i, j: (i, 0)),
        ],
        out_specs=(pl.BlockSpec((1, bs, dv), lambda i, j: (i, j, 0)),
                   pl.BlockSpec((1, dk, dv), lambda i, j: (i, 0, 0))),
        out_shape=(jax.ShapeDtypeStruct((bh, s, dv), r.dtype),
                   jax.ShapeDtypeStruct((bh, dk, dv), jnp.float32)),
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u)
