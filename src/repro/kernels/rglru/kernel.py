"""RG-LRU linear recurrence Pallas kernel (RecurrentGemma / Griffin).

    h_t = a_t * h_{t-1} + u_t

with data-dependent decay a_t in (0,1) and pre-gated input u_t (the wrapper
computes a_t = exp(c * softplus(Lambda) * sigmoid(r_t)) terms; the kernel is
the sequential hot loop).  The sequence dimension is blocked; the TPU grid
executes sequence blocks in order, so the hidden state lives in a VMEM
scratch that persists across grid steps — the paper's "reuse buffer defined
above the inter-tile loop" (d_{a,0}) realised as carried state.

Layouts: a, u (B, S, D) -> h (B, S, D); grid (B, S/bs).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(a_ref, u_ref, o_ref, h_ref, *, bs: int):
    @pl.when(pl.program_id(1) == 0)
    def _reset():
        h_ref[...] = jnp.zeros_like(h_ref)

    def body(r, h):
        a = a_ref[0, r, :].astype(jnp.float32)
        u = u_ref[0, r, :].astype(jnp.float32)
        h = a * h + u
        o_ref[0, r, :] = h.astype(o_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, bs, body, h_ref[0])
    h_ref[0] = h


@functools.partial(jax.jit, static_argnames=("bs", "interpret"))
def rglru(a: jax.Array, u: jax.Array, *, bs: int = 256,
          interpret: bool = False) -> jax.Array:
    b, s, d = a.shape
    assert s % bs == 0, (s, bs)
    return pl.pallas_call(
        functools.partial(_rglru_kernel, bs=bs),
        grid=(b, s // bs),
        in_specs=[
            pl.BlockSpec((1, bs, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, bs, d), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bs, d), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, d), a.dtype),
        scratch_shapes=[pltpu.VMEM((1, d), jnp.float32)],
        interpret=interpret,
    )(a, u)
