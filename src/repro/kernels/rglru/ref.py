"""Pure-jnp oracle for the RG-LRU recurrence (lax.scan over time)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru(a: jax.Array, u: jax.Array) -> jax.Array:
    """h_t = a_t * h_{t-1} + u_t; a, u (B, S, D)."""
    def step(h, au):
        a_t, u_t = au
        h = a_t * h + u_t
        return h, h

    a32 = a.astype(jnp.float32)
    u32 = u.astype(jnp.float32)
    h0 = jnp.zeros((a.shape[0], a.shape[2]), jnp.float32)
    _, hs = jax.lax.scan(step, h0,
                         (jnp.swapaxes(a32, 0, 1), jnp.swapaxes(u32, 0, 1)))
    return jnp.swapaxes(hs, 0, 1).astype(a.dtype)
