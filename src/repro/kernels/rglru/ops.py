"""Public wrapper for the RG-LRU recurrence kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import dispatch
from . import kernel, ref


def rglru(a: jax.Array, u: jax.Array, *, bs: int = 256,
          impl: str | None = None) -> jax.Array:
    """h_t = a_t h_{t-1} + u_t over axis 1.  a, u: (B, S, D)."""
    impl = impl or dispatch.current_impl()
    if impl == "xla":
        return ref.rglru(a, u)
    b, s, d = a.shape
    bs_ = min(bs, s)
    pad = (-s) % bs_
    if pad:
        # zero-pad decay and input: padded steps hold h constant*0 + 0 — but
        # a=0 would RESET the state; pad at the END so real steps are done.
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        u = jnp.pad(u, ((0, 0), (0, pad), (0, 0)))
    out = kernel.rglru(a, u, bs=bs_,
                       interpret=(impl == "pallas_interpret"))
    return out[:, :s]
