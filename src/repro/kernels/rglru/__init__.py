from .ops import rglru
from . import kernel, ops, ref

__all__ = ["rglru", "kernel", "ops", "ref"]
