"""Public wrapper for int8 block quantization."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import dispatch
from . import kernel, ref

dequantize = ref.dequantize


def quantize(x: jax.Array, *, bn: int = 256,
             impl: str | None = None) -> tuple[jax.Array, jax.Array]:
    """Row-quantize a 2D array; returns (int8 values, f32 scales)."""
    impl = impl or dispatch.current_impl()
    if impl == "xla":
        return ref.quantize(x)
    n, d = x.shape
    bn_ = min(bn, n)
    pad = (-n) % bn_
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    q, s = kernel.quantize(x, bn=bn_,
                           interpret=(impl == "pallas_interpret"))
    return q[:n], s[:n]
