"""Symmetric int8 block quantization Pallas kernel.

Used by (a) gradient compression (quantize -> all_reduce -> dequantize with
error feedback) and (b) int8 KV caches (qwen1.5-32b decode_32k does not fit
HBM at bf16).  Per-row scales: q = round(x / s), s = max|row| / 127.

This is also the paper's bitwidth/data-packing knob (``BW_a``) made literal:
int8 rows move 4x the elements per HBM burst vs f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale.astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def quantize(x: jax.Array, *, bn: int = 256,
             interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    n, d = x.shape
    assert n % bn == 0, (n, bn)
    return pl.pallas_call(
        _quant_kernel,
        grid=(n // bn,),
        in_specs=[pl.BlockSpec((bn, d), lambda i: (i, 0))],
        out_specs=(pl.BlockSpec((bn, d), lambda i: (i, 0)),
                   pl.BlockSpec((bn, 1), lambda i: (i, 0))),
        out_shape=(jax.ShapeDtypeStruct((n, d), jnp.int8),
                   jax.ShapeDtypeStruct((n, 1), jnp.float32)),
        interpret=interpret,
    )(x)
