"""Pure-jnp oracle for int8 row quantization."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    x32 = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(x32), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize(q: jax.Array, scale: jax.Array,
               dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)
