from .ops import quantize, dequantize
from . import kernel, ops, ref

__all__ = ["quantize", "dequantize", "kernel", "ops", "ref"]
