"""Jit'd public wrapper for the generalized contraction: dispatch + padding.

Applies the plan's computation padding (operands zero-padded to the spec's
padded trip counts — exact for both product-contractions and projected
sums), runs the kernel (or the einsum oracle under the ``xla`` impl), and
slices the output back to the original extents.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .. import dispatch
from . import kernel, ref
from .spec import ContractionSpec


def _pad_operand(a: jax.Array, ori: tuple[int, ...],
                 padded: tuple[int, ...]) -> jax.Array:
    assert a.shape == ori, (a.shape, ori)
    pads = tuple((0, p - o) for o, p in zip(ori, padded))
    if any(p for (_, p) in pads):
        return jnp.pad(a, pads)
    return a


@functools.partial(jax.jit, static_argnums=(0, 1))
def _run_kernel(spec: ContractionSpec, interpret: bool,
                *operands: jax.Array) -> jax.Array:
    padded = [
        _pad_operand(a, spec.ori_shape(o), spec.padded_shape(o))
        for a, o in zip(operands, spec.all_reads)
    ]
    out = kernel.contract(spec, *padded, interpret=interpret)
    return out[tuple(slice(0, n) for n in spec.out_ori)]


@functools.partial(jax.jit, static_argnums=(0,))
def _run_ref(spec: ContractionSpec, *operands: jax.Array) -> jax.Array:
    return ref.contract(spec, *operands)


def _tracing() -> bool:
    """True when called under an enclosing trace (e.g. the whole-plan
    program jit) — the outer jit wrapper would only add a nested-jit layer
    with its own trace cache, so inline the raw computation instead."""
    try:
        return not jax.core.trace_state_clean()
    except AttributeError:           # older/newer jax: assume top level
        return False


def contract(spec: ContractionSpec, *operands: jax.Array,
             impl: str | None = None) -> jax.Array:
    """Evaluate ``spec`` on unpadded operands (reads then init_reads)."""
    impl = impl or dispatch.current_impl()
    if impl == "xla":
        if _tracing():
            return ref.contract(spec, *operands)
        return _run_ref(spec, *operands)
    if _tracing():
        return _run_kernel.__wrapped__(spec, impl == "pallas_interpret",
                                       *operands)
    return _run_kernel(spec, impl == "pallas_interpret", *operands)
