"""Generalized tiled-contraction Pallas kernel — plan-faithful codegen.

Where the ``matmul`` kernel hard-codes the ``(i,k)x(k,j)`` pattern, this
kernel is *generated from* a :class:`ContractionSpec`: the grid is the plan's
inter-tile loop nest in permutation order (reduction loops innermost, as the
solver pins them), each operand's BlockSpec carries the plan's tile sizes,
and the fused init statement's value seeds the accumulator on the first
visit to an output tile.  One ``pallas_call`` therefore executes one fused
task — the paper's §5 claim that fusion/tiling/permutation decisions are
*lowered into the kernel*, not merely cost-modeled.

Pipelining: the Pallas grid pipeline double-buffers HBM->VMEM transfers;
``dimension_semantics`` marks non-reduction grid dims ``parallel`` when the
plan chose ``buffers >= 2`` (computation-communication overlap) and
``arbitrary`` (strictly sequential) otherwise, so the plan's buffering
decision reaches the Mosaic scheduler.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ref import apply_epilogue, combine_terms, project_term, scale_offset
from .spec import ContractionSpec, Operand


def _index_map(loop_names: tuple[str, ...], opnd: Operand):
    pos = tuple(loop_names.index(it) for it in opnd.iters)
    return lambda *g: tuple(g[p] for p in pos)


def _make_kernel(spec: ContractionSpec):
    n_reads = len(spec.reads)
    n_init = len(spec.init_reads)
    n_epi = len(spec.epi_reads)
    red_dims = spec.reduction_dims
    n_red = {d: spec.grid[d] for d in red_dims}
    out_sub = spec.out_subscript
    read_subs = spec.einsum_inputs(spec.reads)
    init_subs = spec.einsum_inputs(spec.init_reads)
    out_block = spec.out_block

    def contrib(read_vals):
        return combine_terms(read_subs, out_sub, spec.op, read_vals,
                             out_block)

    def init_val(init_vals):
        if not spec.init_reads:
            return jnp.zeros(out_block, jnp.float32)
        return scale_offset(
            combine_terms(init_subs, out_sub, spec.init_op, init_vals,
                          out_block),
            spec.init_coeff, spec.init_offset)

    def split(refs):
        reads = [r[...].astype(jnp.float32) for r in refs[:n_reads]]
        inits = [r[...].astype(jnp.float32)
                 for r in refs[n_reads:n_reads + n_init]]
        epis = [r[...].astype(jnp.float32)
                for r in refs[n_reads + n_init:n_reads + n_init + n_epi]]
        return reads, inits, epis, refs[n_reads + n_init + n_epi]

    def finish(total, inits, epis):
        """total -> stored value: scale, add init, run the fused tail."""
        val = scale_offset(total, spec.coeff, spec.offset)
        if spec.init_reads:
            val = val + init_val(inits)
        return apply_epilogue(spec, val, epis)

    if not red_dims:
        def kernel(*refs):
            reads, inits, epis, o_ref = split(refs)
            o_ref[...] = finish(contrib(reads), inits, epis) \
                .astype(o_ref.dtype)
        return kernel, False

    def _at_zero(dims) -> jax.Array | None:
        pred = None
        for d in dims:
            p = pl.program_id(d) == 0
            pred = p if pred is None else jnp.logical_and(pred, p)
        return pred

    loop_names = spec.loop_names

    def red_contrib(read_vals):
        if spec.op == "mul":
            # The joint contraction is linear in each reduction block, so
            # summing per-block einsums over the reduction grid is exact.
            return contrib(read_vals)
        # "add"/"sub": an operand missing a reduction iterator is constant
        # across that reduction's blocks — count its term once (on the
        # first visit), not once per block, matching the einsum projection.
        total = jnp.zeros(out_block, jnp.float32)
        for i, (sub, opnd, v) in enumerate(zip(read_subs, spec.reads,
                                               read_vals)):
            term = project_term(sub, out_sub, v, out_block)
            missing = [d for d in red_dims
                       if loop_names[d] not in opnd.iters]
            pred = _at_zero(missing)
            if pred is not None:
                term = jnp.where(pred, term, jnp.zeros_like(term))
            if spec.op == "sub" and i > 0:
                term = -term
            total += term
        return total

    def kernel(*refs):
        reads, inits, epis, o_ref = split(refs[:-1])
        acc_ref = refs[-1]

        first = _at_zero(red_dims)
        last = None
        for d in red_dims:
            l = pl.program_id(d) == n_red[d] - 1
            last = l if last is None else jnp.logical_and(last, l)

        # The accumulator holds the raw contribution sum; scaling, the init
        # value and the elementwise epilogue are applied once, at store time
        # on the final reduction step (the init block's index map depends
        # only on output dims, so its value is the same at every step).
        @pl.when(first)
        def _seed():
            acc_ref[...] = jnp.zeros(out_block, jnp.float32)

        acc_ref[...] += red_contrib(reads)

        @pl.when(last)
        def _store():
            o_ref[...] = finish(acc_ref[...], inits, epis) \
                .astype(o_ref.dtype)

    return kernel, True


def _dimension_semantics(spec: ContractionSpec) -> tuple[str, ...]:
    red = set(spec.reduction_dims)
    if spec.buffers < 2:
        return tuple("arbitrary" for _ in spec.loops)
    return tuple("arbitrary" if d in red else "parallel"
                 for d in range(len(spec.loops)))


@functools.lru_cache(maxsize=None)
def build_contraction(spec: ContractionSpec, interpret: bool = False):
    """Build (and cache) the pallas_call for one spec.

    The returned callable takes the *padded* operands (spec.reads, then
    spec.init_reads, then spec.epi_reads order) and returns the padded
    output.
    """
    body, has_scratch = _make_kernel(spec)
    loop_names = spec.loop_names
    in_specs = [
        pl.BlockSpec(spec.block_shape(o), _index_map(loop_names, o))
        for o in spec.all_reads
    ]
    out_spec = pl.BlockSpec(spec.out_block,
                            _index_map(loop_names,
                                       Operand("<out>", spec.out_iters)))
    kwargs = {}
    if has_scratch:
        kwargs["scratch_shapes"] = [pltpu.VMEM(spec.out_block, jnp.float32)]
    if not interpret:
        kwargs["compiler_params"] = _compiler_params(
            _dimension_semantics(spec))
    return pl.pallas_call(
        body,
        grid=spec.grid,
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct(spec.out_padded, jnp.float32),
        interpret=interpret,
        **kwargs,
    )


def _compiler_params(sems: tuple[str, ...]):
    cls = getattr(pltpu, "CompilerParams", None) \
        or getattr(pltpu, "TPUCompilerParams", None)
    if cls is not None:
        try:
            return cls(dimension_semantics=sems)
        except TypeError:
            pass
    return dict(mosaic=dict(dimension_semantics=sems))


def contract(spec: ContractionSpec, *operands: jax.Array,
             interpret: bool = False) -> jax.Array:
    """Run the kernel on padded operands; returns the padded output."""
    return build_contraction(spec, interpret)(*operands)
