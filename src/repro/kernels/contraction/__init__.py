"""Generalized tiled N-D contraction kernel (plan-faithful codegen target).

``spec.py``    the static IR (ContractionSpec) the lowering pass emits;
``kernel.py``  pallas_call generated from a spec (grid = plan permutation,
               BlockSpecs = plan tiles, init fusion, overlap semantics);
``ops.py``     jit'd wrapper with padding + impl dispatch;
``ref.py``     pure-einsum oracle (the ``xla`` impl).
"""
from .spec import ACC, ContractionSpec, EpiOp, LoopDim, Operand
from .ops import contract

__all__ = ["ACC", "ContractionSpec", "EpiOp", "LoopDim", "Operand",
           "contract"]
