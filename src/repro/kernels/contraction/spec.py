"""Static description of one tiled affine contraction — the codegen IR.

A :class:`ContractionSpec` is the bridge between the solver's
:class:`~repro.core.plan.TaskConfig` and an executable kernel: it freezes the
plan decisions that have a structural effect on the generated code (loop
order, tile sizes, padding, buffering) together with the statement's access
functions.  It is hashable so it can serve as a ``jax.jit`` static argument
and as a cache key for built ``pallas_call`` closures.

Semantics (matching the reference oracle in ``repro.codegen.reference``):

    out[out_iters]  =  init  (+)=  contribution per grid step

* ``op == "mul"``: the contribution is the product of all read operands,
  contracted over the reduction loops (an einsum).
* ``op == "add"``: the contribution is the sum of the read operands, each
  projected onto the output iterators (sum of single-operand einsums, with
  output iterators absent from an operand broadcast).
* ``op == "sub"``: like ``"add"`` but every operand after the first is
  negated (the elementwise ``a - b`` / ``-x`` lowering of the frontend).
* ``init_reads`` is the fused init statement's operand list (empty tuple
  means "initialise to zeros"); ``init_op`` combines them like ``op`` does.
  The init value is materialised on the *first* visit to an output tile —
  this is what makes init+accumulate fusion a single kernel.
* ``coeff``/``offset`` post-scale the contribution sum (``coeff * total +
  offset``) and ``init_coeff``/``init_offset`` the init value — the folded
  scalar literals of the frontend (``x * 2.0`` etc.).
* ``epilogue`` is an ordered chain of elementwise :class:`EpiOp` steps
  applied to the finished output tile *inside the kernel* (at store time):
  each step combines the running value (the :data:`ACC` sentinel operand)
  with extra elementwise operands under an op from the statement op
  families (``mul``/``add``/``sub``/``unary:*``/``binary:*``).  This is how
  small elementwise consumers of a contraction execute as a fused tail of
  the producer kernel instead of a separate dispatch.
"""
from __future__ import annotations

import dataclasses
import string

#: Sentinel operand array name: "the value accumulated so far" in an EpiOp.
ACC = "<acc>"


@dataclasses.dataclass(frozen=True)
class LoopDim:
    """One loop of the nest, in grid (permutation) order."""

    name: str
    tile: int          # TC_intra — block extent along this loop
    padded: int        # trip count after computation padding (tile divides it)
    ori: int           # original trip count (slice back to this)

    @property
    def n_tiles(self) -> int:
        return self.padded // self.tile

    def __post_init__(self):
        if self.padded % self.tile:
            raise ValueError(
                f"loop {self.name}: tile {self.tile} does not divide padded "
                f"trip count {self.padded}")
        if self.padded < self.ori:
            raise ValueError(f"loop {self.name}: padded {self.padded} < "
                             f"original {self.ori}")


@dataclasses.dataclass(frozen=True)
class Operand:
    """An affine read: ``array[iters]`` (one loop iterator per dimension)."""

    array: str
    iters: tuple[str, ...]


@dataclasses.dataclass(frozen=True)
class EpiOp:
    """One elementwise epilogue step over the finished output tile.

    ``reads`` may include the :data:`ACC` sentinel operand (the running
    value); every other operand is an extra kernel input, block-mapped on
    the output iterators.  The step computes
    ``coeff * op(reads) + offset``.
    """

    op: str
    reads: tuple[Operand, ...]
    coeff: float = 1.0
    offset: float = 0.0


@dataclasses.dataclass(frozen=True)
class ContractionSpec:
    loops: tuple[LoopDim, ...]        # grid order; reduction loops innermost
    reduction: tuple[str, ...]        # names of contracted loops
    op: str                           # "mul" | "add"
    reads: tuple[Operand, ...]        # contribution operands (no accumulator)
    out_iters: tuple[str, ...]
    init_reads: tuple[Operand, ...] = ()
    init_op: str = "mul"
    buffers: int = 2                  # N_a: >=2 enables pipelined overlap
    coeff: float = 1.0                # out = coeff * sum(contrib) + offset
    offset: float = 0.0
    init_coeff: float = 1.0           # ... + init_coeff * init + init_offset
    init_offset: float = 0.0
    epilogue: tuple[EpiOp, ...] = ()  # fused elementwise tail (store time)

    def __post_init__(self):
        names = {l.name for l in self.loops}
        for opnd in self.reads + self.init_reads:
            missing = [it for it in opnd.iters if it not in names]
            if missing:
                raise ValueError(f"operand {opnd} uses unknown loops "
                                 f"{missing}")
            if len(set(opnd.iters)) != len(opnd.iters):
                raise ValueError(f"operand {opnd} repeats an iterator "
                                 "(non-affine access)")
        ops = ("mul", "add", "sub")
        if self.op not in ops or self.init_op not in ops:
            raise ValueError(f"bad op {self.op!r}/{self.init_op!r}")
        out_set = set(self.out_iters)
        for epi in self.epilogue:
            if epi.op not in ops and not epi.op.startswith(("unary:",
                                                            "binary:")):
                raise ValueError(f"bad epilogue op {epi.op!r}")
            for opnd in epi.reads:
                bad = [it for it in opnd.iters if it not in out_set]
                if bad:
                    # Epilogue steps run on the finished *output tile*: every
                    # operand must be block-mappable on the output iterators.
                    raise ValueError(f"epilogue operand {opnd} uses "
                                     f"non-output iterators {bad}")
                if len(set(opnd.iters)) != len(opnd.iters):
                    raise ValueError(f"epilogue operand {opnd} repeats an "
                                     "iterator")
        # The kernel's single accumulator requires the reduction grid dims
        # to iterate fastest per output tile: reductions must form the
        # innermost suffix of the loop order (the solver pins them there).
        red = set(self.reduction)
        if not red <= names:
            raise ValueError(f"reduction {self.reduction} not in loops")
        tail = tuple(l.name for l in self.loops[len(self.loops) - len(red):])
        if red and set(tail) != red:
            raise ValueError(
                f"reduction loops {sorted(red)} must be innermost "
                f"(loop order is {[l.name for l in self.loops]})")

    # -- derived views ------------------------------------------------------
    @property
    def loop_names(self) -> tuple[str, ...]:
        return tuple(l.name for l in self.loops)

    @property
    def grid(self) -> tuple[int, ...]:
        return tuple(l.n_tiles for l in self.loops)

    @property
    def reduction_dims(self) -> tuple[int, ...]:
        names = self.loop_names
        return tuple(names.index(r) for r in self.reduction)

    @property
    def epi_reads(self) -> tuple[Operand, ...]:
        """Extra kernel operands of the epilogue chain (ACC excluded), in
        application order — appended after init_reads in the operand list."""
        return tuple(o for e in self.epilogue for o in e.reads
                     if o.array != ACC)

    @property
    def all_reads(self) -> tuple[Operand, ...]:
        """Full kernel operand order: reads, init_reads, epilogue reads."""
        return self.reads + self.init_reads + self.epi_reads

    def dim(self, name: str) -> LoopDim:
        for l in self.loops:
            if l.name == name:
                return l
        raise KeyError(name)

    def block_shape(self, opnd: Operand) -> tuple[int, ...]:
        return tuple(self.dim(it).tile for it in opnd.iters)

    def padded_shape(self, opnd: Operand) -> tuple[int, ...]:
        return tuple(self.dim(it).padded for it in opnd.iters)

    def ori_shape(self, opnd: Operand) -> tuple[int, ...]:
        return tuple(self.dim(it).ori for it in opnd.iters)

    @property
    def out_block(self) -> tuple[int, ...]:
        return tuple(self.dim(it).tile for it in self.out_iters)

    @property
    def out_padded(self) -> tuple[int, ...]:
        return tuple(self.dim(it).padded for it in self.out_iters)

    @property
    def out_ori(self) -> tuple[int, ...]:
        return tuple(self.dim(it).ori for it in self.out_iters)

    def letters(self) -> dict[str, str]:
        return {l.name: string.ascii_letters[i]
                for i, l in enumerate(self.loops)}

    def einsum_inputs(self, operands: tuple[Operand, ...]) -> list[str]:
        lt = self.letters()
        return ["".join(lt[it] for it in o.iters) for o in operands]

    @property
    def out_subscript(self) -> str:
        lt = self.letters()
        return "".join(lt[it] for it in self.out_iters)
