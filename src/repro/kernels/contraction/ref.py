"""Pure-jnp oracle for :class:`ContractionSpec` — the ``xla`` impl path.

Evaluates the spec's semantics directly with einsum on the *unpadded*
operands; numerically identical (up to f32 association order) to the Pallas
kernel, and to the statement-level reference executor.

``combine_terms`` is the single definition of the op semantics ("mul" =
joint product contraction, "add"/"sub" = signed sum of per-operand
projections, "unary:<name>"/"binary:<name>" = pointwise function families);
the Pallas kernel body reuses it on VMEM blocks so oracle and kernel cannot
drift apart.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from .spec import ACC, ContractionSpec, Operand

# ---------------------------------------------------------------------------
# Pointwise op families — "unary:<name>" / "binary:<name>" statement ops.
# One table shared by the statement oracle, the xla impl and the Pallas
# kernel epilogue (all jnp/lax primitives, traceable inside kernels).
# ---------------------------------------------------------------------------
_UNARY: dict[str, Callable] = {
    "logistic": jax.lax.logistic,
    "tanh": jnp.tanh,
    "exp": jnp.exp,
    "log": jnp.log,
    "log1p": jnp.log1p,
    "expm1": jnp.expm1,
    "sqrt": jnp.sqrt,
    "rsqrt": jax.lax.rsqrt,
    "cbrt": jax.lax.cbrt,
    "erf": jax.lax.erf,
    "sin": jnp.sin,
    "cos": jnp.cos,
    "abs": jnp.abs,
    "sign": jnp.sign,
    "floor": jnp.floor,
    "ceil": jnp.ceil,
    "round": jnp.round,
}

_BINARY: dict[str, Callable] = {
    "max": jnp.maximum,
    "min": jnp.minimum,
    "div": jnp.divide,
}


def unary_fn(name: str) -> Callable:
    """Resolve a ``unary:<name>`` suffix, including the parameterized
    families ``pow_<k>`` (integer_pow) and ``max_const:<c>``/``min_const:<c>``
    (clamps against a folded scalar literal, e.g. relu's ``max(x, 0)``)."""
    if name.startswith("pow_"):
        k = int(name[len("pow_"):])
        return lambda v: v ** k
    if name.startswith("max_const:"):
        c = float(name[len("max_const:"):])
        return lambda v: jnp.maximum(v, c)
    if name.startswith("min_const:"):
        c = float(name[len("min_const:"):])
        return lambda v: jnp.minimum(v, c)
    try:
        return _UNARY[name]
    except KeyError:
        raise KeyError(f"unknown unary op {name!r}") from None


def binary_fn(name: str) -> Callable:
    try:
        return _BINARY[name]
    except KeyError:
        raise KeyError(f"unknown binary op {name!r}") from None


def has_unary(name: str) -> bool:
    return name in _UNARY


def has_binary(name: str) -> bool:
    return name in _BINARY


def scale_offset(val: jax.Array, coeff: float, offset: float) -> jax.Array:
    """``coeff * val + offset`` without emitting no-op arithmetic."""
    if coeff != 1.0:
        val = val * jnp.float32(coeff)
    if offset != 0.0:
        val = val + jnp.float32(offset)
    return val


def project_term(sub: str, out_sub: str, v: jax.Array,
                 out_shape: tuple[int, ...]) -> jax.Array:
    """Project one operand onto the output iterators.

    Operand iterators absent from the output are summed out (einsum
    projection); output iterators absent from the operand are broadcast —
    the frontend's lowering of ``broadcast_in_dim`` and of size-1
    elementwise operands relies on this (einsum alone cannot introduce an
    output label its inputs lack).
    """
    keep = "".join(c for c in out_sub if c in sub)
    term = jnp.einsum(f"{sub}->{keep}", v,
                      preferred_element_type=jnp.float32)
    if keep != out_sub:
        missing = tuple(i for i, c in enumerate(out_sub) if c not in keep)
        term = jnp.broadcast_to(jnp.expand_dims(term, missing), out_shape)
    return term


def combine_terms(subs: list[str], out_sub: str, op: str,
                  vals: list[jax.Array],
                  zero_shape: tuple[int, ...]) -> jax.Array:
    """Combine operands per the op semantics (shared by oracle + kernel).

    ``"sub"`` is the sum-of-projections with the first operand positive and
    every later operand negated (``a - b - c``) — the lowering of the
    elementwise ``sub``/``neg`` primitives.
    """
    if not vals:
        return jnp.zeros(zero_shape, jnp.float32)
    if op.startswith("unary:"):
        return unary_fn(op[len("unary:"):])(
            project_term(subs[0], out_sub, vals[0], zero_shape))
    if op.startswith("binary:"):
        return binary_fn(op[len("binary:"):])(
            project_term(subs[0], out_sub, vals[0], zero_shape),
            project_term(subs[1], out_sub, vals[1], zero_shape))
    if op == "mul":
        if all(set(sub) <= set(out_sub) for sub in subs):
            # Nothing is contracted: a pure elementwise/broadcast product.
            # Plain multiplies fuse into neighboring XLA ops; the einsum
            # form lowers to a batch dot_general that does not.
            total = None
            for sub, v in zip(subs, vals):
                term = project_term(sub, out_sub, v, zero_shape)
                if term.dtype != jnp.float32:
                    term = term.astype(jnp.float32)
                total = term if total is None else total * term
            return total
        return jnp.einsum(f"{','.join(subs)}->{out_sub}", *vals,
                          preferred_element_type=jnp.float32)
    total = None
    for i, (sub, v) in enumerate(zip(subs, vals)):
        term = project_term(sub, out_sub, v, zero_shape)
        if op == "sub" and i > 0:
            term = -term
        total = term if total is None else total + term
    return total


def _combine(spec: ContractionSpec, operands: tuple[Operand, ...],
             vals: list[jax.Array], op: str,
             zero_shape: tuple[int, ...]) -> jax.Array:
    return combine_terms(spec.einsum_inputs(operands), spec.out_subscript,
                         op, vals, zero_shape)


def apply_epilogue(spec: ContractionSpec, val: jax.Array,
                   epi_vals: list[jax.Array]) -> jax.Array:
    """Run the spec's elementwise epilogue chain over ``val``.

    ``epi_vals`` supplies the non-ACC operand values in ``spec.epi_reads``
    order — either unpadded full arrays (oracle path) or VMEM blocks
    (kernel path); the einsum subscripts work identically on both.
    """
    if not spec.epilogue:
        return val
    lt = spec.letters()
    out_sub = spec.out_subscript
    shape = tuple(val.shape)
    it = iter(epi_vals)
    for epi in spec.epilogue:
        subs, vals = [], []
        for o in epi.reads:
            subs.append("".join(lt[x] for x in o.iters))
            vals.append(val if o.array == ACC else next(it))
        val = scale_offset(combine_terms(subs, out_sub, epi.op, vals, shape),
                           epi.coeff, epi.offset)
    return val


def contract(spec: ContractionSpec, *operands: jax.Array) -> jax.Array:
    """Reference evaluation.  ``operands`` = spec.reads, then
    spec.init_reads, then spec.epi_reads, each with the spec's *original*
    (unpadded) shape."""
    n, ni = len(spec.reads), len(spec.init_reads)
    reads, init_reads = list(operands[:n]), list(operands[n:n + ni])
    epi_vals = list(operands[n + ni:])
    val = scale_offset(_combine(spec, spec.reads, reads, spec.op,
                                spec.out_ori),
                       spec.coeff, spec.offset)
    if spec.init_reads:
        val = val + scale_offset(
            _combine(spec, spec.init_reads, init_reads, spec.init_op,
                     spec.out_ori),
            spec.init_coeff, spec.init_offset)
    return apply_epilogue(spec, val, epi_vals)
