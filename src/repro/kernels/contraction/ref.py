"""Pure-jnp oracle for :class:`ContractionSpec` — the ``xla`` impl path.

Evaluates the spec's semantics directly with einsum on the *unpadded*
operands; numerically identical (up to f32 association order) to the Pallas
kernel, and to the statement-level reference executor.

``combine_terms`` is the single definition of the op semantics ("mul" =
joint product contraction, "add" = sum of per-operand projections); the
Pallas kernel body reuses it on VMEM blocks so oracle and kernel cannot
drift apart.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .spec import ContractionSpec, Operand


def combine_terms(subs: list[str], out_sub: str, op: str,
                  vals: list[jax.Array],
                  zero_shape: tuple[int, ...]) -> jax.Array:
    """Combine operands per the op semantics (shared by oracle + kernel)."""
    if not vals:
        return jnp.zeros(zero_shape, jnp.float32)
    if op == "mul":
        return jnp.einsum(f"{','.join(subs)}->{out_sub}", *vals,
                          preferred_element_type=jnp.float32)
    total = None
    for sub, v in zip(subs, vals):
        term = jnp.einsum(f"{sub}->{out_sub}", v,
                          preferred_element_type=jnp.float32)
        total = term if total is None else total + term
    return total


def _combine(spec: ContractionSpec, operands: tuple[Operand, ...],
             vals: list[jax.Array], op: str) -> jax.Array:
    return combine_terms(spec.einsum_inputs(operands), spec.out_subscript,
                         op, vals, spec.out_ori)


def contract(spec: ContractionSpec, *operands: jax.Array) -> jax.Array:
    """Reference evaluation.  ``operands`` = spec.reads then spec.init_reads,
    each with the spec's *original* (unpadded) shape."""
    n = len(spec.reads)
    reads, init_reads = list(operands[:n]), list(operands[n:])
    val = _combine(spec, spec.reads, reads, spec.op)
    if spec.init_reads:
        val = val + _combine(spec, spec.init_reads, init_reads, spec.init_op)
    return val
