"""Pure-jnp oracle for :class:`ContractionSpec` — the ``xla`` impl path.

Evaluates the spec's semantics directly with einsum on the *unpadded*
operands; numerically identical (up to f32 association order) to the Pallas
kernel, and to the statement-level reference executor.

``combine_terms`` is the single definition of the op semantics ("mul" =
joint product contraction, "add"/"sub" = signed sum of per-operand
projections); the Pallas kernel body reuses it on VMEM blocks so oracle and
kernel cannot drift apart.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .spec import ContractionSpec, Operand


def project_term(sub: str, out_sub: str, v: jax.Array,
                 out_shape: tuple[int, ...]) -> jax.Array:
    """Project one operand onto the output iterators.

    Operand iterators absent from the output are summed out (einsum
    projection); output iterators absent from the operand are broadcast —
    the frontend's lowering of ``broadcast_in_dim`` and of size-1
    elementwise operands relies on this (einsum alone cannot introduce an
    output label its inputs lack).
    """
    keep = "".join(c for c in out_sub if c in sub)
    term = jnp.einsum(f"{sub}->{keep}", v,
                      preferred_element_type=jnp.float32)
    if keep != out_sub:
        missing = tuple(i for i, c in enumerate(out_sub) if c not in keep)
        term = jnp.broadcast_to(jnp.expand_dims(term, missing), out_shape)
    return term


def combine_terms(subs: list[str], out_sub: str, op: str,
                  vals: list[jax.Array],
                  zero_shape: tuple[int, ...]) -> jax.Array:
    """Combine operands per the op semantics (shared by oracle + kernel).

    ``"sub"`` is the sum-of-projections with the first operand positive and
    every later operand negated (``a - b - c``) — the lowering of the
    elementwise ``sub``/``neg`` primitives.
    """
    if not vals:
        return jnp.zeros(zero_shape, jnp.float32)
    if op == "mul":
        return jnp.einsum(f"{','.join(subs)}->{out_sub}", *vals,
                          preferred_element_type=jnp.float32)
    total = None
    for i, (sub, v) in enumerate(zip(subs, vals)):
        term = project_term(sub, out_sub, v, zero_shape)
        if op == "sub" and i > 0:
            term = -term
        total = term if total is None else total + term
    return total


def _combine(spec: ContractionSpec, operands: tuple[Operand, ...],
             vals: list[jax.Array], op: str) -> jax.Array:
    return combine_terms(spec.einsum_inputs(operands), spec.out_subscript,
                         op, vals, spec.out_ori)


def contract(spec: ContractionSpec, *operands: jax.Array) -> jax.Array:
    """Reference evaluation.  ``operands`` = spec.reads then spec.init_reads,
    each with the spec's *original* (unpadded) shape."""
    n = len(spec.reads)
    reads, init_reads = list(operands[:n]), list(operands[n:])
    val = _combine(spec, spec.reads, reads, spec.op)
    if spec.init_reads:
        val = val + _combine(spec, spec.init_reads, init_reads, spec.init_op)
    return val
