"""Block-tiled matmul Pallas kernel — the paper's tiling/unroll analogue.

The (bm, bn, bk) block shape is exactly the solver's intra-tile choice
(``TC_intra`` in the NLP): each grid step loads one (bm, bk) x (bk, bn)
VMEM tile pair, feeds the MXU, and accumulates into a float32 VMEM scratch
(the output-stationary buffer).  The pallas_call grid pipeline provides the
double-buffered HBM->VMEM overlap the paper implements with ping-pong
buffers (§2.1.5).

Grid layout: (m-tiles, n-tiles, k-tiles), k innermost — the pipelined
reduction loop of Eq. 16 (the output tile is revisited across k).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(x_ref, y_ref, o_ref, acc_ref, *, n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32),
        y_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def matmul(x: jax.Array, y: jax.Array, *, bm: int = 128, bn: int = 128,
           bk: int = 128, interpret: bool = False) -> jax.Array:
    """``x @ y`` with explicit VMEM tiling.

    Shapes must be multiples of the block shape — callers pad first
    (``ops.matmul`` applies the paper's computation padding automatically).
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, (x.shape, y.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, \
        (f"unpadded dims {x.shape}x{y.shape} for blocks {(bm, bn, bk)}; "
         f"use ops.matmul which pads")
    n_k = k // bk
    return pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k),
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, y)
