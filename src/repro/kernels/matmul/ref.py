"""Pure-jnp oracle for the block-tiled matmul kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul(x: jax.Array, y: jax.Array) -> jax.Array:
    return jnp.dot(x.astype(jnp.float32), y.astype(jnp.float32),
                   preferred_element_type=jnp.float32).astype(x.dtype)
