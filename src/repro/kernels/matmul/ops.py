"""Jit'd public wrapper: dispatch + automatic computation padding.

Applies the paper's padding-for-computation (§2.1.6): dims are padded up to
block multiples so any (bm, bn, bk) choice from the solver is legal, then the
result is sliced back.  Zero padding is exact for matmul.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import dispatch
from . import kernel, ref


def _pad_to(a: jax.Array, m0: int, m1: int) -> jax.Array:
    p0 = (-a.shape[0]) % m0
    p1 = (-a.shape[1]) % m1
    if p0 or p1:
        a = jnp.pad(a, ((0, p0), (0, p1)))
    return a


def matmul(x: jax.Array, y: jax.Array, *, bm: int = 128, bn: int = 128,
           bk: int = 128, impl: str | None = None) -> jax.Array:
    """``x @ y`` under the configured kernel implementation."""
    impl = impl or dispatch.current_impl()
    if impl == "xla":
        return ref.matmul(x, y)
    m, n = x.shape[0], y.shape[1]
    bm_, bn_, bk_ = (min(bm, _ceil(x.shape[0])), min(bn, _ceil(y.shape[1])),
                     min(bk, _ceil(x.shape[1])))
    xp = _pad_to(x, bm_, bk_)
    yp = _pad_to(y, bk_, bn_)
    out = kernel.matmul(xp, yp, bm=bm_, bn=bn_, bk=bk_,
                        interpret=(impl == "pallas_interpret"))
    return out[:m, :n]


def _ceil(dim: int) -> int:
    """Largest power-of-two block not exceeding the padded dim."""
    b = 1
    while b * 2 <= dim:
        b *= 2
    return b
