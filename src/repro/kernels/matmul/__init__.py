from .ops import matmul
from . import kernel, ops, ref

__all__ = ["matmul", "kernel", "ops", "ref"]
