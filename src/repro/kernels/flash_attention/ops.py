"""Public wrapper: layout handling, GQA, padding, implementation dispatch.

Accepts model-layout tensors q (B, S, H, D), k/v (B, S, Hkv, D); pads the
sequence to a block multiple (padding keys sit at positions >= S, which the
causal mask excludes for every real query row — communication padding that
is exact by construction).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import dispatch
from . import kernel, ref


def _to_bh(x: jax.Array) -> jax.Array:
    b, s, h, d = x.shape
    return jnp.transpose(x, (0, 2, 1, 3)).reshape(b * h, s, d)


def _from_bh(x: jax.Array, b: int, h: int) -> jax.Array:
    bh, s, d = x.shape
    return jnp.transpose(x.reshape(b, h, s, d), (0, 2, 1, 3))


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    scale: float | None = None, bq: int = 128,
                    bk: int = 128, impl: str | None = None) -> jax.Array:
    impl = impl or dispatch.current_impl()
    b, s, h, d = q.shape
    qb, kb, vb = _to_bh(q), _to_bh(k), _to_bh(v)
    if impl == "xla":
        out = ref.attention(qb, kb, vb, causal=causal, window=window,
                            scale=scale)
        return _from_bh(out, b, h)
    bq_ = min(bq, s)
    bk_ = min(bk, s)
    pad = (-s) % max(bq_, bk_)
    if pad:
        qb = jnp.pad(qb, ((0, 0), (0, pad), (0, 0)))
        kb = jnp.pad(kb, ((0, 0), (0, pad), (0, 0)))
        vb = jnp.pad(vb, ((0, 0), (0, pad), (0, 0)))
    out = kernel.flash_attention(
        qb, kb, vb, causal=causal, window=window, scale=scale,
        bq=bq_, bk=bk_, interpret=(impl == "pallas_interpret"))
    return _from_bh(out[:, :s], b, h)
