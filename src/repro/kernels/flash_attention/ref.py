"""Pure-jnp oracle: naive masked softmax attention (fp32 accumulation)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, window: int | None = None,
              scale: float | None = None) -> jax.Array:
    """q (BH, S, D), k/v (BHkv, S, D) with BH % BHkv == 0."""
    bh_q, s, d = q.shape
    bh_kv = k.shape[0]
    group = bh_q // bh_kv
    if group != 1:
        k = jnp.repeat(k, group, axis=0)
        v = jnp.repeat(v, group, axis=0)
    if scale is None:
        scale = d ** -0.5
    logits = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    rows = jnp.arange(s)[:, None]
    cols = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= cols <= rows
    if window is not None:
        mask &= cols > rows - window
    logits = jnp.where(mask[None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bqk,bkd->bqd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)
