"""Blocked (flash) attention Pallas kernel: causal / sliding-window, GQA.

TPU adaptation of attention tiling: the (bq, bk) block pair is the solver's
intra-tile; the kv grid dimension is the pipelined reduction loop (online
softmax replaces the associative sum), and fully-masked blocks are skipped
with ``pl.when`` — the block-level analogue of the paper's triangular-domain
density (only ~half the S x S blocks of a causal map do work).

GQA never materialises repeated KV heads: the kv BlockSpec index_map sends
query head ``h`` to kv head ``h // group`` — a pure index transformation
(zero bytes), where the XLA reference path must broadcast.

Layouts: q (B*H, S, D), k/v (B*Hkv, S, D), out (B*H, S, D).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 bq: int, bk: int, n_k: int, causal: bool,
                 window: int | None, scale: float):
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Block-level visibility: rows [i*bq, i*bq+bq), cols [j*bk, j*bk+bk).
    row_lo = i * bq
    row_hi = row_lo + bq - 1
    col_lo = j * bk
    col_hi = col_lo + bk - 1
    visible = jnp.bool_(True)
    if causal:
        visible = jnp.logical_and(visible, col_lo <= row_hi)
    if window is not None:
        visible = jnp.logical_and(visible, col_hi >= row_lo - (window - 1))

    @pl.when(visible)
    def _update():
        q = q_ref[0].astype(jnp.float32) * scale
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)          # (bq, bk)
        rows = row_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = col_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.bool_(jnp.ones((bq, bk), jnp.bool_))
        if causal:
            mask = jnp.logical_and(mask, cols <= rows)
        if window is not None:
            mask = jnp.logical_and(mask, cols > rows - window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == n_k - 1)
    def _store():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "bq", "bk", "scale", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    scale: float | None = None, bq: int = 128,
                    bk: int = 128, interpret: bool = False) -> jax.Array:
    bh_q, s, d = q.shape
    bh_kv = k.shape[0]
    assert bh_q % bh_kv == 0, (bh_q, bh_kv)
    group = bh_q // bh_kv
    assert s % bq == 0 and s % bk == 0, (s, bq, bk)
    if scale is None:
        scale = d ** -0.5
    n_q, n_k = s // bq, s // bk
    kernel = functools.partial(
        _attn_kernel, bq=bq, bk=bk, n_k=n_k, causal=causal, window=window,
        scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(bh_q, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b // group, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh_q, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
