from .ops import flash_attention
from . import kernel, ops, ref

__all__ = ["flash_attention", "kernel", "ops", "ref"]
