"""Kernel implementation dispatch.

Implementations:
  ``xla``               pure-jnp reference path (the ref.py oracles) — used by
                        the 512-device dry-runs (that is what cost_analysis
                        inspects) and as the numerical oracle.
  ``pallas_interpret``  Pallas kernel bodies executed in interpret mode on
                        CPU — how this container validates the TPU kernels.
  ``pallas``            compiled Pallas (Mosaic) — the TPU target.

Resolution order: explicit argument > ``repro_kernel_impl`` context >
``REPRO_KERNEL_IMPL`` env var > auto (pallas on TPU, xla elsewhere).
"""
from __future__ import annotations

import contextlib
import os
import threading

import jax

_VALID = ("xla", "pallas_interpret", "pallas", "auto")
_state = threading.local()


def _auto() -> str:
    try:
        platform = jax.devices()[0].platform
    except RuntimeError:
        platform = "cpu"
    return "pallas" if platform == "tpu" else "xla"


def current_impl() -> str:
    impl = getattr(_state, "impl", None) \
        or os.environ.get("REPRO_KERNEL_IMPL", "auto")
    if impl not in _VALID:
        raise ValueError(f"bad kernel impl {impl!r}; want one of {_VALID}")
    return _auto() if impl == "auto" else impl


@contextlib.contextmanager
def kernel_impl(impl: str):
    """Force a kernel implementation within a scope (tests use
    ``pallas_interpret``)."""
    if impl not in _VALID:
        raise ValueError(f"bad kernel impl {impl!r}")
    prev = getattr(_state, "impl", None)
    _state.impl = impl
    try:
        yield
    finally:
        _state.impl = prev


def use_pallas() -> bool:
    return current_impl() in ("pallas", "pallas_interpret")


def interpret_mode() -> bool:
    return current_impl() == "pallas_interpret"
