"""Persistent plan store — fingerprint-keyed, checksummed solved plans.

``solve(..., store="auto")`` consults :func:`default_store` (the
``REPRO_PLAN_STORE_DIR`` env var or the process override set by
``ServeConfig.plan_store_dir``); with no directory configured the store
is disabled and solving behaves exactly as before this subsystem
existed.
"""
from .planstore import (DEFAULT_MAX_ENTRIES, PlanStore, default_store,
                        set_default_dir)

__all__ = ["DEFAULT_MAX_ENTRIES", "PlanStore", "default_store",
           "set_default_dir"]
