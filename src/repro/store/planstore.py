"""Persistent plan store: solved plans keyed by content fingerprints.

The solving analogue of the AOT compilation cache (PR 3): a solve is a
pure function of ``(graph, hardware rates, solver options)``, so its
result — the :class:`ExecutionPlan` — can be serialized once and reused
by every replica and restart that asks the same question.  Keys are the
triple of content fingerprints

    <graph_fp>-<hw_fp>-<opts_fp>.json

(:mod:`repro.core.fingerprint`), so a changed kernel, a recalibrated
host, or different solver options each miss cleanly instead of serving a
wrong plan.  Files are written atomically with embedded checksums via
:mod:`repro.ft.artifacts`; a corrupt entry is quarantined (renamed to
``*.corrupt``) and treated as a miss, never an error.  The store is
bounded on disk (oldest-mtime eviction past ``max_entries``).

This module is deliberately JAX-free: a serving replica can answer "do I
already know this plan?" before paying any runtime import.
"""
from __future__ import annotations

import glob
import os
import time

from ..core.fingerprint import graph_fingerprint, solver_options_fingerprint
from ..core.plan import ExecutionPlan
from ..obs import tracer as _obs_tracer
from ..ft.artifacts import (ArtifactError, atomic_write_json, load_json,
                            quarantine_file)

SCHEMA_VERSION = 1

#: Default on-disk bound (entries, not bytes — plans are a few KiB each).
DEFAULT_MAX_ENTRIES = 512

# Process-level default-directory override (set by ServeConfig); the
# REPRO_PLAN_STORE_DIR environment variable is the ambient fallback.
_DIR_OVERRIDE: str | None = None


def set_default_dir(path: str | None) -> None:
    """Set (or clear, with ``None``) the process-wide default store
    directory — ``ServeConfig.plan_store_dir`` routes here so one engine
    config enables the store for every ``solve(store="auto")`` in the
    process, batcher bucket solves included."""
    global _DIR_OVERRIDE
    _DIR_OVERRIDE = path


def default_store() -> "PlanStore | None":
    """The env-configured store (``REPRO_PLAN_STORE_DIR``), or the
    process override, or ``None`` — plan persistence is strictly opt-in,
    so the default solver behavior is byte-identical to a storeless one.
    """
    root = _DIR_OVERRIDE or os.environ.get("REPRO_PLAN_STORE_DIR")
    if not root:
        return None
    return PlanStore(root)


def _max_entries_from_env() -> int:
    try:
        return max(1, int(os.environ.get("REPRO_PLAN_STORE_SIZE", "")))
    except ValueError:
        return DEFAULT_MAX_ENTRIES


class PlanStore:
    """One directory of fingerprint-keyed, checksummed plan files."""

    def __init__(self, root: str, max_entries: int | None = None):
        self.root = root
        self.max_entries = max_entries if max_entries is not None \
            else _max_entries_from_env()
        self.hits = 0
        self.stale_hits = 0
        self.misses = 0
        self.writes = 0
        self.corrupt = 0

    # -- keys -------------------------------------------------------------
    @staticmethod
    def key(graph, hw, opts) -> tuple[str, str, str]:
        return (graph_fingerprint(graph), hw.fingerprint(),
                solver_options_fingerprint(opts))

    def _path(self, gfp: str, hfp: str, ofp: str) -> str:
        return os.path.join(self.root, f"{gfp}-{hfp}-{ofp}.json")

    # -- load -------------------------------------------------------------
    def load(self, graph, hw, opts, *,
             allow_stale: bool = False) -> ExecutionPlan | None:
        """The stored plan for this exact key, or ``None``.

        With ``allow_stale=True`` a miss additionally scans for the same
        ``(graph, opts)`` under any *other* hardware fingerprint — the
        calibration-drift case — and returns the freshest such plan with
        ``stale_hw=True`` so the caller can serve it now and re-solve in
        the background instead of blocking.
        """
        with _obs_tracer().span("load", "store",
                                allow_stale=allow_stale) as sp:
            gfp, hfp, ofp = self.key(graph, hw, opts)
            plan = self._read(self._path(gfp, hfp, ofp))
            if plan is not None:
                self.hits += 1
                sp.set(outcome="hit")
                return plan
            if allow_stale:
                pattern = os.path.join(self.root, f"{gfp}-*-{ofp}.json")
                stale = sorted(glob.glob(pattern),
                               key=lambda p: os.path.getmtime(p),
                               reverse=True)
                for path in stale:
                    plan = self._read(path)
                    if plan is not None:
                        plan.stale_hw = True
                        self.stale_hits += 1
                        sp.set(outcome="stale_hit")
                        return plan
            self.misses += 1
            sp.set(outcome="miss")
            return None

    def _read(self, path: str) -> ExecutionPlan | None:
        if not os.path.exists(path):
            return None
        try:
            payload = load_json(path, require_checksum=True)
            if payload.get("schema") != SCHEMA_VERSION:
                raise ArtifactError(f"plan store schema "
                                    f"{payload.get('schema')!r} != "
                                    f"{SCHEMA_VERSION}")
            plan = ExecutionPlan.from_jsonable(payload["plan"])
        except (ArtifactError, KeyError, TypeError, ValueError) as exc:
            # torn write, bit rot, stale schema, hand-edited file: move it
            # aside (-> *.corrupt) so the caller re-solves and overwrites
            self.corrupt += 1
            quarantine_file(path, reason=repr(exc))
            return None
        plan.store_hit = True
        # a hit performs no sweep: evaluations are a property of *this*
        # solve call, and this call did none
        plan.n_evaluated = 0
        return plan

    # -- save -------------------------------------------------------------
    def save(self, graph, hw, opts, plan: ExecutionPlan) -> str | None:
        """Persist atomically (tmp + rename, checksummed); returns the
        path, or ``None`` for plans not worth keeping (no configs)."""
        if not plan.configs:
            return None
        with _obs_tracer().span("save", "store"):
            gfp, hfp, ofp = self.key(graph, hw, opts)
            payload = {
                "schema": SCHEMA_VERSION,
                "graph_fp": gfp, "hw_fp": hfp, "opts_fp": ofp,
                "created_s": time.time(),
                "plan": plan.to_jsonable(),
            }
            os.makedirs(self.root, exist_ok=True)
            path = atomic_write_json(self._path(gfp, hfp, ofp), payload,
                                     checksum=True)
            self.writes += 1
            self._evict()
            return path

    def _evict(self) -> None:
        entries = glob.glob(os.path.join(self.root, "*.json"))
        if len(entries) <= self.max_entries:
            return
        entries.sort(key=lambda p: os.path.getmtime(p))
        for path in entries[:len(entries) - self.max_entries]:
            try:
                os.remove(path)
            except OSError:
                pass

    # -- introspection ----------------------------------------------------
    def __len__(self) -> int:
        return len(glob.glob(os.path.join(self.root, "*.json")))

    def stats(self) -> dict:
        return {"root": self.root, "entries": len(self),
                "hits": self.hits, "stale_hits": self.stale_hits,
                "misses": self.misses, "writes": self.writes,
                "corrupt": self.corrupt,
                "max_entries": self.max_entries}
