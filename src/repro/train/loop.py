"""Fault-tolerant training loop: data prefetch + jitted step + async
checkpointing + restart supervision + straggler monitoring."""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import CheckpointManager
from ..data import DataConfig, PrefetchLoader, SyntheticLM
from ..ft import FailurePlan, run_with_restarts
from ..models import model as M
from .optimizer import AdamWConfig, init_opt_state
from .train_step import make_train_step

log = logging.getLogger("repro.train")


@dataclasses.dataclass
class TrainConfig:
    total_steps: int = 100
    checkpoint_every: int = 25
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
    log_every: int = 10
    global_batch: int = 8
    seq_len: int = 128
    seed: int = 0
    max_restarts: int = 5


@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any


def train(cfg: M.ModelConfig, tc: TrainConfig,
          opt_cfg: AdamWConfig | None = None, mesh=None,
          failure_plan: FailurePlan | None = None,
          on_metrics: Callable[[int, dict], None] | None = None):
    """Run training; returns (final TrainState, list of (step, loss))."""
    opt_cfg = opt_cfg or AdamWConfig(total_steps=tc.total_steps)
    if mesh is None:
        mesh = jax.make_mesh((1, 1), ("data", "model"))
    key = jax.random.PRNGKey(tc.seed)
    params = M.init_params(cfg, key)
    opt_state = init_opt_state(params)
    shapes = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
    step_fn, shardings = make_train_step(
        mesh, cfg, opt_cfg, shapes, tc.global_batch, tc.seq_len)

    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=tc.seq_len,
                                  global_batch=tc.global_batch,
                                  seed=tc.seed))
    loader = PrefetchLoader(data)
    ckpt = CheckpointManager(tc.checkpoint_dir, keep=tc.keep_checkpoints)
    history: list[tuple[int, float]] = []

    state = TrainState(params=params, opt_state=opt_state)
    # Abstract template for restore (live arrays get donated/deleted).
    template = jax.tree.map(
        lambda x: np.zeros(x.shape, x.dtype),
        {"params": params, "opt_state": opt_state})

    def one_step(state: TrainState, step: int) -> TrainState:
        toks, labels = loader.next()
        t0 = time.monotonic()
        params, opt_state, metrics = step_fn(
            state.params, state.opt_state,
            jnp.asarray(toks), jnp.asarray(labels))
        loss = float(metrics["loss"])
        if not np.isfinite(loss):
            raise FloatingPointError(f"non-finite loss at step {step}")
        history.append((step, loss))
        if step % tc.log_every == 0 or step + 1 == tc.total_steps:
            log.info("step %d loss %.4f (%.0f ms)", step, loss,
                     1e3 * (time.monotonic() - t0))
        if on_metrics:
            on_metrics(step, {k: float(v) for k, v in metrics.items()})
        return TrainState(params=params, opt_state=opt_state)

    def save(state: TrainState, step: int) -> None:
        ckpt.save(step, {"params": state.params,
                         "opt_state": state.opt_state})

    def restore():
        restored, rstep = ckpt.restore(template)
        if restored is None:
            return None, None
        loader.seek(rstep + 1)
        return TrainState(params=jax.tree.map(jnp.asarray,
                                              restored["params"]),
                          opt_state=jax.tree.map(jnp.asarray,
                                                 restored["opt_state"])), \
            rstep

    final, stats = run_with_restarts(
        total_steps=tc.total_steps, state=state, step_fn=one_step,
        save_fn=save, restore_fn=restore,
        checkpoint_every=tc.checkpoint_every,
        max_restarts=tc.max_restarts, failure_plan=failure_plan)
    ckpt.wait()
    loader.close()
    return final, history, stats
