from .optimizer import AdamWConfig, OptState, adamw_update, init_opt_state
from .train_step import make_train_step, train_step, loss_fn, init_all
from .loop import TrainConfig, TrainState, train

__all__ = ["AdamWConfig", "OptState", "adamw_update", "init_opt_state",
           "make_train_step", "train_step", "loss_fn", "init_all",
           "TrainConfig", "TrainState", "train"]
