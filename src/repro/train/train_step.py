"""Jitted training step: loss -> grads -> AdamW, with sharding specs.

``make_train_step`` returns a jitted function with in/out shardings bound
to the mesh (donated params/opt-state buffers) — this is exactly the
callable the multi-pod dry-run lowers with ShapeDtypeStructs.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..distributed import sharding as sh
from ..models import model as M
from .optimizer import AdamWConfig, OptState, adamw_update, init_opt_state


def loss_fn(params: Any, cfg: M.ModelConfig, tokens: jax.Array,
            labels: jax.Array) -> jax.Array:
    hidden = M.forward(params, cfg, tokens)
    return M.lm_loss(params, cfg, hidden, labels)


def train_step(params: Any, opt_state: OptState, tokens: jax.Array,
               labels: jax.Array, *, cfg: M.ModelConfig,
               opt_cfg: AdamWConfig, microbatches: int = 1):
    """One optimizer step.  ``microbatches > 1`` splits the global batch
    and accumulates gradients in fp32 over a scan — the activation
    working set shrinks by the same factor (the §5.7 regeneration lever
    for OOM train cells; identical math up to accumulation order)."""
    if microbatches == 1:
        loss, grads = jax.value_and_grad(loss_fn)(params, cfg, tokens,
                                                  labels)
    else:
        b = tokens.shape[0]
        assert b % microbatches == 0, (b, microbatches)
        tb = tokens.reshape((microbatches, b // microbatches)
                            + tokens.shape[1:])
        lb = labels.reshape((microbatches, b // microbatches)
                            + labels.shape[1:])

        def one(carry, tl):
            t, l = tl
            loss_i, g_i = jax.value_and_grad(loss_fn)(params, cfg, t, l)
            acc_l, acc_g = carry
            acc_g = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), acc_g, g_i)
            return (acc_l + loss_i, acc_g), None

        init = (jnp.zeros((), jnp.float32),
                jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params))
        (loss_sum, gsum), _ = jax.lax.scan(one, init, (tb, lb))
        loss = loss_sum / microbatches
        grads = jax.tree.map(lambda g: g / microbatches, gsum)
    new_params, new_state, metrics = adamw_update(opt_cfg, params, grads,
                                                  opt_state)
    metrics = {**metrics, "loss": loss}
    return new_params, new_state, metrics


def make_train_step(mesh: Mesh, cfg: M.ModelConfig,
                    opt_cfg: AdamWConfig, params_shape: Any,
                    global_batch: int, seq_len: int,
                    microbatches: int = 1):
    """Build the pjit'd train step + its input shardings.

    Returns (jitted_fn, shardings dict) where shardings has entries
    params / opt_state / tokens / labels.
    """
    p_shard = sh.shard_params(mesh, params_shape)
    needs_master = any(x.dtype != jnp.float32
                       for x in jax.tree.leaves(params_shape))
    o_shard = OptState(
        step=NamedSharding(mesh, P()),
        m=p_shard, v=p_shard,
        master=p_shard if needs_master else None)
    extra = 1 if cfg.embed_input else 2
    t_shard = sh.tokens_sharding(mesh, global_batch,
                                 extra_dims=extra)
    l_shard = sh.tokens_sharding(mesh, global_batch, extra_dims=1)
    metric_shard = {k: NamedSharding(mesh, P())
                    for k in ("grad_norm", "lr", "loss")}

    step = functools.partial(train_step, cfg=cfg, opt_cfg=opt_cfg,
                             microbatches=microbatches)
    jitted = jax.jit(
        step,
        in_shardings=(p_shard, o_shard, t_shard, l_shard),
        out_shardings=(p_shard, o_shard, metric_shard),
        donate_argnums=(0, 1),
    )
    shardings = {"params": p_shard, "opt_state": o_shard,
                 "tokens": t_shard, "labels": l_shard}
    return jitted, shardings


def init_all(cfg: M.ModelConfig, key: jax.Array):
    params = M.init_params(cfg, key)
    return params, init_opt_state(params)
