"""AdamW optimizer (hand-rolled — no optax in the container).

State mirrors the parameter pytree (m, v) in fp32; shardings follow the
parameter specs, giving ZeRO-style distribution of optimizer state for
free (params are 2D-sharded over (data, model)).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


class OptState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any
    # fp32 master weights when the model stores params in bf16
    # (mixed-precision recipe; None for fp32 params).
    master: Any = None


def init_opt_state(params: Any) -> OptState:
    def zeros(p):
        return jnp.zeros(p.shape, jnp.float32)

    needs_master = any(x.dtype != jnp.float32
                       for x in jax.tree.leaves(params))
    master = jax.tree.map(lambda p: p.astype(jnp.float32), params) \
        if needs_master else None
    return OptState(step=jnp.zeros((), jnp.int32),
                    m=jax.tree.map(zeros, params),
                    v=jax.tree.map(zeros, params),
                    master=master)


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    decay = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * decay


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, params: Any, grads: Any,
                 state: OptState) -> tuple[Any, OptState, dict]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v, master):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        # decoupled weight decay (skip 1-d params: norms, biases)
        wd = cfg.weight_decay if p.ndim >= 2 else 0.0
        base = p.astype(jnp.float32) if master is None else master
        new_master = base * (1 - lr * wd) - lr * delta
        new_p = new_master.astype(p.dtype)
        return new_p, m, v, (None if master is None else new_master)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    flat_master = treedef.flatten_up_to(state.master) \
        if state.master is not None else [None] * len(flat_p)
    out = [upd(p, g, m, v, mw)
           for p, g, m, v, mw in zip(flat_p, flat_g, flat_m, flat_v,
                                     flat_master)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    new_master = treedef.unflatten([o[3] for o in out]) \
        if state.master is not None else None
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, OptState(step, new_m, new_v, new_master), metrics
