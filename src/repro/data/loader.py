"""Background-prefetching loader over any ``batch(step)`` source."""
from __future__ import annotations

import queue
import threading


class PrefetchLoader:
    """Pulls batches on a daemon thread ``depth`` steps ahead.

    Restartable: ``seek(step)`` repositions the stream (used after
    checkpoint restore / elastic rescale)."""

    def __init__(self, source, start_step: int = 0, depth: int = 2):
        self._source = source
        self._depth = depth
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread: threading.Thread | None = None
        self._start()

    def _start(self):
        self._stop.clear()
        self._q = queue.Queue(maxsize=self._depth)

        def work(start: int):
            s = start
            while not self._stop.is_set():
                item = self._source.batch(s)
                while not self._stop.is_set():
                    try:
                        self._q.put((s, item), timeout=0.1)
                        break
                    except queue.Full:
                        continue
                s += 1

        self._thread = threading.Thread(target=work, args=(self._step,),
                                        daemon=True)
        self._thread.start()

    def next(self):
        step, item = self._q.get()
        self._step = step + 1
        return item

    def seek(self, step: int):
        self.close()
        self._step = step
        self._start()

    def close(self):
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2.0)
