"""Deterministic synthetic LM data pipeline.

Order-2 Markov token stream with a fixed transition structure: learnable
(loss drops well below the uniform entropy) and fully reproducible per
(seed, host, step), so elastic restarts re-produce the identical stream —
the property the checkpoint-restart tests rely on.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.n_hosts == 0
        return self.global_batch // self.n_hosts


def _transition(vocab: int, seed: int) -> np.ndarray:
    """Sparse-ish row-stochastic transition over (prev token) -> token."""
    rng = np.random.default_rng(seed + 1234)
    k = min(8, vocab)
    probs = np.full((vocab, vocab), 1e-9, np.float64)
    for i in range(vocab):
        nxt = rng.choice(vocab, size=k, replace=False)
        w = rng.dirichlet(np.ones(k)) * 0.9
        probs[i, nxt] += w
        probs[i] += 0.1 / vocab
    probs /= probs.sum(axis=1, keepdims=True)
    return probs


class SyntheticLM:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._trans = _transition(cfg.vocab, cfg.seed)
        self._cum = np.cumsum(self._trans, axis=1)

    def batch(self, step: int) -> tuple[np.ndarray, np.ndarray]:
        """(tokens, labels) of shape (host_batch, seq_len) int32."""
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 4096 + cfg.host_id)
        b, s = cfg.host_batch, cfg.seq_len
        toks = np.empty((b, s + 1), np.int32)
        toks[:, 0] = rng.integers(0, cfg.vocab, size=b)
        u = rng.random((b, s))
        for t in range(s):
            rows = self._cum[toks[:, t]]
            toks[:, t + 1] = (rows > u[:, t:t + 1]).argmax(axis=1)
        return toks[:, :-1].copy(), toks[:, 1:].copy()
