from .synthetic import DataConfig, SyntheticLM
from .loader import PrefetchLoader

__all__ = ["DataConfig", "SyntheticLM", "PrefetchLoader"]
