"""jaxpr -> TaskGraph lowering: the frontend's translation pass.

The paper's flow is source-to-source: unannotated affine code in, optimized
accelerator program out.  This module is that front door for JAX: it walks a
closed jaxpr (``pjit`` calls inlined, so ``jax.nn``-style jitted helpers are
seen through) and lowers the **affine subset** to
:class:`~repro.core.taskgraph.Statement` objects the solver/codegen stack
already understands:

========================  =================================================
primitive                 lowering
========================  =================================================
``dot_general``           contraction statement (``op="mul"``): batch +
                          free dims become output iterators, contracting
                          dims become reduction iterators;
                          ``flops_per_iter=2``
``add``/``sub``           elementwise statement (``op="add"``/``"sub"``);
                          size-1 operand dims read through a private
                          trip-1 iterator (exact under the projection
                          semantics); a scalar-*literal* operand folds
                          into the statement's affine ``offset``
``mul``                   elementwise joint-product statement
                          (``op="mul"``); a scalar-literal operand folds
                          into the statement ``coeff`` (``x * 2.0`` stays
                          affine)
``div``                   ``x / c`` folds to ``coeff = 1/c``; tensor
                          divisors lower to ``op="binary:div"``
``neg``                   affine copy with ``coeff = -1``
``max``/``min``           scalar-literal bound folds to
                          ``unary:max_const:<c>`` (relu's ``max(x, 0)``);
                          tensor bounds lower to ``binary:max``/``min``
``tanh``/``logistic``/
``exp``/``log``/...       pointwise ``unary:<name>`` statement (see
                          ``repro.kernels.contraction.ref``)
``integer_pow``           ``unary:pow_<k>``
``transpose``             projection copy (``op="add"``, permuted iters)
``broadcast_in_dim``      projection copy; new output dims broadcast,
                          size-1 source dims read through a trip-1 iter
``reshape``/``squeeze``   projection copy when only size-1 dims are
                          inserted/removed (the non-unit dim sequence is
                          unchanged); other reshapes go opaque
``convert_element_type``  float->float casts alias the operand (zero-cost
                          passthrough: statements compute in f32 and the
                          executable casts at function outputs only)
``reduce_sum``            projection statement with real reduction
                          iterators (rank-0 results fall back to opaque)
========================  =================================================

``pjit``, ``custom_jvp_call`` and ``custom_vjp_call`` sub-jaxprs are
inlined (primal semantics), so ``jax.nn``-style helpers (relu/silu/gelu)
are seen through.  Any floating dtype of at most 4 bytes is accepted —
statements evaluate in f32 internally and the lowering records the
narrowest traced float width (``precision_bytes``) so validation widens
its tolerance accordingly.

Everything else — comparisons, gathers, control flow, integer or f64
dtypes — is carved into **opaque passthrough segments**: maximal runs of
unsupported equations re-evaluated verbatim (``primitive.bind``) inside a
single statement whose semantics live in the codegen opaque registry.
Each opaque output statement reads only the segment inputs its own prefix
actually uses, so unrelated outputs do not inflate consumer counts.
Opaque statements still participate in graph dependencies, scheduling and
the whole-plan program; they are simply not tiled or permuted.  The
per-trace :class:`Coverage` records how much of the function the optimizer
actually owns.

Const values never enter the lowering result: jaxpr constvars become named
off-chip input arrays whose values are bound per
:class:`~repro.frontend.executable.TracedFunction`, so two traces with the
same structure share one graph (and therefore one program-cache entry).
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..codegen.reference import OPAQUE_PREFIX, register_opaque
from ..core.taskgraph import (Access, Statement, TaskGraph, copy_statement,
                              intermediate, iter_names)

try:                       # jax >= 0.4.36 moved the jaxpr types here
    from jax.extend.core import Literal, Var
except ImportError:        # pragma: no cover - older jax
    from jax.core import Literal, Var

#: Pointwise primitives lowered to ``unary:<name>`` statements.
UNARY_PRIMITIVES = ("tanh", "logistic", "exp", "log", "log1p", "expm1",
                    "sqrt", "rsqrt", "cbrt", "erf", "sin", "cos", "abs",
                    "sign", "floor", "ceil", "round")

#: Primitives lowered to affine statements (everything else goes opaque).
SUPPORTED_PRIMITIVES = ("dot_general", "add", "sub", "mul", "div", "neg",
                        "max", "min", "integer_pow", "transpose",
                        "broadcast_in_dim", "reshape", "squeeze",
                        "convert_element_type", "reduce_sum") \
    + UNARY_PRIMITIVES

#: Floating dtypes statements accept (computed in f32 internally; f64 stays
#: opaque so the lowering never silently narrows a wider request).
_FLOAT_OK = ("float32", "bfloat16", "float16")


# ---------------------------------------------------------------------------
# jaxpr flattening (pjit inlining)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class FlatEqn:
    """One primitive application with its inputs resolved through every
    inlined ``pjit`` boundary (invars are parent-scope atoms)."""

    eqn: Any                       # the original JaxprEqn
    invars: tuple[Any, ...]        # resolved atoms: Var | Literal
    outvars: tuple[Any, ...]


def flatten_jaxpr(jaxpr) -> tuple[list[FlatEqn], list[Any], dict]:
    """Inline ``pjit`` sub-jaxprs into one flat equation list.

    Returns ``(flat_eqns, resolved_outvars, sub_consts)`` where
    ``sub_consts`` maps sub-jaxpr constvars to their (structural) values —
    these become static graph inputs and feed the trace fingerprint.
    """
    subst: dict[Var, Any] = {}
    sub_consts: dict[Var, Any] = {}
    out: list[FlatEqn] = []

    def resolve(a):
        while isinstance(a, Var) and a in subst:
            a = subst[a]
        return a

    def inline(closed, eqn) -> bool:
        """Substitute a sub-jaxpr call in place; False if shapes mismatch."""
        sj = closed.jaxpr
        if len(sj.invars) != len(eqn.invars) \
                or len(sj.outvars) < len(eqn.outvars):
            return False
        for cv, cval in zip(sj.constvars, closed.consts):
            sub_consts[cv] = cval
        for iv, a in zip(sj.invars, eqn.invars):
            subst[iv] = resolve(a)
        walk(sj)
        for ov, sov in zip(eqn.outvars, sj.outvars):
            subst[ov] = resolve(sov)
        return True

    def walk(jx) -> None:
        for eqn in jx.eqns:
            if eqn.primitive.name == "pjit":
                if inline(eqn.params["jaxpr"], eqn):
                    continue
            elif eqn.primitive.name in ("custom_jvp_call",
                                        "custom_vjp_call"):
                # Primal semantics: the call_jaxpr IS the function being
                # differentiated — inline it exactly like a pjit body (the
                # jvp/fwd/bwd rules only matter under differentiation,
                # which a traced executable never performs).
                closed = eqn.params.get("call_jaxpr") \
                    or eqn.params.get("fun_jaxpr")
                if closed is not None and inline(closed, eqn):
                    continue
            out.append(FlatEqn(eqn, tuple(resolve(a) for a in eqn.invars),
                               tuple(eqn.outvars)))

    walk(jaxpr)
    resolved_outs = [resolve(v) for v in jaxpr.outvars]
    return out, resolved_outs, sub_consts


def fingerprint_jaxpr(closed, sub_consts: dict) -> str:
    """Content hash of a closed jaxpr: structure + input/const avals +
    inlined sub-jaxpr const values.  Two closures with the same structure
    but different top-level const *values* share a fingerprint on purpose —
    the graph is identical, only the bound values differ."""
    h = hashlib.sha256()
    h.update(str(closed.jaxpr).encode())
    for v in closed.jaxpr.invars:
        h.update(repr((tuple(v.aval.shape), str(v.aval.dtype))).encode())
    for c in closed.consts:
        h.update(repr((tuple(np.shape(c)),
                       str(np.result_type(c)))).encode())
    for v in sub_consts.values():
        h.update(np.asarray(v).tobytes())
    return h.hexdigest()


# ---------------------------------------------------------------------------
# Lowering result
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Coverage:
    """How much of the traced function the optimizer owns."""

    n_eqns: int
    n_supported: int
    supported_flops: float
    opaque_flops_est: float        # 1 flop per output element per opaque eqn

    @property
    def eqn_ratio(self) -> float:
        return self.n_supported / self.n_eqns if self.n_eqns else 1.0

    @property
    def flop_ratio(self) -> float:
        total = self.supported_flops + self.opaque_flops_est
        return self.supported_flops / total if total else 1.0

    def to_jsonable(self) -> dict:
        return {"n_eqns": self.n_eqns, "n_supported": self.n_supported,
                "eqn_ratio": round(self.eqn_ratio, 4),
                "flop_ratio": round(self.flop_ratio, 4)}


@dataclasses.dataclass(frozen=True)
class OutSpec:
    """How one flat function output is produced.

    ``kind="array"``: read from the executed graph's outputs under ``ref``;
    ``kind="binding"``: read straight from the bound input dict (an input,
    const or literal forwarded unchanged).  ``promoted`` marks rank-0
    values carried as shape-(1,) arrays inside the graph."""

    kind: str
    ref: str
    promoted: bool = False


@dataclasses.dataclass
class LoweredJaxpr:
    """The trace-cache value: everything derived from jaxpr *structure*.

    Const values are deliberately absent (bound per TracedFunction);
    ``static_bindings`` holds values that ARE structure — literals,
    inlined sub-jaxpr consts and synthetic constants the lowering itself
    introduced (the scalar zero seeding ``neg``)."""

    fingerprint: str
    graph: TaskGraph
    in_names: tuple[str, ...]                  # one per flat invar
    const_names: tuple[str, ...]               # one per closed.consts entry
    static_bindings: dict[str, jax.Array]
    in_avals: tuple[tuple[tuple[int, ...], Any], ...]
    out_specs: tuple[OutSpec, ...]
    out_avals: tuple[tuple[tuple[int, ...], Any], ...]
    coverage: Coverage
    opaque_ops: tuple[str, ...] = ()    # registry entries owned by this record
    #: Narrowest floating itemsize among supported statements' avals —
    #: statements compute in f32, so validation against the traced function
    #: must widen its tolerance to this precision band (bf16 intermediates
    #: in the jit baseline carry ~1e-2 relative error the f32 graph lacks).
    precision_bytes: int = 4
    plan_cache: dict = dataclasses.field(default_factory=dict)

    @property
    def graph_name(self) -> str:
        return self.graph.name


def graph_name_of(fingerprint: str) -> str:
    return f"traced:{fingerprint[:16]}"


# ---------------------------------------------------------------------------
# Opaque segment evaluation
# ---------------------------------------------------------------------------
def eval_flat_eqns(feqns: list[FlatEqn], env: dict) -> None:
    """Re-evaluate flat equations against a Var->value environment (the
    ``jax.core.eval_jaxpr`` loop, over resolved atoms)."""
    for fe in feqns:
        vals = [a.val if isinstance(a, Literal) else env[a]
                for a in fe.invars]
        subfuns, bind_params = fe.eqn.primitive.get_bind_params(
            fe.eqn.params)
        outs = fe.eqn.primitive.bind(*subfuns, *vals, **bind_params)
        if not fe.eqn.primitive.multiple_results:
            outs = [outs]
        for ov, o in zip(fe.outvars, outs):
            env[ov] = o


def _segment_callable(feqns: list[FlatEqn], in_vars: tuple,
                      unpromote: tuple[bool, ...], out_var,
                      promote_out: bool) -> Callable:
    """Traceable residual computing one needed output of an opaque segment.

    Each output statement re-derives the segment prefix up to its producer;
    in program mode XLA CSE collapses the duplicates back into one
    computation, so a k-output segment costs one evaluation."""

    def run(*vals):
        env: dict = {}
        for v, val, unp in zip(in_vars, vals, unpromote):
            val = jnp.reshape(val, ()) if unp else val
            # Statements compute in f32 regardless of the traced dtype —
            # restore the dtype the segment's jaxpr was traced with so its
            # primitives see the avals they were bound against.
            if val.dtype != v.aval.dtype:
                val = val.astype(v.aval.dtype)
            env[v] = val
        eval_flat_eqns(feqns, env)
        out = env[out_var]
        return jnp.reshape(out, (1,)) if promote_out else out

    return run


# ---------------------------------------------------------------------------
# Lowering context
# ---------------------------------------------------------------------------
class _Ctx:
    def __init__(self, fingerprint: str):
        self.fingerprint = fingerprint
        self.arrays: dict[str, Any] = {}
        self.statements: list[Statement] = []
        self.var_name: dict[Var, str] = {}
        self.promoted: set[str] = set()
        self.static: dict[str, jax.Array] = {}
        self._literals: dict[tuple, str] = {}
        self._n = 0
        self.supported_flops = 0.0
        self.opaque_flops_est = 0.0
        self.opaque_ops: list[str] = []

    def fresh(self, stem: str) -> str:
        self._n += 1
        return f"t{self._n}_{stem}"

    def add_array(self, name: str, shape, dtype) -> str:
        self.arrays[name] = intermediate(
            name, tuple(shape), dtype_bytes=np.dtype(dtype).itemsize)
        return name

    def name_of(self, atom) -> str:
        if isinstance(atom, Literal):
            return self.static_value(atom.val)
        return self.var_name[atom]

    def static_value(self, val: np.ndarray) -> str:
        """Materialize a structural constant as a named static input."""
        val = np.asarray(val)
        key = (val.tobytes(), str(val.dtype), val.shape)
        name = self._literals.get(key)
        if name is None:
            name = f"lit{len(self._literals)}"
            self._literals[key] = name
            self.add_array(name, val.shape, val.dtype)
            self.static[name] = jnp.asarray(val)
        return name

    def static_scalar(self, value: float) -> str:
        return self.static_value(np.float32(value))

    def emit(self, stmt: Statement, outvar, shape=None, dtype=None) -> None:
        out = stmt.writes[0].array
        aval = outvar.aval
        self.add_array(out, aval.shape if shape is None else shape,
                       aval.dtype if dtype is None else dtype)
        self.statements.append(stmt)
        self.var_name[outvar] = out


# ---------------------------------------------------------------------------
# Supported-primitive handlers (one Statement each)
# ---------------------------------------------------------------------------
def _h_dot_general(ctx: _Ctx, fe: FlatEqn) -> None:
    (lc, rc), (lb, rb) = fe.eqn.params["dimension_numbers"]
    lhs, rhs = fe.invars
    lshape, rshape = lhs.aval.shape, rhs.aval.shape
    out_aval = fe.outvars[0].aval
    name = ctx.fresh("dot")
    out_its = iter_names(name, len(out_aval.shape))
    red_its = iter_names(name, len(lc), "r")
    lfree = [d for d in range(len(lshape)) if d not in lb and d not in lc]
    rfree = [d for d in range(len(rshape)) if d not in rb and d not in rc]
    lits: list[str] = [""] * len(lshape)
    for i, d in enumerate(lb):
        lits[d] = out_its[i]
    for i, d in enumerate(lc):
        lits[d] = red_its[i]
    for i, d in enumerate(lfree):
        lits[d] = out_its[len(lb) + i]
    rits: list[str] = [""] * len(rshape)
    for i, d in enumerate(rb):
        rits[d] = out_its[i]
    for i, d in enumerate(rc):
        rits[d] = red_its[i]
    for i, d in enumerate(rfree):
        rits[d] = out_its[len(lb) + len(lfree) + i]
    trip = {it: int(n) for it, n in zip(out_its, out_aval.shape)}
    for i, d in enumerate(lc):
        trip[red_its[i]] = int(lshape[d])
    stmt = Statement(
        name=name, loops=tuple(out_its) + tuple(red_its), trip_counts=trip,
        reads=(Access(ctx.name_of(lhs), tuple(lits)),
               Access(ctx.name_of(rhs), tuple(rits))),
        writes=(Access(name, out_its),), flops_per_iter=2.0, op="mul")
    ctx.emit(stmt, fe.outvars[0])


def _ew_access(ctx: _Ctx, atom, out_its, out_shape, name: str,
               z_its: list[str], trip: dict[str, int]) -> Access:
    """Access map of one elementwise operand: same-size dims share the
    output iterator; size-1 broadcast dims read through a private trip-1
    iterator (summed out exactly); scalars read with rank-0 access."""
    shp = atom.aval.shape
    if len(shp) == 0:
        return Access(ctx.name_of(atom), ())
    its = []
    for d, (s, os) in enumerate(zip(shp, out_shape)):
        if int(s) == int(os):
            its.append(out_its[d])
        else:                                   # s == 1: broadcast dim
            z = f"{name}_z{len(z_its)}"
            z_its.append(z)
            trip[z] = 1
            its.append(z)
    return Access(ctx.name_of(atom), tuple(its))


def _h_elementwise(op: str):
    def handler(ctx: _Ctx, fe: FlatEqn) -> None:
        out_aval = fe.outvars[0].aval
        name = ctx.fresh(op)
        out_its = iter_names(name, len(out_aval.shape))
        trip = {it: int(n) for it, n in zip(out_its, out_aval.shape)}
        z_its: list[str] = []
        reads = tuple(_ew_access(ctx, a, out_its, out_aval.shape, name,
                                 z_its, trip) for a in fe.invars)
        stmt = Statement(
            name=name, loops=tuple(out_its) + tuple(z_its),
            trip_counts=trip, reads=reads,
            writes=(Access(name, out_its),), flops_per_iter=1.0, op=op)
        ctx.emit(stmt, fe.outvars[0])
    return handler


def _scalar_literal(atom) -> float | None:
    """The float value of a rank-0 numeric literal operand, else None —
    the foldable subset (value is structure, not a bound input)."""
    if isinstance(atom, Literal) and np.ndim(atom.val) == 0 \
            and np.issubdtype(np.result_type(atom.val), np.number):
        return float(atom.val)
    return None


def _emit_scaled_copy(ctx: _Ctx, fe: FlatEqn, src, coeff: float,
                      offset: float, stem: str) -> None:
    """``out = coeff * src + offset`` as a single-read affine statement —
    scalar-literal mul/add/sub/div/neg all land here."""
    out_aval = fe.outvars[0].aval
    name = ctx.fresh(stem)
    out_its = iter_names(name, len(out_aval.shape))
    trip = {it: int(n) for it, n in zip(out_its, out_aval.shape)}
    z_its: list[str] = []
    read = _ew_access(ctx, src, out_its, out_aval.shape, name, z_its, trip)
    stmt = Statement(
        name=name, loops=tuple(out_its) + tuple(z_its), trip_counts=trip,
        reads=(read,), writes=(Access(name, out_its),),
        flops_per_iter=1.0, op="add", coeff=coeff, offset=offset)
    ctx.emit(stmt, fe.outvars[0])


def _h_mul(ctx: _Ctx, fe: FlatEqn) -> None:
    a, b = fe.invars
    ca, cb = _scalar_literal(a), _scalar_literal(b)
    if ca is not None and cb is None:
        return _emit_scaled_copy(ctx, fe, b, ca, 0.0, "smul")
    if cb is not None and ca is None:
        return _emit_scaled_copy(ctx, fe, a, cb, 0.0, "smul")
    _h_elementwise("mul")(ctx, fe)


def _h_add_sub(op: str):
    def handler(ctx: _Ctx, fe: FlatEqn) -> None:
        a, b = fe.invars
        ca, cb = _scalar_literal(a), _scalar_literal(b)
        if cb is not None and ca is None:
            return _emit_scaled_copy(
                ctx, fe, a, 1.0, cb if op == "add" else -cb, "sadd")
        if ca is not None and cb is None:
            if op == "add":
                return _emit_scaled_copy(ctx, fe, b, 1.0, ca, "sadd")
            return _emit_scaled_copy(ctx, fe, b, -1.0, ca, "sadd")
        _h_elementwise(op)(ctx, fe)
    return handler


def _h_neg(ctx: _Ctx, fe: FlatEqn) -> None:
    _emit_scaled_copy(ctx, fe, fe.invars[0], -1.0, 0.0, "neg")


def _h_binary(name: str):
    """Pointwise two-operand family (``binary:max``/``min``/``div``) —
    operand order preserved (division is not commutative)."""
    def handler(ctx: _Ctx, fe: FlatEqn) -> None:
        out_aval = fe.outvars[0].aval
        sname = ctx.fresh(name)
        out_its = iter_names(sname, len(out_aval.shape))
        trip = {it: int(n) for it, n in zip(out_its, out_aval.shape)}
        z_its: list[str] = []
        reads = tuple(_ew_access(ctx, a, out_its, out_aval.shape, sname,
                                 z_its, trip) for a in fe.invars)
        stmt = Statement(
            name=sname, loops=tuple(out_its) + tuple(z_its),
            trip_counts=trip, reads=reads,
            writes=(Access(sname, out_its),), flops_per_iter=1.0,
            op=f"binary:{name}")
        ctx.emit(stmt, fe.outvars[0])
    return handler


def _h_div(ctx: _Ctx, fe: FlatEqn) -> None:
    c = _scalar_literal(fe.invars[1])
    if c is not None and c != 0.0:
        return _emit_scaled_copy(ctx, fe, fe.invars[0], 1.0 / c, 0.0,
                                 "sdiv")
    _h_binary("div")(ctx, fe)


def _h_minmax(name: str):
    def handler(ctx: _Ctx, fe: FlatEqn) -> None:
        a, b = fe.invars
        ca, cb = _scalar_literal(a), _scalar_literal(b)
        src, c = (b, ca) if ca is not None else (a, cb)
        if c is not None and (ca is None or cb is None):
            # clamp against a folded constant: relu's ``max(x, 0.0)``
            return _h_unary(f"{name}_const:{c!r}", stem=name)(
                ctx, dataclasses.replace(fe, invars=(src,)))
        _h_binary(name)(ctx, fe)
    return handler


def _h_unary(name: str, flops: float = 2.0, stem: str | None = None):
    def handler(ctx: _Ctx, fe: FlatEqn) -> None:
        out_aval = fe.outvars[0].aval
        sname = ctx.fresh(stem or name)
        out_its = iter_names(sname, len(out_aval.shape))
        stmt = Statement(
            name=sname, loops=out_its,
            trip_counts={it: int(n)
                         for it, n in zip(out_its, out_aval.shape)},
            reads=(Access(ctx.name_of(fe.invars[0]), out_its),),
            writes=(Access(sname, out_its),), flops_per_iter=flops,
            op=f"unary:{name}")
        ctx.emit(stmt, fe.outvars[0])
    return handler


def _h_integer_pow(ctx: _Ctx, fe: FlatEqn) -> None:
    _h_unary(f"pow_{int(fe.eqn.params['y'])}", stem="pow")(ctx, fe)


def _h_convert(ctx: _Ctx, fe: FlatEqn) -> None:
    """float->float casts are pure aliases: statements compute in f32 and
    the executable casts at function outputs, so the cast costs nothing
    (the jit baseline pays a real convert here)."""
    src = fe.invars[0]
    name = ctx.name_of(src)
    ctx.var_name[fe.outvars[0]] = name


def _h_transpose(ctx: _Ctx, fe: FlatEqn) -> None:
    perm = tuple(fe.eqn.params["permutation"])
    out_aval = fe.outvars[0].aval
    name = ctx.fresh("tr")
    out_its = iter_names(name, len(out_aval.shape))
    src_its = tuple(out_its[perm.index(d)] for d in range(len(perm)))
    ctx.emit(copy_statement(
        name, name, ctx.name_of(fe.invars[0]), src_its, out_its,
        {it: int(n) for it, n in zip(out_its, out_aval.shape)}),
        fe.outvars[0])


def _h_broadcast_in_dim(ctx: _Ctx, fe: FlatEqn) -> None:
    bd = tuple(fe.eqn.params["broadcast_dimensions"])
    src = fe.invars[0]
    out_aval = fe.outvars[0].aval
    name = ctx.fresh("bc")
    out_its = iter_names(name, len(out_aval.shape))
    trip = {it: int(n) for it, n in zip(out_its, out_aval.shape)}
    z_its: list[str] = []
    its: list[str] = []
    for p, s in enumerate(src.aval.shape):
        if int(s) == int(out_aval.shape[bd[p]]):
            its.append(out_its[bd[p]])
        else:                                   # size-1 source dim
            z = f"{name}_z{len(z_its)}"
            z_its.append(z)
            trip[z] = 1
            its.append(z)
    stmt = Statement(
        name=name, loops=tuple(out_its) + tuple(z_its), trip_counts=trip,
        reads=(Access(ctx.name_of(src), tuple(its)),),
        writes=(Access(name, out_its),), flops_per_iter=0.0, op="add")
    ctx.emit(stmt, fe.outvars[0])


def _h_reshape(ctx: _Ctx, fe: FlatEqn) -> None:
    """Singleton-insert/remove reshapes (and ``squeeze``) as projection
    copies: non-unit dims keep their order, so each non-unit source dim
    reads the matching output iterator; size-1 source dims read through a
    trip-1 iterator and size-1 output dims are broadcast."""
    src = fe.invars[0]
    out_aval = fe.outvars[0].aval
    out_shape = tuple(int(n) for n in out_aval.shape)
    src_shape = tuple(int(n) for n in src.aval.shape)
    name = ctx.fresh("rs")
    out_its = iter_names(name, len(out_shape))
    trip = {it: int(n) for it, n in zip(out_its, out_shape)}
    nz_out = [i for i, n in enumerate(out_shape) if n != 1]
    z_its: list[str] = []
    src_its: list[str] = []
    k = 0
    for s in src_shape:
        if s == 1:
            z = f"{name}_z{len(z_its)}"
            z_its.append(z)
            trip[z] = 1
            src_its.append(z)
        else:
            src_its.append(out_its[nz_out[k]])
            k += 1
    stmt = Statement(
        name=name, loops=tuple(out_its) + tuple(z_its), trip_counts=trip,
        reads=(Access(ctx.name_of(src), tuple(src_its)),),
        writes=(Access(name, out_its),), flops_per_iter=0.0, op="add")
    ctx.emit(stmt, fe.outvars[0])


def _h_reduce_sum(ctx: _Ctx, fe: FlatEqn) -> None:
    axes = tuple(fe.eqn.params["axes"])
    src = fe.invars[0]
    out_aval = fe.outvars[0].aval
    name = ctx.fresh("rsum")
    out_its = iter_names(name, len(out_aval.shape))
    red_its = iter_names(name, len(axes), "r")
    trip = {it: int(n) for it, n in zip(out_its, out_aval.shape)}
    its: list[str] = []
    kept = 0
    for d, s in enumerate(src.aval.shape):
        if d in axes:
            r = red_its[axes.index(d)]
            trip[r] = int(s)
            its.append(r)
        else:
            its.append(out_its[kept])
            kept += 1
    stmt = Statement(
        name=name, loops=tuple(out_its) + tuple(red_its), trip_counts=trip,
        reads=(Access(ctx.name_of(src), tuple(its)),),
        writes=(Access(name, out_its),), flops_per_iter=1.0, op="add")
    ctx.emit(stmt, fe.outvars[0])


HANDLERS: dict[str, Callable[[_Ctx, FlatEqn], None]] = {
    "dot_general": _h_dot_general,
    "add": _h_add_sub("add"),
    "sub": _h_add_sub("sub"),
    "mul": _h_mul,
    "div": _h_div,
    "neg": _h_neg,
    "max": _h_minmax("max"),
    "min": _h_minmax("min"),
    "integer_pow": _h_integer_pow,
    "transpose": _h_transpose,
    "broadcast_in_dim": _h_broadcast_in_dim,
    "reshape": _h_reshape,
    "squeeze": _h_reshape,
    "convert_element_type": _h_convert,
    "reduce_sum": _h_reduce_sum,
    **{p: _h_unary(p) for p in UNARY_PRIMITIVES},
}


def _float_ok(dtype) -> bool:
    return str(np.dtype(dtype)) in _FLOAT_OK


def _nonunit(shape) -> tuple[int, ...]:
    return tuple(int(n) for n in shape if int(n) != 1)


def _prim_supported(fe: FlatEqn) -> bool:
    """Per-primitive structural constraints beyond the generic gate."""
    name = fe.eqn.primitive.name
    if name == "reshape":
        if fe.eqn.params.get("dimensions") is not None:
            return False                     # fused transpose-reshape
        return _nonunit(fe.invars[0].aval.shape) == \
            _nonunit(fe.outvars[0].aval.shape)
    if name == "squeeze":
        return True
    return True


def _supported(fe: FlatEqn, eqn_produced: set) -> bool:
    if fe.eqn.primitive.name not in HANDLERS:
        return False
    if len(fe.outvars) != 1:
        return False
    out_aval = fe.outvars[0].aval
    if not _float_ok(out_aval.dtype) or len(out_aval.shape) == 0:
        return False
    if any(int(n) == 0 for n in out_aval.shape):
        return False
    for a in fe.invars:
        if not _float_ok(a.aval.dtype):
            # non-float operands are only acceptable as foldable scalar
            # literals (``x * 2`` with an int literal)
            if _scalar_literal(a) is None:
                return False
        if any(int(n) == 0 for n in a.aval.shape):
            return False
        # A rank-0 value produced by an equation comes out of an opaque
        # segment promoted to shape (1,); affine statements cannot read
        # it — the consumer joins the opaque segment instead.
        if isinstance(a, Var) and a in eqn_produced \
                and len(a.aval.shape) == 0:
            return False
    return _prim_supported(fe)


# ---------------------------------------------------------------------------
# Main lowering pass
# ---------------------------------------------------------------------------
def lower_flat(closed, flat_eqns: list[FlatEqn], resolved_outs: list,
               sub_consts: dict, fingerprint: str) -> LoweredJaxpr:
    """Lower one flattened closed jaxpr into a :class:`LoweredJaxpr`."""
    ctx = _Ctx(fingerprint)
    jaxpr = closed.jaxpr

    in_names = []
    for i, v in enumerate(jaxpr.invars):
        name = f"in{i}"
        ctx.add_array(name, v.aval.shape, v.aval.dtype)
        ctx.var_name[v] = name
        in_names.append(name)
    const_names = []
    for i, v in enumerate(jaxpr.constvars):
        name = f"c{i}"
        ctx.add_array(name, v.aval.shape, v.aval.dtype)
        ctx.var_name[v] = name
        const_names.append(name)
    for i, (v, val) in enumerate(sub_consts.items()):
        name = f"sc{i}"
        arr = np.asarray(val)
        ctx.add_array(name, arr.shape, arr.dtype)
        ctx.static[name] = jnp.asarray(val)
        ctx.var_name[v] = name

    eqn_produced: set = set()
    n_supported = 0
    pending: list[tuple[int, FlatEqn]] = []
    # vars needed outside any opaque segment: read by a later equation or
    # returned by the function
    last_reader: dict[Var, int] = {}
    for idx, fe in enumerate(flat_eqns):
        for a in fe.invars:
            if isinstance(a, Var):
                last_reader[a] = idx
    needed_late = {a for a in resolved_outs if isinstance(a, Var)}

    def flush_opaque() -> None:
        nonlocal pending
        if not pending:
            return
        seg = pending
        pending = []
        seg_first, seg_last = seg[0][0], seg[-1][0]
        feqns = [fe for (_, fe) in seg]
        defined = {ov for fe in feqns for ov in fe.outvars}
        # outputs needed beyond the segment
        outs = []
        for fi, fe in enumerate(feqns):
            for ov in fe.outvars:
                if ov in needed_late or last_reader.get(ov, -1) > seg_last:
                    outs.append((fi, ov))
        ctx.opaque_flops_est += sum(
            float(np.prod(ov.aval.shape)) if ov.aval.shape else 1.0
            for fe in feqns for ov in fe.outvars)
        for k, (fi, ov) in enumerate(outs):
            # Each output statement re-runs only its own prefix, so it
            # reads only the external inputs that prefix actually uses —
            # otherwise every segment output would count as a consumer of
            # every segment input and inflate materialization boundaries.
            prefix = feqns[:fi + 1]
            ins: list[Var] = []
            for pfe in prefix:
                for a in pfe.invars:
                    if isinstance(a, Var) and a not in defined \
                            and a not in ins:
                        ins.append(a)
            in_names_seg = tuple(ctx.name_of(a) for a in ins)
            unpromote = tuple(n in ctx.promoted for n in in_names_seg)
            promote = len(ov.aval.shape) == 0
            shape = (1,) if promote else tuple(int(n)
                                               for n in ov.aval.shape)
            name = ctx.fresh("opq")
            digest = hashlib.sha256(
                f"{fingerprint}:{seg_first}:{k}".encode()).hexdigest()
            op = f"{OPAQUE_PREFIX}{digest[:24]}"
            register_opaque(op, _segment_callable(
                prefix, tuple(ins), unpromote, ov, promote))
            ctx.opaque_ops.append(op)
            out_its = iter_names(name, len(shape))
            stmt = Statement(
                name=name, loops=out_its,
                trip_counts={it: int(n)
                             for it, n in zip(out_its, shape)},
                reads=tuple(Access(n, ()) for n in in_names_seg),
                writes=(Access(name, out_its),),
                flops_per_iter=1.0, op=op)
            ctx.emit(stmt, ov, shape=shape, dtype=ov.aval.dtype)
            if promote:
                ctx.promoted.add(name)

    precision_bytes = 4
    for idx, fe in enumerate(flat_eqns):
        if _supported(fe, eqn_produced):
            flush_opaque()
            n_before = len(ctx.statements)
            HANDLERS[fe.eqn.primitive.name](ctx, fe)
            n_supported += 1
            # dtype aliases (convert_element_type) emit no statement
            ctx.supported_flops += sum(
                s.flops for s in ctx.statements[n_before:])
            for a in tuple(fe.invars) + tuple(fe.outvars):
                dt = np.dtype(a.aval.dtype)
                # jnp.issubdtype: ml_dtypes (bfloat16) are not numpy floats
                if jnp.issubdtype(dt, jnp.floating):
                    precision_bytes = min(precision_bytes, dt.itemsize)
        else:
            pending.append((idx, fe))
        eqn_produced.update(fe.outvars)
    flush_opaque()

    # ---- function outputs -------------------------------------------------
    produced = {s.writes[0].array for s in ctx.statements}
    read_anywhere = {a.array for s in ctx.statements for a in s.reads}
    out_specs: list[OutSpec] = []
    out_avals: list[tuple] = []
    copied: dict[str, str] = {}
    for v in resolved_outs:
        if isinstance(v, Literal):
            name = ctx.name_of(v)
            out_specs.append(OutSpec("binding", name))
            val = np.asarray(v.val)
            out_avals.append((val.shape, val.dtype))
            continue
        name = ctx.var_name[v]
        aval = v.aval
        out_avals.append((tuple(int(n) for n in aval.shape), aval.dtype))
        promoted = name in ctx.promoted
        if name not in produced:
            out_specs.append(OutSpec("binding", name, promoted))
            continue
        if name in read_anywhere:
            # consumed downstream: forward through a copy so the value
            # stays a *final* graph output
            cname = copied.get(name)
            if cname is None:
                cname = f"{name}_out"
                arr = ctx.arrays[name]
                its = iter_names(cname, len(arr.shape))
                ctx.statements.append(copy_statement(
                    cname, cname, name, its, its,
                    dict(zip(its, arr.shape))))
                ctx.arrays[cname] = intermediate(
                    cname, arr.shape, dtype_bytes=arr.dtype_bytes)
                copied[name] = cname
                if promoted:
                    ctx.promoted.add(cname)
            out_specs.append(OutSpec("array", cname, promoted))
        else:
            out_specs.append(OutSpec("array", name, promoted))

    # Work-reducing rewrites before the graph freezes: matmul chains keep
    # the user's association order in the jaxpr, but the graph may legally
    # re-parenthesize to the cheapest order (final outputs stay put).
    from ..core.rewrite import reassociate_matmul_chains
    reassociate_matmul_chains(
        ctx.arrays, ctx.statements,
        protected={spec.ref for spec in out_specs if spec.kind == "array"})
    graph = TaskGraph(name=graph_name_of(fingerprint),
                      arrays=ctx.arrays, statements=ctx.statements,
                      traced=True)
    coverage = Coverage(
        n_eqns=len(flat_eqns), n_supported=n_supported,
        supported_flops=ctx.supported_flops,
        opaque_flops_est=ctx.opaque_flops_est)
    return LoweredJaxpr(
        fingerprint=fingerprint,
        graph=graph,
        in_names=tuple(in_names),
        const_names=tuple(const_names),
        static_bindings=dict(ctx.static),
        in_avals=tuple((tuple(int(n) for n in v.aval.shape), v.aval.dtype)
                       for v in jaxpr.invars),
        out_specs=tuple(out_specs),
        out_avals=tuple(out_avals),
        coverage=coverage,
        opaque_ops=tuple(ctx.opaque_ops),
        precision_bytes=precision_bytes,
    )
