"""jaxpr -> TaskGraph lowering: the frontend's translation pass.

The paper's flow is source-to-source: unannotated affine code in, optimized
accelerator program out.  This module is that front door for JAX: it walks a
closed jaxpr (``pjit`` calls inlined, so ``jax.nn``-style jitted helpers are
seen through) and lowers the **affine subset** to
:class:`~repro.core.taskgraph.Statement` objects the solver/codegen stack
already understands:

====================  =====================================================
primitive             lowering
====================  =====================================================
``dot_general``       contraction statement (``op="mul"``): batch + free
                      dims become output iterators, contracting dims become
                      reduction iterators; ``flops_per_iter=2``
``add``/``sub``       elementwise statement (``op="add"``/``"sub"``);
                      size-1 operand dims read through a private trip-1
                      reduction iterator (exact under the projection
                      semantics), scalar operands read with rank-0 access
``mul``               elementwise joint-product statement (``op="mul"``)
``neg``               ``0 - x`` (``op="sub"`` seeded by a shared scalar
                      zero constant)
``transpose``         projection copy (``op="add"``, permuted read iters)
``broadcast_in_dim``  projection copy; new output dims broadcast, size-1
                      source dims read through a trip-1 iterator
``reduce_sum``        projection statement with real reduction iterators
                      (full-axis sums; rank-0 results fall back to opaque)
====================  =====================================================

Everything else — transcendentals, comparisons, gathers, control flow,
non-f32 dtypes — is carved into **opaque passthrough segments**: maximal
runs of unsupported equations re-evaluated verbatim (``primitive.bind``)
inside a single statement whose semantics live in the codegen opaque
registry.  Opaque statements still participate in graph dependencies,
scheduling and the whole-plan program; they are simply not tiled or
permuted.  The per-trace :class:`Coverage` records how much of the function
the optimizer actually owns.

Const values never enter the lowering result: jaxpr constvars become named
off-chip input arrays whose values are bound per
:class:`~repro.frontend.executable.TracedFunction`, so two traces with the
same structure share one graph (and therefore one program-cache entry).
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..codegen.reference import OPAQUE_PREFIX, register_opaque
from ..core.taskgraph import (Access, Statement, TaskGraph, copy_statement,
                              intermediate, iter_names)

try:                       # jax >= 0.4.36 moved the jaxpr types here
    from jax.extend.core import Literal, Var
except ImportError:        # pragma: no cover - older jax
    from jax.core import Literal, Var

#: Primitives lowered to affine statements (everything else goes opaque).
SUPPORTED_PRIMITIVES = ("dot_general", "add", "sub", "mul", "neg",
                        "transpose", "broadcast_in_dim", "reduce_sum")


# ---------------------------------------------------------------------------
# jaxpr flattening (pjit inlining)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class FlatEqn:
    """One primitive application with its inputs resolved through every
    inlined ``pjit`` boundary (invars are parent-scope atoms)."""

    eqn: Any                       # the original JaxprEqn
    invars: tuple[Any, ...]        # resolved atoms: Var | Literal
    outvars: tuple[Any, ...]


def flatten_jaxpr(jaxpr) -> tuple[list[FlatEqn], list[Any], dict]:
    """Inline ``pjit`` sub-jaxprs into one flat equation list.

    Returns ``(flat_eqns, resolved_outvars, sub_consts)`` where
    ``sub_consts`` maps sub-jaxpr constvars to their (structural) values —
    these become static graph inputs and feed the trace fingerprint.
    """
    subst: dict[Var, Any] = {}
    sub_consts: dict[Var, Any] = {}
    out: list[FlatEqn] = []

    def resolve(a):
        while isinstance(a, Var) and a in subst:
            a = subst[a]
        return a

    def walk(jx) -> None:
        for eqn in jx.eqns:
            if eqn.primitive.name == "pjit":
                closed = eqn.params["jaxpr"]
                sj = closed.jaxpr
                for cv, cval in zip(sj.constvars, closed.consts):
                    sub_consts[cv] = cval
                for iv, a in zip(sj.invars, eqn.invars):
                    subst[iv] = resolve(a)
                walk(sj)
                for ov, sov in zip(eqn.outvars, sj.outvars):
                    subst[ov] = resolve(sov)
                continue
            out.append(FlatEqn(eqn, tuple(resolve(a) for a in eqn.invars),
                               tuple(eqn.outvars)))

    walk(jaxpr)
    resolved_outs = [resolve(v) for v in jaxpr.outvars]
    return out, resolved_outs, sub_consts


def fingerprint_jaxpr(closed, sub_consts: dict) -> str:
    """Content hash of a closed jaxpr: structure + input/const avals +
    inlined sub-jaxpr const values.  Two closures with the same structure
    but different top-level const *values* share a fingerprint on purpose —
    the graph is identical, only the bound values differ."""
    h = hashlib.sha256()
    h.update(str(closed.jaxpr).encode())
    for v in closed.jaxpr.invars:
        h.update(repr((tuple(v.aval.shape), str(v.aval.dtype))).encode())
    for c in closed.consts:
        h.update(repr((tuple(np.shape(c)),
                       str(np.result_type(c)))).encode())
    for v in sub_consts.values():
        h.update(np.asarray(v).tobytes())
    return h.hexdigest()


# ---------------------------------------------------------------------------
# Lowering result
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Coverage:
    """How much of the traced function the optimizer owns."""

    n_eqns: int
    n_supported: int
    supported_flops: float
    opaque_flops_est: float        # 1 flop per output element per opaque eqn

    @property
    def eqn_ratio(self) -> float:
        return self.n_supported / self.n_eqns if self.n_eqns else 1.0

    @property
    def flop_ratio(self) -> float:
        total = self.supported_flops + self.opaque_flops_est
        return self.supported_flops / total if total else 1.0

    def to_jsonable(self) -> dict:
        return {"n_eqns": self.n_eqns, "n_supported": self.n_supported,
                "eqn_ratio": round(self.eqn_ratio, 4),
                "flop_ratio": round(self.flop_ratio, 4)}


@dataclasses.dataclass(frozen=True)
class OutSpec:
    """How one flat function output is produced.

    ``kind="array"``: read from the executed graph's outputs under ``ref``;
    ``kind="binding"``: read straight from the bound input dict (an input,
    const or literal forwarded unchanged).  ``promoted`` marks rank-0
    values carried as shape-(1,) arrays inside the graph."""

    kind: str
    ref: str
    promoted: bool = False


@dataclasses.dataclass
class LoweredJaxpr:
    """The trace-cache value: everything derived from jaxpr *structure*.

    Const values are deliberately absent (bound per TracedFunction);
    ``static_bindings`` holds values that ARE structure — literals,
    inlined sub-jaxpr consts and synthetic constants the lowering itself
    introduced (the scalar zero seeding ``neg``)."""

    fingerprint: str
    graph: TaskGraph
    in_names: tuple[str, ...]                  # one per flat invar
    const_names: tuple[str, ...]               # one per closed.consts entry
    static_bindings: dict[str, jax.Array]
    in_avals: tuple[tuple[tuple[int, ...], Any], ...]
    out_specs: tuple[OutSpec, ...]
    out_avals: tuple[tuple[tuple[int, ...], Any], ...]
    coverage: Coverage
    opaque_ops: tuple[str, ...] = ()    # registry entries owned by this record
    plan_cache: dict = dataclasses.field(default_factory=dict)

    @property
    def graph_name(self) -> str:
        return self.graph.name


def graph_name_of(fingerprint: str) -> str:
    return f"traced:{fingerprint[:16]}"


# ---------------------------------------------------------------------------
# Opaque segment evaluation
# ---------------------------------------------------------------------------
def eval_flat_eqns(feqns: list[FlatEqn], env: dict) -> None:
    """Re-evaluate flat equations against a Var->value environment (the
    ``jax.core.eval_jaxpr`` loop, over resolved atoms)."""
    for fe in feqns:
        vals = [a.val if isinstance(a, Literal) else env[a]
                for a in fe.invars]
        subfuns, bind_params = fe.eqn.primitive.get_bind_params(
            fe.eqn.params)
        outs = fe.eqn.primitive.bind(*subfuns, *vals, **bind_params)
        if not fe.eqn.primitive.multiple_results:
            outs = [outs]
        for ov, o in zip(fe.outvars, outs):
            env[ov] = o


def _segment_callable(feqns: list[FlatEqn], in_vars: tuple,
                      unpromote: tuple[bool, ...], out_var,
                      promote_out: bool) -> Callable:
    """Traceable residual computing one needed output of an opaque segment.

    Each output statement re-derives the segment prefix up to its producer;
    in program mode XLA CSE collapses the duplicates back into one
    computation, so a k-output segment costs one evaluation."""

    def run(*vals):
        env: dict = {}
        for v, val, unp in zip(in_vars, vals, unpromote):
            env[v] = jnp.reshape(val, ()) if unp else val
        eval_flat_eqns(feqns, env)
        out = env[out_var]
        return jnp.reshape(out, (1,)) if promote_out else out

    return run


# ---------------------------------------------------------------------------
# Lowering context
# ---------------------------------------------------------------------------
class _Ctx:
    def __init__(self, fingerprint: str):
        self.fingerprint = fingerprint
        self.arrays: dict[str, Any] = {}
        self.statements: list[Statement] = []
        self.var_name: dict[Var, str] = {}
        self.promoted: set[str] = set()
        self.static: dict[str, jax.Array] = {}
        self._literals: dict[tuple, str] = {}
        self._n = 0
        self.supported_flops = 0.0
        self.opaque_flops_est = 0.0
        self.opaque_ops: list[str] = []

    def fresh(self, stem: str) -> str:
        self._n += 1
        return f"t{self._n}_{stem}"

    def add_array(self, name: str, shape, dtype) -> str:
        self.arrays[name] = intermediate(
            name, tuple(shape), dtype_bytes=np.dtype(dtype).itemsize)
        return name

    def name_of(self, atom) -> str:
        if isinstance(atom, Literal):
            return self.static_value(atom.val)
        return self.var_name[atom]

    def static_value(self, val: np.ndarray) -> str:
        """Materialize a structural constant as a named static input."""
        val = np.asarray(val)
        key = (val.tobytes(), str(val.dtype), val.shape)
        name = self._literals.get(key)
        if name is None:
            name = f"lit{len(self._literals)}"
            self._literals[key] = name
            self.add_array(name, val.shape, val.dtype)
            self.static[name] = jnp.asarray(val)
        return name

    def static_scalar(self, value: float) -> str:
        return self.static_value(np.float32(value))

    def emit(self, stmt: Statement, outvar, shape=None, dtype=None) -> None:
        out = stmt.writes[0].array
        aval = outvar.aval
        self.add_array(out, aval.shape if shape is None else shape,
                       aval.dtype if dtype is None else dtype)
        self.statements.append(stmt)
        self.var_name[outvar] = out


# ---------------------------------------------------------------------------
# Supported-primitive handlers (one Statement each)
# ---------------------------------------------------------------------------
def _h_dot_general(ctx: _Ctx, fe: FlatEqn) -> None:
    (lc, rc), (lb, rb) = fe.eqn.params["dimension_numbers"]
    lhs, rhs = fe.invars
    lshape, rshape = lhs.aval.shape, rhs.aval.shape
    out_aval = fe.outvars[0].aval
    name = ctx.fresh("dot")
    out_its = iter_names(name, len(out_aval.shape))
    red_its = iter_names(name, len(lc), "r")
    lfree = [d for d in range(len(lshape)) if d not in lb and d not in lc]
    rfree = [d for d in range(len(rshape)) if d not in rb and d not in rc]
    lits: list[str] = [""] * len(lshape)
    for i, d in enumerate(lb):
        lits[d] = out_its[i]
    for i, d in enumerate(lc):
        lits[d] = red_its[i]
    for i, d in enumerate(lfree):
        lits[d] = out_its[len(lb) + i]
    rits: list[str] = [""] * len(rshape)
    for i, d in enumerate(rb):
        rits[d] = out_its[i]
    for i, d in enumerate(rc):
        rits[d] = red_its[i]
    for i, d in enumerate(rfree):
        rits[d] = out_its[len(lb) + len(lfree) + i]
    trip = {it: int(n) for it, n in zip(out_its, out_aval.shape)}
    for i, d in enumerate(lc):
        trip[red_its[i]] = int(lshape[d])
    stmt = Statement(
        name=name, loops=tuple(out_its) + tuple(red_its), trip_counts=trip,
        reads=(Access(ctx.name_of(lhs), tuple(lits)),
               Access(ctx.name_of(rhs), tuple(rits))),
        writes=(Access(name, out_its),), flops_per_iter=2.0, op="mul")
    ctx.emit(stmt, fe.outvars[0])


def _ew_access(ctx: _Ctx, atom, out_its, out_shape, name: str,
               z_its: list[str], trip: dict[str, int]) -> Access:
    """Access map of one elementwise operand: same-size dims share the
    output iterator; size-1 broadcast dims read through a private trip-1
    iterator (summed out exactly); scalars read with rank-0 access."""
    shp = atom.aval.shape
    if len(shp) == 0:
        return Access(ctx.name_of(atom), ())
    its = []
    for d, (s, os) in enumerate(zip(shp, out_shape)):
        if int(s) == int(os):
            its.append(out_its[d])
        else:                                   # s == 1: broadcast dim
            z = f"{name}_z{len(z_its)}"
            z_its.append(z)
            trip[z] = 1
            its.append(z)
    return Access(ctx.name_of(atom), tuple(its))


def _h_elementwise(op: str):
    def handler(ctx: _Ctx, fe: FlatEqn) -> None:
        out_aval = fe.outvars[0].aval
        name = ctx.fresh(op)
        out_its = iter_names(name, len(out_aval.shape))
        trip = {it: int(n) for it, n in zip(out_its, out_aval.shape)}
        z_its: list[str] = []
        reads = tuple(_ew_access(ctx, a, out_its, out_aval.shape, name,
                                 z_its, trip) for a in fe.invars)
        stmt = Statement(
            name=name, loops=tuple(out_its) + tuple(z_its),
            trip_counts=trip, reads=reads,
            writes=(Access(name, out_its),), flops_per_iter=1.0, op=op)
        ctx.emit(stmt, fe.outvars[0])
    return handler


def _h_neg(ctx: _Ctx, fe: FlatEqn) -> None:
    out_aval = fe.outvars[0].aval
    name = ctx.fresh("neg")
    out_its = iter_names(name, len(out_aval.shape))
    zero = ctx.static_scalar(0.0)
    stmt = Statement(
        name=name, loops=out_its,
        trip_counts={it: int(n) for it, n in zip(out_its, out_aval.shape)},
        reads=(Access(zero, ()), Access(ctx.name_of(fe.invars[0]), out_its)),
        writes=(Access(name, out_its),), flops_per_iter=1.0, op="sub")
    ctx.emit(stmt, fe.outvars[0])


def _h_transpose(ctx: _Ctx, fe: FlatEqn) -> None:
    perm = tuple(fe.eqn.params["permutation"])
    out_aval = fe.outvars[0].aval
    name = ctx.fresh("tr")
    out_its = iter_names(name, len(out_aval.shape))
    src_its = tuple(out_its[perm.index(d)] for d in range(len(perm)))
    ctx.emit(copy_statement(
        name, name, ctx.name_of(fe.invars[0]), src_its, out_its,
        {it: int(n) for it, n in zip(out_its, out_aval.shape)}),
        fe.outvars[0])


def _h_broadcast_in_dim(ctx: _Ctx, fe: FlatEqn) -> None:
    bd = tuple(fe.eqn.params["broadcast_dimensions"])
    src = fe.invars[0]
    out_aval = fe.outvars[0].aval
    name = ctx.fresh("bc")
    out_its = iter_names(name, len(out_aval.shape))
    trip = {it: int(n) for it, n in zip(out_its, out_aval.shape)}
    z_its: list[str] = []
    its: list[str] = []
    for p, s in enumerate(src.aval.shape):
        if int(s) == int(out_aval.shape[bd[p]]):
            its.append(out_its[bd[p]])
        else:                                   # size-1 source dim
            z = f"{name}_z{len(z_its)}"
            z_its.append(z)
            trip[z] = 1
            its.append(z)
    stmt = Statement(
        name=name, loops=tuple(out_its) + tuple(z_its), trip_counts=trip,
        reads=(Access(ctx.name_of(src), tuple(its)),),
        writes=(Access(name, out_its),), flops_per_iter=0.0, op="add")
    ctx.emit(stmt, fe.outvars[0])


def _h_reduce_sum(ctx: _Ctx, fe: FlatEqn) -> None:
    axes = tuple(fe.eqn.params["axes"])
    src = fe.invars[0]
    out_aval = fe.outvars[0].aval
    name = ctx.fresh("rsum")
    out_its = iter_names(name, len(out_aval.shape))
    red_its = iter_names(name, len(axes), "r")
    trip = {it: int(n) for it, n in zip(out_its, out_aval.shape)}
    its: list[str] = []
    kept = 0
    for d, s in enumerate(src.aval.shape):
        if d in axes:
            r = red_its[axes.index(d)]
            trip[r] = int(s)
            its.append(r)
        else:
            its.append(out_its[kept])
            kept += 1
    stmt = Statement(
        name=name, loops=tuple(out_its) + tuple(red_its), trip_counts=trip,
        reads=(Access(ctx.name_of(src), tuple(its)),),
        writes=(Access(name, out_its),), flops_per_iter=1.0, op="add")
    ctx.emit(stmt, fe.outvars[0])


HANDLERS: dict[str, Callable[[_Ctx, FlatEqn], None]] = {
    "dot_general": _h_dot_general,
    "add": _h_elementwise("add"),
    "sub": _h_elementwise("sub"),
    "mul": _h_elementwise("mul"),
    "neg": _h_neg,
    "transpose": _h_transpose,
    "broadcast_in_dim": _h_broadcast_in_dim,
    "reduce_sum": _h_reduce_sum,
}


def _supported(fe: FlatEqn, eqn_produced: set) -> bool:
    if fe.eqn.primitive.name not in HANDLERS:
        return False
    if len(fe.outvars) != 1:
        return False
    out_aval = fe.outvars[0].aval
    if out_aval.dtype != np.float32 or len(out_aval.shape) == 0:
        return False
    if any(int(n) == 0 for n in out_aval.shape):
        return False
    for a in fe.invars:
        if a.aval.dtype != np.float32:
            return False
        if any(int(n) == 0 for n in a.aval.shape):
            return False
        # A rank-0 value produced by an equation comes out of an opaque
        # segment promoted to shape (1,); affine statements cannot read
        # it — the consumer joins the opaque segment instead.
        if isinstance(a, Var) and a in eqn_produced \
                and len(a.aval.shape) == 0:
            return False
    return True


# ---------------------------------------------------------------------------
# Main lowering pass
# ---------------------------------------------------------------------------
def lower_flat(closed, flat_eqns: list[FlatEqn], resolved_outs: list,
               sub_consts: dict, fingerprint: str) -> LoweredJaxpr:
    """Lower one flattened closed jaxpr into a :class:`LoweredJaxpr`."""
    ctx = _Ctx(fingerprint)
    jaxpr = closed.jaxpr

    in_names = []
    for i, v in enumerate(jaxpr.invars):
        name = f"in{i}"
        ctx.add_array(name, v.aval.shape, v.aval.dtype)
        ctx.var_name[v] = name
        in_names.append(name)
    const_names = []
    for i, v in enumerate(jaxpr.constvars):
        name = f"c{i}"
        ctx.add_array(name, v.aval.shape, v.aval.dtype)
        ctx.var_name[v] = name
        const_names.append(name)
    for i, (v, val) in enumerate(sub_consts.items()):
        name = f"sc{i}"
        arr = np.asarray(val)
        ctx.add_array(name, arr.shape, arr.dtype)
        ctx.static[name] = jnp.asarray(val)
        ctx.var_name[v] = name

    eqn_produced: set = set()
    n_supported = 0
    pending: list[tuple[int, FlatEqn]] = []
    # vars needed outside any opaque segment: read by a later equation or
    # returned by the function
    last_reader: dict[Var, int] = {}
    for idx, fe in enumerate(flat_eqns):
        for a in fe.invars:
            if isinstance(a, Var):
                last_reader[a] = idx
    needed_late = {a for a in resolved_outs if isinstance(a, Var)}

    def flush_opaque() -> None:
        nonlocal pending
        if not pending:
            return
        seg = pending
        pending = []
        seg_first, seg_last = seg[0][0], seg[-1][0]
        feqns = [fe for (_, fe) in seg]
        defined = {ov for fe in feqns for ov in fe.outvars}
        # ordered unique external inputs
        ins: list[Var] = []
        for fe in feqns:
            for a in fe.invars:
                if isinstance(a, Var) and a not in defined and a not in ins:
                    ins.append(a)
        in_names_seg = tuple(ctx.name_of(a) for a in ins)
        unpromote = tuple(n in ctx.promoted for n in in_names_seg)
        # outputs needed beyond the segment
        outs = []
        for fi, fe in enumerate(feqns):
            for ov in fe.outvars:
                if ov in needed_late or last_reader.get(ov, -1) > seg_last:
                    outs.append((fi, ov))
        ctx.opaque_flops_est += sum(
            float(np.prod(ov.aval.shape)) if ov.aval.shape else 1.0
            for fe in feqns for ov in fe.outvars)
        for k, (fi, ov) in enumerate(outs):
            promote = len(ov.aval.shape) == 0
            shape = (1,) if promote else tuple(int(n)
                                               for n in ov.aval.shape)
            name = ctx.fresh("opq")
            digest = hashlib.sha256(
                f"{fingerprint}:{seg_first}:{k}".encode()).hexdigest()
            op = f"{OPAQUE_PREFIX}{digest[:24]}"
            register_opaque(op, _segment_callable(
                feqns[:fi + 1], tuple(ins), unpromote, ov, promote))
            ctx.opaque_ops.append(op)
            out_its = iter_names(name, len(shape))
            stmt = Statement(
                name=name, loops=out_its,
                trip_counts={it: int(n)
                             for it, n in zip(out_its, shape)},
                reads=tuple(Access(n, ()) for n in in_names_seg),
                writes=(Access(name, out_its),),
                flops_per_iter=1.0, op=op)
            ctx.emit(stmt, ov, shape=shape, dtype=ov.aval.dtype)
            if promote:
                ctx.promoted.add(name)

    for idx, fe in enumerate(flat_eqns):
        if _supported(fe, eqn_produced):
            flush_opaque()
            HANDLERS[fe.eqn.primitive.name](ctx, fe)
            n_supported += 1
            ctx.supported_flops += ctx.statements[-1].flops
        else:
            pending.append((idx, fe))
        eqn_produced.update(fe.outvars)
    flush_opaque()

    # ---- function outputs -------------------------------------------------
    produced = {s.writes[0].array for s in ctx.statements}
    read_anywhere = {a.array for s in ctx.statements for a in s.reads}
    out_specs: list[OutSpec] = []
    out_avals: list[tuple] = []
    copied: dict[str, str] = {}
    for v in resolved_outs:
        if isinstance(v, Literal):
            name = ctx.name_of(v)
            out_specs.append(OutSpec("binding", name))
            val = np.asarray(v.val)
            out_avals.append((val.shape, val.dtype))
            continue
        name = ctx.var_name[v]
        aval = v.aval
        out_avals.append((tuple(int(n) for n in aval.shape), aval.dtype))
        promoted = name in ctx.promoted
        if name not in produced:
            out_specs.append(OutSpec("binding", name, promoted))
            continue
        if name in read_anywhere:
            # consumed downstream: forward through a copy so the value
            # stays a *final* graph output
            cname = copied.get(name)
            if cname is None:
                cname = f"{name}_out"
                arr = ctx.arrays[name]
                its = iter_names(cname, len(arr.shape))
                ctx.statements.append(copy_statement(
                    cname, cname, name, its, its,
                    dict(zip(its, arr.shape))))
                ctx.arrays[cname] = intermediate(
                    cname, arr.shape, dtype_bytes=arr.dtype_bytes)
                copied[name] = cname
                if promoted:
                    ctx.promoted.add(cname)
            out_specs.append(OutSpec("array", cname, promoted))
        else:
            out_specs.append(OutSpec("array", name, promoted))

    graph = TaskGraph(name=graph_name_of(fingerprint),
                      arrays=ctx.arrays, statements=ctx.statements)
    coverage = Coverage(
        n_eqns=len(flat_eqns), n_supported=n_supported,
        supported_flops=ctx.supported_flops,
        opaque_flops_est=ctx.opaque_flops_est)
    return LoweredJaxpr(
        fingerprint=fingerprint,
        graph=graph,
        in_names=tuple(in_names),
        const_names=tuple(const_names),
        static_bindings=dict(ctx.static),
        in_avals=tuple((tuple(int(n) for n in v.aval.shape), v.aval.dtype)
                       for v in jaxpr.invars),
        out_specs=tuple(out_specs),
        out_avals=tuple(out_avals),
        coverage=coverage,
        opaque_ops=tuple(ctx.opaque_ops),
    )
