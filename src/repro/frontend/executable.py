"""TracedFunction: a lowered JAX callable bound to its closure values.

The trace cache stores :class:`~repro.frontend.lowering.LoweredJaxpr` —
pure structure.  A :class:`TracedFunction` is one *instance* of that
structure: the original callable (kept for oracle validation), the const
values captured by its closure, and the pytree layout of its arguments and
results.  It knows how to

* ``solve()`` — run the NLP solver over the traced graph (plan cached on
  the shared record, so two traces of the same structure solve once);
* ``executable()`` — build a positional-argument callable around the
  plan-faithful executor (whole-plan compiled program by default), binding
  inputs/consts to graph arrays and casting outputs back to the traced
  dtypes;
* ``validate()`` — execute and compare against ``jax.jit(fn)``, the oracle
  the acceptance contract names.

Rank-0 values are carried through the graph as shape-(1,) arrays (the
``promoted`` flag) and reshaped back at the boundary.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from .lowering import Coverage, LoweredJaxpr


def _default_rtol(dtype) -> float:
    """Scale-aware oracle tolerance per dtype: f32 blocked accumulation
    stays at the codegen oracle's 2e-4; half-precision oracles (bf16/f16)
    round at ~4e-3 relative, so they get the looser band."""
    return 2e-2 if np.dtype(dtype).itemsize <= 2 else 2e-4


@dataclasses.dataclass
class TracedFunction:
    """One traced (fn, example shapes) pair, ready to solve and serve."""

    fn: Callable
    record: LoweredJaxpr
    const_values: tuple
    in_tree: Any
    out_tree: Any
    example_flat: tuple
    name: str

    def __post_init__(self):
        self._consts = {
            n: jnp.asarray(v)
            for n, v in zip(self.record.const_names, self.const_values)}

    # -- introspection ----------------------------------------------------
    @property
    def graph(self):
        return self.record.graph

    @property
    def fingerprint(self) -> str:
        return self.record.fingerprint

    @property
    def coverage(self) -> Coverage:
        return self.record.coverage

    def __repr__(self) -> str:
        c = self.coverage
        return (f"TracedFunction({self.name}, graph={self.graph.name}, "
                f"statements={len(self.graph.statements)}, "
                f"coverage={c.n_supported}/{c.n_eqns} eqns "
                f"({c.flop_ratio:.0%} est. flops))")

    # -- binding ----------------------------------------------------------
    def bind(self, flat_inputs) -> dict:
        """Graph-array environment for one call: positional inputs, bound
        consts, and the structural static values (literals etc.)."""
        env = dict(zip(self.record.in_names, flat_inputs))
        env.update(self._consts)
        env.update(self.record.static_bindings)
        return env

    def bind_args(self, args: tuple) -> dict:
        """Flatten positional args (checking the traced pytree/avals) and
        bind them — the entry the serving engine uses."""
        flat, tree = jax.tree_util.tree_flatten(tuple(args))
        if tree != self.in_tree:
            raise TypeError(
                f"{self.name}: argument structure {tree} does not match "
                f"the traced structure {self.in_tree}")
        flat = [jnp.asarray(v) for v in flat]
        for i, (v, (shape, dtype)) in enumerate(
                zip(flat, self.record.in_avals)):
            if tuple(v.shape) != tuple(shape) or v.dtype != dtype:
                raise ValueError(
                    f"{self.name}: argument {i} is {v.shape}/{v.dtype}, "
                    f"traced as {shape}/{np.dtype(dtype)} — re-trace the "
                    "function for new shapes/dtypes")
        return self.bind(flat)

    def unbind(self, outs: dict, env: dict):
        """Assemble the function's pytree result from executed graph
        outputs + the bound environment, restoring rank and dtype."""
        flat_out = []
        for spec, (shape, dtype) in zip(self.record.out_specs,
                                        self.record.out_avals):
            v = outs[spec.ref] if spec.kind == "array" else env[spec.ref]
            if spec.promoted:
                v = jnp.reshape(v, ())
            if v.dtype != dtype:
                v = v.astype(dtype)
            flat_out.append(v)
        return jax.tree_util.tree_unflatten(self.out_tree, flat_out)

    # -- solving / execution ----------------------------------------------
    def solve(self, hw=None, opts=None):
        """Solve the traced graph (cached on the shared record when called
        with default hardware/options, so repeated traces and the serving
        engine reuse one plan)."""
        from ..core.solver import solve
        if not self.graph.statements:
            return None
        default = hw is None and opts is None
        if default and "default" in self.record.plan_cache:
            return self.record.plan_cache["default"]
        if opts is None:
            from ..core.solver import SolverOptions
            opts = SolverOptions(time_budget_s=20.0)
        plan = solve(self.graph, hw, opts)
        if default:
            self.record.plan_cache["default"] = plan
        return plan

    def executable(self, impl: str | None = None, mode: str = "program",
                   pool_size: int | None = None, hw=None, opts=None,
                   plan=None) -> "TracedExecutable":
        if plan is None:
            plan = self.solve(hw=hw, opts=opts)
        return TracedExecutable(self, plan, impl=impl, mode=mode,
                                pool_size=pool_size)

    def validate(self, *args, impl: str | None = None,
                 mode: str = "program", rtol: float | None = None,
                 plan=None) -> bool:
        """Execute the traced graph and assert it matches ``jax.jit(fn)``
        (the oracle) on ``args`` (default: the example inputs).  Scale-aware
        per-output comparison; raises ``AssertionError`` on mismatch."""
        from ..codegen.reference import assert_close
        if not args:
            args = jax.tree_util.tree_unflatten(
                self.in_tree, list(self.example_flat))
        expect = jax.jit(self.fn)(*args)
        got = self.executable(impl=impl, mode=mode, plan=plan)(*args)
        e_flat, e_tree = jax.tree_util.tree_flatten(expect)
        g_flat, g_tree = jax.tree_util.tree_flatten(got)
        assert e_tree == g_tree, (e_tree, g_tree)
        for i, (e, g) in enumerate(zip(e_flat, g_flat)):
            tol = rtol if rtol is not None else _default_rtol(e.dtype)
            assert_close(g, e, rtol=tol,
                         name=f"{self.name} output {i} vs jax.jit oracle")
        return True


class TracedExecutable:
    """Positional-argument callable over the plan-faithful executor.

    Mirrors the original function's signature and result pytree; inside, it
    is the same :class:`~repro.codegen.executor.PlanExecutable` (and
    therefore the same process-wide compiled-program cache) the serving
    engine uses.  A trace whose graph holds no statements (pure passthrough
    functions) short-circuits to binding alone.
    """

    def __init__(self, tf: TracedFunction, plan, impl: str | None = None,
                 mode: str = "program", pool_size: int | None = None):
        from ..codegen import plan_executor
        self.tf = tf
        self.plan = plan
        self._exe = None
        if tf.graph.statements:
            if plan is None:
                raise ValueError(f"{tf.name}: no plan for non-empty graph")
            self._exe = plan_executor(tf.graph, plan, impl=impl, mode=mode,
                                      pool_size=pool_size)

    @property
    def executor(self):
        return self._exe

    def __call__(self, *args):
        env = self.tf.bind_args(args)
        outs = self._exe(env) if self._exe is not None else {}
        return self.tf.unbind(outs, env)
