"""TracedFunction: a lowered JAX callable bound to its closure values.

The trace cache stores :class:`~repro.frontend.lowering.LoweredJaxpr` —
pure structure.  A :class:`TracedFunction` is one *instance* of that
structure: the original callable (kept for oracle validation), the const
values captured by its closure, and the pytree layout of its arguments and
results.  It knows how to

* ``solve()`` — run the NLP solver over the traced graph (plan cached on
  the shared record, so two traces of the same structure solve once);
* ``executable()`` — build a positional-argument callable around the
  plan-faithful executor (whole-plan compiled program by default), binding
  inputs/consts to graph arrays and casting outputs back to the traced
  dtypes;
* ``validate()`` — execute and compare against ``jax.jit(fn)``, the oracle
  the acceptance contract names.

Rank-0 values are carried through the graph as shape-(1,) arrays (the
``promoted`` flag) and reshaped back at the boundary.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from .lowering import Coverage, LoweredJaxpr


def _default_rtol(dtype) -> float:
    """Scale-aware oracle tolerance per dtype: f32 blocked accumulation
    stays at the codegen oracle's 2e-4; half-precision oracles (bf16/f16)
    round at ~4e-3 relative, so they get the looser band."""
    return 2e-2 if np.dtype(dtype).itemsize <= 2 else 2e-4


@dataclasses.dataclass
class TracedFunction:
    """One traced (fn, example shapes) pair, ready to solve and serve."""

    fn: Callable
    record: LoweredJaxpr
    const_values: tuple
    in_tree: Any
    out_tree: Any
    example_flat: tuple
    name: str

    def __post_init__(self):
        self._consts = {
            n: jnp.asarray(v)
            for n, v in zip(self.record.const_names, self.const_values)}
        # bucket -> batched TracedFunction (this instance's consts bound);
        # the underlying lowering is shared process-wide by
        # (fingerprint, bucket) through the trace cache
        self._batched: dict[int, "TracedFunction"] = {}
        self._batched_lock = threading.Lock()

    # -- introspection ----------------------------------------------------
    @property
    def graph(self):
        return self.record.graph

    @property
    def fingerprint(self) -> str:
        return self.record.fingerprint

    @property
    def coverage(self) -> Coverage:
        return self.record.coverage

    def __repr__(self) -> str:
        c = self.coverage
        return (f"TracedFunction({self.name}, graph={self.graph.name}, "
                f"statements={len(self.graph.statements)}, "
                f"coverage={c.n_supported}/{c.n_eqns} eqns "
                f"({c.flop_ratio:.0%} est. flops))")

    # -- binding ----------------------------------------------------------
    def bind(self, flat_inputs) -> dict:
        """Graph-array environment for one call: positional inputs, bound
        consts, and the structural static values (literals etc.)."""
        env = dict(zip(self.record.in_names, flat_inputs))
        env.update(self._consts)
        env.update(self.record.static_bindings)
        return env

    def bind_args(self, args: tuple) -> dict:
        """Flatten positional args (checking the traced pytree/avals) and
        bind them — the entry the serving engine uses."""
        flat, tree = jax.tree_util.tree_flatten(tuple(args))
        if tree != self.in_tree:
            raise TypeError(
                f"{self.name}: argument structure {tree} does not match "
                f"the traced structure {self.in_tree}")
        flat = [jnp.asarray(v) for v in flat]
        for i, (v, (shape, dtype)) in enumerate(
                zip(flat, self.record.in_avals)):
            if tuple(v.shape) != tuple(shape) or v.dtype != dtype:
                raise ValueError(
                    f"{self.name}: argument {i} is {v.shape}/{v.dtype}, "
                    f"traced as {shape}/{np.dtype(dtype)} — re-trace the "
                    "function for new shapes/dtypes")
        return self.bind(flat)

    def unbind(self, outs: dict, env: dict):
        """Assemble the function's pytree result from executed graph
        outputs + the bound environment, restoring rank and dtype."""
        flat_out = []
        for spec, (shape, dtype) in zip(self.record.out_specs,
                                        self.record.out_avals):
            v = outs[spec.ref] if spec.kind == "array" else env[spec.ref]
            if spec.promoted:
                v = jnp.reshape(v, ())
            if v.dtype != dtype:
                v = v.astype(dtype)
            flat_out.append(v)
        return jax.tree_util.tree_unflatten(self.out_tree, flat_out)

    # -- batching ---------------------------------------------------------
    def batched(self, bucket: int) -> "TracedFunction":
        """This function re-traced with a leading batch dimension of
        ``bucket`` (see :func:`repro.frontend.trace.batched_trace`).
        Memoized per instance; the lowering itself is shared process-wide
        by ``(fingerprint, bucket)``, so the continuous-batching tier pays
        one re-trace per bucket per structure, not per engine."""
        with self._batched_lock:
            btf = self._batched.get(bucket)
        if btf is not None:
            return btf
        from .trace import batched_trace
        btf = batched_trace(self, bucket)
        with self._batched_lock:
            return self._batched.setdefault(bucket, btf)

    # -- solving / execution ----------------------------------------------
    def solve(self, hw=None, opts=None, *, allow_stale: bool = False):
        """Solve the traced graph (cached on the shared record when called
        with default hardware/options, so repeated traces and the serving
        engine reuse one plan).  ``allow_stale`` flows to the plan store:
        a plan priced for an older hardware profile is accepted (marked
        ``stale_hw``) so the caller can refresh it off the hot path."""
        from ..core.solver import solve
        if not self.graph.statements:
            return None
        default = hw is None and opts is None
        if default and "default" in self.record.plan_cache:
            return self.record.plan_cache["default"]
        if opts is None:
            from ..core.solver import SolverOptions
            opts = SolverOptions(time_budget_s=20.0)
        plan = solve(self.graph, hw, opts, allow_stale=allow_stale)
        if default:
            self.record.plan_cache["default"] = plan
        return plan

    def executable(self, impl: str | None = None, mode: str = "program",
                   pool_size: int | None = None, hw=None, opts=None,
                   plan=None) -> "TracedExecutable":
        if plan is None:
            plan = self.solve(hw=hw, opts=opts)
        return TracedExecutable(self, plan, impl=impl, mode=mode,
                                pool_size=pool_size)

    def validate(self, *args, impl: str | None = None,
                 mode: str = "program", rtol: float | None = None,
                 plan=None) -> bool:
        """Execute the traced graph and assert it matches ``jax.jit(fn)``
        (the oracle) on ``args`` (default: the example inputs).  Scale-aware
        per-output comparison; raises ``AssertionError`` on mismatch."""
        from ..codegen.reference import assert_close
        if not args:
            args = jax.tree_util.tree_unflatten(
                self.in_tree, list(self.example_flat))
        expect = jax.jit(self.fn)(*args)
        got = self.executable(impl=impl, mode=mode, plan=plan)(*args)
        e_flat, e_tree = jax.tree_util.tree_flatten(expect)
        g_flat, g_tree = jax.tree_util.tree_flatten(got)
        assert e_tree == g_tree, (e_tree, g_tree)
        # When the trace carries half-precision values anywhere (inputs or
        # intermediates), the oracle itself rounds at that resolution even
        # if the outputs are f32 — compare in the narrowest band.
        band = 2e-2 if self.record.precision_bytes <= 2 else 0.0
        for i, (e, g) in enumerate(zip(e_flat, g_flat)):
            tol = rtol if rtol is not None else max(_default_rtol(e.dtype),
                                                    band)
            assert_close(g, e, rtol=tol,
                         name=f"{self.name} output {i} vs jax.jit oracle")
        return True


class TracedExecutable:
    """Positional-argument callable over the plan-faithful executor.

    Mirrors the original function's signature and result pytree; inside, it
    is the same :class:`~repro.codegen.executor.PlanExecutable` (and
    therefore the same process-wide compiled-program cache) the serving
    engine uses.  A trace whose graph holds no statements (pure passthrough
    functions) short-circuits to binding alone.
    """

    def __init__(self, tf: TracedFunction, plan, impl: str | None = None,
                 mode: str = "program", pool_size: int | None = None):
        from ..codegen import plan_executor
        self.tf = tf
        self.plan = plan
        self._exe = None
        if tf.graph.statements:
            if plan is None:
                raise ValueError(f"{tf.name}: no plan for non-empty graph")
            self._exe = plan_executor(tf.graph, plan, impl=impl, mode=mode,
                                      pool_size=pool_size)
        # With an explicit impl the compiled program is immutable for this
        # executable's lifetime: resolve it once and call it directly
        # (impl=None keeps the per-call resolution so ``kernel_impl``
        # scoping still applies).
        self._run = self._exe
        if self._exe is not None and impl is not None and mode == "program":
            self._run = self._exe.program(impl)
        # Precomputed fast-call structures: the steady-state serving path
        # must cost dict work, not per-leaf jnp.asarray + aval formatting
        # (measured ~80us/call on the frontend benchmark — larger than the
        # entire jit-vs-program gap it was hiding).
        rec = tf.record
        self._in_tree = tf.in_tree
        self._in_names = rec.in_names
        self._in_avals = tuple((tuple(s), np.dtype(d))
                               for s, d in rec.in_avals)
        self._base_env = {**tf._consts, **rec.static_bindings}
        self._out_info = tuple(
            (sp.ref, sp.kind == "array", sp.promoted, np.dtype(d))
            for sp, (_, d) in zip(rec.out_specs, rec.out_avals))
        # Boundary restoration (rank-0 demotion, dtype cast back to the
        # traced output dtype) as ONE jitted call: an eager ``astype`` per
        # output costs a full dispatch (~70us/call measured on the frontend
        # benchmark's bf16 chain — half the workload's runtime).
        restore = tuple((promoted, dt)
                        for _, _, promoted, dt in self._out_info)

        def _restore(*vals):
            out = []
            for v, (promoted, dt) in zip(vals, restore):
                if promoted:
                    v = jnp.reshape(v, ())
                if v.dtype != dt:
                    v = v.astype(dt)
                out.append(v)
            return tuple(out)

        self._finish = jax.jit(_restore)
        # Whole-call jit: for single-segment single-device programs, the
        # ENTIRE call — pytree/aval contract checks, const binding, the
        # segment body and boundary restoration — traces into one jitted
        # function over the original argument pytree.  The checks and dict
        # work run at *trace* time (once per signature, raising the same
        # TypeError/ValueError the slow path raises); a steady-state call
        # is a single C++ jit dispatch, the exact price ``jax.jit(fn)``
        # pays.  (The generic path through PlanProgram.__call__ adds an
        # env dict, a counter lock and pool rotation: ~9us/call measured
        # on the frontend benchmark.)
        self._direct = None
        entry = getattr(self._run, "entry", lambda: None)()
        if entry is not None:
            seg_in, seg_out, body = entry
            base_env, in_names = self._base_env, self._in_names
            in_tree, in_avals = self._in_tree, self._in_avals
            out_info, out_tree, name = self._out_info, tf.out_tree, tf.name

            def _direct(*call_args):
                flat, tree = jax.tree_util.tree_flatten(call_args)
                if tree != in_tree:
                    raise TypeError(
                        f"{name}: argument structure {tree} does not "
                        f"match the traced structure {in_tree}")
                for i, (v, (shape, dt)) in enumerate(zip(flat, in_avals)):
                    if tuple(v.shape) != shape or v.dtype != dt:
                        raise ValueError(
                            f"{name}: argument {i} is {v.shape}/{v.dtype},"
                            f" traced as {shape}/{dt} — re-trace the "
                            "function for new shapes/dtypes")
                env = dict(base_env)
                env.update(zip(in_names, flat))
                outs = dict(zip(seg_out,
                                body(*[env[a] for a in seg_in])))
                vals = []
                for ref, is_array, promoted, dt in out_info:
                    v = outs[ref] if is_array else env[ref]
                    if promoted:
                        v = jnp.reshape(v, ())
                    if v.dtype != dt:
                        v = v.astype(dt)
                    vals.append(v)
                return jax.tree_util.tree_unflatten(out_tree, vals)

            self._direct = jax.jit(_direct)

    @property
    def executor(self):
        return self._exe

    def __call__(self, *args):
        if self._direct is not None:
            return self._direct(*args)
        flat, tree = jax.tree_util.tree_flatten(args)
        if tree == self._in_tree:
            for v, (shape, dt) in zip(flat, self._in_avals):
                if getattr(v, "shape", None) != shape \
                        or getattr(v, "dtype", None) != dt:
                    break
            else:
                env = dict(self._base_env)
                env.update(zip(self._in_names, flat))
                outs = self._run(env) if self._run is not None else {}
                vals = [outs[ref] if is_array else env[ref]
                        for ref, is_array, _, _ in self._out_info]
                if any(promoted or getattr(v, "dtype", None) != dt
                       for v, (_, _, promoted, dt)
                       in zip(vals, self._out_info)):
                    vals = list(self._finish(*vals))
                return jax.tree_util.tree_unflatten(self.tf.out_tree,
                                                    vals)
        # slow path: normalizes non-array leaves and raises the contract
        # errors (shape/dtype/pytree mismatch) with full context
        env = self.tf.bind_args(args)
        outs = self._exe(env) if self._exe is not None else {}
        return self.tf.unbind(outs, env)
