"""``repro.frontend`` — trace arbitrary JAX functions into the pipeline.

The missing front half of the paper's source-to-source flow: instead of
hand-building :class:`~repro.core.taskgraph.TaskGraph` objects (the
polybench builders), capture *any* JAX callable::

    from repro import frontend

    tf = frontend.trace(fn, *example_inputs)   # jaxpr -> TaskGraph
    plan = tf.solve()                          # the usual NLP solve
    exe = tf.executable()                      # whole-plan compiled program
    out = exe(*inputs)                         # original signature/pytrees
    tf.validate()                              # vs jax.jit(fn) oracle

The affine subset (``dot_general`` incl. batch dims, elementwise
add/sub/mul/neg, ``transpose``, ``broadcast_in_dim``, full-axis
``reduce_sum`` — float32) lowers to real solver statements; everything else
is carved into opaque passthrough segments executed verbatim inside the
same compiled program, so coverage is partial but execution is total.
``TracedFunction.coverage`` reports the split.

Traces are cached process-wide by jaxpr fingerprint (see
:func:`trace_cache_stats`), aligned with the compiled-program cache: same
structure -> same graph -> same program-cache entries.  The serving path is
``PlanEngine.register_function(name, fn, example_inputs)``.
"""
from .executable import TracedExecutable, TracedFunction
from .lowering import Coverage, LoweredJaxpr, SUPPORTED_PRIMITIVES
from .trace import (TraceCache, batched_trace, batched_trace_index,
                    clear_trace_cache, trace, trace_cache,
                    trace_cache_stats, traced_graph)

__all__ = [
    "Coverage", "LoweredJaxpr", "SUPPORTED_PRIMITIVES",
    "TraceCache", "TracedExecutable", "TracedFunction",
    "batched_trace", "batched_trace_index",
    "clear_trace_cache", "trace", "trace_cache", "trace_cache_stats",
    "traced_graph",
]
