"""``trace(fn, *args)``: capture a JAX callable into the Prometheus pipeline.

``trace`` runs ``jax.make_jaxpr`` over the flattened callable, fingerprints
the resulting jaxpr (structure + avals + inlined structural consts), and
resolves the lowering through a process-wide bounded LRU **trace cache**
keyed by that fingerprint — the front-door counterpart of the compiled
program cache: two traces of the same structure share one
:class:`~repro.frontend.lowering.LoweredJaxpr` (graph, coverage, solved
plan), and because the shared graph fingerprints identically, they also
share program-cache entries downstream.

``traced_graph(name)`` resolves a ``traced:<fp16>`` graph name back to its
graph — :func:`repro.core.solver.build_graph`'s hook for traced sources, so
``measure_plan``/benchmarks treat traced workloads exactly like polybench
kernels.

``batched_trace(tf, bucket)`` is the continuous-batching tier's re-trace:
the same function mapped over a leading batch dimension of ``bucket``.
Batched lowerings live in the same trace cache (the vmap of a fixed jaxpr
structure at a fixed bucket fingerprints deterministically, so replicas
share one record per ``(fingerprint, bucket)``), and a process-wide index
records that mapping so the batcher never re-lowers a bucket it has seen.
"""
from __future__ import annotations

import os
import threading
from collections import OrderedDict

import jax
import jax.numpy as jnp

from ..obs import tracer as _obs_tracer
from .executable import TracedFunction
from .lowering import (LoweredJaxpr, fingerprint_jaxpr, flatten_jaxpr,
                       graph_name_of, lower_flat)

#: Default LRU capacity of the process-wide trace cache.
DEFAULT_TRACE_CACHE_SIZE = 64


def _env_int(name: str, default: int) -> int:
    try:
        return max(1, int(os.environ.get(name, default)))
    except ValueError:
        return default


class TraceCache:
    """Bounded LRU of lowered jaxprs, keyed by jaxpr fingerprint.

    Thread-safe (the serving engine registers functions from server
    threads); the graph-name index lets :func:`traced_graph` resolve
    ``traced:<fp16>`` names in O(1).
    """

    def __init__(self, capacity: int = DEFAULT_TRACE_CACHE_SIZE):
        self.lock = threading.RLock()
        self.capacity = max(1, capacity)
        self._entries: OrderedDict[str, LoweredJaxpr] = OrderedDict()
        self._by_name: dict[str, str] = {}      # graph name -> fingerprint
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        with self.lock:
            return len(self._entries)

    def get(self, fp: str) -> LoweredJaxpr | None:
        with self.lock:
            rec = self._entries.get(fp)
            if rec is None:
                self.misses += 1
                return None
            self._entries.move_to_end(fp)
            self.hits += 1
            return rec

    def put(self, fp: str, rec: LoweredJaxpr) -> LoweredJaxpr:
        with self.lock:
            self._entries[fp] = rec
            self._by_name[rec.graph.name] = fp
            self._entries.move_to_end(fp)
            while len(self._entries) > self.capacity:
                _, old = self._entries.popitem(last=False)
                self._drop(old)
            return rec

    def put_if_absent(self, fp: str, rec: LoweredJaxpr) -> LoweredJaxpr:
        """Admit ``rec`` unless a concurrent trace of the same structure
        got there first — the winner's record is what every caller keeps,
        so the shared plan cache stays shared (both lowerings register
        identical opaque digests, so the loser leaves no orphans)."""
        with self.lock:
            cur = self._entries.get(fp)
            if cur is not None:
                self._entries.move_to_end(fp)
                return cur
            return self.put(fp, rec)

    def _drop(self, rec: LoweredJaxpr) -> None:
        """Eviction hook: the opaque-segment callables registered by this
        record leave the codegen registry with it (a compiled program that
        outlives the record only needs them again on a re-trace, which
        re-registers identical semantics)."""
        from ..codegen.reference import unregister_opaque
        self._by_name.pop(rec.graph.name, None)
        unregister_opaque(rec.opaque_ops)
        self.evictions += 1

    def by_graph_name(self, name: str) -> LoweredJaxpr | None:
        with self.lock:
            fp = self._by_name.get(name)
            return self._entries.get(fp) if fp is not None else None

    def resize(self, capacity: int) -> None:
        with self.lock:
            self.capacity = max(1, capacity)
            while len(self._entries) > self.capacity:
                _, old = self._entries.popitem(last=False)
                self._drop(old)

    def clear(self) -> None:
        with self.lock:
            from ..codegen.reference import unregister_opaque
            for rec in self._entries.values():
                unregister_opaque(rec.opaque_ops)
            self._entries.clear()
            self._by_name.clear()
            self.hits = 0
            self.misses = 0
            self.evictions = 0

    def stats(self) -> dict:
        """Cache counters plus a per-entry coverage census — what fraction
        of each cached lowering the solver actually owns (the rest runs as
        opaque passthrough segments)."""
        with self.lock:
            entries = {}
            for rec in self._entries.values():
                c = rec.coverage
                entries[rec.graph.name] = {
                    "n_eqns": c.n_eqns,
                    "n_supported": c.n_supported,
                    "coverage_eqns": round(c.eqn_ratio, 4),
                    "coverage_flops": round(c.flop_ratio, 4),
                }
            return {"size": len(self._entries), "capacity": self.capacity,
                    "hits": self.hits, "misses": self.misses,
                    "evictions": self.evictions,
                    "graphs": sorted(self._by_name),
                    "entries": entries}


_CACHE = TraceCache(_env_int("REPRO_TRACE_CACHE_SIZE",
                             DEFAULT_TRACE_CACHE_SIZE))


def trace_cache() -> TraceCache:
    """The process-wide trace cache."""
    return _CACHE


def trace_cache_stats() -> dict:
    return _CACHE.stats()


def clear_trace_cache() -> None:
    """Drop every cached lowering, including the opaque-segment callables
    the records registered with the codegen registry (re-tracing
    re-registers identical semantics)."""
    _CACHE.clear()


def traced_graph(name: str):
    """Resolve a ``traced:<fp16>`` graph name to its TaskGraph (the
    :func:`repro.core.solver.build_graph` hook for traced sources)."""
    rec = _CACHE.by_graph_name(name)
    if rec is None:
        raise KeyError(
            f"traced graph {name!r} is not in this process's trace cache — "
            "call repro.frontend.trace(fn, *example_inputs) first")
    return rec.graph


def trace(fn, *example_args, name: str | None = None) -> TracedFunction:
    """Capture ``fn`` at the example arguments' shapes/dtypes.

    Returns a :class:`TracedFunction` whose graph covers the affine subset
    of the function (dot_general, elementwise add/sub/mul/neg, transpose,
    broadcast_in_dim, full-axis reduce_sum — all at float32) as solver
    statements and everything else as opaque passthrough segments, so *any*
    function executes end-to-end with the supported core optimized.

    The lowering is cached process-wide by jaxpr fingerprint; const values
    captured by the closure are bound on the returned instance, so
    structurally-identical closures share graphs, plans and compiled
    programs while keeping their own values.
    """
    flat, in_tree = jax.tree_util.tree_flatten(tuple(example_args))
    trees: list = []

    def flat_fn(*vals):
        args = jax.tree_util.tree_unflatten(in_tree, list(vals))
        out = fn(*args)
        flat_out, out_tree = jax.tree_util.tree_flatten(out)
        trees.append(out_tree)
        return flat_out

    with _obs_tracer().span("trace", "frontend",
                            fn=getattr(fn, "__name__", "fn")) as sp:
        closed = jax.make_jaxpr(flat_fn)(*flat)
        out_tree = trees[-1]
        flat_eqns, resolved_outs, sub_consts = flatten_jaxpr(closed.jaxpr)
        fp = fingerprint_jaxpr(closed, sub_consts)
        rec = _CACHE.get(fp)
        cached = rec is not None
        if rec is None:
            # put_if_absent: if a concurrent trace of the same structure
            # wins the race, keep ITS record so the shared plan cache
            # stays shared
            rec = _CACHE.put_if_absent(
                fp, lower_flat(closed, flat_eqns, resolved_outs, sub_consts,
                               fp))
        sp.set(cached=cached, eqns=len(flat_eqns))
    assert rec.graph.name == graph_name_of(fp)
    return TracedFunction(
        fn=fn, record=rec, const_values=tuple(closed.consts),
        in_tree=in_tree, out_tree=out_tree,
        example_flat=tuple(flat), name=name or getattr(fn, "__name__", "fn"))


# ---------------------------------------------------------------------------
# Batch-dimension re-trace (the continuous-batching tier's entry point)
# ---------------------------------------------------------------------------
# (base fingerprint, bucket) -> batched fingerprint: the structural index
# the batcher's trace reuse is keyed by.  The heavy state (graph, plan,
# compiled program) lives in the ordinary trace/program caches under the
# batched fingerprint; this map only records which batched records exist,
# so stats and tests can see bucket lowerings being shared, not re-made.
_BATCH_INDEX: dict[tuple[str, int], str] = {}
_BATCH_LOCK = threading.Lock()


def batched_trace_index() -> dict[tuple[str, int], str]:
    """Snapshot of the ``(fingerprint, bucket) -> batched fingerprint``
    index (introspection for stats and tests)."""
    with _BATCH_LOCK:
        return dict(_BATCH_INDEX)


def batched_trace(tf: TracedFunction, bucket: int) -> TracedFunction:
    """Re-trace ``tf.fn`` with a leading batch dimension of ``bucket``.

    Returns a new :class:`TracedFunction` over ``jax.vmap(tf.fn)`` whose
    example inputs are the original examples broadcast to
    ``(bucket,) + shape``.  The lowering is resolved through the ordinary
    process-wide trace cache: structurally identical functions batched at
    the same bucket share one record (and therefore one solved plan and
    one compiled program), which is what keeps the program cache small —
    buckets are a handful of powers of two, not one entry per batch size
    ever seen.  The ``(fingerprint, bucket)`` pair is also recorded in
    :func:`batched_trace_index`.
    """
    if bucket < 1:
        raise ValueError(f"bucket must be >= 1, got {bucket}")
    fn = tf.fn

    def _batched(*args):
        return jax.vmap(fn)(*args)

    _batched.__name__ = f"{getattr(fn, '__name__', 'fn')}@b{bucket}"
    flat = [jnp.broadcast_to(jnp.asarray(v), (bucket,) + tuple(
        jnp.shape(v))) for v in tf.example_flat]
    args = jax.tree_util.tree_unflatten(tf.in_tree, flat)
    btf = trace(_batched, *args, name=f"{tf.name}@b{bucket}")
    with _BATCH_LOCK:
        _BATCH_INDEX[(tf.fingerprint, bucket)] = btf.fingerprint
    return btf
