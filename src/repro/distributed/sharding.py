"""Parameter/activation sharding rules (GSPMD partition specs).

Mesh axes: ``("data", "model")`` single-pod, ``("pod", "data", "model")``
multi-pod.  Default layout (the paper-faithful baseline the solver then
perturbs):

  * batch over (pod, data) — pure DP across pods (the pod axis role is a
    solver decision, DESIGN.md: SLR-assignment analogue);
  * weights 2D-sharded: contraction dim over ``data`` (ZeRO/FSDP-style so
    fp32 master + Adam state fit HBM), output-feature / head / expert /
    vocab dim over ``model`` (tensor parallel);
  * anything non-divisible falls back to replication **per dim** — this
    fixup is what makes kv_heads < model-size (yi-34b, qwen3-moe) and
    n_experts < model-size (mixtral) legal without special cases; head
    padding (padding-for-computation) keeps the big dims divisible.

Specs are assigned by parameter *name* via path matching and apply equally
to optimizer-state mirrors.  Scanned layer stacks get a leading None.
"""
from __future__ import annotations

import re
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


# Per-process rule overrides (name-pattern -> spec template).  The §Perf
# loop uses this to test alternative layouts, e.g. lm_head (None, "model")
# — replicating the contraction dim trades a small params all-gather for
# NOT partial-sum-all-reducing the (tokens x vocab) f32 logits.
_OVERRIDES: dict[str, tuple] = {}


def set_overrides(overrides: dict[str, tuple | list]) -> None:
    _OVERRIDES.clear()
    for k, v in (overrides or {}).items():
        _OVERRIDES[k] = tuple(None if x is None else x for x in v)


# name -> spec template (checked/fixed against shapes at assignment)
_RULES: list[tuple[str, tuple]] = [
    (r"embed$", ("model", "data")),
    (r"lm_head$", ("data", "model")),
    (r"\bwq$|\bwk$|\bwv$", ("data", "model")),
    (r"\bwo$", ("model", "data")),
    (r"\bw1$|\bw3$", ("data", "model")),          # 2d mlp (3d moe handled below)
    (r"\bw2$", ("model", "data")),
    (r"router$", ("data", None)),
    (r"conv_w$", (None, "model")),
    (r"w_gate$|w_in$|w_a$|w_x$", ("data", "model")),
    (r"w_out$", ("model", "data")),
    (r"\bwr$|\bwg$|cm_r$|cm_k$", ("data", "model")),
    (r"cm_v$", ("model", "data")),
    (r"wd1$", ("data", None)),
    (r"wd2$", (None, "model")),
    (r"\bbq$|\bbk$|\bbv$", ("model",)),
]

_MOE_RULES = {
    # (param, experts divisible): spec
    ("w1", True): ("model", "data", None),
    ("w3", True): ("model", "data", None),
    ("w2", True): ("model", None, "data"),
    ("w1", False): (None, "data", "model"),
    ("w3", False): (None, "data", "model"),
    ("w2", False): (None, "model", "data"),
}


def _fixup(mesh: Mesh, spec: tuple, shape: tuple[int, ...]) -> P:
    """Drop axes that do not divide their dim (per-dim replication)."""
    spec = tuple(spec) + (None,) * (len(shape) - len(spec))
    fixed = []
    for axes, dim in zip(spec, shape):
        if axes is None:
            fixed.append(None)
        elif dim % axis_size(mesh, axes) == 0:
            fixed.append(axes)
        else:
            fixed.append(None)
    return P(*fixed)


def param_spec(mesh: Mesh, path: str, shape: tuple[int, ...]) -> P:
    """Partition spec for one parameter identified by its tree path."""
    scanned = bool(re.search(r"\blayers\b", path))
    base_shape = shape[1:] if scanned else shape
    name = path.split("/")[-1]
    spec: tuple | None = None
    for pat, sp in _OVERRIDES.items():
        if re.search(pat, name):
            spec = sp
            break
    if spec is not None:
        pass
    elif re.search(r"w[123]$", name) and len(base_shape) == 3:
        div = base_shape[0] % axis_size(mesh, "model") == 0 \
            if "model" in mesh.axis_names else False
        spec = _MOE_RULES[(name, div)]
    else:
        for pat, sp in _RULES:
            if re.search(pat, name):
                spec = sp
                break
    if spec is None:
        spec = (None,) * len(base_shape)      # norms, gates, scalars
    p = _fixup(mesh, spec, base_shape)
    if scanned:
        p = P(None, *p)
    return p


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def shard_params(mesh: Mesh, params: Any) -> Any:
    """NamedSharding pytree matching ``params`` (works for opt-state mirrors
    via tree structure reuse)."""
    def spec_of(path, leaf):
        return NamedSharding(mesh, param_spec(mesh, _path_str(path),
                                              leaf.shape))
    return jax.tree_util.tree_map_with_path(spec_of, params)


def batch_spec(mesh: Mesh, global_batch: int) -> P:
    axes = dp_axes(mesh)
    if axes and global_batch % axis_size(mesh, axes) == 0:
        return P(axes)
    # try data only, then replicate (long_500k batch=1)
    if "data" in mesh.axis_names and global_batch % mesh.shape["data"] == 0:
        return P("data")
    return P(None)


def tokens_sharding(mesh: Mesh, global_batch: int,
                    extra_dims: int = 1) -> NamedSharding:
    spec = batch_spec(mesh, global_batch)
    return NamedSharding(mesh, P(*(tuple(spec) + (None,) * extra_dims)))


def cache_spec(mesh: Mesh, path: str, shape: tuple[int, ...],
               global_batch: int) -> P:
    """KV / recurrent cache sharding: batch over DP axes, kv-head (or
    state-feature) dim over model when divisible."""
    scanned = bool(re.search(r"\blayers\b", path))
    base_shape = shape[1:] if scanned else shape
    bspec = batch_spec(mesh, global_batch)
    b_axes = tuple(bspec)[0] if len(tuple(bspec)) else None
    name = path.split("/")[-1]
    fixed: list = [b_axes]
    if name in ("k", "v", "k_scale", "v_scale") and len(base_shape) == 4:
        # (B, S, Hkv, hd): shard heads over model when divisible; else
        # shard the SEQUENCE dim (sequence-parallel cache: each model
        # shard owns a slice of positions; attention over the cache
        # becomes partial online-softmax pieces XLA merges with two tiny
        # all-reduces).  Without this, kv_heads % model != 0 archs
        # (yi-34b, internvl2, qwen3-*, musicgen) replicate multi-GB
        # caches per chip and blow HBM.
        hkv = base_shape[2]
        sc = base_shape[1]
        msize = axis_size(mesh, "model") if "model" in mesh.axis_names else 1
        if hkv % msize == 0:
            fixed += [None, "model", None]
        elif sc % msize == 0:
            fixed += ["model", None, None]
        else:
            fixed += [None, None, None]
    else:
        fixed += [None] * (len(base_shape) - 1)
    p = _fixup(mesh, tuple(fixed), base_shape)
    if scanned:
        p = P(None, *p)
    return p


def shard_cache(mesh: Mesh, cache: Any, global_batch: int) -> Any:
    def spec_of(path, leaf):
        ps = _path_str(path)
        if ps.endswith("pos"):
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, cache_spec(mesh, ps, leaf.shape,
                                              global_batch))
    return jax.tree_util.tree_map_with_path(spec_of, cache)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
