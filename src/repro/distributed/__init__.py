"""Distribution layer: sharding rules, pipeline parallelism, compression."""
from . import compression, pipeline, sharding
from .compat import shard_map_compat

__all__ = ["compression", "pipeline", "sharding", "shard_map_compat"]
