"""Distribution layer: sharding rules, pipeline parallelism, compression."""
from . import compression, pipeline, sharding

__all__ = ["compression", "pipeline", "sharding"]
