"""Gradient compression for data-parallel reduction (shard_map layer).

Distributed-optimization tricks for the DP axis:

* ``allreduce_mean_bf16`` — cast to bf16 before the wire (2x bytes saved),
  fp32 accumulation after.
* ``allreduce_mean_int8_ef`` — symmetric int8 row quantization (the
  ``kernels.quant`` scheme, 4x bytes saved) with **error feedback**: the
  local quantization residual is carried to the next step, so the
  compression bias telescopes instead of accumulating (Seide et al.;
  1-bit Adam lineage).

These run inside ``shard_map`` over the DP axes; the sharded pjit trainer
uses plain fp32 reductions by default (the solver may switch — collective
bytes are a §Perf lever).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def allreduce_mean(grads: Any, axis) -> Any:
    return jax.tree.map(
        lambda g: jax.lax.pmean(g, axis), grads)


def allreduce_mean_bf16(grads: Any, axis) -> Any:
    def one(g):
        return jax.lax.pmean(g.astype(jnp.bfloat16), axis) \
            .astype(jnp.float32)
    return jax.tree.map(one, grads)


def _rowwise(x: jax.Array) -> jax.Array:
    if x.ndim == 0:
        return x.reshape(1, 1)
    if x.ndim == 1:
        return x.reshape(1, -1)
    return x.reshape(x.shape[0], -1)


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-row symmetric int8; returns (q int8, scale f32)."""
    r = _rowwise(x.astype(jnp.float32))
    amax = jnp.max(jnp.abs(r), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(r / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array,
                    shape: tuple[int, ...]) -> jax.Array:
    return (q.astype(jnp.float32) * scale).reshape(shape)


def allreduce_mean_int8_ef(grads: Any, errors: Any, axis) \
        -> tuple[Any, Any]:
    """Error-feedback int8 compressed mean-all-reduce.

    Returns (averaged fp32 grads, new error state).  ``errors`` is a pytree
    like ``grads`` (zeros at step 0).
    """
    def one(g, e):
        target = g.astype(jnp.float32) + e
        r = _rowwise(target)
        # SHARED per-row scale (pmax over peers; one f32/row on the wire):
        # the summed int8 payload then dequantizes to exactly the mean of
        # the peers' local dequantizations, so the only residual is each
        # peer's own rounding — which error feedback telescopes away.
        amax = jax.lax.pmax(
            jnp.max(jnp.abs(r), axis=-1, keepdims=True), axis)
        scale = jnp.maximum(amax, 1e-12) / 127.0
        q = jnp.clip(jnp.round(r / scale), -127, 127).astype(jnp.int8)
        local_deq = (q.astype(jnp.float32) * scale).reshape(g.shape)
        new_e = target - local_deq
        qsum = jax.lax.psum(q.astype(jnp.int32), axis)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis)
        mean = (qsum.astype(jnp.float32) * scale).reshape(g.shape) / n
        return mean, new_e

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(errors)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    means = treedef.unflatten([m for m, _ in out])
    new_errors = treedef.unflatten([e for _, e in out])
    return means, new_errors


def zeros_like_errors(grads: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compressed_bytes(grads: Any, scheme: str) -> int:
    """Wire bytes per step for reporting (fp32 baseline vs compressed)."""
    total = 0
    for g in jax.tree.leaves(grads):
        n = int(g.size)
        rows = _rowwise(g).shape[0]
        if scheme == "fp32":
            total += 4 * n
        elif scheme == "bf16":
            total += 2 * n
        elif scheme == "int8":
            total += n + 4 * rows
        else:
            raise ValueError(scheme)
    return total
