"""jax-version compatibility helpers for the distribution layer."""
from __future__ import annotations


def shard_map_compat(fn, mesh, in_specs, out_specs):
    """``shard_map`` across jax versions: new top-level ``jax.shard_map``
    (``check_vma``) vs the older ``jax.experimental.shard_map.shard_map``
    (``check_rep``)."""
    import jax
    if hasattr(jax, "shard_map"):
        try:
            return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=False)
        except TypeError:
            return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs)
    from jax.experimental.shard_map import shard_map
    return shard_map(fn, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)
