"""Pipeline parallelism over a mesh axis (the pod/SLR-assignment analogue).

GPipe-style micro-batched pipeline implemented with ``shard_map`` +
``ppermute`` (differentiable, so ``jax.grad`` through the schedule gives
pipeline-parallel backward for free; activation stash memory = GPipe).

The schedule runs S + M - 1 ticks for S stages and M microbatches; at each
tick a stage receives its predecessor's activation via collective_permute
and runs its layer block on the in-flight microbatch.  Bubble fraction
(S-1)/(S+M-1) — the cost model the stage-assignment solver (core/slr.py)
charges for choosing the pipeline role of the pod axis.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from .compat import shard_map_compat


def pipeline_apply(stage_fn: Callable, mesh: Mesh, axis: str,
                   stage_params, x_micro: jax.Array) -> jax.Array:
    """Run a pipelined stack.

    stage_fn(params_stage, x) -> y : one stage's layer block.
    stage_params: pytree with leading dim = n_stages (sharded over
    ``axis``); x_micro (M, mb, ...) microbatched inputs (replicated).
    Returns (M, mb, ...) outputs of the LAST stage.
    """
    n_stages = mesh.shape[axis]

    def per_stage(params, xs):
        # params: (1, ...) slice for this stage; xs: full (M, mb, ...)
        params = jax.tree.map(lambda a: a[0], params)
        stage = jax.lax.axis_index(axis)
        m = xs.shape[0]
        ticks = n_stages + m - 1
        buf = jnp.zeros_like(xs[0])                 # in-flight activation
        outs = jnp.zeros_like(xs)

        def tick(carry, t):
            buf, outs = carry
            # stage 0 injects microbatch t (when valid)
            inject = jnp.where(t < m, t, m - 1)
            x0 = xs[inject]
            cur = jnp.where(stage == 0, x0, buf)
            y = stage_fn(params, cur)
            # last stage emits microbatch t - (S-1)
            emit = t - (n_stages - 1)
            do_emit = jnp.logical_and(stage == n_stages - 1, emit >= 0)
            idx = jnp.clip(emit, 0, m - 1)
            outs = jnp.where(
                do_emit,
                outs.at[idx].set(y),
                outs)
            # send activation to the next stage
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            buf = jax.lax.ppermute(y, axis, perm)
            return (buf, outs), None

        (buf, outs), _ = jax.lax.scan(tick, (buf, outs),
                                      jnp.arange(ticks))
        # all stages hold ``outs``; only the last stage's is real — share it
        outs = jax.lax.psum(
            jnp.where(stage == n_stages - 1, outs, jnp.zeros_like(outs)),
            axis)
        return outs

    in_specs = (jax.tree.map(lambda _: P(axis), stage_params), P())
    return shard_map_compat(per_stage, mesh=mesh, in_specs=in_specs,
                            out_specs=P())(stage_params, x_micro)


def stage_assignment_cost(n_stages: int, n_micro: int,
                          stage_flops: list[float],
                          peak_flops: float) -> float:
    """Analytic pipeline latency (the Eq. 12/13 schedule specialized to a
    chain): max-stage time dominates, (S-1) bubble ticks."""
    t_stage = max(stage_flops) / peak_flops
    return (n_stages + n_micro - 1) * t_stage
