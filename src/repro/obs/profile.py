"""Opt-in sampled per-segment timing for ``PlanProgram`` execution.

``REPRO_OBS_SAMPLE=N`` profiles one in every N program calls (0 or unset
disables).  On a sampled call the program runs segment-by-segment with a
device sync after each, so the host-side clock brackets real execution —
which is why it is sampled, not always-on: the sync defeats the async
dispatch pipelining the steady-state path relies on.

Each sampled segment is recorded here (count / total / min / max seconds,
plus the wave composition of the segment) and emitted as a
``profile/segment`` span into the shared tracer, so the overlap and
materialization decisions from the segment splitter are inspectable in
Perfetto next to the request spans.
"""

from __future__ import annotations

import os
import threading
import time

from .trace import tracer

__all__ = ["ProgramProfiler", "profiler", "configure_sampling"]

ENV_SAMPLE = "REPRO_OBS_SAMPLE"


def _env_sample_every() -> int:
    try:
        return max(0, int(os.environ.get(ENV_SAMPLE, "0")))
    except ValueError:
        return 0


class ProgramProfiler:
    """Aggregates sampled per-segment timings keyed by (program, impl)."""

    def __init__(self, sample_every: int | None = None):
        self.sample_every = (
            _env_sample_every() if sample_every is None else max(0, int(sample_every))
        )
        self._lock = threading.Lock()
        self._calls: dict[str, int] = {}
        self._segments: dict[tuple[str, str, int], dict] = {}
        self._sampled_calls = 0

    @property
    def enabled(self) -> bool:
        return self.sample_every > 0

    def should_sample(self, key: str) -> bool:
        """One in ``sample_every`` calls per program key."""
        if self.sample_every <= 0:
            return False
        with self._lock:
            n = self._calls.get(key, 0) + 1
            self._calls[key] = n
            if n % self.sample_every:
                return False
            self._sampled_calls += 1
            return True

    def record_segment(self, program: str, impl: str, seg_index: int,
                       seconds: float, *, n_tasks: int = 0,
                       waves: tuple[int, ...] = ()) -> None:
        key = (program, impl, seg_index)
        with self._lock:
            agg = self._segments.get(key)
            if agg is None:
                agg = self._segments[key] = {
                    "count": 0, "total_s": 0.0,
                    "min_s": float("inf"), "max_s": 0.0,
                    "n_tasks": n_tasks, "waves": tuple(waves),
                }
            agg["count"] += 1
            agg["total_s"] += seconds
            agg["min_s"] = min(agg["min_s"], seconds)
            agg["max_s"] = max(agg["max_s"], seconds)
        tracer().record(
            f"{program}/seg{seg_index}", "profile",
            time.perf_counter() - seconds, seconds,
            {"impl": impl, "n_tasks": n_tasks, "waves": list(waves)},
        )

    def stats(self) -> dict:
        with self._lock:
            segs = {}
            for (program, impl, idx), agg in self._segments.items():
                segs.setdefault(program, {}).setdefault(impl, {})[idx] = {
                    "count": agg["count"],
                    "mean_s": agg["total_s"] / agg["count"] if agg["count"] else 0.0,
                    "min_s": 0.0 if agg["min_s"] == float("inf") else agg["min_s"],
                    "max_s": agg["max_s"],
                    "n_tasks": agg["n_tasks"],
                    "waves": list(agg["waves"]),
                }
            return {
                "sample_every": self.sample_every,
                "sampled_calls": self._sampled_calls,
                "programs": segs,
            }

    def clear(self) -> None:
        with self._lock:
            self._calls.clear()
            self._segments.clear()
            self._sampled_calls = 0


_profiler = ProgramProfiler()


def profiler() -> ProgramProfiler:
    return _profiler


def configure_sampling(sample_every: int) -> ProgramProfiler:
    _profiler.sample_every = max(0, int(sample_every))
    return _profiler
