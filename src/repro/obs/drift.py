"""Cost-model drift detection.

The solver annotates every :class:`ExecutionPlan` with its predicted
steady-state latency (``plan.latency_s``).  The serving layer samples
observed per-entry latency on the optimized path and folds it into an
EMA; when the observed/predicted ratio leaves the configured band for
long enough, the entry is declared *drifted* and the engine triggers
the existing background re-solve + plan-store refresh path (PR 7/9) so
the plan is re-priced against reality.

Pure stdlib; the clock is injectable so tests can drive cooldown logic
deterministically.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass

__all__ = ["DriftConfig", "DriftEvent", "DriftDetector"]


@dataclass(frozen=True)
class DriftConfig:
    """Knobs for the drift detector.

    ``sample_every``: observe one in N optimized requests (sampling keeps
    the device sync needed for wall-time off the common path).
    ``ratio_threshold``: drift fires when ``ema/predicted`` falls outside
    ``[1/ratio_threshold, ratio_threshold]``.
    ``min_samples``: EMA must have at least this many observations first.
    ``cooldown_s``: min seconds between triggers per entry, so one noisy
    profile cannot spam background re-solves.
    """

    enabled: bool = True
    sample_every: int = 16
    alpha: float = 0.2
    ratio_threshold: float = 8.0
    min_samples: int = 12
    cooldown_s: float = 300.0


@dataclass
class DriftEvent:
    name: str
    predicted_s: float
    observed_ema_s: float
    ratio: float
    samples: int


@dataclass
class _EntryDrift:
    predicted_s: float = 0.0
    ema_s: float = 0.0
    samples: int = 0
    triggers: int = 0
    last_trigger_at: float = float("-inf")
    calls: int = 0  # sampling counter


class DriftDetector:
    """Per-entry EMA of observed latency vs. the solver's prediction."""

    def __init__(self, config: DriftConfig | None = None, clock=time.monotonic):
        self.config = config or DriftConfig()
        self._clock = clock
        self._lock = threading.Lock()
        self._entries: dict[str, _EntryDrift] = {}

    def _entry(self, name: str) -> _EntryDrift:
        e = self._entries.get(name)
        if e is None:
            e = self._entries[name] = _EntryDrift()
        return e

    # -- feeding --------------------------------------------------------
    def note_predicted(self, name: str, latency_s: float) -> None:
        """Record the cost model's prediction for an entry's plan.
        Re-noting (after a re-solve) resets the EMA so fresh plans are
        judged on fresh observations."""
        with self._lock:
            e = self._entry(name)
            if e.predicted_s != latency_s:
                e.predicted_s = float(latency_s)
                e.ema_s = 0.0
                e.samples = 0

    def should_sample(self, name: str) -> bool:
        """Cheap per-request check: True one in ``sample_every`` calls."""
        cfg = self.config
        if not cfg.enabled:
            return False
        every = max(1, int(cfg.sample_every))
        with self._lock:
            e = self._entry(name)
            e.calls += 1
            return e.calls % every == 0

    def observe(self, name: str, observed_s: float) -> DriftEvent | None:
        """Fold one observed latency in; return a DriftEvent when the
        entry just crossed the drift threshold (and cooldown allows)."""
        cfg = self.config
        if not cfg.enabled or observed_s <= 0.0:
            return None
        now = self._clock()
        with self._lock:
            e = self._entry(name)
            if e.samples == 0:
                e.ema_s = float(observed_s)
            else:
                e.ema_s += cfg.alpha * (observed_s - e.ema_s)
            e.samples += 1
            if e.predicted_s <= 0.0 or e.samples < cfg.min_samples:
                return None
            ratio = e.ema_s / e.predicted_s
            thr = cfg.ratio_threshold
            if 1.0 / thr <= ratio <= thr:
                return None
            if now - e.last_trigger_at < cfg.cooldown_s:
                return None
            e.last_trigger_at = now
            e.triggers += 1
            return DriftEvent(
                name=name,
                predicted_s=e.predicted_s,
                observed_ema_s=e.ema_s,
                ratio=ratio,
                samples=e.samples,
            )

    def forget(self, name: str) -> None:
        """Drop an entry's state (engine ``unregister``)."""
        with self._lock:
            self._entries.pop(name, None)

    # -- reading --------------------------------------------------------
    def stats(self) -> dict:
        """Plain-dict snapshot (only the detector's own lock)."""
        cfg = self.config
        with self._lock:
            entries = {
                name: {
                    "predicted_s": e.predicted_s,
                    "observed_ema_s": e.ema_s,
                    "ratio": (e.ema_s / e.predicted_s) if e.predicted_s > 0 else None,
                    "samples": e.samples,
                    "drifted": bool(
                        e.predicted_s > 0
                        and e.samples >= cfg.min_samples
                        and not (
                            1.0 / cfg.ratio_threshold
                            <= e.ema_s / e.predicted_s
                            <= cfg.ratio_threshold
                        )
                    ),
                    "triggers": e.triggers,
                }
                for name, e in self._entries.items()
            }
        return {
            "enabled": cfg.enabled,
            "alpha": cfg.alpha,
            "sample_every": cfg.sample_every,
            "ratio_threshold": cfg.ratio_threshold,
            "min_samples": cfg.min_samples,
            "cooldown_s": cfg.cooldown_s,
            "triggers": sum(e["triggers"] for e in entries.values()),
            "entries": entries,
        }
