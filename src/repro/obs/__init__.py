"""repro.obs — observability for the serving/solver stack.

* :mod:`repro.obs.trace`   — per-request spans in a bounded ring buffer,
  exportable as Chrome-trace/Perfetto JSON (``scripts/obs_dump.py``).
* :mod:`repro.obs.metrics` — counters/gauges/histograms registry with
  Prometheus text exposition; backs ``PlanEngine.stats()``.
* :mod:`repro.obs.profile` — ``REPRO_OBS_SAMPLE``-gated per-segment
  timing inside ``PlanProgram`` execution.
* :mod:`repro.obs.drift`   — cost-model predicted vs. observed latency
  EMA; drift triggers the background re-solve + plan-store refresh path.

Everything here is stdlib-only (importable without jax).
``configure_logging()`` wires the ``repro`` logger family to the
``REPRO_LOG`` env level so background daemon threads (breaker re-solve,
bucket presolve, stale plan refresh) leave a record instead of retrying
silently.
"""

from __future__ import annotations

import logging
import os

from .drift import DriftConfig, DriftDetector, DriftEvent
from .metrics import Counter, Gauge, Histogram, MetricsRegistry, default_registry
from .profile import ProgramProfiler, configure_sampling, profiler
from .trace import Span, Tracer, chrome_trace, configure, dump_chrome_trace, tracer

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "default_registry",
    "Span",
    "Tracer",
    "tracer",
    "configure",
    "chrome_trace",
    "dump_chrome_trace",
    "ProgramProfiler",
    "profiler",
    "configure_sampling",
    "DriftConfig",
    "DriftDetector",
    "DriftEvent",
    "configure_logging",
]

ENV_LOG = "REPRO_LOG"
_LOG_CONFIGURED = False


def configure_logging(level: str | int | None = None, force: bool = False) -> logging.Logger:
    """Configure the ``repro`` logger family from ``REPRO_LOG``.

    ``REPRO_LOG=debug|info|warning|error`` sets the level; unset leaves
    the default (WARNING) so normal runs stay quiet.  Idempotent unless
    ``force``.  Records carry a timestamp, level, logger name, and the
    message — background loops embed entry name / attempt / backoff as
    ``key=value`` pairs in the message for grep-ability.
    """
    global _LOG_CONFIGURED
    log = logging.getLogger("repro")
    if _LOG_CONFIGURED and not force and level is None:
        return log
    raw = level if level is not None else os.environ.get(ENV_LOG, "")
    if isinstance(raw, str):
        resolved = logging.getLevelName(raw.strip().upper()) if raw.strip() else logging.WARNING
        if not isinstance(resolved, int):
            resolved = logging.WARNING
    else:
        resolved = int(raw)
    log.setLevel(resolved)
    if not log.handlers:
        h = logging.StreamHandler()
        h.setFormatter(logging.Formatter(
            "%(asctime)s %(levelname)s %(name)s %(message)s"
        ))
        log.addHandler(h)
        log.propagate = False
    _LOG_CONFIGURED = True
    return log
