"""Per-request span tracing into a bounded ring buffer.

Spans cover the whole request path (admission → queue wait → batch
coalesce → execute → fallback/canary) plus solver phases (fusion,
enumeration, chunk-merge) and sampled program segments.  Recording is
lock-cheap: one short lock around a ``deque(maxlen=...)`` append, and a
single ``enabled`` check on the fast path when tracing is off.

Export is Chrome-trace JSON (``chrome_trace()``), which Perfetto and
``chrome://tracing`` both load directly; ``scripts/obs_dump.py`` writes
it to disk.

Span taxonomy (category / name):

* ``request/admission``   — semaphore wait + deadline check in ``submit``
* ``request/queue_wait``  — batcher enqueue → flush pick-up
* ``request/batch_coalesce`` — stacking + batched submit of one bucket
* ``request/execute``     — optimized program run (one clone dispatch)
* ``request/fallback``    — plain-jit fallback run
* ``request/canary``      — canary validation of a rebuilt program
* ``solver/fuse``, ``solver/enumerate``, ``solver/chunk_merge``
* ``store/load``, ``store/save``
* ``frontend/trace``      — jaxpr capture + lowering
* ``profile/segment``     — sampled per-segment timing (obs/profile.py)
"""

from __future__ import annotations

import json
import os
import threading
import time
from collections import deque
from dataclasses import dataclass, field

__all__ = ["Span", "Tracer", "tracer", "configure", "chrome_trace"]

DEFAULT_CAPACITY = 4096


def _env_truthy(name: str) -> bool:
    return os.environ.get(name, "").strip().lower() in {"1", "true", "on", "yes"}


@dataclass
class Span:
    name: str
    cat: str
    start_s: float          # time.perf_counter() at span start
    dur_s: float            # duration in seconds
    tid: int                # recording thread id
    args: dict = field(default_factory=dict)


class _NullSpan:
    """No-op context manager returned when tracing is disabled."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **kw):
        return self


_NULL = _NullSpan()


class _LiveSpan:
    __slots__ = ("_tracer", "name", "cat", "args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, cat: str, args: dict):
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.args = args

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, etype, exc, tb):
        t1 = time.perf_counter()
        if etype is not None:
            self.args.setdefault("error", etype.__name__)
        self._tracer.record(self.name, self.cat, self._t0, t1 - self._t0, self.args)
        return False

    def set(self, **kw):
        self.args.update(kw)
        return self


class Tracer:
    """Bounded span recorder.  ``enabled`` flips the whole thing off at
    the cost of one attribute read per span site."""

    def __init__(self, capacity: int = DEFAULT_CAPACITY, enabled: bool | None = None):
        if enabled is None:
            enabled = _env_truthy("REPRO_OBS_TRACE")
        self.enabled = bool(enabled)
        self._lock = threading.Lock()
        self._buf: deque[Span] = deque(maxlen=max(1, int(capacity)))
        self._dropped = 0
        self._recorded = 0

    # -- recording ------------------------------------------------------
    def span(self, name: str, cat: str = "request", **args):
        """Context manager timing a block; no-op when disabled."""
        if not self.enabled:
            return _NULL
        return _LiveSpan(self, name, cat, args)

    def record(self, name: str, cat: str, start_s: float, dur_s: float,
               args: dict | None = None) -> None:
        """Record a completed span (used for queue waits measured after
        the fact, where a context manager can't straddle threads)."""
        if not self.enabled:
            return
        sp = Span(name, cat, start_s, dur_s, threading.get_ident(),
                  args or {})
        with self._lock:
            if len(self._buf) == self._buf.maxlen:
                self._dropped += 1
            self._buf.append(sp)
            self._recorded += 1

    # -- reading --------------------------------------------------------
    def snapshot(self) -> list[Span]:
        with self._lock:
            return list(self._buf)

    def clear(self) -> None:
        with self._lock:
            self._buf.clear()
            self._dropped = 0
            self._recorded = 0

    def resize(self, capacity: int) -> None:
        with self._lock:
            self._buf = deque(self._buf, maxlen=max(1, int(capacity)))

    def stats(self) -> dict:
        with self._lock:
            return {
                "enabled": self.enabled,
                "capacity": self._buf.maxlen,
                "buffered": len(self._buf),
                "recorded": self._recorded,
                "dropped": self._dropped,
            }


def chrome_trace(spans: list[Span]) -> dict:
    """Render spans as a Chrome-trace / Perfetto-loadable JSON object.

    Complete events (``ph: "X"``) with microsecond timestamps relative
    to the earliest span, one virtual thread row per recording thread.
    """
    base = min((s.start_s for s in spans), default=0.0)
    pid = os.getpid()
    events = []
    tids: dict[int, int] = {}
    for s in spans:
        tid = tids.setdefault(s.tid, len(tids))
        events.append({
            "name": s.name,
            "cat": s.cat,
            "ph": "X",
            "ts": (s.start_s - base) * 1e6,
            "dur": s.dur_s * 1e6,
            "pid": pid,
            "tid": tid,
            "args": s.args,
        })
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def dump_chrome_trace(spans: list[Span], path: str) -> None:
    with open(path, "w") as f:
        json.dump(chrome_trace(spans), f)


_tracer = Tracer()


def tracer() -> Tracer:
    """Process-wide tracer shared by every layer."""
    return _tracer


def configure(enabled: bool | None = None, capacity: int | None = None) -> Tracer:
    if enabled is not None:
        _tracer.enabled = bool(enabled)
    if capacity is not None:
        _tracer.resize(capacity)
    return _tracer
