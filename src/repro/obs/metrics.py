"""Counters, gauges, and histograms with Prometheus text exposition.

One :class:`MetricsRegistry` per engine (plus a process-wide default) is
the single source of truth for serving counters: ``PlanEngine.stats()``
and ``Batcher.stats()`` read their numbers out of the registry instead
of hand-rolled dicts, and ``expose()`` renders the same numbers in the
Prometheus text format for scraping.

Design constraints:

* stdlib only — importable without jax (the solver and tests use it).
* lock-cheap — one short ``Lock`` per metric family, never held while
  calling into another subsystem.  ``MetricsRegistry.snapshot()`` takes
  each family lock in turn and returns plain dicts, so ``stats()`` can
  assemble its nested output without nested lock acquisition.
* one definition per counter — re-requesting a name returns the same
  family; requesting it with a different type raises.
"""

from __future__ import annotations

import threading
from bisect import bisect_right

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "default_registry",
]

# Prometheus-ish latency buckets, in seconds.  Tuned for this stack:
# steady-state optimized dispatches are O(100us), batch flushes O(10ms),
# cold solves O(1s).
DEFAULT_BUCKETS = (
    100e-6, 250e-6, 500e-6, 1e-3, 2.5e-3, 5e-3, 10e-3,
    25e-3, 50e-3, 100e-3, 250e-3, 500e-3, 1.0, 2.5, 5.0,
)


def _fmt_value(v) -> str:
    if isinstance(v, float) and v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return repr(v) if isinstance(v, float) else str(v)


def _escape(v) -> str:
    return str(v).replace("\\", "\\\\").replace('"', '\\"')


def _label_str(names: tuple[str, ...], values: tuple[str, ...]) -> str:
    if not names:
        return ""
    pairs = ",".join(f'{n}="{_escape(v)}"' for n, v in zip(names, values))
    return "{" + pairs + "}"


class _Family:
    """Base: a named metric with optional labels and per-family lock."""

    kind = "untyped"

    def __init__(self, name: str, help: str = "", labelnames: tuple[str, ...] = ()):
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._lock = threading.Lock()
        self._children: dict[tuple[str, ...], object] = {}

    def labels(self, *values) -> "_Family":
        if len(values) != len(self.labelnames):
            raise ValueError(
                f"{self.name}: expected {len(self.labelnames)} label values, "
                f"got {len(values)}"
            )
        key = tuple(str(v) for v in values)
        with self._lock:
            child = self._children.get(key)
            if child is None:
                child = self._make_child(key)
                self._children[key] = child
            return child

    def _make_child(self, key):  # pragma: no cover - overridden
        raise NotImplementedError

    def remove(self, *values) -> None:
        """Drop a labeled child (e.g. engine ``unregister``)."""
        key = tuple(str(v) for v in values)
        with self._lock:
            self._children.pop(key, None)

    def _snapshot_children(self) -> dict[tuple[str, ...], object]:
        with self._lock:
            return dict(self._children)


class Counter(_Family):
    """Monotonic counter.  Unlabeled families are their own child."""

    kind = "counter"

    def __init__(self, name, help="", labelnames=()):
        super().__init__(name, help, labelnames)
        self._value = 0

    def _make_child(self, key):
        return Counter(self.name)

    def inc(self, n: int | float = 1):
        """Increment and return the new value (atomic fetch-and-add, so
        cadence logic like canary sampling needs no outer lock)."""
        with self._lock:
            self._value += n
            return self._value

    @property
    def value(self):
        with self._lock:
            return self._value

    def snapshot(self) -> dict:
        if self.labelnames:
            return {k: c.value for k, c in self._snapshot_children().items()}
        return {(): self.value}


class Gauge(_Family):
    """Last-value gauge; supports set/inc/dec and callable backing."""

    kind = "gauge"

    def __init__(self, name, help="", labelnames=(), fn=None):
        super().__init__(name, help, labelnames)
        self._value = 0
        self._fn = fn

    def _make_child(self, key):
        return Gauge(self.name)

    def set(self, v) -> None:
        with self._lock:
            self._value = v

    def inc(self, n=1) -> None:
        with self._lock:
            self._value += n

    def dec(self, n=1) -> None:
        with self._lock:
            self._value -= n

    @property
    def value(self):
        if self._fn is not None:
            return self._fn()
        with self._lock:
            return self._value

    def snapshot(self) -> dict:
        if self.labelnames:
            return {k: g.value for k, g in self._snapshot_children().items()}
        return {(): self.value}


class Histogram(_Family):
    """Cumulative-bucket histogram (Prometheus semantics) + percentiles.

    Keeps per-bucket counts, sum, and count; ``quantile()`` interpolates
    from the bucket counts (good enough for p50/p99 reporting without
    retaining raw samples).
    """

    kind = "histogram"

    def __init__(self, name, help="", labelnames=(), buckets=DEFAULT_BUCKETS):
        super().__init__(name, help, labelnames)
        self.buckets = tuple(sorted(buckets))
        self._counts = [0] * (len(self.buckets) + 1)  # +inf tail
        self._sum = 0.0
        self._count = 0

    def _make_child(self, key):
        return Histogram(self.name, buckets=self.buckets)

    def observe(self, v: float) -> None:
        i = bisect_right(self.buckets, v)
        with self._lock:
            self._counts[i] += 1
            self._sum += v
            self._count += 1

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    def quantile(self, q: float) -> float:
        """Approximate quantile from bucket counts (upper-bound interp)."""
        with self._lock:
            total = self._count
            counts = list(self._counts)
        if total == 0:
            return 0.0
        target = q * total
        acc = 0
        for i, c in enumerate(counts):
            acc += c
            if acc >= target:
                return self.buckets[i] if i < len(self.buckets) else self.buckets[-1]
        return self.buckets[-1]

    def snapshot(self) -> dict:
        if self.labelnames:
            return {
                k: h.snapshot()[()] for k, h in self._snapshot_children().items()
            }
        with self._lock:
            return {
                (): {
                    "count": self._count,
                    "sum": self._sum,
                    "counts": list(self._counts),
                }
            }


class MetricsRegistry:
    """Named metric families + invariant assertions + text exposition."""

    def __init__(self):
        self._lock = threading.Lock()
        self._families: dict[str, _Family] = {}
        self._invariants: list[tuple[str, object]] = []

    # -- registration (get-or-create; one definition per name) ---------
    def _get_or_create(self, cls, name, help, labelnames, **kw):
        with self._lock:
            fam = self._families.get(name)
            if fam is not None:
                if not isinstance(fam, cls):
                    raise TypeError(
                        f"metric {name!r} already registered as {fam.kind}"
                    )
                return fam
            fam = cls(name, help, tuple(labelnames), **kw)
            self._families[name] = fam
            return fam

    def counter(self, name, help="", labelnames=()) -> Counter:
        return self._get_or_create(Counter, name, help, labelnames)

    def gauge(self, name, help="", labelnames=(), fn=None) -> Gauge:
        if fn is not None:
            with self._lock:
                fam = self._families.get(name)
                if fam is None:
                    fam = Gauge(name, help, tuple(labelnames), fn=fn)
                    self._families[name] = fam
                return fam
        return self._get_or_create(Gauge, name, help, labelnames)

    def histogram(self, name, help="", labelnames=(), buckets=DEFAULT_BUCKETS) -> Histogram:
        return self._get_or_create(Histogram, name, help, labelnames, buckets=buckets)

    # -- invariants -----------------------------------------------------
    def register_invariant(self, description: str, fn) -> None:
        """``fn`` returns True when the invariant holds.  Checked from a
        consistent snapshot by ``check_invariants()`` — the one place the
        serving accounting closures (``ok+fallbacks == completed`` etc.)
        are asserted."""
        with self._lock:
            self._invariants.append((description, fn))

    def check_invariants(self) -> list[str]:
        with self._lock:
            invs = list(self._invariants)
        return [desc for desc, fn in invs if not fn()]

    # -- reading --------------------------------------------------------
    def families(self) -> list[_Family]:
        with self._lock:
            return list(self._families.values())

    def get(self, name: str) -> _Family | None:
        with self._lock:
            return self._families.get(name)

    def value(self, name: str, *labels):
        """Convenience scalar read; 0 for a never-touched labeled child."""
        fam = self.get(name)
        if fam is None:
            return 0
        if labels:
            key = tuple(str(v) for v in labels)
            with fam._lock:
                child = fam._children.get(key)
            return child.value if child is not None else 0
        return fam.value

    def snapshot(self) -> dict:
        """Plain-dict snapshot of every family.

        Takes only registry/family locks (no engine, breaker, or batcher
        locks) so callers can assemble composite ``stats()`` output
        without nested lock acquisition.
        """
        out = {}
        for fam in self.families():
            out[fam.name] = {
                "kind": fam.kind,
                "labelnames": fam.labelnames,
                "values": fam.snapshot(),
            }
        return out

    # -- Prometheus text exposition ------------------------------------
    def expose(self) -> str:
        """Render every family in the Prometheus text format v0.0.4."""
        lines: list[str] = []
        for fam in self.families():
            if fam.help:
                lines.append(f"# HELP {fam.name} {fam.help}")
            lines.append(f"# TYPE {fam.name} {fam.kind}")
            if isinstance(fam, Histogram):
                snaps = (
                    fam.snapshot()
                    if fam.labelnames
                    else {(): fam.snapshot()[()]}
                )
                for key, snap in snaps.items():
                    base = list(zip(fam.labelnames, key))
                    acc = 0
                    for i, ub in enumerate(list(fam.buckets) + ["+Inf"]):
                        acc += snap["counts"][i]
                        le = "+Inf" if ub == "+Inf" else _fmt_value(float(ub))
                        ls = _label_str(
                            tuple(n for n, _ in base) + ("le",),
                            tuple(str(v) for _, v in base) + (le,),
                        )
                        lines.append(f"{fam.name}_bucket{ls} {acc}")
                    ls = _label_str(fam.labelnames, key)
                    lines.append(f"{fam.name}_sum{ls} {_fmt_value(snap['sum'])}")
                    lines.append(f"{fam.name}_count{ls} {snap['count']}")
            else:
                for key, v in fam.snapshot().items():
                    ls = _label_str(fam.labelnames, key)
                    lines.append(f"{fam.name}{ls} {_fmt_value(v)}")
        return "\n".join(lines) + "\n"


_default = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    """Process-wide registry (solver/store/frontend metrics land here)."""
    return _default
