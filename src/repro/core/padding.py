"""Padding for computation and communication (paper §2.1.6, §3.2, Eqs. 1-3).

*Padding for computation* expands the set of legal tile (unroll) factors: a
loop of trip count 190 only admits factors {1,2,5,10,19,38,95,190}; padded to
192 it admits {1,2,3,4,6,8,12,16,...,192} (paper Listing 1).  On TPU this is
doubly important because the MXU/VPU want the last two block dims to be
multiples of (8, 128): padding 190 -> 192 makes 8/16/32/64-wide tiles legal,
and padding head counts (56 -> 64) makes tensor-parallel degrees legal.

*Padding for communication* aligns the minor dimension so HBM DMAs move full
(8,128) granules — the analogue of the paper's 512-bit burst alignment
(Fig. 1: J=190 -> 192 lifts the transfer from 64 to 512 bits/cycle).

Eq. 1:  TC_intra % TC_ori == 0  ||  TC_intra % TC_padded == 0
Eq. 2:  TC_padded = TC_ori + n,  n <= N   (user-bounded padding)
Eq. 3:  BW_a = max b in B s.t. S_last % b == 0   (burst width selection)
"""
from __future__ import annotations

import dataclasses
import functools


@functools.lru_cache(maxsize=None)
def divisors(n: int) -> tuple[int, ...]:
    out = []
    d = 1
    while d * d <= n:
        if n % d == 0:
            out.append(d)
            if d != n // d:
                out.append(n // d)
        d += 1
    return tuple(sorted(out))


@dataclasses.dataclass(frozen=True)
class TileOption:
    """A legal intra-tile factor together with the padding that enables it."""

    tile: int            # TC_intra
    padded_tc: int       # TC^l (trip count after padding); == ori if unpadded
    ori_tc: int          # TC_ori^l

    @property
    def pad(self) -> int:
        return self.padded_tc - self.ori_tc

    @property
    def n_tiles(self) -> int:     # TC_inter
        return self.padded_tc // self.tile

    @property
    def waste(self) -> float:
        """Fraction of iterations that are padding (computed but discarded)."""
        return self.pad / self.padded_tc


def tile_options(ori_tc: int, max_pad: int = 0,
                 max_tile: int | None = None) -> list[TileOption]:
    """All (tile, padded_tc) pairs satisfying Eqs. 1-2.

    With ``max_pad == 0`` this is the divisor-only space (the Sisyphus
    restriction the paper calls out: "their approach avoids padding,
    limiting the unroll factor to divisors of the loop's trip count").
    """
    best: dict[int, TileOption] = {}
    for pad in range(0, max_pad + 1):
        tc = ori_tc + pad
        for d in divisors(tc):
            if max_tile is not None and d > max_tile:
                continue
            cur = best.get(d)
            # Prefer the smallest padding that legalises this tile size.
            if cur is None or tc < cur.padded_tc:
                best[d] = TileOption(tile=d, padded_tc=tc, ori_tc=ori_tc)
    return sorted(best.values(), key=lambda t: t.tile)


def pad_to_multiple(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def burst_width(last_dim: int, dtype_bytes: int = 4,
                widths=(16, 8, 4, 2, 1)) -> int:
    """Eq. 3: widest vector (elements/transfer) that divides the minor dim.

    ``widths`` defaults to the float32 ladder {16,8,4,2,1} elements, i.e.
    512..32-bit bursts on the FPGA; on TPU the same ladder expresses how much
    of a 128-lane DMA granule each row fills.
    """
    for b in widths:
        if last_dim % b == 0:
            return b
    return 1


def communication_padding(last_dim: int, dtype_bytes: int = 4,
                          max_pad: int | None = None,
                          target_elems: int = 16) -> tuple[int, int]:
    """Choose padding P for the minor dim to widen bursts (paper Fig. 1).

    Returns ``(padded_last_dim, burst_elems)``.  Stops at the smallest pad
    reaching ``target_elems`` per transfer; bounded by ``max_pad`` (defaults
    to ``target_elems``)."""
    if max_pad is None:
        max_pad = target_elems
    best = (last_dim, burst_width(last_dim, dtype_bytes))
    for pad in range(0, max_pad + 1):
        n = last_dim + pad
        b = burst_width(n, dtype_bytes)
        if b > best[1]:
            best = (n, b)
        if b >= target_elems:
            break
    return best
