"""Output-stationary task fusion (paper §3.1).

"Tasks with identical outputs are then merged (when legal), creating fused
tasks with output-stationary properties.  This ensures that each tile's
output is handled (loaded, computed, and either stored or transmitted) only
once."

Legality here follows the paper's setting: after maximal distribution each
statement owns one loop body; statements writing the same array (the
``E=0`` init and the ``E+=...`` accumulation of Listing 4) are fused when the
producer is the immediately preceding writer of that array and no other
statement consumes the array in between.  The fused task inherits the union
of loops; the *shared* non-reduction loops must take identical permutations
(Eq. 4) — enforced downstream by the solver, which permutes fused tasks as a
unit.
"""
from __future__ import annotations

import dataclasses

from .taskgraph import Statement, TaskGraph


@dataclasses.dataclass
class FusedTask:
    """A dataflow node: one or more statements sharing their output array."""

    tid: int
    name: str
    statements: list[Statement]

    @property
    def output_array(self) -> str:
        return self.statements[-1].output_arrays()[-1]

    @property
    def main(self) -> Statement:
        """The dominant statement (largest domain) — drives tiling choices."""
        return max(self.statements, key=lambda s: s.domain_size)

    @property
    def loops(self) -> tuple[str, ...]:
        """Union of loops, ordered as in the dominant statement then extras."""
        seen = list(self.main.loops)
        for s in self.statements:
            for l in s.loops:
                if l not in seen:
                    seen.append(l)
        return tuple(seen)

    @property
    def trip_counts(self) -> dict[str, int]:
        tc: dict[str, int] = {}
        for s in self.statements:
            for l, n in s.trip_counts.items():
                tc[l] = max(tc.get(l, 0), n)
        return tc

    @property
    def flops(self) -> float:
        return sum(s.flops for s in self.statements)

    def read_arrays(self) -> list[str]:
        out: list[str] = []
        for s in self.statements:
            for a in s.reads:
                # Output-stationary: reads of the own output (accumulator)
                # stay in registers/VMEM — not a transfer.
                if a.array != self.output_array and a.array not in out:
                    out.append(a.array)
        return out


@dataclasses.dataclass
class FusedGraph:
    """Dataflow DAG over fused tasks (paper Fig. 3 after fusion)."""

    graph: TaskGraph
    tasks: list[FusedTask]
    # (producer_tid, consumer_tid, array)
    edges: list[tuple[int, int, str]]

    def preds(self, tid: int) -> list[tuple[int, str]]:
        return [(u, a) for (u, v, a) in self.edges if v == tid]

    def succs(self, tid: int) -> list[tuple[int, str]]:
        return [(v, a) for (u, v, a) in self.edges if u == tid]

    def sinks(self) -> list[int]:
        have_succ = {u for (u, _, _) in self.edges}
        return [t.tid for t in self.tasks if t.tid not in have_succ]

    def topo_order(self) -> list[int]:
        order: list[int] = []
        indeg = {t.tid: 0 for t in self.tasks}
        for (_, v, _) in set((u, v, a) for (u, v, a) in self.edges):
            pass
        indeg = {t.tid: len({u for (u, a) in self.preds(t.tid)})
                 for t in self.tasks}
        ready = sorted(t for t, d in indeg.items() if d == 0)
        seen: set[int] = set()
        while ready:
            t = ready.pop(0)
            order.append(t)
            seen.add(t)
            for (v, _) in self.succs(t):
                if v in seen or v in order or v in ready:
                    continue
                if all(u in order for (u, _) in self.preds(v)):
                    ready.append(v)
            ready.sort()
        if len(order) != len(self.tasks):
            raise ValueError("cycle in fused graph")
        return order

    def intermediate_arrays(self) -> list[str]:
        return sorted({a for (_, _, a) in self.edges})

    def comm_between_tasks_elems(self) -> float:
        """Paper Table 5 'Communication Between Tasks' column: data elements
        flowing across dataflow edges (excluding initial input loading)."""
        import numpy as np
        total = 0.0
        for (_, _, a) in self.edges:
            arr = self.graph.arrays[a]
            total += float(np.prod(arr.shape))
        return total


def fuse(graph: TaskGraph) -> FusedGraph:
    """Merge statements with identical output arrays into fused tasks."""
    tasks: list[FusedTask] = []
    owner: dict[str, FusedTask] = {}   # array -> fused task currently writing
    for s in graph.statements:
        outs = s.output_arrays()
        assert len(outs) >= 1, f"statement {s.name} writes nothing"
        key = outs[-1]
        task = owner.get(key)
        # Fusion is legal only if nothing consumed the array since the last
        # writer; in program order that means the owner is still "open"
        # (no intervening reader task).  For the affine kernels handled here
        # init/update pairs are always adjacent in program order.
        if task is not None and _no_intervening_reader(graph, task, s, key):
            task.statements.append(s)
        else:
            task = FusedTask(tid=len(tasks), name=f"FT{len(tasks)}",
                             statements=[s])
            tasks.append(task)
            owner[key] = task

    # Dataflow edges between fused tasks: RAW on arrays across tasks.
    stmt_task: dict[str, int] = {}
    for t in tasks:
        for s in t.statements:
            stmt_task[s.name] = t.tid
    edges: set[tuple[int, int, str]] = set()
    for (i, j, arr) in graph.edges():
        u = stmt_task[graph.statements[i].name]
        v = stmt_task[graph.statements[j].name]
        if u != v:
            edges.add((u, v, arr))
    return FusedGraph(graph=graph, tasks=tasks, edges=sorted(edges))


def _no_intervening_reader(graph: TaskGraph, task: FusedTask,
                           stmt: Statement, array: str) -> bool:
    names = [s.name for s in graph.statements]
    last_in_task = names.index(task.statements[-1].name)
    here = names.index(stmt.name)
    for s in graph.statements[last_in_task + 1:here]:
        if array in {a.array for a in s.reads} or array in s.output_arrays():
            return False
    return True
