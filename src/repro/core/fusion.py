"""Output-stationary task fusion (paper §3.1).

"Tasks with identical outputs are then merged (when legal), creating fused
tasks with output-stationary properties.  This ensures that each tile's
output is handled (loaded, computed, and either stored or transmitted) only
once."

Legality here follows the paper's setting: after maximal distribution each
statement owns one loop body; statements writing the same array (the
``E=0`` init and the ``E+=...`` accumulation of Listing 4) are fused when the
producer is the immediately preceding writer of that array and no other
statement consumes the array in between.  The fused task inherits the union
of loops; the *shared* non-reduction loops must take identical permutations
(Eq. 4) — enforced downstream by the solver, which permutes fused tasks as a
unit.
"""
from __future__ import annotations

import dataclasses

from .taskgraph import Statement, TaskGraph


@dataclasses.dataclass
class FusedTask:
    """A dataflow node: one or more statements sharing their output array."""

    tid: int
    name: str
    statements: list[Statement]

    @property
    def output_array(self) -> str:
        return self.statements[-1].output_arrays()[-1]

    @property
    def main(self) -> Statement:
        """The dominant statement (largest domain) — drives tiling choices."""
        return max(self.statements, key=lambda s: s.domain_size)

    @property
    def loops(self) -> tuple[str, ...]:
        """Union of loops, ordered as in the dominant statement then extras."""
        seen = list(self.main.loops)
        for s in self.statements:
            for l in s.loops:
                if l not in seen:
                    seen.append(l)
        return tuple(seen)

    @property
    def trip_counts(self) -> dict[str, int]:
        tc: dict[str, int] = {}
        for s in self.statements:
            for l, n in s.trip_counts.items():
                tc[l] = max(tc.get(l, 0), n)
        return tc

    @property
    def flops(self) -> float:
        return sum(s.flops for s in self.statements)

    def read_arrays(self) -> list[str]:
        written = {w.array for s in self.statements for w in s.writes}
        out: list[str] = []
        for s in self.statements:
            for a in s.reads:
                # Output-stationary: reads of arrays the task itself writes
                # (the accumulator, or intermediates of a fused pointwise
                # chain) stay in registers/VMEM — not a transfer.
                if a.array not in written and a.array not in out:
                    out.append(a.array)
        return out


@dataclasses.dataclass
class FusedGraph:
    """Dataflow DAG over fused tasks (paper Fig. 3 after fusion)."""

    graph: TaskGraph
    tasks: list[FusedTask]
    # (producer_tid, consumer_tid, array)
    edges: list[tuple[int, int, str]]

    def preds(self, tid: int) -> list[tuple[int, str]]:
        return [(u, a) for (u, v, a) in self.edges if v == tid]

    def succs(self, tid: int) -> list[tuple[int, str]]:
        return [(v, a) for (u, v, a) in self.edges if u == tid]

    def sinks(self) -> list[int]:
        have_succ = {u for (u, _, _) in self.edges}
        return [t.tid for t in self.tasks if t.tid not in have_succ]

    def topo_order(self) -> list[int]:
        order: list[int] = []
        indeg = {t.tid: 0 for t in self.tasks}
        for (_, v, _) in set((u, v, a) for (u, v, a) in self.edges):
            pass
        indeg = {t.tid: len({u for (u, a) in self.preds(t.tid)})
                 for t in self.tasks}
        ready = sorted(t for t, d in indeg.items() if d == 0)
        seen: set[int] = set()
        while ready:
            t = ready.pop(0)
            order.append(t)
            seen.add(t)
            for (v, _) in self.succs(t):
                if v in seen or v in order or v in ready:
                    continue
                if all(u in order for (u, _) in self.preds(v)):
                    ready.append(v)
            ready.sort()
        if len(order) != len(self.tasks):
            raise ValueError("cycle in fused graph")
        return order

    def intermediate_arrays(self) -> list[str]:
        return sorted({a for (_, _, a) in self.edges})

    def comm_between_tasks_elems(self) -> float:
        """Paper Table 5 'Communication Between Tasks' column: data elements
        flowing across dataflow edges (excluding initial input loading)."""
        import numpy as np
        total = 0.0
        for (_, _, a) in self.edges:
            arr = self.graph.arrays[a]
            total += float(np.prod(arr.shape))
        return total


def fuse(graph: TaskGraph) -> FusedGraph:
    """Merge statements with identical output arrays into fused tasks.

    For traced graphs (``graph.traced``) a second pass then merges
    all-pointwise consumer tasks into their producers (:func:`_fuse_pointwise`)
    so activation chains ride inside the contraction task that feeds them."""
    tasks: list[FusedTask] = []
    owner: dict[str, FusedTask] = {}   # array -> fused task currently writing
    for s in graph.statements:
        outs = s.output_arrays()
        assert len(outs) >= 1, f"statement {s.name} writes nothing"
        key = outs[-1]
        task = owner.get(key)
        # Fusion is legal only if nothing consumed the array since the last
        # writer; in program order that means the owner is still "open"
        # (no intervening reader task).  For the affine kernels handled here
        # init/update pairs are always adjacent in program order.
        if task is not None and _no_intervening_reader(graph, task, s, key):
            task.statements.append(s)
        else:
            task = FusedTask(tid=len(tasks), name=f"FT{len(tasks)}",
                             statements=[s])
            tasks.append(task)
            owner[key] = task

    if graph.traced:
        tasks = _fuse_pointwise(graph, tasks)
    return FusedGraph(graph=graph, tasks=tasks,
                      edges=_task_edges(graph, tasks))


def _task_edges(graph: TaskGraph,
                tasks: list[FusedTask]) -> list[tuple[int, int, str]]:
    """(producer_tid, consumer_tid, array) RAW edges across fused tasks."""
    stmt_task: dict[str, int] = {}
    for t in tasks:
        for s in t.statements:
            stmt_task[s.name] = t.tid
    edges: set[tuple[int, int, str]] = set()
    for (i, j, arr) in graph.edges():
        u = stmt_task[graph.statements[i].name]
        v = stmt_task[graph.statements[j].name]
        if u != v:
            edges.add((u, v, arr))
    return sorted(edges)


_POINTWISE_OPS = ("add", "sub", "mul")


def _pointwise_stmt(s: Statement) -> bool:
    """True for elementwise statements a producer can absorb: no real
    reductions (trip-1 broadcast ``z`` dims are fine), no accumulation,
    no triangular density, and an op the kernels evaluate pointwise."""
    if not (s.op in _POINTWISE_OPS or s.op.startswith(("unary:", "binary:"))):
        return False
    if s.density != 1.0:
        return False
    if any(s.trip_counts[l] > 1 for l in s.reduction_loops):
        return False
    written = set(s.output_arrays())
    return not any(a.array in written for a in s.reads)


def _fuse_pointwise(graph: TaskGraph,
                    tasks: list[FusedTask]) -> list[FusedTask]:
    """Merge all-pointwise consumer tasks into their producers (fixpoint).

    A consumer task ``E`` whose statements are all pointwise merges into the
    producer ``P`` of an array that *only* ``E`` reads — the activation /
    scaling tail of a contraction then executes inside the producer's task
    (one dataflow node, one kernel dispatch, no HBM bounce for the
    intermediate).  Legality: the merge must not create a cycle, i.e. no
    other predecessor of ``E`` may be reachable from ``P``.  Statements keep
    their per-statement-unique iterators (the traced-frontend convention);
    the solver pins non-dominant loops to their full extent, so the merged
    search space stays the producer's.
    """
    while True:
        edges = _task_edges(graph, tasks)
        succs: dict[int, set[int]] = {}
        consumers: dict[str, set[int]] = {}
        for (u, v, a) in edges:
            succs.setdefault(u, set()).add(v)
            consumers.setdefault(a, set()).add(v)

        def reachable(src: int, dst: int) -> bool:
            seen, stack = set(), [src]
            while stack:
                n = stack.pop()
                if n == dst:
                    return True
                for m in succs.get(n, ()):
                    if m not in seen:
                        seen.add(m)
                        stack.append(m)
            return False

        merged = False
        for (u, v, arr) in edges:
            E = tasks[v]
            if consumers.get(arr) != {v}:
                continue
            if not all(_pointwise_stmt(s) for s in E.statements):
                continue
            preds_e = {pu for (pu, pv, _) in edges if pv == v}
            if any(p != u and reachable(u, p) for p in preds_e):
                continue
            tasks[u].statements.extend(E.statements)
            del tasks[v]
            for i, t in enumerate(tasks):
                t.tid, t.name = i, f"FT{i}"
            merged = True
            break
        if not merged:
            return tasks


def _no_intervening_reader(graph: TaskGraph, task: FusedTask,
                           stmt: Statement, array: str) -> bool:
    names = [s.name for s in graph.statements]
    last_in_task = names.index(task.statements[-1].name)
    here = names.index(stmt.name)
    for s in graph.statements[last_in_task + 1:here]:
        if array in {a.array for a in s.reads} or array in s.output_arrays():
            return False
    return True
