"""Hardware resource model — the TPU analogue of the paper's FPGA constants.

The paper constrains its NLP with per-SLR DSP budgets, BRAM capacity and a
maximum array-partitioning factor (Eqs. 7-11).  On TPU the corresponding
budget terms are:

    DSP budget        -> MXU peak FLOP rate per chip (de-rated by alignment)
    BRAM capacity     -> VMEM bytes per core
    max partitioning  -> vector lane geometry (8 sublanes x 128 lanes)
    off-chip bitwidth -> HBM bandwidth (bytes/s) with lane-packing efficiency
    inter-SLR routing -> ICI link bandwidth between slices / pods

``Slice`` is the SLR analogue: a physically distinct resource region that a
task is assigned to (``slr_t`` in the paper, Eq. 11).  A slice may be one chip
(the default for PolyBench-scale task graphs, mirroring "1 SLR") or a mesh
sub-slice / pod for LM-scale placement.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

# ---------------------------------------------------------------------------
# Roofline constants (assignment-specified for TPU v5e)
# ---------------------------------------------------------------------------
PEAK_FLOPS_BF16 = 197e12          # FLOP/s per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link
VMEM_BYTES = 16 * 2 ** 20         # usable VMEM per core (capacity constraint)
VMEM_BW = 20 * HBM_BW             # on-chip buffer handoff bandwidth (VMEM)
CLOCK_HZ = 940e6                  # nominal core clock (latency-term conversion)

# MXU / VPU geometry: the "max array partitioning" analogue.  A block whose
# trailing dim is a multiple of LANE and second-to-last a multiple of SUBLANE
# issues at full rate; misaligned blocks are padded by the hardware and the
# padded fraction is wasted.
LANE = 128
SUBLANE = 8

# Fixed per-grid-step overhead (DMA issue + pipeline bubble), in seconds.
# Plays the role of the paper's iteration-latency constants IL_par / IL_red.
STEP_OVERHEAD_S = 120 / CLOCK_HZ
# Extra cycles to drain a reduction tree of depth log2(n) (Eq. 15 analogue).
RED_LATENCY_S = 6 / CLOCK_HZ


def alignment_efficiency(block: Sequence[int]) -> float:
    """Fraction of MXU/VPU issue slots doing useful work for a VMEM block.

    The paper models unroll efficiency via DSP counts of the fully unrolled
    intra-tile (Eq. 10); on TPU the analogous de-rating is the lane/sublane
    padding of the last two block dims.  A (m, 190) block issues as (m, 256)
    -> efficiency 190/256.
    """
    if not block:
        return 1.0
    dims = list(block)
    eff = 1.0
    last = dims[-1]
    eff *= last / _round_up(last, LANE)
    if len(dims) >= 2:
        sub = dims[-2]
        eff *= sub / _round_up(sub, SUBLANE)
    return eff


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def packing_efficiency(last_dim_elems: int, dtype_bytes: int) -> float:
    """HBM burst efficiency for a transfer whose minor dim is ``last_dim_elems``.

    FPGA analogue: data packing into <=512-bit bursts (paper §2.1.6) — a
    transfer whose row size is not a multiple of the burst width wastes
    bandwidth.  TPU DMAs move (8, 128)-element granules; a row of
    ``last_dim_elems`` occupies ceil(n/128) granule rows.
    """
    row_bytes = last_dim_elems * dtype_bytes
    granule = LANE * dtype_bytes
    padded = _round_up(max(row_bytes, 1), granule)
    return row_bytes / padded


# A "board" (chip) exposes SLICES — the SLR analogue.  Like SLRs on an SSI
# device, slices are physically distinct COMPUTE regions (TPU cores /
# MXU groups) that SHARE the off-chip memory system: placing a design on
# more slices multiplies compute and VMEM but NOT HBM bandwidth — exactly
# the paper's multi-SLR economics (compute-bound kernels scale, memory-
# bound ones don't; Table 8).  The board has BOARD_SLICES regions.
BOARD_SLICES = 3


@dataclasses.dataclass(frozen=True)
class Slice:
    """An SLR analogue: one compute region of the board."""

    sid: int
    chips: int = 1
    # Budget fractions mirror the paper's per-SLR utilisation targets
    # (e.g. "60% of one SLR" in the on-board evaluation).
    compute_frac: float = 1.0
    vmem_frac: float = 1.0
    # Board-level rates this slice divides — overridden by calibration
    # (repro.calibrate) with rates measured on the running host.
    board_flops: float = PEAK_FLOPS_BF16
    board_hbm_bw: float = HBM_BW

    @property
    def flops(self) -> float:
        """Peak of ONE region = board peak / BOARD_SLICES."""
        return self.board_flops / BOARD_SLICES * self.chips \
            * self.compute_frac

    @property
    def hbm_bw(self) -> float:
        """A single active region can saturate the full HBM system; the
        schedule-level share (per-wave active slices) is applied by the
        cost model (plan_latency) — DRAM channels are a board resource."""
        return self.board_hbm_bw * self.chips

    @property
    def vmem(self) -> float:
        return VMEM_BYTES * self.vmem_frac


@dataclasses.dataclass(frozen=True)
class Hardware:
    """Board-level description: a set of slices plus interconnect.

    The rate fields default to the static TPU-v5e constants above; a
    calibrated board (``repro.calibrate.CalibratedHardware.hardware()``)
    replaces them with rates *measured on the running host*, including two
    terms the static model has no number for:

    * ``dispatch_s`` — fixed per-task host dispatch overhead.  Tasks on the
      same slice serialize their dispatches; tasks on different slices
      overlap them, so this is exactly the "dispatch saving" the solver
      weighs against cross-slice stream cost.
    * ``hbm_share`` — measured per-slice fraction of solo HBM bandwidth
      when ``k`` slices are concurrently active (index ``k-1``).  Real
      memory systems de-rate more gracefully than the analytic ``1/k``.
    """

    slices: tuple[Slice, ...]
    ici_bw: float = ICI_BW       # bytes/s between slices (FIFO/stream analogue)
    hbm_bw: float = HBM_BW       # bytes/s off-chip, shared across slices
    vmem: float = VMEM_BYTES
    peak_flops: float = PEAK_FLOPS_BF16
    dispatch_s: float = 0.0      # per-task dispatch overhead (calibrated)
    hbm_share: tuple[float, ...] | None = None   # measured share curve

    @staticmethod
    def make(n_slices: int = 1, chips_per_slice: int = 1,
             compute_frac: float = 1.0, vmem_frac: float = 1.0,
             peak_flops: float = PEAK_FLOPS_BF16, hbm_bw: float = HBM_BW,
             ici_bw: float = ICI_BW, dispatch_s: float = 0.0,
             hbm_share: tuple[float, ...] | None = None) -> "Hardware":
        return Hardware(
            slices=tuple(
                Slice(sid=i, chips=chips_per_slice,
                      compute_frac=compute_frac, vmem_frac=vmem_frac,
                      board_flops=peak_flops, board_hbm_bw=hbm_bw)
                for i in range(n_slices)),
            ici_bw=ici_bw, hbm_bw=hbm_bw, peak_flops=peak_flops,
            dispatch_s=dispatch_s, hbm_share=hbm_share)

    @property
    def n_slices(self) -> int:
        return len(self.slices)

    def fingerprint(self) -> str:
        """Content hash of every rate the cost model prices with — the
        plan store's hardware key.  Calibration drift (new measured
        rates) changes this, which is what invalidates stored plans."""
        from .fingerprint import hardware_fingerprint
        return hardware_fingerprint(self)

    def bw_share_at(self, n_active: int) -> float:
        """Per-slice fraction of solo HBM bandwidth when ``n_active``
        slices are concurrently active in the same wave.  Uses the
        measured share curve when calibrated, the analytic ``1/n``
        split otherwise."""
        n = max(int(n_active), 1)
        if self.hbm_share:
            return self.hbm_share[min(n, len(self.hbm_share)) - 1]
        return 1.0 / n


# Canonical boards used by benchmarks (Table 8 analogue: "1 SLR" vs "3 SLR").
ONE_SLICE = Hardware.make(n_slices=1)
THREE_SLICE = Hardware.make(n_slices=3)
# 60%-utilisation variants (the paper's on-board constraint scenario).
ONE_SLICE_60 = Hardware.make(n_slices=1, compute_frac=0.6, vmem_frac=0.6)
THREE_SLICE_60 = Hardware.make(n_slices=3, compute_frac=0.6, vmem_frac=0.6)
