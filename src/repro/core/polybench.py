"""PolyBench/C 4.2.1 task-graph builders (paper §6.1, Table 5).

Medium dataset sizes, single-precision — the paper's evaluation setting.
Each builder returns the maximally-distributed statement list (paper
Listing 5 style: every loop body is one statement), from which fusion
reconstructs the paper's fused tasks.

Iterator names are unique per future fused task so tile factors are shared
exactly where the paper shares them (within a fused task) and free elsewhere.
"""
from __future__ import annotations

from .taskgraph import Access, Array, Statement, TaskGraph

F4 = 4  # float32 bytes


def _mm(prefix: str, out: str, lhs: str, rhs: str, i: str, j: str, k: str,
        I: int, J: int, K: int) -> list[Statement]:
    return [
        Statement(name=f"{prefix}_init", loops=(i, j),
                  trip_counts={i: I, j: J}, reads=(),
                  writes=(Access(out, (i, j)),), flops_per_iter=0.0),
        Statement(name=f"{prefix}_mac", loops=(i, j, k),
                  trip_counts={i: I, j: J, k: K},
                  reads=(Access(lhs, (i, k)), Access(rhs, (k, j)),
                         Access(out, (i, j))),
                  writes=(Access(out, (i, j)),), flops_per_iter=2.0),
    ]


def build_3mm(NI=180, NJ=190, NK=200, NL=210, NM=220) -> TaskGraph:
    """G = (A x B) x (C x D) — the paper's flagship kernel (Listing 4)."""
    arrays = {
        "A": Array("A", (NI, NK), F4), "B": Array("B", (NK, NJ), F4),
        "C": Array("C", (NJ, NM), F4), "D": Array("D", (NM, NL), F4),
        "E": Array("E", (NI, NJ), F4), "F": Array("F", (NJ, NL), F4),
        "G": Array("G", (NI, NL), F4),
    }
    stmts = (_mm("E", "E", "A", "B", "i0", "j0", "k0", NI, NJ, NK)
             + _mm("F", "F", "C", "D", "i1", "j1", "k1", NJ, NL, NM)
             + _mm("G", "G", "E", "F", "i2", "j2", "k2", NI, NL, NJ))
    return TaskGraph(name="3mm", arrays=arrays, statements=stmts)


def build_2mm(NI=180, NJ=190, NK=210, NL=220) -> TaskGraph:
    """D = alpha*A*B*C + beta*D (scalars folded into flop counts)."""
    arrays = {
        "A": Array("A", (NI, NK), F4), "B": Array("B", (NK, NJ), F4),
        "C": Array("C", (NJ, NL), F4), "D": Array("D", (NI, NL), F4),
        "tmp": Array("tmp", (NI, NJ), F4),
    }
    stmts = (_mm("tmp", "tmp", "A", "B", "i0", "j0", "k0", NI, NJ, NK)
             + _mm("D", "D", "tmp", "C", "i1", "j1", "k1", NI, NL, NJ))
    return TaskGraph(name="2mm", arrays=arrays, statements=stmts)


def build_gemm(NI=200, NJ=220, NK=240) -> TaskGraph:
    arrays = {
        "A": Array("A", (NI, NK), F4), "B": Array("B", (NK, NJ), F4),
        "Cout": Array("Cout", (NI, NJ), F4),
    }
    stmts = _mm("C", "Cout", "A", "B", "i0", "j0", "k0", NI, NJ, NK)
    return TaskGraph(name="gemm", arrays=arrays, statements=stmts)


def build_atax(M=390, N=410) -> TaskGraph:
    """y = A^T (A x):  tmp[i] = sum_j A[i,j] x[j];  y[j] += A[i,j] tmp[i]."""
    arrays = {
        "A": Array("A", (M, N), F4), "x": Array("x", (N,), F4),
        "tmp": Array("tmp", (M,), F4), "y": Array("y", (N,), F4),
    }
    stmts = [
        Statement("tmp_init", ("i0",), {"i0": M}, (),
                  (Access("tmp", ("i0",)),), 0.0),
        Statement("tmp_mac", ("i0", "j0"), {"i0": M, "j0": N},
                  (Access("A", ("i0", "j0")), Access("x", ("j0",)),
                   Access("tmp", ("i0",))),
                  (Access("tmp", ("i0",)),), 2.0),
        Statement("y_init", ("j1",), {"j1": N}, (),
                  (Access("y", ("j1",)),), 0.0),
        Statement("y_mac", ("j1", "i1"), {"i1": M, "j1": N},
                  (Access("A", ("i1", "j1")), Access("tmp", ("i1",)),
                   Access("y", ("j1",))),
                  (Access("y", ("j1",)),), 2.0),
    ]
    return TaskGraph(name="atax", arrays=arrays, statements=stmts)


def build_bicg(M=390, N=410) -> TaskGraph:
    """s = A^T r;  q = A p  (two independent MVs sharing A)."""
    arrays = {
        "A": Array("A", (N, M), F4), "r": Array("r", (N,), F4),
        "p": Array("p", (M,), F4), "s": Array("s", (M,), F4),
        "q": Array("q", (N,), F4),
    }
    stmts = [
        Statement("s_init", ("j0",), {"j0": M}, (),
                  (Access("s", ("j0",)),), 0.0),
        Statement("s_mac", ("j0", "i0"), {"i0": N, "j0": M},
                  (Access("A", ("i0", "j0")), Access("r", ("i0",)),
                   Access("s", ("j0",))),
                  (Access("s", ("j0",)),), 2.0),
        Statement("q_init", ("i1",), {"i1": N}, (),
                  (Access("q", ("i1",)),), 0.0),
        Statement("q_mac", ("i1", "j1"), {"i1": N, "j1": M},
                  (Access("A", ("i1", "j1")), Access("p", ("j1",)),
                   Access("q", ("i1",))),
                  (Access("q", ("i1",)),), 2.0),
    ]
    return TaskGraph(name="bicg", arrays=arrays, statements=stmts)


def build_mvt(N=400) -> TaskGraph:
    """x1 += A y1;  x2 += A^T y2."""
    arrays = {
        "A": Array("A", (N, N), F4),
        "y1": Array("y1", (N,), F4), "y2": Array("y2", (N,), F4),
        "x1": Array("x1", (N,), F4), "x2": Array("x2", (N,), F4),
    }
    stmts = [
        Statement("x1_init", ("i0",), {"i0": N}, (),
                  (Access("x1", ("i0",)),), 0.0),
        Statement("x1_mac", ("i0", "j0"), {"i0": N, "j0": N},
                  (Access("A", ("i0", "j0")), Access("y1", ("j0",)),
                   Access("x1", ("i0",))),
                  (Access("x1", ("i0",)),), 2.0),
        Statement("x2_init", ("i1",), {"i1": N}, (),
                  (Access("x2", ("i1",)),), 0.0),
        Statement("x2_mac", ("i1", "j1"), {"i1": N, "j1": N},
                  (Access("A", ("j1", "i1")), Access("y2", ("j1",)),
                   Access("x2", ("i1",))),
                  (Access("x2", ("i1",)),), 2.0),
    ]
    return TaskGraph(name="mvt", arrays=arrays, statements=stmts)


def build_gesummv(N=250) -> TaskGraph:
    """y = alpha A x + beta B x."""
    arrays = {
        "A": Array("A", (N, N), F4), "B": Array("B", (N, N), F4),
        "x": Array("x", (N,), F4),
        "t1": Array("t1", (N,), F4), "t2": Array("t2", (N,), F4),
        "y": Array("y", (N,), F4),
    }
    stmts = [
        Statement("t1_init", ("i0",), {"i0": N}, (),
                  (Access("t1", ("i0",)),), 0.0),
        Statement("t1_mac", ("i0", "j0"), {"i0": N, "j0": N},
                  (Access("A", ("i0", "j0")), Access("x", ("j0",)),
                   Access("t1", ("i0",))),
                  (Access("t1", ("i0",)),), 2.0),
        Statement("t2_init", ("i1",), {"i1": N}, (),
                  (Access("t2", ("i1",)),), 0.0),
        Statement("t2_mac", ("i1", "j1"), {"i1": N, "j1": N},
                  (Access("B", ("i1", "j1")), Access("x", ("j1",)),
                   Access("t2", ("i1",))),
                  (Access("t2", ("i1",)),), 2.0),
        Statement("y_sum", ("i2",), {"i2": N},
                  (Access("t1", ("i2",)), Access("t2", ("i2",))),
                  (Access("y", ("i2",)),), 3.0, op="add"),
    ]
    return TaskGraph(name="gesummv", arrays=arrays, statements=stmts)


def _add(prefix: str, out: str, a: str, b: str, i: str, j: str,
         N: int) -> Statement:
    return Statement(f"{prefix}_add", (i, j), {i: N, j: N},
                     (Access(a, (i, j)), Access(b, (i, j))),
                     (Access(out, (i, j)),), 1.0, op="add")


def build_madd(N=400, n=1) -> TaskGraph:
    """n-madd chains (paper §6.1): 1 = C=A+B; 2 = D=(A+B)+C;
    3 = F=(A+B)+(C+D)."""
    if n == 1:
        arrays = {k: Array(k, (N, N), F4) for k in ("A", "B", "Cout")}
        stmts = [_add("C", "Cout", "A", "B", "i0", "j0", N)]
        return TaskGraph(name="madd", arrays=arrays, statements=stmts)
    if n == 2:
        arrays = {k: Array(k, (N, N), F4)
                  for k in ("A", "B", "C", "T", "Dout")}
        stmts = [_add("T", "T", "A", "B", "i0", "j0", N),
                 _add("D", "Dout", "T", "C", "i1", "j1", N)]
        return TaskGraph(name="2-madd", arrays=arrays, statements=stmts)
    if n == 3:
        arrays = {k: Array(k, (N, N), F4)
                  for k in ("A", "B", "C", "D", "T1", "T2", "Fout")}
        stmts = [_add("T1", "T1", "A", "B", "i0", "j0", N),
                 _add("T2", "T2", "C", "D", "i1", "j1", N),
                 _add("F", "Fout", "T1", "T2", "i2", "j2", N)]
        return TaskGraph(name="3-madd", arrays=arrays, statements=stmts)
    raise ValueError(n)


def build_gemver(N=400) -> TaskGraph:
    """A_hat = A + u1 v1^T + u2 v2^T; x += beta A_hat^T y (+z); w = alpha A_hat x."""
    arrays = {
        "A": Array("A", (N, N), F4),
        "u1": Array("u1", (N,), F4), "v1": Array("v1", (N,), F4),
        "u2": Array("u2", (N,), F4), "v2": Array("v2", (N,), F4),
        "y": Array("y", (N,), F4), "z": Array("z", (N,), F4),
        "Ah": Array("Ah", (N, N), F4),
        "x": Array("x", (N,), F4), "w": Array("w", (N,), F4),
    }
    stmts = [
        Statement("Ah_upd", ("i0", "j0"), {"i0": N, "j0": N},
                  (Access("A", ("i0", "j0")), Access("u1", ("i0",)),
                   Access("v1", ("j0",)), Access("u2", ("i0",)),
                   Access("v2", ("j0",))),
                  (Access("Ah", ("i0", "j0")),), 4.0),
        Statement("x_init", ("j1",), {"j1": N},
                  (Access("z", ("j1",)),), (Access("x", ("j1",)),), 0.0),
        Statement("x_mac", ("j1", "i1"), {"i1": N, "j1": N},
                  (Access("Ah", ("i1", "j1")), Access("y", ("i1",)),
                   Access("x", ("j1",))),
                  (Access("x", ("j1",)),), 2.0),
        Statement("w_init", ("i2",), {"i2": N}, (),
                  (Access("w", ("i2",)),), 0.0),
        Statement("w_mac", ("i2", "j2"), {"i2": N, "j2": N},
                  (Access("Ah", ("i2", "j2")), Access("x", ("j2",)),
                   Access("w", ("i2",))),
                  (Access("w", ("i2",)),), 2.0),
    ]
    return TaskGraph(name="gemver", arrays=arrays, statements=stmts)


def build_symm(M=200, N=240) -> TaskGraph:
    """C = alpha A B + beta C, A symmetric (triangular access, density .5)."""
    arrays = {
        "A": Array("A", (M, M), F4), "B": Array("B", (M, N), F4),
        "Cout": Array("Cout", (M, N), F4),
    }
    stmts = [
        Statement("C_init", ("i0", "j0"), {"i0": M, "j0": N},
                  (Access("B", ("i0", "j0")),),
                  (Access("Cout", ("i0", "j0")),), 1.0),
        Statement("C_mac", ("i0", "j0", "k0"), {"i0": M, "j0": N, "k0": M},
                  (Access("A", ("i0", "k0")), Access("B", ("k0", "j0")),
                   Access("Cout", ("i0", "j0"))),
                  (Access("Cout", ("i0", "j0")),), 4.0, density=0.5),
    ]
    return TaskGraph(name="symm", arrays=arrays, statements=stmts)


def build_syrk(N=240, M=200) -> TaskGraph:
    """C = alpha A A^T + beta C (lower triangular update)."""
    arrays = {"A": Array("A", (N, M), F4), "Cout": Array("Cout", (N, N), F4)}
    stmts = [
        Statement("C_init", ("i0", "j0"), {"i0": N, "j0": N}, (),
                  (Access("Cout", ("i0", "j0")),), 1.0, density=0.5),
        Statement("C_mac", ("i0", "j0", "k0"), {"i0": N, "j0": N, "k0": M},
                  (Access("A", ("i0", "k0")), Access("A", ("j0", "k0")),
                   Access("Cout", ("i0", "j0"))),
                  (Access("Cout", ("i0", "j0")),), 2.0, density=0.5),
    ]
    return TaskGraph(name="syrk", arrays=arrays, statements=stmts)


def build_syr2k(N=240, M=200) -> TaskGraph:
    arrays = {"A": Array("A", (N, M), F4), "B": Array("B", (N, M), F4),
              "Cout": Array("Cout", (N, N), F4)}
    stmts = [
        Statement("C_init", ("i0", "j0"), {"i0": N, "j0": N}, (),
                  (Access("Cout", ("i0", "j0")),), 1.0, density=0.5),
        Statement("C_mac", ("i0", "j0", "k0"), {"i0": N, "j0": N, "k0": M},
                  (Access("A", ("i0", "k0")), Access("B", ("j0", "k0")),
                   Access("Cout", ("i0", "j0"))),
                  (Access("Cout", ("i0", "j0")),), 4.0, density=0.5),
    ]
    return TaskGraph(name="syr2k", arrays=arrays, statements=stmts)


def build_trmm(M=200, N=240) -> TaskGraph:
    """B = alpha A B, A unit lower triangular."""
    arrays = {"A": Array("A", (M, M), F4), "Bout": Array("Bout", (M, N), F4)}
    stmts = [
        Statement("B_mac", ("i0", "j0", "k0"), {"i0": M, "j0": N, "k0": M},
                  (Access("A", ("k0", "i0")), Access("Bout", ("k0", "j0")),
                   Access("Bout", ("i0", "j0"))),
                  (Access("Bout", ("i0", "j0")),), 2.0, density=0.5),
    ]
    return TaskGraph(name="trmm", arrays=arrays, statements=stmts)


BUILDERS = {
    "3mm": build_3mm, "2mm": build_2mm, "gemm": build_gemm,
    "atax": build_atax, "bicg": build_bicg, "mvt": build_mvt,
    "gesummv": build_gesummv, "gemver": build_gemver,
    "madd": lambda **kw: build_madd(n=1, **kw),
    "2-madd": lambda **kw: build_madd(n=2, **kw),
    "3-madd": lambda **kw: build_madd(n=3, **kw),
    "symm": build_symm, "syrk": build_syrk, "syr2k": build_syr2k,
    "trmm": build_trmm,
}

# Hardware adaptation of the problem sizes: the paper's "medium" datasets
# put the FPGA (368 GF/s, ~16 GB/s DDR) in a balanced compute/communication
# regime.  A TPU v5e core is ~200x faster but only ~50x higher-bandwidth,
# so the same arrays are purely memory-bound.  ``scale`` multiplies every
# extent; TPU_SCALE=16 restores the paper's arithmetic-intensity regime
# (O(N) reuse kernels become compute-bound again) without changing any
# structural property.  Tests use scale=1 (medium, paper-exact trip counts);
# benchmark tables report both.
TPU_SCALE = 16


def build(name: str, scale: int = 1) -> TaskGraph:
    g = BUILDERS[name]()
    if scale == 1:
        return g
    return _scaled(g, scale)


def _scaled(g: TaskGraph, s: int) -> TaskGraph:
    arrays = {n: Array(n, tuple(d * s for d in a.shape), a.dtype_bytes,
                       a.offchip)
              for n, a in g.arrays.items()}
    stmts = [Statement(
        name=st.name, loops=st.loops,
        trip_counts={l: tc * s for l, tc in st.trip_counts.items()},
        reads=st.reads, writes=st.writes,
        flops_per_iter=st.flops_per_iter, density=st.density, op=st.op)
        for st in g.statements]
    return TaskGraph(name=f"{g.name}@x{s}", arrays=arrays, statements=stmts)
