"""NLP-based design-space exploration (paper §4) — self-contained solver.

The paper formulates tile sizes, loop orders, transfer levels, buffer counts
and SLR assignments as one Non-Linear Program and solves it with AMPL+Gurobi.
This container is offline, so the solver is built here from scratch — which
is itself faithful to the *shape* of the problem:

* Per-task enumeration with constraint propagation and Pareto pruning
  (latency vs VMEM) over the factored discrete space
  (permutation x tiles x placements) — exact for the spaces we generate.
* A global placement phase (slice assignment = ``slr_t``, Eq. 11; streaming
  vs shared-buffer routing of dataflow edges) solved exactly for small task
  counts and by seeded simulated annealing beyond that.
* The **mode** switch reproduces the paper's comparison frameworks as
  restrictions of the same space (Table 1):

    ``prometheus``  full space (this work)
    ``sisyphus``    tiling+permutation, NO padding / dataflow / overlap /
                    multi-slice; the search is *joint* across tasks (shared
                    buffers couple them) — reproducing the Table 10 blowup.
    ``streamhls``   dataflow streaming + permutation, data assumed on-chip
                    (transfers pinned to level 0), parallelism limited to
                    FIFO width, no tiling/padding/overlap.
    ``autodse``     pragma-only: no code transformation; innermost unroll
                    factors restricted to trip-count divisors; whole arrays
                    buffered; no dataflow/overlap/multi-slice.

Determinism: all enumeration orders are sorted; annealing uses a fixed seed.
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
import random
import time

from .costmodel import footprint_elems, n_transfers, plan_latency, task_report
from .fusion import FusedGraph, FusedTask, fuse
from .padding import TileOption, tile_options
from .plan import ArrayPlacement, ExecutionPlan, TaskConfig, TaskReport
from .resources import Hardware, THREE_SLICE
from .taskgraph import TaskGraph, legal_permutations


@dataclasses.dataclass(frozen=True)
class ModeCaps:
    tiling: bool
    permutation: bool
    padding: bool
    streaming: bool
    concurrency: bool
    overlap: bool
    multi_slice: bool
    joint_search: bool = False      # couple tasks in one product space


CAPS: dict[str, ModeCaps] = {
    "prometheus": ModeCaps(True, True, True, True, True, True, True),
    "sisyphus": ModeCaps(True, True, False, False, False, False, False,
                         joint_search=True),
    "streamhls": ModeCaps(False, True, False, True, True, False, False),
    "autodse": ModeCaps(False, False, False, False, False, False, False),
}


@dataclasses.dataclass
class SolverOptions:
    mode: str = "prometheus"
    max_tile: int = 256
    tile_menu: tuple[int, ...] = (1, 2, 4, 8, 16, 32, 64, 128, 256)
    max_options_per_loop: int = 6
    top_k: int = 8
    time_budget_s: float = 120.0
    anneal_iters: int = 4000
    seed: int = 0

    @property
    def caps(self) -> ModeCaps:
        return CAPS[self.mode]


@dataclasses.dataclass
class SolveStats:
    n_evaluated: int = 0
    timed_out: bool = False
    space_size: float = 0.0          # estimated raw product-space size


# ---------------------------------------------------------------------------
# Candidate generation
# ---------------------------------------------------------------------------
# Candidate menus depend only on the task's *content* and the option fields
# below — memoize them so coordinate-descent sweeps and repeated solves of
# the same kernel (benchmark tables re-solve per mode/budget/seed) stop
# recomputing identical menus.  FusedTask is mutable/unhashable, so keys are
# content-derived, never identity-derived.  Bounded: long-lived processes
# sweeping many (graph, mode, scale) combinations must not grow forever.
_CAND_MEMO: dict[tuple, object] = {}
_CAND_MEMO_MAX = 1024


def _memo_put(key: tuple, value):
    if len(_CAND_MEMO) >= _CAND_MEMO_MAX:
        _CAND_MEMO.pop(next(iter(_CAND_MEMO)))      # FIFO eviction
    _CAND_MEMO[key] = value
    return value


def _task_key(task: FusedTask) -> tuple:
    return (task.tid, task.name,
            tuple(s.content_key() for s in task.statements))


def _opts_key(opts: SolverOptions) -> tuple:
    return (opts.mode, opts.max_tile, tuple(opts.tile_menu),
            opts.max_options_per_loop)


def candidate_tiles(task: FusedTask, opts: SolverOptions) \
        -> dict[str, list[TileOption]]:
    """Per-loop tile options under the mode's transformation capabilities
    (memoized on task content — callers must not mutate the menus)."""
    key = ("tiles", _task_key(task), _opts_key(opts))
    hit = _CAND_MEMO.get(key)
    if hit is None:
        hit = _memo_put(key, _candidate_tiles(task, opts))
    return hit


def _candidate_tiles(task: FusedTask, opts: SolverOptions) \
        -> dict[str, list[TileOption]]:
    caps = opts.caps
    tcs = task.trip_counts
    out: dict[str, list[TileOption]] = {}
    main = task.main
    for loop in task.loops:
        tc = tcs[loop]
        if loop not in main.loops:
            # Loops private to fused pointwise statements (traced chains keep
            # per-statement iterators): pin to the full extent — the tail is
            # evaluated whole per output tile, and enumerating tiles here
            # would multiply the search space without changing the kernel.
            out[loop] = [TileOption(tc, tc, tc)]
            continue
        if not caps.tiling:
            if opts.mode == "streamhls":
                # parallelism only via FIFO width on the innermost loop
                if loop == main.loops[-1]:
                    opts_l = [t for t in tile_options(tc, 0, max_tile=16)]
                else:
                    opts_l = [TileOption(1, tc, tc)]
            elif opts.mode == "autodse":
                # pragma unroll on the innermost loop, divisors only
                if loop == main.loops[-1]:
                    opts_l = [t for t in tile_options(tc, 0, max_tile=64)]
                else:
                    opts_l = [TileOption(1, tc, tc)]
            else:
                opts_l = [TileOption(1, tc, tc)]
            out[loop] = _prune_tiles(opts_l, tc, opts)
            continue
        max_pad = max(16, tc // 8) if caps.padding else 0
        opts_l = tile_options(tc, max_pad=max_pad, max_tile=opts.max_tile)
        out[loop] = _prune_tiles(opts_l, tc, opts)
    return out


def _prune_tiles(options: list[TileOption], tc: int,
                 opts: SolverOptions) -> list[TileOption]:
    """Keep a small, well-spread menu: tile=1, the full unpadded extent,
    aligned (8-multiple) sizes from the menu, and the largest plain
    divisors — the shapes the MXU/VPU and the HBM bursts care about."""
    by_tile = {}
    for t in options:
        cur = by_tile.get(t.tile)
        if cur is None or t.padded_tc < cur.padded_tc:
            by_tile[t.tile] = t
    keep: dict[int, TileOption] = {}

    def add(tile: int) -> None:
        if tile in by_tile and tile not in keep:
            keep[tile] = by_tile[tile]

    add(1)
    add(tc)                                   # full extent, no padding
    for m in sorted((x for x in opts.tile_menu if x > 1), reverse=True):
        if len(keep) >= opts.max_options_per_loop:
            break
        add(m)
    # largest plain (unpadded) divisors — the Sisyphus-style choices
    plain = sorted((t.tile for t in by_tile.values()
                    if t.pad == 0 and t.tile not in keep), reverse=True)
    for d in plain[:2]:
        if len(keep) >= opts.max_options_per_loop + 2:
            break
        add(d)
    return sorted(keep.values(), key=lambda t: t.tile)


def candidate_perms(task: FusedTask, opts: SolverOptions) \
        -> list[tuple[str, ...]]:
    """Legal inter-tile loop orders for the task (memoized on content)."""
    key = ("perms", _task_key(task), _opts_key(opts))
    hit = _CAND_MEMO.get(key)
    if hit is None:
        hit = _memo_put(key, _candidate_perms(task, opts))
    return hit


def _candidate_perms(task: FusedTask, opts: SolverOptions) \
        -> list[tuple[str, ...]]:
    main = task.main
    perms = legal_permutations(main)
    if not opts.caps.permutation:
        red = [l for l in main.loops if l in main.reduction_loops]
        par = [l for l in main.loops if l not in red]
        perms = [tuple(par) + tuple(red)]
    # Extend with any extra loops from other fused statements (appended at
    # their natural position: before the reductions).
    extra = [l for l in task.loops if l not in main.loops]
    if extra:
        perms = [p[:len(p) - len(main.reduction_loops)] + tuple(extra)
                 + p[len(p) - len(main.reduction_loops):] for p in perms]
    return perms


def _placement_options(task: FusedTask, perm: tuple[str, ...],
                       tiles: dict[str, TileOption], fg: FusedGraph,
                       hw: Hardware, opts: SolverOptions, array: str,
                       is_output: bool, overlap: bool = True) \
        -> list[ArrayPlacement]:
    """Enumerate (transfer level, define level) for one array under a given
    buffering regime, pruned to the Pareto frontier of
    (transfer bytes, buffer bytes).  ``overlap`` sets N_a (paper Table 2):
    2 for double-buffered streams, 1 otherwise."""
    caps = opts.caps
    n_levels = len(perm)
    main = task.main
    red = set(main.reduction_loops)
    n_nonred = len([l for l in perm if l not in red])
    buffers = 2 if (caps.overlap and overlap) else 1
    if is_output:
        # Output-stationary: store once per output tile — at the level just
        # below the last non-reduction loop, or hoisted fully (level 0).
        return [ArrayPlacement(transfer_level=lv, define_level=lv,
                               buffers=buffers)
                for lv in sorted({0, n_nonred})]
    if not caps.tiling and opts.mode in ("streamhls", "autodse"):
        # on-chip / whole-array assumption: everything loaded up front.
        # When the array does not fit VMEM (TPU-scale data), the
        # assumption breaks — model the buffer as HBM-resident, re-
        # streamed per innermost tile (the paper's critique of this
        # assumption, §2.3: "often results in low QoR on real hardware").
        cfg0 = TaskConfig(perm=perm, tiles=tiles, placements={}, slice_id=0)
        whole = footprint_elems(cfg0, task, array, 0) \
            * fg.graph.arrays[array].dtype_bytes
        if whole <= hw.vmem:
            return [ArrayPlacement(0, 0, buffers=1)]
        return [ArrayPlacement(n_levels, n_levels, buffers=1)]
    scored: list[tuple[float, float, ArrayPlacement]] = []
    for lv in range(0, n_levels + 1):
        for dv in sorted({0, lv}):
            pl = ArrayPlacement(transfer_level=lv, define_level=dv,
                                buffers=buffers)
            cfg = TaskConfig(perm=perm, tiles=tiles,
                             placements={array: pl}, slice_id=0)
            tile_b = footprint_elems(cfg, task, array, lv) \
                * fg.graph.arrays[array].dtype_bytes
            cnt = n_transfers(cfg, task, array, pl)
            buf_b = footprint_elems(cfg, task, array, dv) \
                * fg.graph.arrays[array].dtype_bytes * buffers
            if buf_b > hw.vmem:
                continue
            scored.append((cnt * tile_b, buf_b, pl))
    # Pareto prune on (transfer bytes, buffer bytes)
    scored.sort(key=lambda x: (x[0], x[1]))
    front: list[tuple[float, float, ArrayPlacement]] = []
    best_buf = float("inf")
    for tb, bb, pl in scored:
        if bb < best_buf - 1e-9:
            front.append((tb, bb, pl))
            best_buf = bb
    return [pl for (_, _, pl) in front[:4]] or \
        [ArrayPlacement(n_levels, n_levels, buffers=buffers)]


# ---------------------------------------------------------------------------
# Per-task enumeration
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class TaskChoice:
    cfg: TaskConfig
    report: TaskReport


def enumerate_task(task: FusedTask, fg: FusedGraph, hw: Hardware,
                   opts: SolverOptions, stats: SolveStats, deadline: float,
                   per_combo: int = 2, cap: int = 2048) -> list[TaskChoice]:
    """Candidate configs for one task, sorted by local latency.

    Keeps the ``per_combo`` best placement combos for every (perm, tiles)
    pair so the global phase (which rewires edges to on-chip buffers or ICI
    streams and re-costs) can coordinate-descend over a rich list.  Local
    costs assume off-chip edges — a lower bound refined globally."""
    sl = hw.slices[0]
    perms = candidate_perms(task, opts)
    tiles_menu = candidate_tiles(task, opts)
    reads = task.read_arrays()
    out: list[TaskChoice] = []

    loops = list(task.loops)
    combos = 1
    for l in loops:
        combos *= len(tiles_menu[l])
    stats.space_size += len(perms) * combos

    overlap_opts = (True, False) if opts.caps.overlap else (False,)
    for perm in perms:
        for tile_sel in itertools.product(*(tiles_menu[l] for l in loops)):
            # honour the deadline only once at least one feasible config
            # exists (under heavy CPU contention the budget can elapse
            # before the first evaluation — never return empty-handed)
            if out and time.monotonic() > deadline:
                stats.timed_out = True
                return _sorted_choices(out, cap)
            tiles = dict(zip(loops, tile_sel))
            local: list[TaskChoice] = []
            for overlap in overlap_opts:   # N_a: buffering is a variable
                out_opts = _placement_options(
                    task, perm, tiles, fg, hw, opts, task.output_array,
                    is_output=True, overlap=overlap)
                read_opts = [
                    _placement_options(task, perm, tiles, fg, hw, opts, a,
                                       is_output=False, overlap=overlap)
                    for a in reads]
                for out_pl in out_opts:
                    for read_sel in itertools.product(*read_opts) \
                            if read_opts else [()]:
                        placements = dict(zip(reads, read_sel))
                        placements[task.output_array] = out_pl
                        cfg = TaskConfig(perm=perm, tiles=tiles,
                                         placements=placements, slice_id=0)
                        rep = task_report(task, cfg, fg, hw)
                        stats.n_evaluated += 1
                        if rep.vmem_bytes > sl.vmem:
                            continue
                        local.append(TaskChoice(cfg, rep))
            local.sort(key=lambda c: c.report.latency_s)
            out.extend(local[:per_combo])
    return _sorted_choices(out, cap)


def _sorted_choices(choices: list[TaskChoice], cap: int) -> list[TaskChoice]:
    return sorted(choices, key=lambda c: (c.report.latency_s,
                                          c.report.vmem_bytes))[:cap]


# ---------------------------------------------------------------------------
# Edge routing: shared on-chip buffer (same slice) vs ICI stream (cross)
# ---------------------------------------------------------------------------
def _rewire_edges(fg: FusedGraph, choice: dict[int, TaskChoice],
                  assign: dict[int, int], hw: Hardware,
                  opts: SolverOptions) -> dict[int, TaskConfig]:
    """Route each dataflow edge and rewrite BOTH endpoint placements.

    Routing per edge:
      same slice  -> shared VMEM buffer handoff when the consumer buffer
                     fits (``onchip``), else HBM bounce;
      cross slice -> the bytes traverse ICI either way (distributed
                     memory), so both endpoints are marked ``stream``;
                     whether the consumer may *start early* (the paper's
                     FIFO shift, Eq. 12) is decided in ``dag_latency`` from
                     emission-order compatibility.
    A producer feeding several consumers takes the most conservative
    routing (HBM if any edge bounces, stream if any crosses slices).
    """
    cfgs: dict[int, TaskConfig] = {}
    for t in fg.tasks:
        cfgs[t.tid] = dataclasses.replace(choice[t.tid].cfg,
                                          slice_id=assign[t.tid])
    producer_route: dict[int, set[str]] = {t.tid: set() for t in fg.tasks}
    for (u, v, arr) in fg.edges:
        ccfg = cfgs[v]
        if arr not in ccfg.placements:
            continue
        pl = ccfg.placements[arr]
        same = assign[u] == assign[v]
        if same:
            consumer = fg.tasks[v]
            buf = footprint_elems(ccfg, consumer, arr, pl.define_level) \
                * fg.graph.arrays[arr].dtype_bytes * pl.buffers
            if buf <= hw.vmem:
                new = pl.replace(onchip=True, stream=False)
                producer_route[u].add("onchip")
            else:
                new = pl.replace(onchip=False, stream=False)
                producer_route[u].add("hbm")
        else:
            new = pl.replace(stream=True, onchip=False)
            producer_route[u].add("stream")
        placements = dict(ccfg.placements)
        placements[arr] = new
        cfgs[v] = dataclasses.replace(ccfg, placements=placements)
    # Producer output placements
    for (u, v, arr) in fg.edges:
        ucfg = cfgs[u]
        out_arr = fg.tasks[u].output_array
        if out_arr != arr or out_arr not in ucfg.placements:
            continue
        routes = producer_route[u]
        upl = ucfg.placements[out_arr]
        if "hbm" in routes or not routes:
            new = upl.replace(stream=False, onchip=False)
        elif "stream" in routes:
            new = upl.replace(stream=True, onchip=False)
        else:
            new = upl.replace(onchip=True, stream=False)
        uplace = dict(ucfg.placements)
        uplace[out_arr] = new
        cfgs[u] = dataclasses.replace(ucfg, placements=uplace)
    return cfgs


# ---------------------------------------------------------------------------
# Global phase: slice assignment + config choice
# ---------------------------------------------------------------------------
def _evaluate(fg: FusedGraph, choice: dict[int, TaskChoice],
              assign: dict[int, int], hw: Hardware, opts: SolverOptions) \
        -> tuple[float, dict[int, TaskConfig], dict[int, TaskReport]]:
    cfgs = _rewire_edges(fg, choice, assign, hw, opts)
    lat, reports = plan_latency(fg, cfgs, hw)
    # VMEM feasibility after rewiring (on-chip buffers count on both sides)
    for t in fg.tasks:
        if reports[t.tid].vmem_bytes > hw.slices[assign[t.tid]].vmem:
            lat = float("inf")
    return lat, cfgs, reports


def default_hardware(n_slices: int = 3) -> Hardware:
    """The board ``solve`` uses when the caller passes ``hw=None``: this
    host's cached calibrated profile (``repro.calibrate``) so slice and
    stream decisions answer to measured rates, falling back to the static
    TPU constants when the host was never calibrated.  Never measures —
    run ``scripts/calibrate.py`` (or ``repro.calibrate.calibrate()``) once
    per host to materialize the profile."""
    from ..calibrate import cached_hardware
    hw = cached_hardware(n_slices=n_slices)
    if hw is not None:
        return hw
    return THREE_SLICE if n_slices == 3 else Hardware.make(n_slices=n_slices)


def solve(graph: TaskGraph, hw: Hardware | None = None,
          opts: SolverOptions | None = None) -> ExecutionPlan:
    opts = opts or SolverOptions()
    if hw is None:
        hw = default_hardware()
    caps = opts.caps
    t0 = time.monotonic()
    deadline = t0 + opts.time_budget_s
    stats = SolveStats()
    fg = fuse(graph)

    if caps.joint_search:
        plan = _solve_joint(fg, hw, opts, stats, deadline)
    else:
        plan = _solve_decomposed(fg, hw, opts, stats, deadline)
    plan.solver_seconds = time.monotonic() - t0
    plan.n_evaluated = stats.n_evaluated
    plan.mode = opts.mode
    plan.space_size = stats.space_size
    plan.timed_out = stats.timed_out
    return plan


def _solve_decomposed(fg: FusedGraph, hw: Hardware, opts: SolverOptions,
                      stats: SolveStats, deadline: float) -> ExecutionPlan:
    """Prometheus decomposition (paper §6.4): dataflow decouples tasks, so
    the search is per-task candidate lists + a global placement phase
    (slice assignment x candidate picks) refined by coordinate descent on
    the true DAG objective.  Effective work is SUM of per-task spaces times
    a few sweeps — not the PRODUCT the shared-buffer formulation needs."""
    caps = opts.caps
    per_task = {t.tid: enumerate_task(t, fg, hw, opts, stats, deadline)
                for t in fg.tasks}
    for tid, cands in per_task.items():
        if not cands:
            raise RuntimeError(f"no feasible config for task {tid} "
                               f"(VMEM too small?)")
    n_slices = hw.n_slices if (caps.concurrency and caps.multi_slice) else 1
    tids = [t.tid for t in fg.tasks]

    best = (float("inf"), None, None, None)
    pick = {tid: 0 for tid in tids}
    assign = {tid: 0 for tid in tids}

    def evaluate(assign_: dict[int, int], pick_: dict[int, int]) -> float:
        nonlocal best
        choice = {tid: per_task[tid][pick_[tid]] for tid in tids}
        lat, cfgs, reports = _evaluate(fg, choice, assign_, hw, opts)
        stats.n_evaluated += 1
        if lat < best[0]:
            best = (lat, dict(assign_), cfgs, reports)
        return lat

    def assignment_search(pick_: dict[int, int]) -> dict[int, int]:
        """Exact slice-assignment enumeration (symmetry-broken) for small
        graphs, greedy + local moves otherwise."""
        if n_slices == 1:
            return {tid: 0 for tid in tids}
        best_a = (float("inf"), {tid: 0 for tid in tids})
        if len(tids) <= 7:
            for combo in itertools.product(range(n_slices),
                                           repeat=len(tids) - 1):
                a = {tids[0]: 0}
                for tid, s in zip(tids[1:], combo):
                    a[tid] = s
                lat = evaluate(a, pick_)
                if lat < best_a[0]:
                    best_a = (lat, dict(a))
                if time.monotonic() > deadline:
                    stats.timed_out = True
                    break
        else:
            rng = random.Random(opts.seed)
            a = {tid: tid % n_slices for tid in tids}
            cur = evaluate(a, pick_)
            best_a = (cur, dict(a))
            for it in range(opts.anneal_iters):
                if time.monotonic() > deadline:
                    stats.timed_out = True
                    break
                tid = rng.choice(tids)
                old = a[tid]
                a[tid] = rng.randrange(n_slices)
                lat = evaluate(a, pick_)
                temp = max(1e-12, 1.0 - it / max(opts.anneal_iters, 1))
                if lat < cur or rng.random() < temp * 0.05:
                    cur = lat
                    if lat < best_a[0]:
                        best_a = (lat, dict(a))
                else:
                    a[tid] = old
        return best_a[1]

    evaluate(assign, pick)
    assign = assignment_search(pick)

    # Coordinate descent over per-task candidate lists against the global
    # DAG objective, interleaved with assignment re-search.
    for _sweep in range(6):
        improved = False
        for tid in tids:
            cur_lat = best[0]
            cur_k = pick[tid]
            for k in range(len(per_task[tid])):
                if time.monotonic() > deadline:
                    stats.timed_out = True
                    break
                if k == cur_k:
                    continue
                trial = dict(pick)
                trial[tid] = k
                lat = evaluate(assign, trial)
                if lat < cur_lat:
                    cur_lat = lat
                    pick = trial
                    improved = True
            if time.monotonic() > deadline:
                break
        if improved and n_slices > 1:
            new_assign = assignment_search(pick)
            if new_assign != assign:
                assign = new_assign
                continue
        if not improved or time.monotonic() > deadline:
            break

    lat, assign, cfgs, reports = best
    if cfgs is None:
        raise RuntimeError("solver found no feasible plan")
    useful = sum(t.flops for t in fg.tasks)
    return ExecutionPlan(graph_name=fg.graph.name, configs=cfgs,
                         reports=reports, latency_s=lat,
                         useful_flops=useful)


def _solve_joint(fg: FusedGraph, hw: Hardware, opts: SolverOptions,
                 stats: SolveStats, deadline: float) -> ExecutionPlan:
    """Sisyphus-style shared-buffer formulation: permutations and tiles are
    coupled across tasks (one product space).  This is the formulation whose
    size explodes with task count (paper Table 10: 3mm times out at 4 h).

    We record the raw product-space size (the blowup) and, like a good NLP
    solver under a time budget, navigate it with coordinate descent: sweep
    tasks, re-optimizing each against the fixed others, until a fixpoint or
    the deadline.  ``timed_out`` is set when the exhaustive space could not
    have been covered within the budget (the Table 10 condition)."""
    tids = [t.tid for t in fg.tasks]
    spaces: dict[int, list[tuple]] = {}
    for t in fg.tasks:
        perms = candidate_perms(t, opts)
        tiles_menu = candidate_tiles(t, opts)
        loops = list(t.loops)
        combos = []
        for perm in perms:
            for sel in itertools.product(*(tiles_menu[l] for l in loops)):
                combos.append((perm, dict(zip(loops, sel))))
        spaces[t.tid] = combos
    size = 1.0
    for tid in tids:
        size *= len(spaces[tid])
    stats.space_size = size

    assign = {tid: 0 for tid in tids}

    def make_choice(tid: int, perm, tiles) -> TaskChoice | None:
        """Min-transfer placements, greedily demoted (next Pareto option:
        smaller buffer, more transfers) until the joint VMEM budget fits."""
        task = fg.tasks[tid]
        reads = task.read_arrays()
        options: dict[str, list[ArrayPlacement]] = {}
        for a in reads:
            options[a] = _placement_options(task, perm, tiles, fg, hw,
                                            opts, a, is_output=False)
        out_arr = task.output_array
        options[out_arr] = _placement_options(task, perm, tiles, fg, hw,
                                              opts, out_arr, is_output=True)
        pick = {a: 0 for a in options}

        def buf_bytes(a: str) -> float:
            pl = options[a][pick[a]]
            return footprint_elems(
                TaskConfig(perm=perm, tiles=tiles,
                           placements={a: pl}, slice_id=0),
                task, a, pl.define_level) \
                * fg.graph.arrays[a].dtype_bytes * pl.buffers

        vmem_budget = hw.slices[0].vmem
        for _ in range(sum(len(v) for v in options.values())):
            if sum(buf_bytes(a) for a in options) <= vmem_budget:
                break
            # demote the biggest buffer that still has a next option
            cand = sorted(options, key=buf_bytes, reverse=True)
            for a in cand:
                if pick[a] + 1 < len(options[a]):
                    pick[a] += 1
                    break
            else:
                return None
        placements = {a: options[a][pick[a]] for a in options}
        cfg = TaskConfig(perm=perm, tiles=tiles, placements=placements,
                         slice_id=0)
        rep = task_report(task, cfg, fg, hw)
        stats.n_evaluated += 1
        if rep.vmem_bytes > hw.slices[0].vmem:
            return None
        return TaskChoice(cfg, rep)

    # make_choice is deterministic per (task, point) — memoize so the
    # coordinate-descent sweeps below re-score points instead of re-deriving
    # their placements every sweep.  A hit still counts as an evaluated
    # point: n_evaluated feeds the evals_per_s coverage estimate behind the
    # Table 10 timed_out condition, which measures points *examined*, not
    # placements derived.
    choice_memo: dict[tuple[int, int], TaskChoice | None] = {}

    def cached_choice(tid: int, idx: int) -> TaskChoice | None:
        key = (tid, idx)
        if key in choice_memo:
            stats.n_evaluated += 1
            return choice_memo[key]
        perm, tiles = spaces[tid][idx]
        choice_memo[key] = make_choice(tid, perm, tiles)
        return choice_memo[key]

    # init: per-task locally-best feasible config
    choice: dict[int, TaskChoice] = {}
    for tid in tids:
        cands = [cached_choice(tid, i) for i in range(len(spaces[tid]))]
        cands = [c for c in cands if c is not None]
        if not cands:
            raise RuntimeError(f"no feasible sisyphus config for task {tid}")
        choice[tid] = min(cands, key=lambda c: c.report.latency_s)
    best = _evaluate(fg, choice, assign, hw, opts)

    improved = True
    while improved and time.monotonic() < deadline:
        improved = False
        for tid in tids:
            cur = best[0]
            for idx in range(len(spaces[tid])):
                if time.monotonic() > deadline:
                    break
                cand = cached_choice(tid, idx)
                if cand is None:
                    continue
                trial = dict(choice)
                trial[tid] = cand
                lat, cfgs, reports = _evaluate(fg, trial, assign, hw, opts)
                if lat < cur:
                    cur = lat
                    choice = trial
                    best = (lat, cfgs, reports)
                    improved = True
    # Exhaustive coverage check: the joint product space vs what the budget
    # allowed — this is what times out for 3mm in the paper.
    evals_per_s = max(stats.n_evaluated, 1) / max(
        time.monotonic() - (deadline - opts.time_budget_s), 1e-6)
    if size > evals_per_s * opts.time_budget_s:
        stats.timed_out = True

    lat, cfgs, reports = best
    useful = sum(t.flops for t in fg.tasks)
    return ExecutionPlan(graph_name=fg.graph.name, configs=cfgs,
                         reports=reports, latency_s=lat,
                         useful_flops=useful)


# ---------------------------------------------------------------------------
# Measured execution (solve-time validation = serve-time executables)
# ---------------------------------------------------------------------------
def build_graph(name: str, scale: int = 1) -> TaskGraph:
    """One graph build per (kernel, scale) — solving, measuring and serving
    the same kernel share the graph (and therefore its fingerprint, i.e.
    its program-cache entries).  Treat the result read-only.

    ``traced:<fp16>`` names resolve through the frontend's trace cache
    (``repro.frontend.trace`` must have captured the function in this
    process), so traced workloads flow through ``measure_plan`` and the
    benchmark tables exactly like PolyBench kernels; ``scale`` does not
    apply to traced sources (shapes are frozen at trace time).  Traced
    names deliberately bypass the polybench lru: their lifetime is owned
    by the *bounded* trace cache — pinning them here would defeat its
    LRU and serve stale graphs after a re-trace.
    """
    if name.startswith("traced:"):
        from ..frontend import traced_graph
        return traced_graph(name)
    return _build_polybench(name, scale)


@functools.lru_cache(maxsize=None)
def _build_polybench(name: str, scale: int) -> TaskGraph:
    from . import polybench
    return polybench.build(name, scale=scale)


def steady_state_s(exe, ins, *, batch: int = 10, samples: int = 7) -> float:
    """Best per-call seconds over ``samples`` timed batches of ``batch``
    back-to-back calls (one block at the batch end).  The ONE timing
    methodology every benchmark uses: batching amortizes scheduler noise on
    contended hosts far better than single-call timings, and best-of
    filters the remaining interference."""
    out = exe(ins)                              # compile + warm up
    for v in out.values():
        v.block_until_ready()                   # drain async dispatch
    best = float("inf")
    for _ in range(samples):
        t0 = time.perf_counter()
        for _ in range(batch):
            out = exe(ins)
        for v in out.values():
            v.block_until_ready()
        best = min(best, (time.perf_counter() - t0) / batch)
    return best


def measure_plan(name: str, plan: ExecutionPlan, *, graph=None,
                 scale: int = 1, impl: str | None = None, repeats: int = 3,
                 validate: bool = True, mode: str = "program",
                 pool_size: int | None = None):
    """Execute a plan through the codegen subsystem and time it.

    Returns ``(seconds, gflops, validated)`` — the measured counterpart of
    the model-predicted GF/s, timed with :func:`steady_state_s` (``repeats``
    = samples).  ``mode="program"`` runs the whole-plan compiled program
    resolved through the SAME process-wide program cache (and executable
    pool) the serving engine uses, so solve-time measurement and serve-time
    execution hit identical executables; ``mode="per_task"`` runs the
    host-driven per-task dispatch for comparison.  ``graph`` lets callers
    pass the already-built graph (:func:`build_graph` otherwise caches the
    rebuild).  Triangular-density kernels are not executable; callers
    should catch ``NotImplementedError``.
    """
    from ..codegen import (allclose, plan_executor, random_inputs,
                           reference_executor)
    g = graph if graph is not None else build_graph(name, scale)
    exe = plan_executor(g, plan, impl=impl, mode=mode, pool_size=pool_size)
    ins = random_inputs(g, seed=0)
    best = steady_state_s(exe, ins, samples=repeats)
    ok = True
    if validate:
        ref = reference_executor(g)(ins)
        out = exe(ins)
        ok = all(allclose(out[k], ref[k]) for k in ref)
    gflops = g.total_flops() / best / 1e9 if best else 0.0
    return best, gflops, ok
