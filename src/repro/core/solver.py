"""NLP-based design-space exploration (paper §4) — self-contained solver.

The paper formulates tile sizes, loop orders, transfer levels, buffer counts
and SLR assignments as one Non-Linear Program and solves it with AMPL+Gurobi.
This container is offline, so the solver is built here from scratch — which
is itself faithful to the *shape* of the problem:

* Per-task enumeration with constraint propagation and Pareto pruning
  (latency vs VMEM) over the factored discrete space
  (permutation x tiles x placements) — exact for the spaces we generate.
* A global placement phase (slice assignment = ``slr_t``, Eq. 11; streaming
  vs shared-buffer routing of dataflow edges) solved exactly for small task
  counts and by seeded simulated annealing beyond that.
* The **mode** switch reproduces the paper's comparison frameworks as
  restrictions of the same space (Table 1):

    ``prometheus``  full space (this work)
    ``sisyphus``    tiling+permutation, NO padding / dataflow / overlap /
                    multi-slice; the search is *joint* across tasks (shared
                    buffers couple them) — reproducing the Table 10 blowup.
    ``streamhls``   dataflow streaming + permutation, data assumed on-chip
                    (transfers pinned to level 0), parallelism limited to
                    FIFO width, no tiling/padding/overlap.
    ``autodse``     pragma-only: no code transformation; innermost unroll
                    factors restricted to trip-count divisors; whole arrays
                    buffered; no dataflow/overlap/multi-slice.

Determinism: all enumeration orders are sorted; annealing uses a fixed seed.
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
import functools
import itertools
import multiprocessing
import os
import random
import sys
import time

from ..obs import tracer as _obs_tracer
from .costmodel import (_access_of, footprint_elems, n_transfers,
                        plan_latency, task_report)
from .fusion import FusedGraph, FusedTask, fuse
from .padding import TileOption, tile_options
from .plan import ArrayPlacement, ExecutionPlan, TaskConfig, TaskReport
from .resources import Hardware, THREE_SLICE, alignment_efficiency
from .taskgraph import TaskGraph, legal_permutations


@dataclasses.dataclass(frozen=True)
class ModeCaps:
    tiling: bool
    permutation: bool
    padding: bool
    streaming: bool
    concurrency: bool
    overlap: bool
    multi_slice: bool
    joint_search: bool = False      # couple tasks in one product space


CAPS: dict[str, ModeCaps] = {
    "prometheus": ModeCaps(True, True, True, True, True, True, True),
    "sisyphus": ModeCaps(True, True, False, False, False, False, False,
                         joint_search=True),
    "streamhls": ModeCaps(False, True, False, True, True, False, False),
    "autodse": ModeCaps(False, False, False, False, False, False, False),
}


@dataclasses.dataclass
class SolverOptions:
    mode: str = "prometheus"
    max_tile: int = 256
    tile_menu: tuple[int, ...] = (1, 2, 4, 8, 16, 32, 64, 128, 256)
    max_options_per_loop: int = 6
    top_k: int = 8
    time_budget_s: float = 120.0
    anneal_iters: int = 4000
    seed: int = 0
    # Process-pool fan-out for the candidate sweep.  ``None`` resolves to
    # ``os.cpu_count() - 1`` (REPRO_SOLVER_WORKERS overrides); ``1`` is
    # today's exact serial sweep, bit-for-bit.  workers > 1 additionally
    # enables cost-model-guided pruning (compute lower bounds against the
    # shared best-so-far), so its candidate set is a subset of serial's.
    workers: int | None = None
    # Sweeps smaller than this many (perm, tiles) points stay serial even
    # with workers > 1 — pool spin-up would dominate.
    min_parallel_units: int = 192

    @property
    def caps(self) -> ModeCaps:
        return CAPS[self.mode]

    @property
    def effective_workers(self) -> int:
        if self.workers is not None:
            return max(1, int(self.workers))
        env = os.environ.get("REPRO_SOLVER_WORKERS")
        if env:
            try:
                return max(1, int(env))
            except ValueError:
                pass
        return max(1, (os.cpu_count() or 2) - 1)

    def fingerprint(self) -> str:
        """Plan-store key component — see
        :func:`repro.core.fingerprint.solver_options_fingerprint` for what
        is (and deliberately is not) part of the identity."""
        from .fingerprint import solver_options_fingerprint
        return solver_options_fingerprint(self)


@dataclasses.dataclass
class SolveStats:
    n_evaluated: int = 0
    timed_out: bool = False
    space_size: float = 0.0          # estimated raw product-space size


# ---------------------------------------------------------------------------
# Candidate generation
# ---------------------------------------------------------------------------
# Candidate menus depend only on the task's *content* and the option fields
# below — memoize them so coordinate-descent sweeps and repeated solves of
# the same kernel (benchmark tables re-solve per mode/budget/seed) stop
# recomputing identical menus.  FusedTask is mutable/unhashable, so keys are
# content-derived, never identity-derived.  Bounded: long-lived processes
# sweeping many (graph, mode, scale) combinations must not grow forever.
_CAND_MEMO: dict[tuple, object] = {}
_CAND_MEMO_MAX = 1024


def _memo_put(key: tuple, value):
    if len(_CAND_MEMO) >= _CAND_MEMO_MAX:
        _CAND_MEMO.pop(next(iter(_CAND_MEMO)))      # FIFO eviction
    _CAND_MEMO[key] = value
    return value


def _task_key(task: FusedTask) -> tuple:
    return (task.tid, task.name,
            tuple(s.content_key() for s in task.statements))


def _opts_key(opts: SolverOptions) -> tuple:
    return (opts.mode, opts.max_tile, tuple(opts.tile_menu),
            opts.max_options_per_loop)


def candidate_tiles(task: FusedTask, opts: SolverOptions) \
        -> dict[str, list[TileOption]]:
    """Per-loop tile options under the mode's transformation capabilities
    (memoized on task content — callers must not mutate the menus)."""
    key = ("tiles", _task_key(task), _opts_key(opts))
    hit = _CAND_MEMO.get(key)
    if hit is None:
        hit = _memo_put(key, _candidate_tiles(task, opts))
    return hit


def _candidate_tiles(task: FusedTask, opts: SolverOptions) \
        -> dict[str, list[TileOption]]:
    caps = opts.caps
    tcs = task.trip_counts
    out: dict[str, list[TileOption]] = {}
    main = task.main
    for loop in task.loops:
        tc = tcs[loop]
        if loop not in main.loops:
            # Loops private to fused pointwise statements (traced chains keep
            # per-statement iterators): pin to the full extent — the tail is
            # evaluated whole per output tile, and enumerating tiles here
            # would multiply the search space without changing the kernel.
            out[loop] = [TileOption(tc, tc, tc)]
            continue
        if not caps.tiling:
            if opts.mode == "streamhls":
                # parallelism only via FIFO width on the innermost loop
                if loop == main.loops[-1]:
                    opts_l = [t for t in tile_options(tc, 0, max_tile=16)]
                else:
                    opts_l = [TileOption(1, tc, tc)]
            elif opts.mode == "autodse":
                # pragma unroll on the innermost loop, divisors only
                if loop == main.loops[-1]:
                    opts_l = [t for t in tile_options(tc, 0, max_tile=64)]
                else:
                    opts_l = [TileOption(1, tc, tc)]
            else:
                opts_l = [TileOption(1, tc, tc)]
            out[loop] = _prune_tiles(opts_l, tc, opts)
            continue
        max_pad = max(16, tc // 8) if caps.padding else 0
        opts_l = tile_options(tc, max_pad=max_pad, max_tile=opts.max_tile)
        out[loop] = _prune_tiles(opts_l, tc, opts)
    return out


def _prune_tiles(options: list[TileOption], tc: int,
                 opts: SolverOptions) -> list[TileOption]:
    """Keep a small, well-spread menu: tile=1, the full unpadded extent,
    aligned (8-multiple) sizes from the menu, and the largest plain
    divisors — the shapes the MXU/VPU and the HBM bursts care about."""
    by_tile = {}
    for t in options:
        cur = by_tile.get(t.tile)
        if cur is None or t.padded_tc < cur.padded_tc:
            by_tile[t.tile] = t
    keep: dict[int, TileOption] = {}

    def add(tile: int) -> None:
        if tile in by_tile and tile not in keep:
            keep[tile] = by_tile[tile]

    add(1)
    add(tc)                                   # full extent, no padding
    for m in sorted((x for x in opts.tile_menu if x > 1), reverse=True):
        if len(keep) >= opts.max_options_per_loop:
            break
        add(m)
    # largest plain (unpadded) divisors — the Sisyphus-style choices
    plain = sorted((t.tile for t in by_tile.values()
                    if t.pad == 0 and t.tile not in keep), reverse=True)
    for d in plain[:2]:
        if len(keep) >= opts.max_options_per_loop + 2:
            break
        add(d)
    return sorted(keep.values(), key=lambda t: t.tile)


def candidate_perms(task: FusedTask, opts: SolverOptions) \
        -> list[tuple[str, ...]]:
    """Legal inter-tile loop orders for the task (memoized on content)."""
    key = ("perms", _task_key(task), _opts_key(opts))
    hit = _CAND_MEMO.get(key)
    if hit is None:
        hit = _memo_put(key, _candidate_perms(task, opts))
    return hit


def _candidate_perms(task: FusedTask, opts: SolverOptions) \
        -> list[tuple[str, ...]]:
    main = task.main
    perms = legal_permutations(main)
    if not opts.caps.permutation:
        red = [l for l in main.loops if l in main.reduction_loops]
        par = [l for l in main.loops if l not in red]
        perms = [tuple(par) + tuple(red)]
    # Extend with any extra loops from other fused statements (appended at
    # their natural position: before the reductions).
    extra = [l for l in task.loops if l not in main.loops]
    if extra:
        perms = [p[:len(p) - len(main.reduction_loops)] + tuple(extra)
                 + p[len(p) - len(main.reduction_loops):] for p in perms]
    return perms


def _placement_options(task: FusedTask, perm: tuple[str, ...],
                       tiles: dict[str, TileOption], fg: FusedGraph,
                       hw: Hardware, opts: SolverOptions, array: str,
                       is_output: bool, overlap: bool = True) \
        -> list[ArrayPlacement]:
    """Enumerate (transfer level, define level) for one array under a given
    buffering regime, pruned to the Pareto frontier of
    (transfer bytes, buffer bytes).  ``overlap`` sets N_a (paper Table 2):
    2 for double-buffered streams, 1 otherwise."""
    caps = opts.caps
    n_levels = len(perm)
    main = task.main
    red = set(main.reduction_loops)
    n_nonred = len([l for l in perm if l not in red])
    buffers = 2 if (caps.overlap and overlap) else 1
    if is_output:
        # Output-stationary: store once per output tile — at the level just
        # below the last non-reduction loop, or hoisted fully (level 0).
        return [ArrayPlacement(transfer_level=lv, define_level=lv,
                               buffers=buffers)
                for lv in sorted({0, n_nonred})]
    if not caps.tiling and opts.mode in ("streamhls", "autodse"):
        # on-chip / whole-array assumption: everything loaded up front.
        # When the array does not fit VMEM (TPU-scale data), the
        # assumption breaks — model the buffer as HBM-resident, re-
        # streamed per innermost tile (the paper's critique of this
        # assumption, §2.3: "often results in low QoR on real hardware").
        cfg0 = TaskConfig(perm=perm, tiles=tiles, placements={}, slice_id=0)
        whole = footprint_elems(cfg0, task, array, 0) \
            * fg.graph.arrays[array].dtype_bytes
        if whole <= hw.vmem:
            return [ArrayPlacement(0, 0, buffers=1)]
        return [ArrayPlacement(n_levels, n_levels, buffers=1)]
    scored: list[tuple[float, float, ArrayPlacement]] = []
    for lv in range(0, n_levels + 1):
        for dv in sorted({0, lv}):
            pl = ArrayPlacement(transfer_level=lv, define_level=dv,
                                buffers=buffers)
            cfg = TaskConfig(perm=perm, tiles=tiles,
                             placements={array: pl}, slice_id=0)
            tile_b = footprint_elems(cfg, task, array, lv) \
                * fg.graph.arrays[array].dtype_bytes
            cnt = n_transfers(cfg, task, array, pl)
            buf_b = footprint_elems(cfg, task, array, dv) \
                * fg.graph.arrays[array].dtype_bytes * buffers
            if buf_b > hw.vmem:
                continue
            scored.append((cnt * tile_b, buf_b, pl))
    # Pareto prune on (transfer bytes, buffer bytes)
    scored.sort(key=lambda x: (x[0], x[1]))
    front: list[tuple[float, float, ArrayPlacement]] = []
    best_buf = float("inf")
    for tb, bb, pl in scored:
        if bb < best_buf - 1e-9:
            front.append((tb, bb, pl))
            best_buf = bb
    return [pl for (_, _, pl) in front[:4]] or \
        [ArrayPlacement(n_levels, n_levels, buffers=buffers)]


# ---------------------------------------------------------------------------
# Per-task enumeration
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class TaskChoice:
    cfg: TaskConfig
    report: TaskReport


def _eval_combo(task: FusedTask, fg: FusedGraph, hw: Hardware,
                opts: SolverOptions, perm: tuple[str, ...],
                tiles: dict[str, TileOption],
                per_combo: int) -> tuple[list[TaskChoice], int]:
    """Evaluate every placement option of one (perm, tiles) point; returns
    the ``per_combo`` locally-best feasible choices and the number of
    placements evaluated.  Shared verbatim by the serial sweep and the
    process-pool workers so both paths score identically."""
    sl = hw.slices[0]
    reads = task.read_arrays()
    overlap_opts = (True, False) if opts.caps.overlap else (False,)
    local: list[TaskChoice] = []
    n = 0
    for overlap in overlap_opts:   # N_a: buffering is a variable
        out_opts = _placement_options(
            task, perm, tiles, fg, hw, opts, task.output_array,
            is_output=True, overlap=overlap)
        read_opts = [
            _placement_options(task, perm, tiles, fg, hw, opts, a,
                               is_output=False, overlap=overlap)
            for a in reads]
        for out_pl in out_opts:
            for read_sel in itertools.product(*read_opts) \
                    if read_opts else [()]:
                placements = dict(zip(reads, read_sel))
                placements[task.output_array] = out_pl
                cfg = TaskConfig(perm=perm, tiles=tiles,
                                 placements=placements, slice_id=0)
                rep = task_report(task, cfg, fg, hw)
                n += 1
                if rep.vmem_bytes > sl.vmem:
                    continue
                local.append(TaskChoice(cfg, rep))
    local.sort(key=lambda c: c.report.latency_s)
    return local[:per_combo], n


# Pruning margin for the parallel sweep's compute-only lower bound: a
# (perm, tiles) point is skipped when even its *compute floor* (padded
# FLOPs at its alignment efficiency — invariant under placement, routing
# and slice assignment) exceeds this multiple of the best full local
# latency already found.  > 1 keeps headroom for the global phase's
# rewiring, which can only make the *kept* candidates cheaper.
_PRUNE_MARGIN = 2.0


def _combo_lower_bound(task: FusedTask, tiles: dict[str, TileOption],
                       sl) -> float:
    """Lower bound on any placement's latency for (task, tiles): the MXU
    time of the padded compute at the output block's alignment efficiency.
    ``task_report``'s latency is >= t_mxu x total tile executions, which
    is exactly this quantity, for every placement choice."""
    main = task.main
    flops = main.flops_per_iter * main.density
    for l in main.loops:
        flops *= tiles[l].padded_tc
    out_acc = _access_of(task, task.output_array)
    eff = alignment_efficiency([tiles[it].tile for it in out_acc.iters])
    return flops / max(sl.flops * eff, 1.0)


def _decode_combo(menu_lists: list[list[TileOption]], loops: list[str],
                  idx: int) -> dict[str, TileOption]:
    """Map a flat combo index to the tile selection ``itertools.product``
    would emit at that position (first menu varies slowest) — workers
    address sweep points by index instead of shipping the selections.
    Insertion order matches ``dict(zip(loops, sel))`` exactly: tile dicts
    feed ``repr``-based plan fingerprints, so key order is identity."""
    digits: list[int] = []
    for menu in reversed(menu_lists):
        idx, r = divmod(idx, len(menu))
        digits.append(r)
    digits.reverse()
    return {loop: menu[d]
            for loop, menu, d in zip(loops, menu_lists, digits)}


def enumerate_task(task: FusedTask, fg: FusedGraph, hw: Hardware,
                   opts: SolverOptions, stats: SolveStats, deadline: float,
                   per_combo: int = 2, cap: int = 2048,
                   pool: "_SweepPool | None" = None) -> list[TaskChoice]:
    """Candidate configs for one task, sorted by local latency.

    Keeps the ``per_combo`` best placement combos for every (perm, tiles)
    pair so the global phase (which rewires edges to on-chip buffers or ICI
    streams and re-costs) can coordinate-descend over a rich list.  Local
    costs assume off-chip edges — a lower bound refined globally.

    With a live ``pool`` (workers > 1) the (perm, tiles) grid is split
    into chunked work units fanned out to worker processes, with the
    best-so-far latency shared between waves as a pruning bound."""
    perms = candidate_perms(task, opts)
    tiles_menu = candidate_tiles(task, opts)
    loops = list(task.loops)
    combos = 1
    for l in loops:
        combos *= len(tiles_menu[l])
    stats.space_size += len(perms) * combos

    if pool is not None and pool.alive \
            and len(perms) * combos >= opts.min_parallel_units:
        result = _enumerate_task_parallel(task, fg, hw, opts, stats,
                                          deadline, per_combo, cap, pool,
                                          perms, tiles_menu, loops, combos)
        if result is not None:
            return result
        # broken pool: fall through to the serial sweep below

    out: list[TaskChoice] = []
    for perm in perms:
        for tile_sel in itertools.product(*(tiles_menu[l] for l in loops)):
            # honour the deadline only once at least one feasible config
            # exists (under heavy CPU contention the budget can elapse
            # before the first evaluation — never return empty-handed)
            if out and time.monotonic() > deadline:
                stats.timed_out = True
                return _sorted_choices(out, cap)
            tiles = dict(zip(loops, tile_sel))
            local, n = _eval_combo(task, fg, hw, opts, perm, tiles,
                                   per_combo)
            stats.n_evaluated += n
            out.extend(local)
    return _sorted_choices(out, cap)


def _enumerate_task_parallel(task, fg, hw, opts, stats, deadline, per_combo,
                             cap, pool, perms, tiles_menu, loops,
                             combos) -> "list[TaskChoice] | None":
    """Fan the (perm, tiles) grid out to the process pool in deterministic
    waves.  The pruning bound only advances between waves (from the merged
    results of ALL earlier waves), so the evaluated set — and therefore
    the candidate list — is a pure function of (task, opts, workers),
    independent of worker scheduling."""
    menu_lists = [tiles_menu[l] for l in loops]
    chunk = max(16, -(-combos * len(perms) // (pool.workers * 8)))
    payloads: list[tuple] = []
    for pi in range(len(perms)):
        start = 0
        while start < combos:
            payloads.append((task.tid, pi, start,
                             min(start + chunk, combos), per_combo))
            start += chunk

    # Seed the pruning bound before the first wave: one aligned, largest-
    # tile point evaluated in-process (its chunk re-evaluates it later —
    # a duplicate costing one combo, never a lost candidate).
    bound = float("inf")
    seed_tiles = {l: menu[-1] for l, menu in zip(loops, menu_lists)}
    seeded, n = _eval_combo(task, fg, hw, opts, perms[0], seed_tiles,
                            per_combo)
    stats.n_evaluated += n
    for c in seeded:
        bound = min(bound, c.report.latency_s)

    out: list[TaskChoice] = []
    wave = pool.workers * 2
    try:
        for i in range(0, len(payloads), wave):
            now = time.monotonic()
            if out and now > deadline:
                stats.timed_out = True
                break
            budget = max(deadline - now, 0.25)
            futs = [pool.submit(_w_enum_chunk, p + (bound, budget))
                    for p in payloads[i:i + wave]]
            for f in futs:
                choices, n_eval, timed_out = f.result()
                stats.n_evaluated += n_eval
                stats.timed_out |= timed_out
                out.extend(choices)
            for c in out:
                bound = min(bound, c.report.latency_s)
    except (concurrent.futures.process.BrokenProcessPool, OSError):
        pool.alive = False
        return None
    return _sorted_choices(out, cap)


def _sorted_choices(choices: list[TaskChoice], cap: int) -> list[TaskChoice]:
    return sorted(choices, key=lambda c: (c.report.latency_s,
                                          c.report.vmem_bytes))[:cap]


# ---------------------------------------------------------------------------
# Process-pool sweep infrastructure
# ---------------------------------------------------------------------------
# Worker-process context, installed once per worker by the pool initializer
# (the fused graph, hardware and options are pickled exactly once per
# worker, not once per chunk — chunks carry only indices and bounds).
# repro.core is deliberately JAX-free, so workers never pay a JAX import.
_WORKER_CTX: tuple | None = None


def _pool_init(fg: FusedGraph, hw: Hardware, opts: SolverOptions) -> None:
    global _WORKER_CTX
    _WORKER_CTX = (fg, hw, opts)


class _SweepPool:
    """A per-solve ``ProcessPoolExecutor`` whose workers hold the solve
    context as process globals.  ``fork`` start where available (cheap,
    inherits the warm interpreter); ``spawn`` elsewhere — workers then
    re-import ``repro.core`` only.

    ``alive`` flips to False the first time the pool breaks (workers
    killed, spawn unable to re-import an interactive ``__main__``, fd
    exhaustion...); every call site then falls back to the serial sweep —
    a broken pool degrades throughput, never the solve."""

    def __init__(self, workers: int, fg: FusedGraph, hw: Hardware,
                 opts: SolverOptions):
        self.workers = workers
        self.alive = True
        # fork is cheap but unsafe once JAX's runtime threads exist
        # (os.fork + multithreaded XLA can deadlock the child); spawn
        # re-imports only the JAX-free repro.core chain, so it stays
        # correct — just slower to start — whenever jax is loaded.
        if sys.platform.startswith("linux") and "jax" not in sys.modules:
            method = "fork"
        else:
            method = "spawn"
        ctx = multiprocessing.get_context(method)
        self._ex = concurrent.futures.ProcessPoolExecutor(
            max_workers=workers, mp_context=ctx,
            initializer=_pool_init, initargs=(fg, hw, opts))

    def submit(self, fn, *args):
        return self._ex.submit(fn, *args)

    def shutdown(self) -> None:
        self._ex.shutdown(wait=True, cancel_futures=True)


def _w_enum_chunk(payload: tuple) -> tuple[list[TaskChoice], int, bool]:
    """Worker: evaluate combo indices [start, stop) of one permutation.

    Refines the shipped pruning bound with its own discoveries as it
    scans (deterministic: sequential within the chunk).  Honors the
    remaining time budget, but — like the serial sweep — never before
    producing at least one feasible choice."""
    tid, perm_idx, start, stop, per_combo, bound, budget_s = payload
    fg, hw, opts = _WORKER_CTX
    task = fg.tasks[tid]
    sl = hw.slices[0]
    perm = candidate_perms(task, opts)[perm_idx]
    tiles_menu = candidate_tiles(task, opts)
    loops = list(task.loops)
    menu_lists = [tiles_menu[l] for l in loops]
    deadline = time.monotonic() + budget_s
    choices: list[TaskChoice] = []
    n_eval = 0
    timed_out = False
    for ci in range(start, stop):
        if choices and time.monotonic() > deadline:
            timed_out = True
            break
        tiles = _decode_combo(menu_lists, loops, ci)
        if bound < float("inf") and \
                _combo_lower_bound(task, tiles, sl) > bound * _PRUNE_MARGIN:
            continue
        local, n = _eval_combo(task, fg, hw, opts, perm, tiles, per_combo)
        n_eval += n
        choices.extend(local)
        for c in local:
            bound = min(bound, c.report.latency_s)
    return choices, n_eval, timed_out


def _w_eval_chunk(payload: tuple) -> tuple[float, int, int]:
    """Worker: score trial plans against the global DAG objective.

    One of the coordinate-descent inner loops, chunked: the base choice
    (one ``TaskChoice`` per task) is fixed; each element of ``cands``
    swaps task ``tid``'s choice (or, with ``tid is None``, swaps the
    slice assignment).  Candidates whose compute floor already exceeds
    the incumbent makespan are skipped — sound, because any plan's
    makespan >= each task's compute time under every routing.  Returns
    (best latency, its candidate index, evaluations)."""
    tid, base, assign, cands, bound, budget_s = payload
    fg, hw, opts = _WORKER_CTX
    deadline = time.monotonic() + budget_s
    best_lat, best_idx, n_eval = float("inf"), -1, 0
    for idx, cand in cands:
        if n_eval and time.monotonic() > deadline:
            break
        if tid is not None:
            if bound < float("inf") and cand.report.compute_s >= bound:
                continue
            trial = dict(base)
            trial[tid] = cand
            lat, _, _ = _evaluate(fg, trial, assign, hw, opts)
        else:
            lat, _, _ = _evaluate(fg, base, cand, hw, opts)
        n_eval += 1
        if lat < best_lat:
            best_lat, best_idx = lat, idx
    return best_lat, best_idx, n_eval


def _parallel_argmin(pool: "_SweepPool", tid, base: dict, assign,
                     cands: list[tuple], bound: float, deadline: float) \
        -> "tuple[float, int, int] | None":
    """Chunk one coordinate's candidates across the pool and merge to the
    argmin.  Merging walks chunks in submission order with a strict ``<``,
    so ties resolve to the lowest candidate index — the same winner the
    serial scan picks.  ``None`` when the pool broke (caller goes serial).
    """
    budget = max(deadline - time.monotonic(), 0.25)
    chunk = max(8, -(-len(cands) // (pool.workers * 2)))
    try:
        with _obs_tracer().span("chunk_merge", "solver", task=tid,
                                candidates=len(cands), chunk=chunk) as sp:
            futs = [pool.submit(_w_eval_chunk,
                                (tid, base, assign, cands[s:s + chunk], bound,
                                 budget))
                    for s in range(0, len(cands), chunk)]
            best_lat, best_idx, n_eval = float("inf"), -1, 0
            for f in futs:
                lat, idx, ne = f.result()
                n_eval += ne
                if lat < best_lat:
                    best_lat, best_idx = lat, idx
            sp.set(chunks=len(futs), n_evaluated=n_eval)
    except (concurrent.futures.process.BrokenProcessPool, OSError):
        pool.alive = False
        return None
    return best_lat, best_idx, n_eval


def _pool_for(fg: FusedGraph, hw: Hardware,
              opts: SolverOptions) -> "_SweepPool | None":
    """A sweep pool when the options ask for one, else None (serial)."""
    workers = opts.effective_workers
    if workers <= 1:
        return None
    try:
        return _SweepPool(workers, fg, hw, opts)
    except (OSError, ValueError):    # no fork/sem support: stay serial
        return None


# ---------------------------------------------------------------------------
# Edge routing: shared on-chip buffer (same slice) vs ICI stream (cross)
# ---------------------------------------------------------------------------
def _rewire_edges(fg: FusedGraph, choice: dict[int, TaskChoice],
                  assign: dict[int, int], hw: Hardware,
                  opts: SolverOptions) -> dict[int, TaskConfig]:
    """Route each dataflow edge and rewrite BOTH endpoint placements.

    Routing per edge:
      same slice  -> shared VMEM buffer handoff when the consumer buffer
                     fits (``onchip``), else HBM bounce;
      cross slice -> the bytes traverse ICI either way (distributed
                     memory), so both endpoints are marked ``stream``;
                     whether the consumer may *start early* (the paper's
                     FIFO shift, Eq. 12) is decided in ``dag_latency`` from
                     emission-order compatibility.
    A producer feeding several consumers takes the most conservative
    routing (HBM if any edge bounces, stream if any crosses slices).
    """
    cfgs: dict[int, TaskConfig] = {}
    for t in fg.tasks:
        cfgs[t.tid] = dataclasses.replace(choice[t.tid].cfg,
                                          slice_id=assign[t.tid])
    producer_route: dict[int, set[str]] = {t.tid: set() for t in fg.tasks}
    for (u, v, arr) in fg.edges:
        ccfg = cfgs[v]
        if arr not in ccfg.placements:
            continue
        pl = ccfg.placements[arr]
        same = assign[u] == assign[v]
        if same:
            consumer = fg.tasks[v]
            buf = footprint_elems(ccfg, consumer, arr, pl.define_level) \
                * fg.graph.arrays[arr].dtype_bytes * pl.buffers
            if buf <= hw.vmem:
                new = pl.replace(onchip=True, stream=False)
                producer_route[u].add("onchip")
            else:
                new = pl.replace(onchip=False, stream=False)
                producer_route[u].add("hbm")
        else:
            new = pl.replace(stream=True, onchip=False)
            producer_route[u].add("stream")
        placements = dict(ccfg.placements)
        placements[arr] = new
        cfgs[v] = dataclasses.replace(ccfg, placements=placements)
    # Producer output placements
    for (u, v, arr) in fg.edges:
        ucfg = cfgs[u]
        out_arr = fg.tasks[u].output_array
        if out_arr != arr or out_arr not in ucfg.placements:
            continue
        routes = producer_route[u]
        upl = ucfg.placements[out_arr]
        if "hbm" in routes or not routes:
            new = upl.replace(stream=False, onchip=False)
        elif "stream" in routes:
            new = upl.replace(stream=True, onchip=False)
        else:
            new = upl.replace(onchip=True, stream=False)
        uplace = dict(ucfg.placements)
        uplace[out_arr] = new
        cfgs[u] = dataclasses.replace(ucfg, placements=uplace)
    return cfgs


# ---------------------------------------------------------------------------
# Global phase: slice assignment + config choice
# ---------------------------------------------------------------------------
def _evaluate(fg: FusedGraph, choice: dict[int, TaskChoice],
              assign: dict[int, int], hw: Hardware, opts: SolverOptions) \
        -> tuple[float, dict[int, TaskConfig], dict[int, TaskReport]]:
    cfgs = _rewire_edges(fg, choice, assign, hw, opts)
    lat, reports = plan_latency(fg, cfgs, hw)
    # VMEM feasibility after rewiring (on-chip buffers count on both sides)
    for t in fg.tasks:
        if reports[t.tid].vmem_bytes > hw.slices[assign[t.tid]].vmem:
            lat = float("inf")
    return lat, cfgs, reports


def default_hardware(n_slices: int = 3) -> Hardware:
    """The board ``solve`` uses when the caller passes ``hw=None``: this
    host's cached calibrated profile (``repro.calibrate``) so slice and
    stream decisions answer to measured rates, falling back to the static
    TPU constants when the host was never calibrated.  Never measures —
    run ``scripts/calibrate.py`` (or ``repro.calibrate.calibrate()``) once
    per host to materialize the profile."""
    from ..calibrate import cached_hardware
    hw = cached_hardware(n_slices=n_slices)
    if hw is not None:
        return hw
    return THREE_SLICE if n_slices == 3 else Hardware.make(n_slices=n_slices)


def _resolve_store(store):
    """``"auto"`` -> the env-configured default store (None when
    ``REPRO_PLAN_STORE_DIR`` is unset), ``None`` -> disabled, anything
    else is used as a ``PlanStore`` directly."""
    if store is None:
        return None
    if store == "auto":
        from ..store import default_store
        return default_store()
    return store


def _sweep_units(fg: FusedGraph, opts: SolverOptions) -> int:
    """Total (perm, tiles) points across tasks — decides whether spinning
    up a process pool can pay for itself."""
    total = 0
    for t in fg.tasks:
        combos = 1
        for l in t.loops:
            combos *= len(candidate_tiles(t, opts)[l])
        total += len(candidate_perms(t, opts)) * combos
    return total


def solve(graph: TaskGraph, hw: Hardware | None = None,
          opts: SolverOptions | None = None, *, store="auto",
          allow_stale: bool = False, refresh: bool = False) -> ExecutionPlan:
    """Solve ``graph`` for ``hw`` under ``opts``.

    ``store`` routes the persistent plan store (``repro.store``): the
    default ``"auto"`` uses the ``REPRO_PLAN_STORE_DIR``-configured store
    when one is set (hit -> return the stored plan with ``store_hit=True``
    and zero evaluations; solve -> persist the result), ``None`` disables
    it, or pass a ``PlanStore``.  ``allow_stale`` additionally accepts a
    stored plan keyed to an older hardware fingerprint (``stale_hw=True``
    on the result — callers should schedule a background ``refresh``).
    ``refresh=True`` skips the lookup (never trust the entry being
    replaced) but still persists the fresh result.
    """
    opts = opts or SolverOptions()
    if hw is None:
        hw = default_hardware()
    caps = opts.caps
    t0 = time.monotonic()
    deadline = t0 + opts.time_budget_s

    st = _resolve_store(store)
    if st is not None and not refresh:
        hit = st.load(graph, hw, opts, allow_stale=allow_stale)
        if hit is not None:
            hit.solver_seconds = time.monotonic() - t0
            return hit

    stats = SolveStats()
    tr = _obs_tracer()
    with tr.span("fuse", "solver", statements=len(graph.statements)) as sp:
        fg = fuse(graph)
        sp.set(fused_tasks=len(fg.tasks))
    pool = None
    if opts.effective_workers > 1 and \
            _sweep_units(fg, opts) >= opts.min_parallel_units:
        pool = _pool_for(fg, hw, opts)
    try:
        with tr.span("enumerate", "solver", mode=opts.mode,
                     joint=caps.joint_search,
                     workers=0 if pool is None else pool.workers) as sp:
            if caps.joint_search:
                plan = _solve_joint(fg, hw, opts, stats, deadline, pool)
            else:
                plan = _solve_decomposed(fg, hw, opts, stats, deadline, pool)
            sp.set(n_evaluated=stats.n_evaluated, timed_out=stats.timed_out)
    finally:
        if pool is not None:
            pool.shutdown()
    plan.solver_seconds = time.monotonic() - t0
    plan.n_evaluated = stats.n_evaluated
    plan.mode = opts.mode
    plan.space_size = stats.space_size
    plan.timed_out = stats.timed_out
    if st is not None:
        st.save(graph, hw, opts, plan)
    return plan


def _solve_decomposed(fg: FusedGraph, hw: Hardware, opts: SolverOptions,
                      stats: SolveStats, deadline: float,
                      pool: _SweepPool | None = None) -> ExecutionPlan:
    """Prometheus decomposition (paper §6.4): dataflow decouples tasks, so
    the search is per-task candidate lists + a global placement phase
    (slice assignment x candidate picks) refined by coordinate descent on
    the true DAG objective.  Effective work is SUM of per-task spaces times
    a few sweeps — not the PRODUCT the shared-buffer formulation needs."""
    caps = opts.caps
    per_task = {t.tid: enumerate_task(t, fg, hw, opts, stats, deadline,
                                      pool=pool)
                for t in fg.tasks}
    for tid, cands in per_task.items():
        if not cands:
            raise RuntimeError(f"no feasible config for task {tid} "
                               f"(VMEM too small?)")
    n_slices = hw.n_slices if (caps.concurrency and caps.multi_slice) else 1
    tids = [t.tid for t in fg.tasks]

    best = (float("inf"), None, None, None)
    pick = {tid: 0 for tid in tids}
    assign = {tid: 0 for tid in tids}

    def evaluate(assign_: dict[int, int], pick_: dict[int, int]) -> float:
        nonlocal best
        choice = {tid: per_task[tid][pick_[tid]] for tid in tids}
        lat, cfgs, reports = _evaluate(fg, choice, assign_, hw, opts)
        stats.n_evaluated += 1
        if lat < best[0]:
            best = (lat, dict(assign_), cfgs, reports)
        return lat

    def assignment_search(pick_: dict[int, int]) -> dict[int, int]:
        """Exact slice-assignment enumeration (symmetry-broken) for small
        graphs, greedy + local moves otherwise."""
        if n_slices == 1:
            return {tid: 0 for tid in tids}
        best_a = (float("inf"), {tid: 0 for tid in tids})
        if len(tids) <= 7:
            assigns = []
            for combo in itertools.product(range(n_slices),
                                           repeat=len(tids) - 1):
                a = {tids[0]: 0}
                for tid, s in zip(tids[1:], combo):
                    a[tid] = s
                assigns.append(a)
            if pool is not None and pool.alive and len(assigns) >= 64:
                base = {tid: per_task[tid][pick_[tid]] for tid in tids}
                res = _parallel_argmin(
                    pool, None, base, None,
                    list(enumerate(assigns)), float("inf"), deadline)
                if res is not None:
                    lat, idx, n_eval = res
                    stats.n_evaluated += n_eval
                    if idx >= 0:
                        # one in-process re-eval of the winner records its
                        # cfgs/reports in ``best``
                        evaluate(assigns[idx], pick_)
                        best_a = (lat, dict(assigns[idx]))
                    if time.monotonic() > deadline:
                        stats.timed_out = True
                    return best_a[1]
            for a in assigns:
                lat = evaluate(a, pick_)
                if lat < best_a[0]:
                    best_a = (lat, dict(a))
                if time.monotonic() > deadline:
                    stats.timed_out = True
                    break
        else:
            rng = random.Random(opts.seed)
            a = {tid: tid % n_slices for tid in tids}
            cur = evaluate(a, pick_)
            best_a = (cur, dict(a))
            for it in range(opts.anneal_iters):
                if time.monotonic() > deadline:
                    stats.timed_out = True
                    break
                tid = rng.choice(tids)
                old = a[tid]
                a[tid] = rng.randrange(n_slices)
                lat = evaluate(a, pick_)
                temp = max(1e-12, 1.0 - it / max(opts.anneal_iters, 1))
                if lat < cur or rng.random() < temp * 0.05:
                    cur = lat
                    if lat < best_a[0]:
                        best_a = (lat, dict(a))
                else:
                    a[tid] = old
        return best_a[1]

    evaluate(assign, pick)
    assign = assignment_search(pick)

    # Coordinate descent over per-task candidate lists against the global
    # DAG objective, interleaved with assignment re-search.  One tid's
    # inner loop is an argmin over its candidate list with the others
    # fixed — which is what the chunked parallel path computes, skipping
    # candidates whose compute floor already exceeds the incumbent.
    for _sweep in range(6):
        improved = False
        for tid in tids:
            cur_lat = best[0]
            cur_k = pick[tid]
            if pool is not None and pool.alive and len(per_task[tid]) >= 32:
                base = {t: per_task[t][pick[t]] for t in tids}
                cands = [(k, per_task[tid][k])
                         for k in range(len(per_task[tid])) if k != cur_k]
                res = _parallel_argmin(
                    pool, tid, base, assign, cands, cur_lat, deadline)
                if res is not None:
                    lat, k, n_eval = res
                    stats.n_evaluated += n_eval
                    if k >= 0 and lat < cur_lat:
                        trial = dict(pick)
                        trial[tid] = k
                        evaluate(assign, trial)     # records cfgs/reports
                        pick = trial
                        improved = True
                    if time.monotonic() > deadline:
                        stats.timed_out = True
                        break
                    continue
            for k in range(len(per_task[tid])):
                if time.monotonic() > deadline:
                    stats.timed_out = True
                    break
                if k == cur_k:
                    continue
                trial = dict(pick)
                trial[tid] = k
                lat = evaluate(assign, trial)
                if lat < cur_lat:
                    cur_lat = lat
                    pick = trial
                    improved = True
            if time.monotonic() > deadline:
                break
        if improved and n_slices > 1:
            new_assign = assignment_search(pick)
            if new_assign != assign:
                assign = new_assign
                continue
        if not improved or time.monotonic() > deadline:
            break

    lat, assign, cfgs, reports = best
    if cfgs is None:
        raise RuntimeError("solver found no feasible plan")
    useful = sum(t.flops for t in fg.tasks)
    return ExecutionPlan(graph_name=fg.graph.name, configs=cfgs,
                         reports=reports, latency_s=lat,
                         useful_flops=useful)


def _joint_choice(task: FusedTask, fg: FusedGraph, hw: Hardware,
                  opts: SolverOptions, perm, tiles) -> TaskChoice | None:
    """Min-transfer placements, greedily demoted (next Pareto option:
    smaller buffer, more transfers) until the joint VMEM budget fits.
    Module-level (not a closure) so pool workers run it too."""
    reads = task.read_arrays()
    options: dict[str, list[ArrayPlacement]] = {}
    for a in reads:
        options[a] = _placement_options(task, perm, tiles, fg, hw,
                                        opts, a, is_output=False)
    out_arr = task.output_array
    options[out_arr] = _placement_options(task, perm, tiles, fg, hw,
                                          opts, out_arr, is_output=True)
    pick = {a: 0 for a in options}

    def buf_bytes(a: str) -> float:
        pl = options[a][pick[a]]
        return footprint_elems(
            TaskConfig(perm=perm, tiles=tiles,
                       placements={a: pl}, slice_id=0),
            task, a, pl.define_level) \
            * fg.graph.arrays[a].dtype_bytes * pl.buffers

    vmem_budget = hw.slices[0].vmem
    for _ in range(sum(len(v) for v in options.values())):
        if sum(buf_bytes(a) for a in options) <= vmem_budget:
            break
        # demote the biggest buffer that still has a next option
        cand = sorted(options, key=buf_bytes, reverse=True)
        for a in cand:
            if pick[a] + 1 < len(options[a]):
                pick[a] += 1
                break
        else:
            return None
    placements = {a: options[a][pick[a]] for a in options}
    cfg = TaskConfig(perm=perm, tiles=tiles, placements=placements,
                     slice_id=0)
    rep = task_report(task, cfg, fg, hw)
    if rep.vmem_bytes > hw.slices[0].vmem:
        return None
    return TaskChoice(cfg, rep)


def _w_joint_chunk(payload: tuple) \
        -> tuple[list[tuple[int, TaskChoice | None]], int, bool]:
    """Worker: derive joint-mode choices for point indices [start, stop)
    of one task's coupled (perm x tiles) space, pruning points whose
    compute floor exceeds the shared bound."""
    tid, start, stop, bound, budget_s = payload
    fg, hw, opts = _WORKER_CTX
    task = fg.tasks[tid]
    sl = hw.slices[0]
    perms = candidate_perms(task, opts)
    tiles_menu = candidate_tiles(task, opts)
    loops = list(task.loops)
    menu_lists = [tiles_menu[l] for l in loops]
    combos = 1
    for m in menu_lists:
        combos *= len(m)
    deadline = time.monotonic() + budget_s
    results: list[tuple[int, TaskChoice | None]] = []
    n_eval = 0
    timed_out = False
    found = False
    for i in range(start, stop):
        if found and time.monotonic() > deadline:
            timed_out = True
            break
        pi, ci = divmod(i, combos)
        perm = perms[pi]
        tiles = _decode_combo(menu_lists, loops, ci)
        if bound < float("inf") and \
                _combo_lower_bound(task, tiles, sl) > bound * _PRUNE_MARGIN:
            results.append((i, None))
            continue
        ch = _joint_choice(task, fg, hw, opts, perm, tiles)
        n_eval += 1
        results.append((i, ch))
        if ch is not None:
            found = True
            bound = min(bound, ch.report.latency_s)
    return results, n_eval, timed_out


def _joint_init_parallel(pool: _SweepPool, fg: FusedGraph, tid: int,
                         spaces: dict, choice_memo: dict,
                         stats: SolveStats,
                         deadline: float) -> "list[TaskChoice] | None":
    """Fan one task's joint space across the pool in deterministic waves
    (same wave/bound discipline as the decomposed enumeration), filling
    ``choice_memo`` for the descent sweeps."""
    n = len(spaces[tid])
    chunk = max(16, -(-n // (pool.workers * 8)))
    payloads = [(tid, s, min(s + chunk, n)) for s in range(0, n, chunk)]
    cands: list[TaskChoice] = []
    bound = float("inf")
    wave = pool.workers * 2
    try:
        for i in range(0, len(payloads), wave):
            now = time.monotonic()
            if cands and now > deadline:
                stats.timed_out = True
                break
            budget = max(deadline - now, 0.25)
            futs = [pool.submit(_w_joint_chunk, p + (bound, budget))
                    for p in payloads[i:i + wave]]
            for f in futs:
                results, n_eval, timed_out = f.result()
                stats.n_evaluated += n_eval
                stats.timed_out |= timed_out
                for idx, ch in results:
                    choice_memo[(tid, idx)] = ch
                    if ch is not None:
                        cands.append(ch)
            for c in cands:
                bound = min(bound, c.report.latency_s)
    except (concurrent.futures.process.BrokenProcessPool, OSError):
        pool.alive = False
        return None
    return cands


def _solve_joint(fg: FusedGraph, hw: Hardware, opts: SolverOptions,
                 stats: SolveStats, deadline: float,
                 pool: _SweepPool | None = None) -> ExecutionPlan:
    """Sisyphus-style shared-buffer formulation: permutations and tiles are
    coupled across tasks (one product space).  This is the formulation whose
    size explodes with task count (paper Table 10: 3mm times out at 4 h).

    We record the raw product-space size (the blowup) and, like a good NLP
    solver under a time budget, navigate it with coordinate descent: sweep
    tasks, re-optimizing each against the fixed others, until a fixpoint or
    the deadline.  ``timed_out`` is set when the exhaustive space could not
    have been covered within the budget (the Table 10 condition)."""
    tids = [t.tid for t in fg.tasks]
    spaces: dict[int, list[tuple]] = {}
    for t in fg.tasks:
        perms = candidate_perms(t, opts)
        tiles_menu = candidate_tiles(t, opts)
        loops = list(t.loops)
        combos = []
        for perm in perms:
            for sel in itertools.product(*(tiles_menu[l] for l in loops)):
                combos.append((perm, dict(zip(loops, sel))))
        spaces[t.tid] = combos
    size = 1.0
    for tid in tids:
        size *= len(spaces[tid])
    stats.space_size = size

    assign = {tid: 0 for tid in tids}

    # _joint_choice is deterministic per (task, point) — memoize so the
    # coordinate-descent sweeps below re-score points instead of re-deriving
    # their placements every sweep.  A hit still counts as an evaluated
    # point: n_evaluated feeds the evals_per_s coverage estimate behind the
    # Table 10 timed_out condition, which measures points *examined*, not
    # placements derived.
    choice_memo: dict[tuple[int, int], TaskChoice | None] = {}

    def cached_choice(tid: int, idx: int) -> TaskChoice | None:
        key = (tid, idx)
        if key in choice_memo:
            stats.n_evaluated += 1
            return choice_memo[key]
        perm, tiles = spaces[tid][idx]
        choice_memo[key] = _joint_choice(fg.tasks[tid], fg, hw, opts,
                                         perm, tiles)
        stats.n_evaluated += 1
        return choice_memo[key]

    # init: per-task locally-best feasible config.  Deadline-checked —
    # a budget that elapses mid-enumeration keeps the best feasible
    # choices found so far instead of scanning on (the solve then
    # returns a best-effort plan, never raises past first-feasible).
    choice: dict[int, TaskChoice] = {}
    for tid in tids:
        cands: "list[TaskChoice] | None" = None
        if pool is not None and pool.alive \
                and len(spaces[tid]) >= opts.min_parallel_units:
            cands = _joint_init_parallel(pool, fg, tid, spaces, choice_memo,
                                         stats, deadline)
        if cands is None:
            cands = []
            for i in range(len(spaces[tid])):
                c = cached_choice(tid, i)
                if c is not None:
                    cands.append(c)
                if cands and time.monotonic() > deadline:
                    stats.timed_out = True
                    break
        if not cands:
            raise RuntimeError(f"no feasible sisyphus config for task {tid}")
        choice[tid] = min(cands, key=lambda c: c.report.latency_s)
    best = _evaluate(fg, choice, assign, hw, opts)

    improved = True
    while improved and time.monotonic() < deadline:
        improved = False
        for tid in tids:
            cur = best[0]
            if pool is not None and pool.alive and len(spaces[tid]) >= 32:
                cands2 = [(idx, choice_memo.get((tid, idx)))
                          for idx in range(len(spaces[tid]))]
                cands2 = [(i, c) for i, c in cands2 if c is not None]
                res = _parallel_argmin(
                    pool, tid, choice, assign, cands2, cur, deadline)
                if res is not None:
                    lat, idx, n_eval = res
                    stats.n_evaluated += n_eval
                    if idx >= 0 and lat < cur:
                        trial = dict(choice)
                        trial[tid] = choice_memo[(tid, idx)]
                        lat2, cfgs, reports = _evaluate(fg, trial, assign,
                                                        hw, opts)
                        choice = trial
                        best = (lat2, cfgs, reports)
                        improved = True
                    if time.monotonic() > deadline:
                        break
                    continue
            for idx in range(len(spaces[tid])):
                if time.monotonic() > deadline:
                    break
                cand = cached_choice(tid, idx)
                if cand is None:
                    continue
                trial = dict(choice)
                trial[tid] = cand
                lat, cfgs, reports = _evaluate(fg, trial, assign, hw, opts)
                if lat < cur:
                    cur = lat
                    choice = trial
                    best = (lat, cfgs, reports)
                    improved = True
    # Exhaustive coverage check: the joint product space vs what the budget
    # allowed — this is what times out for 3mm in the paper.
    evals_per_s = max(stats.n_evaluated, 1) / max(
        time.monotonic() - (deadline - opts.time_budget_s), 1e-6)
    if size > evals_per_s * opts.time_budget_s:
        stats.timed_out = True

    lat, cfgs, reports = best
    useful = sum(t.flops for t in fg.tasks)
    return ExecutionPlan(graph_name=fg.graph.name, configs=cfgs,
                         reports=reports, latency_s=lat,
                         useful_flops=useful)


# ---------------------------------------------------------------------------
# Measured execution (solve-time validation = serve-time executables)
# ---------------------------------------------------------------------------
def build_graph(name: str, scale: int = 1) -> TaskGraph:
    """One graph build per (kernel, scale) — solving, measuring and serving
    the same kernel share the graph (and therefore its fingerprint, i.e.
    its program-cache entries).  Treat the result read-only.

    ``traced:<fp16>`` names resolve through the frontend's trace cache
    (``repro.frontend.trace`` must have captured the function in this
    process), so traced workloads flow through ``measure_plan`` and the
    benchmark tables exactly like PolyBench kernels; ``scale`` does not
    apply to traced sources (shapes are frozen at trace time).  Traced
    names deliberately bypass the polybench lru: their lifetime is owned
    by the *bounded* trace cache — pinning them here would defeat its
    LRU and serve stale graphs after a re-trace.
    """
    if name.startswith("traced:"):
        from ..frontend import traced_graph
        return traced_graph(name)
    return _build_polybench(name, scale)


@functools.lru_cache(maxsize=None)
def _build_polybench(name: str, scale: int) -> TaskGraph:
    from . import polybench
    return polybench.build(name, scale=scale)


def steady_state_s(exe, ins, *, batch: int = 10, samples: int = 7) -> float:
    """Best per-call seconds over ``samples`` timed batches of ``batch``
    back-to-back calls (one block at the batch end).  The ONE timing
    methodology every benchmark uses: batching amortizes scheduler noise on
    contended hosts far better than single-call timings, and best-of
    filters the remaining interference."""
    out = exe(ins)                              # compile + warm up
    for v in out.values():
        v.block_until_ready()                   # drain async dispatch
    best = float("inf")
    for _ in range(samples):
        t0 = time.perf_counter()
        for _ in range(batch):
            out = exe(ins)
        for v in out.values():
            v.block_until_ready()
        best = min(best, (time.perf_counter() - t0) / batch)
    return best


def measure_plan(name: str, plan: ExecutionPlan, *, graph=None,
                 scale: int = 1, impl: str | None = None, repeats: int = 3,
                 validate: bool = True, mode: str = "program",
                 pool_size: int | None = None):
    """Execute a plan through the codegen subsystem and time it.

    Returns ``(seconds, gflops, validated)`` — the measured counterpart of
    the model-predicted GF/s, timed with :func:`steady_state_s` (``repeats``
    = samples).  ``mode="program"`` runs the whole-plan compiled program
    resolved through the SAME process-wide program cache (and executable
    pool) the serving engine uses, so solve-time measurement and serve-time
    execution hit identical executables; ``mode="per_task"`` runs the
    host-driven per-task dispatch for comparison.  ``graph`` lets callers
    pass the already-built graph (:func:`build_graph` otherwise caches the
    rebuild).  Triangular-density kernels are not executable; callers
    should catch ``NotImplementedError``.
    """
    from ..codegen import (allclose, plan_executor, random_inputs,
                           reference_executor)
    g = graph if graph is not None else build_graph(name, scale)
    exe = plan_executor(g, plan, impl=impl, mode=mode, pool_size=pool_size)
    ins = random_inputs(g, seed=0)
    best = steady_state_s(exe, ins, samples=repeats)
    ok = True
    if validate:
        ref = reference_executor(g)(ins)
        out = exe(ins)
        ok = all(allclose(out[k], ref[k]) for k in ref)
    gflops = g.total_flops() / best / 1e9 if best else 0.0
    return best, gflops, ok
