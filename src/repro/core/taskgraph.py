"""Affine task graphs — the paper's dependency-graph IR (§3.1).

The paper starts from affine C code, applies maximal loop distribution so each
loop body holds one statement, and builds a dependency graph whose nodes are
tasks and whose edges carry data tiles (PoCC/ISCC provide trip counts,
schedules and dependences).  This module is the equivalent IR, constructed
directly in Python: each :class:`Statement` carries its iteration domain
(ordered loops with trip counts), its array accesses (one iterator per array
dimension — the affine subset the paper targets), and its reduction loops.

The graph is deliberately *synchronous-dataflow* flavoured: all extents are
static, so footprints, transfer volumes and FLOP counts are exact — the
property §3 of the paper relies on ("compile-time awareness enables a precise
model").
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Mapping

import numpy as np


@dataclasses.dataclass(frozen=True)
class Array:
    """A named dense array.  ``offchip`` marks arrays that live in HBM (DDR
    analogue); intermediates produced and consumed on-chip may still be
    spilled if the solver decides so."""

    name: str
    shape: tuple[int, ...]
    dtype_bytes: int = 4
    offchip: bool = True

    @property
    def bytes(self) -> int:
        return int(np.prod(self.shape)) * self.dtype_bytes


@dataclasses.dataclass(frozen=True)
class Access:
    """An affine array access ``A[it_0][it_1]...`` — one iterator per dim.

    ``None`` entries denote broadcast dims (the iterator set does not index
    that dimension; e.g. ``x[j]`` inside loops (i, j) has dims ("j",)).
    """

    array: str
    iters: tuple[str, ...]


@dataclasses.dataclass(frozen=True)
class Statement:
    """One fully-distributed loop body (paper Listing 5: S0..S5)."""

    name: str
    loops: tuple[str, ...]                  # written order, outermost first
    trip_counts: Mapping[str, int]
    reads: tuple[Access, ...]
    writes: tuple[Access, ...]
    flops_per_iter: float = 2.0             # e.g. 1 mul + 1 add
    # Fraction of the rectangular domain actually executed (triangular
    # domains in symm/trmm/syrk are ~0.5); keeps the model affine-exact in
    # volume terms without full polyhedra.
    density: float = 1.0
    # How non-accumulator reads combine: "mul" = product (contracted over
    # reduction loops), "add" = elementwise sum of per-read projections,
    # "sub" = like "add" with every read after the first negated,
    # "unary:<name>" = pointwise function of a single read (tanh, logistic,
    # exp, ... — see repro.kernels.contraction.ref.unary_fn),
    # "binary:<name>" = pointwise pairing of two reads (max/min/div), and
    # "opaque:<digest>" = passthrough segment whose semantics live in the
    # codegen opaque registry (repro.codegen.register_opaque).  Drives the
    # codegen lowering (repro.codegen) and the reference oracle.
    op: str = "mul"
    # Affine post-scaling: the statement computes ``coeff * op(reads) +
    # offset`` — how the frontend folds scalar literals (``x * 2.0``,
    # ``x / c``, ``1.0 + tanh(e)``) into otherwise-affine statements
    # instead of materializing rank-0 operands.
    coeff: float = 1.0
    offset: float = 0.0

    def __post_init__(self):
        for acc in self.reads + self.writes:
            for it in acc.iters:
                if it is not None and it not in self.loops:
                    raise ValueError(
                        f"{self.name}: access {acc} uses iterator {it!r} "
                        f"not in loops {self.loops}")

    def content_key(self) -> tuple:
        """Hashable summary of everything semantically relevant — the shared
        basis for solver memo keys and codegen graph fingerprints (one
        definition so the two caches cannot drift)."""
        key = (self.name, tuple(self.loops),
               tuple(sorted(self.trip_counts.items())),
               tuple((a.array, tuple(a.iters)) for a in self.reads),
               tuple((a.array, tuple(a.iters)) for a in self.writes),
               self.flops_per_iter, self.density, self.op)
        # Appended only when non-default so pre-existing fingerprints (and
        # the persistent program cache keyed on them) stay stable.
        if self.coeff != 1.0 or self.offset != 0.0:
            key = key + (self.coeff, self.offset)
        return key

    @property
    def reduction_loops(self) -> tuple[str, ...]:
        """Loops not appearing in any write access — accumulation dims."""
        written = {it for w in self.writes for it in w.iters if it is not None}
        return tuple(l for l in self.loops if l not in written)

    @property
    def domain_size(self) -> float:
        return float(np.prod([self.trip_counts[l] for l in self.loops])) \
            * self.density

    @property
    def flops(self) -> float:
        return self.domain_size * self.flops_per_iter

    def output_arrays(self) -> tuple[str, ...]:
        return tuple(dict.fromkeys(w.array for w in self.writes))


@dataclasses.dataclass
class TaskGraph:
    """Dependency graph over distributed statements.

    Edges are read-after-write array flows: statement ``v`` depends on ``u``
    if ``v`` reads an array that ``u`` writes and ``u`` precedes ``v`` in
    program order.  (Program order is the statement list order, as in the
    paper's sequential affine input.)
    """

    name: str
    arrays: dict[str, Array]
    statements: list[Statement]
    #: True for graphs lowered from a traced jaxpr (repro.frontend): their
    #: statements carry per-statement-unique iterators and elementwise
    #: chains, which unlocks the pointwise fusion pass and segment merging
    #: (hand-built polybench graphs keep the conservative defaults).
    traced: bool = False

    def __post_init__(self):
        names = [s.name for s in self.statements]
        if len(set(names)) != len(names):
            raise ValueError("duplicate statement names")
        for s in self.statements:
            for acc in s.reads + s.writes:
                if acc.array not in self.arrays:
                    raise ValueError(f"{s.name} references unknown array "
                                     f"{acc.array!r}")

    # -- dependence structure -------------------------------------------------
    def producer_of(self, array: str, before: int) -> int | None:
        """Index of the last statement writing ``array`` before position
        ``before`` in program order (RAW source)."""
        for i in range(before - 1, -1, -1):
            if array in self.statements[i].output_arrays():
                return i
        return None

    def edges(self) -> list[tuple[int, int, str]]:
        """(producer_idx, consumer_idx, array) RAW edges."""
        out = []
        for j, s in enumerate(self.statements):
            for acc in s.reads:
                i = self.producer_of(acc.array, j)
                if i is not None:
                    out.append((i, j, acc.array))
        # WAW edges (init -> accumulate on the same array) — these are what
        # output-stationary fusion later merges.
        for j, s in enumerate(self.statements):
            for arr in s.output_arrays():
                i = self.producer_of(arr, j)
                if i is not None:
                    out.append((i, j, arr))
        return sorted(set(out))

    def external_inputs(self) -> list[str]:
        """Arrays read before ever being written (true off-chip inputs)."""
        written: set[str] = set()
        inputs: list[str] = []
        for s in self.statements:
            for acc in s.reads:
                if acc.array not in written and acc.array not in inputs:
                    inputs.append(acc.array)
            written.update(s.output_arrays())
        return inputs

    def final_outputs(self) -> list[str]:
        """Arrays written and not consumed afterwards (results)."""
        outs: list[str] = []
        for i, s in enumerate(self.statements):
            for arr in s.output_arrays():
                consumed_later = any(
                    arr in {a.array for a in t.reads}
                    for t in self.statements[i + 1:])
                overwritten_later = any(
                    arr in t.output_arrays() for t in self.statements[i + 1:])
                if not consumed_later and not overwritten_later \
                        and arr not in outs:
                    outs.append(arr)
        return outs

    def total_flops(self) -> float:
        return sum(s.flops for s in self.statements)

    def io_bytes(self) -> float:
        """Minimum off-chip traffic: inputs once in + outputs once out."""
        ins = sum(self.arrays[a].bytes for a in self.external_inputs())
        outs = sum(self.arrays[a].bytes for a in self.final_outputs())
        return float(ins + outs)


# ---------------------------------------------------------------------------
# Convenience builders
# ---------------------------------------------------------------------------
def iter_names(stem: str, rank: int, kind: str = "d") -> tuple[str, ...]:
    """Fresh iterator names for one statement: ``{stem}_{kind}{0..rank-1}``.

    The frontend names iterators uniquely per statement (the polybench
    convention: tile factors are shared exactly within a fused task and
    free elsewhere); ``kind`` distinguishes output dims (``d``) from
    reduction dims (``r``) and degenerate broadcast dims (``z``).
    """
    return tuple(f"{stem}_{kind}{k}" for k in range(rank))


def intermediate(name: str, shape: tuple[int, ...],
                 dtype_bytes: int = 4) -> Array:
    """A fresh intermediate/input array for graph construction (HBM-resident
    by default, like every polybench array — the solver decides whether it
    is ever actually spilled)."""
    return Array(name=name, shape=tuple(int(s) for s in shape),
                 dtype_bytes=dtype_bytes, offchip=True)


def copy_statement(name: str, out: str, src: str,
                   src_iters: tuple[str, ...], out_iters: tuple[str, ...],
                   trip_counts: Mapping[str, int]) -> Statement:
    """Identity/projection copy ``out[out_iters] = src[src_iters]`` as an
    ``op="add"`` single-read statement — how the frontend materializes
    transposes and forwards arrays that are both consumed downstream and
    function outputs."""
    loops = tuple(dict.fromkeys(tuple(out_iters) + tuple(src_iters)))
    return Statement(
        name=name, loops=loops, trip_counts=dict(trip_counts),
        reads=(Access(src, tuple(src_iters)),),
        writes=(Access(out, tuple(out_iters)),),
        flops_per_iter=0.0, op="add")


def matmul_statements(prefix: str, out: str, lhs: str, rhs: str,
                      i: str, j: str, k: str,
                      I: int, J: int, K: int,
                      init: bool = True) -> list[Statement]:
    """``out[i][j] (=0); out[i][j] += lhs[i][k] * rhs[k][j]`` — the 3mm/2mm
    building block (paper Listing 4)."""
    stmts = []
    if init:
        stmts.append(Statement(
            name=f"{prefix}_init", loops=(i, j),
            trip_counts={i: I, j: J},
            reads=(), writes=(Access(out, (i, j)),),
            flops_per_iter=0.0))
    stmts.append(Statement(
        name=f"{prefix}_mac", loops=(i, j, k),
        trip_counts={i: I, j: J, k: K},
        reads=(Access(lhs, (i, k)), Access(rhs, (k, j)),
               Access(out, (i, j))),
        writes=(Access(out, (i, j)),),
        flops_per_iter=2.0))
    return stmts


def legal_permutations(stmt: Statement) -> list[tuple[str, ...]]:
    """All legal inter-tile loop orders for a statement.

    Following the paper (§3.4): reduction loops are pinned innermost (they
    are pipelined directly above the task, ranked by trip count with the
    largest innermost), so the NLP only permutes the non-reduction loops.
    For the affine kernels targeted (fully permutable loop nests after
    distribution) every order of the non-reduction loops is legal — the
    ISCC legality check of the paper reduces to this for permutable nests.
    """
    red = list(stmt.reduction_loops)
    red.sort(key=lambda l: stmt.trip_counts[l])  # largest trip count innermost
    par = [l for l in stmt.loops if l not in red]
    return [tuple(p) + tuple(red) for p in itertools.permutations(par)]
