"""Content fingerprints shared by the program cache and the plan store.

These used to live in ``repro.codegen.program`` (which imports JAX at
module scope); the persistent plan store (``repro.store``) needs the same
identities from an import-light context — a replica deciding whether a
cached plan applies must not pay a JAX import to hash a graph.  The
codegen module re-exports them, so existing callers are unaffected.

All fingerprints are sha256 over ``repr`` of *content* tuples (never
object identities), truncated to 16 hex chars — collision-safe for cache
keys, short enough to compose into filenames.
"""
from __future__ import annotations

import hashlib

from .plan import ExecutionPlan
from .taskgraph import TaskGraph


def _digest(items) -> str:
    return hashlib.sha256(repr(items).encode()).hexdigest()[:16]


def graph_fingerprint(graph: TaskGraph) -> str:
    """Stable content hash of a task graph (structure, shapes, semantics)."""
    items = (
        graph.name,
        tuple(sorted((a.name, a.shape, a.dtype_bytes, a.offchip)
                     for a in graph.arrays.values())),
        tuple(s.content_key() for s in graph.statements),
    )
    return _digest(items)


def plan_fingerprint(plan: ExecutionPlan) -> str:
    """Stable content hash of the plan decisions codegen consumes."""
    items = (plan.graph_name,
             tuple(sorted((tid, repr(cfg.to_jsonable()))
                          for tid, cfg in plan.configs.items())))
    return _digest(items)


def hardware_fingerprint(hw) -> str:
    """Stable content hash of a ``Hardware`` board — every rate the cost
    model prices with, so calibration drift (new measured HBM/ICI/FLOP
    rates) changes the fingerprint and therefore the plan-store key."""
    items = (
        tuple((s.sid, s.chips, s.compute_frac, s.vmem_frac,
               s.board_flops, s.board_hbm_bw) for s in hw.slices),
        hw.ici_bw, hw.hbm_bw, hw.vmem, hw.peak_flops, hw.dispatch_s,
        tuple(hw.hbm_share) if hw.hbm_share else None,
    )
    return _digest(items)


def solver_options_fingerprint(opts) -> str:
    """Stable content hash of the ``SolverOptions`` fields that shape the
    *search space and budget* — NOT the execution strategy.  ``workers``
    (and the parallel-engagement threshold) are deliberately excluded: a
    plan solved with any worker count is valid for every replica, and the
    parallel sweep's pruning only discards provably-dominated candidates,
    so replicas with different core counts share store entries."""
    items = (opts.mode, opts.max_tile, tuple(opts.tile_menu),
             opts.max_options_per_loop, opts.top_k,
             round(float(opts.time_budget_s), 6), opts.anneal_iters,
             opts.seed)
    return _digest(items)
