"""Prometheus-JAX core: the paper's holistic NLP optimization engine.

Pipeline (paper Fig. 2): affine task graph -> fusion -> unified design space
(tiling + permutation + padding + buffering + concurrency + slice placement)
-> NLP solve -> execution plan -> code generation (`repro.codegen`).
"""
from .taskgraph import Access, Array, Statement, TaskGraph
from .fusion import FusedGraph, FusedTask, fuse
from .padding import TileOption, tile_options, communication_padding
from .plan import ArrayPlacement, ExecutionPlan, TaskConfig, TaskReport
from .resources import Hardware, Slice, ONE_SLICE, THREE_SLICE
from .solver import (SolverOptions, build_graph, default_hardware,
                     measure_plan, solve, steady_state_s)
from . import polybench

# Codegen is layered above core (it consumes plans).  Resolved lazily
# (PEP 562) so `import repro.codegen` -> `repro.core` -> back into the
# partially-initialised codegen package cannot deadlock the import.
_CODEGEN_NAMES = ("plan_executor", "random_inputs", "reference_executor")


def __getattr__(name):
    if name in _CODEGEN_NAMES:
        from .. import codegen
        return getattr(codegen, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = [
    "Access", "Array", "Statement", "TaskGraph",
    "FusedGraph", "FusedTask", "fuse",
    "TileOption", "tile_options", "communication_padding",
    "ArrayPlacement", "ExecutionPlan", "TaskConfig", "TaskReport",
    "Hardware", "Slice", "ONE_SLICE", "THREE_SLICE",
    "SolverOptions", "solve", "polybench",
    "build_graph", "default_hardware", "measure_plan", "steady_state_s",
    "plan_executor", "random_inputs", "reference_executor",
]
