"""Prometheus-JAX core: the paper's holistic NLP optimization engine.

Pipeline (paper Fig. 2): affine task graph -> fusion -> unified design space
(tiling + permutation + padding + buffering + concurrency + slice placement)
-> NLP solve -> execution plan -> code generation.
"""
from .taskgraph import Access, Array, Statement, TaskGraph
from .fusion import FusedGraph, FusedTask, fuse
from .padding import TileOption, tile_options, communication_padding
from .plan import ArrayPlacement, ExecutionPlan, TaskConfig, TaskReport
from .resources import Hardware, Slice, ONE_SLICE, THREE_SLICE
from .solver import SolverOptions, solve
from . import polybench

__all__ = [
    "Access", "Array", "Statement", "TaskGraph",
    "FusedGraph", "FusedTask", "fuse",
    "TileOption", "tile_options", "communication_padding",
    "ArrayPlacement", "ExecutionPlan", "TaskConfig", "TaskReport",
    "Hardware", "Slice", "ONE_SLICE", "THREE_SLICE",
    "SolverOptions", "solve", "polybench",
]
