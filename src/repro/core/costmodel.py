"""Analytic latency model — the paper's NLP objective (Eqs. 12-16), TPU terms.

Structure mirrors the paper exactly:

* Eq. 15 (intra-task base case): one fully-"unrolled" intra-tile executes on
  the MXU/VPU; latency = issue overhead + FLOP time (de-rated by lane/sublane
  alignment) + a reduction-tree drain term ``RED_LATENCY * log2(red_elems)``.
* Eq. 16 (pipelined reduction): inter-tile *reduction* loops revisit the same
  output tile, pipelined with initiation interval II = steady-state tile time.
* Eq. 14 (level recursion): every non-reduction inter-tile loop level adds
  ``trips * max(inner, comm)`` when double/triple-buffered (computation-
  communication overlap) or ``trips * (inner + comm)`` when not, plus
  prologue/epilogue fill terms.
* Eqs. 12-13 (DAG): per-task latencies compose over the fused dataflow graph
  with producer->consumer ``shift`` terms for streamed (FIFO) edges, a
  per-slice serialization constraint (a TPU core runs one task at a time —
  concurrency comes from placing tasks on different slices, the SLR
  adaptation), and makespan = latest sink finish.

All byte volumes honour padding (padded trip counts cost real compute and
real transfer bytes) and burst packing (minor-dim alignment de-rates HBM
bandwidth) — the paper's padding-for-computation / padding-for-communication
trade-off is therefore visible to the solver.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Mapping

from .fusion import FusedGraph, FusedTask
from .plan import ArrayPlacement, TaskConfig, TaskReport
from .resources import (Hardware, STEP_OVERHEAD_S, RED_LATENCY_S, VMEM_BW,
                        alignment_efficiency, packing_efficiency)
from .taskgraph import Access


# ---------------------------------------------------------------------------
# Footprints (paper f_{a,l}) and transfer counts
# ---------------------------------------------------------------------------
def _access_of(task: FusedTask, array: str) -> Access:
    """First access of ``array`` in the task, memoized per task.

    This sits in the solver's innermost enumeration loop (every footprint /
    transfer-count query lands here); a linear rescan of all statements per
    call dominated solve time.  The cache lives on the task object and is
    rebuilt if fusion appends statements after a lookup.
    """
    cache = getattr(task, "_access_cache", None)
    if cache is None or cache[0] != len(task.statements):
        mapping: dict[str, Access] = {}
        for s in task.statements:
            for acc in tuple(s.reads) + tuple(s.writes):
                mapping.setdefault(acc.array, acc)
        cache = (len(task.statements), mapping)
        task._access_cache = cache
    try:
        return cache[1][array]
    except KeyError:
        raise KeyError(f"array {array!r} not accessed by task {task.name}") \
            from None


def tile_extent(cfg: TaskConfig, task: FusedTask, it: str, level: int) -> int:
    """Extent along iterator ``it`` of the data-tile transferred at ``level``.

    If the loop carrying ``it`` encloses the transfer (its level < given
    level) each transfer covers one tile of it; otherwise the transfer must
    cover all remaining iterations (full padded extent)."""
    t = cfg.tiles[it]
    if it in cfg.perm and cfg.level_of(it) <= level:
        return t.tile
    return t.padded_tc


def footprint_elems(cfg: TaskConfig, task: FusedTask, array: str,
                    level: int) -> int:
    acc = _access_of(task, array)
    n = 1
    for it in acc.iters:
        n *= tile_extent(cfg, task, it, level)
    return n


def minor_dim_elems(cfg: TaskConfig, task: FusedTask, array: str,
                    level: int) -> int:
    acc = _access_of(task, array)
    if not acc.iters:
        return 1
    return tile_extent(cfg, task, acc.iters[-1], level)


def n_transfers(cfg: TaskConfig, task: FusedTask, array: str,
                placement: ArrayPlacement) -> int:
    """How many times the data-tile of ``array`` is (re)loaded.

    Product of inter-tile trip counts of loops enclosing the transfer level,
    *skipping* loops that do not index the array when the buffer is defined
    at or above that loop (data reuse across that loop — the paper's
    d_{a,l} mechanism, e.g. array E reused across j0 in Listing 6)."""
    acc = _access_of(task, array)
    used = set(acc.iters)
    total = 1
    for pos, loop in enumerate(cfg.perm):
        level_of_loop = pos + 1
        if level_of_loop > placement.transfer_level:
            break
        if loop in used or placement.define_level >= level_of_loop:
            total *= cfg.tiles[loop].n_tiles
    return total


# ---------------------------------------------------------------------------
# Per-task latency (Eqs. 14-16)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class StreamRates:
    """Bandwidths for each array feeding/leaving a task."""

    hbm_bw: float
    ici_bw: float


def task_report(task: FusedTask, cfg: TaskConfig, graph: FusedGraph,
                hw: Hardware, bw_share: float = 1.0) -> TaskReport:
    """``bw_share`` divides HBM bandwidth among concurrently-active slices
    (the DRAM channels are a board-level resource shared by SLR regions —
    paper §2.2.2 economics: compute scales with slices, bandwidth doesn't).
    """
    sl = hw.slices[cfg.slice_id]
    main = task.main
    out_arr = task.output_array
    arrays = graph.graph.arrays

    # ----- intra-tile (Eq. 15) ------------------------------------------
    red_loops = [l for l in main.loops if l in main.reduction_loops]
    intra_elems = 1.0
    for l in main.loops:
        intra_elems *= cfg.tiles[l].tile
    out_acc = _access_of(task, out_arr)
    out_block = [cfg.tiles[it].tile for it in out_acc.iters]
    eff = alignment_efficiency(out_block)
    flops_tile = intra_elems * main.flops_per_iter * main.density
    t_mxu = flops_tile / max(sl.flops * eff, 1.0)
    red_elems = 1
    for l in red_loops:
        red_elems *= cfg.tiles[l].tile
    lat_intra = STEP_OVERHEAD_S + t_mxu \
        + RED_LATENCY_S * math.log2(max(red_elems, 1) or 1)

    # ----- pipelined inter-tile reduction loops (Eq. 16) ----------------
    red_trips = 1
    for l in red_loops:
        red_trips *= cfg.tiles[l].n_tiles
    ii = max(t_mxu, RED_LATENCY_S)           # initiation interval, seconds
    lat_red_chain = lat_intra + ii * (red_trips - 1)

    # Reduction loops sit innermost (paper §3.4); the level recursion below
    # walks the *non-reduction* inter-tile loops outermost-first.  Arrays
    # transferred "inside" reduction levels stream per reduction step.
    red_level_start = len(cfg.perm) - len(red_loops) + 1

    def bw_of(array: str, placement: ArrayPlacement, level: int) -> float:
        if placement.onchip:
            return VMEM_BW            # shared-buffer handoff on the same slice
        if placement.stream:
            return hw.ici_bw          # FIFO across slices (inter-SLR analogue)
        pk = packing_efficiency(
            minor_dim_elems(cfg, task, array, level),
            arrays[array].dtype_bytes)
        return sl.hbm_bw * bw_share * pk

    # Total transfer seconds & bytes per array (amortised over reuse).
    reads = [a for a in task.read_arrays()]
    load_s_total = 0.0
    hbm_bytes = 0.0
    stream_bytes = 0.0
    per_level_load_s: dict[int, float] = {}
    for a in reads:
        pl = cfg.placements[a]
        tile_b = footprint_elems(cfg, task, a, pl.transfer_level) \
            * arrays[a].dtype_bytes
        cnt = n_transfers(cfg, task, a, pl)
        secs = cnt * tile_b / bw_of(a, pl, pl.transfer_level)
        load_s_total += secs
        if pl.stream:
            stream_bytes += cnt * tile_b
        else:
            hbm_bytes += cnt * tile_b
        per_level_load_s[pl.transfer_level] = \
            per_level_load_s.get(pl.transfer_level, 0.0) + secs

    # Output: stored (or sent) once per output tile — output-stationary.
    out_pl = cfg.placements[out_arr]
    out_tile_b = footprint_elems(cfg, task, out_arr, out_pl.transfer_level) \
        * arrays[out_arr].dtype_bytes
    out_cnt = n_transfers(cfg, task, out_arr, out_pl)
    store_s_total = out_cnt * out_tile_b / bw_of(out_arr, out_pl,
                                                 out_pl.transfer_level)
    if out_pl.stream:
        stream_bytes += out_cnt * out_tile_b
    else:
        hbm_bytes += out_cnt * out_tile_b

    # ----- level recursion (Eq. 14) -------------------------------------
    # Amortised per-execution transfer time at each level; levels are the
    # non-reduction inter-tile loops in permutation order.
    nonred_perm = [l for l in cfg.perm if l not in red_loops]

    def execs_of_level(level: int) -> int:
        n = 1
        for pos, loop in enumerate(cfg.perm):
            if pos + 1 > level:
                break
            n *= cfg.tiles[loop].n_tiles
        return n

    def level_lat(idx: int) -> float:
        """Latency of one execution of the loop at position idx (0-based in
        nonred_perm) including everything inside it."""
        if idx >= len(nonred_perm):
            # Innermost: one pipelined reduction chain plus transfers assigned
            # inside reduction levels (streamed per reduction step).  One
            # chain = one execution of the subtree below the last
            # non-reduction loop; amortise the total red-level transfer time
            # over the number of chains.
            n_chains = max(execs_of_level(red_level_start - 1), 1)
            comm = sum(per_level_load_s.get(lv, 0.0)
                       for lv in range(red_level_start, len(cfg.perm) + 1)) \
                / n_chains
            overlapped = all(cfg.placements[a].buffers >= 2 for a in reads) \
                if reads else True
            if overlapped:
                return max(lat_red_chain, comm) + (comm / max(red_trips, 1))
            return lat_red_chain + comm

        loop = nonred_perm[idx]
        level = cfg.perm.index(loop) + 1
        trips = cfg.tiles[loop].n_tiles
        inner = level_lat(idx + 1)
        # per-iteration-of-this-loop amortised transfer time at this level
        n_iters = max(execs_of_level(level), 1)
        load_tile = per_level_load_s.get(level, 0.0) / n_iters
        store_here = store_s_total / n_iters \
            if out_pl.transfer_level == level else 0.0
        overlapped = any(cfg.placements[a].buffers >= 2 for a in reads) \
            or out_pl.buffers >= 2
        if overlapped:
            steady = max(inner, load_tile + store_here)
            # prologue: first load; epilogue: last store (the alpha term)
            return trips * steady + load_tile + store_here
        return trips * (inner + load_tile + store_here)

    body = level_lat(0)
    # Level-0 transfers (before any loop): strictly serial prologue/epilogue.
    pre = per_level_load_s.get(0, 0.0)
    post = store_s_total if out_pl.transfer_level == 0 else 0.0
    latency = pre + body + post

    compute_total = execs_of_level(len(cfg.perm)) / max(red_trips, 1) \
        * lat_red_chain
    # Padded vs useful FLOPs: useful uses original trip counts.
    useful = task.flops
    padded = useful
    for l in cfg.perm:
        t = cfg.tiles[l]
        if t.ori_tc:
            padded *= t.padded_tc / t.ori_tc

    # ----- VMEM occupancy (Eq. 7) ----------------------------------------
    vmem = 0.0
    for a in reads + [out_arr]:
        pl = cfg.placements[a]
        buf = footprint_elems(cfg, task, a, pl.define_level) \
            * arrays[a].dtype_bytes * pl.buffers
        vmem += buf

    return TaskReport(
        latency_s=latency,
        compute_s=compute_total,
        load_s=load_s_total,
        store_s=store_s_total,
        vmem_bytes=vmem,
        hbm_bytes=hbm_bytes,
        stream_bytes=stream_bytes,
        useful_flops=useful,
        padded_flops=padded,
        fill_s=pre + post,
    )


# ---------------------------------------------------------------------------
# DAG latency (Eqs. 12-13) with slice serialization + streaming shifts
# ---------------------------------------------------------------------------
def emission_order(task: FusedTask, cfg: TaskConfig, array: str) \
        -> tuple[int, ...]:
    """Order in which array dims are visited (outer->inner) by the task."""
    acc = _access_of(task, array)
    order: list[int] = []
    for loop in cfg.perm:
        for d, it in enumerate(acc.iters):
            if it == loop and d not in order:
                order.append(d)
    return tuple(order)


def edge_order_compatible(fg: FusedGraph, configs: Mapping[int, TaskConfig],
                          u: int, v: int, arr: str) -> bool:
    """FIFO legality (paper §6.4): the consumer visits the array's dims in
    the producer's emission order, or full-buffers it (define level 0)."""
    pl = configs[v].placements.get(arr)
    if pl is not None and pl.define_level == 0:
        return True
    return emission_order(fg.tasks[u], configs[u], arr) == \
        emission_order(fg.tasks[v], configs[v], arr)


def dag_latency(fg: FusedGraph, configs: Mapping[int, TaskConfig],
                reports: Mapping[int, TaskReport],
                dispatch_s: float = 0.0) -> float:
    """Makespan of the DAG (Eqs. 12-13).

    ``dispatch_s`` is the fixed per-task host dispatch overhead (calibrated
    ``Hardware.dispatch_s``; 0 under the static model).  It serializes with
    the task on its slice, so co-locating independent tasks pays it once
    per task back-to-back while spreading them overlaps it — the measured
    "dispatch saving" the solver weighs against stream cost.
    """
    start: dict[int, float] = {}
    finish: dict[int, float] = {}
    slice_free: dict[int, float] = {}
    for tid in fg.topo_order():
        cfg = configs[tid]
        rep = reports[tid]
        ready = 0.0
        for (u, arr) in fg.preds(tid):
            pl = configs[tid].placements.get(arr)
            streamed = pl is not None and pl.stream
            if streamed and edge_order_compatible(fg, configs, u, tid, arr):
                # Eq. 12 shift: consumer starts once the first tile arrives
                # through the FIFO...
                out_tiles = max(_n_out_tiles(fg, u, configs[u]), 1)
                first_tile = reports[u].latency_s / out_tiles
                ready = max(ready, start[u] + dispatch_s + first_tile)
                # ...but cannot drain the last tile before the producer
                # emits it: finish >= producer finish + one tile hop.
                ready = max(ready, finish[u] + first_tile - rep.latency_s)
            else:
                ready = max(ready, finish[u])
        s0 = max(ready, slice_free.get(cfg.slice_id, 0.0))
        start[tid] = s0
        finish[tid] = s0 + dispatch_s + rep.latency_s
        slice_free[cfg.slice_id] = finish[tid]
    return max(finish[t] for t in fg.sinks())


def _n_out_tiles(fg: FusedGraph, tid: int, cfg: TaskConfig) -> int:
    task = fg.tasks[tid]
    out = task.output_array
    acc = _access_of(task, out)
    n = 1
    for it in acc.iters:
        if it in cfg.tiles:
            n *= cfg.tiles[it].n_tiles
    return n


def topo_waves(fg: FusedGraph) -> dict[int, int]:
    """Topological level of every task: wave ``w`` tasks have all producers
    in waves ``< w``, so same-wave tasks are mutually independent.  This is
    the cost model's view of the wave schedule the executors run
    (``repro.codegen.schedule`` derives its waves from this function).

    Memoized on the graph object (the ``_access_of`` idiom): the solver's
    assignment search calls ``plan_latency`` thousands of times per solve
    and the waves depend only on graph structure, never on the candidate
    plan.  Callers must treat the returned dict as read-only.
    """
    cache = getattr(fg, "_wave_cache", None)
    if cache is None or cache[0] != len(fg.tasks):
        preds = {t.tid: [u for (u, _) in fg.preds(t.tid)] for t in fg.tasks}
        wave_of: dict[int, int] = {}
        for tid in fg.topo_order():
            wave_of[tid] = 1 + max((wave_of[u] for u in preds[tid]),
                                   default=-1)
        cache = (len(fg.tasks), wave_of)
        fg._wave_cache = cache
    return cache[1]


def plan_latency(fg: FusedGraph, configs: Mapping[int, TaskConfig],
                 hw: Hardware) -> tuple[float, dict[int, TaskReport]]:
    """DAG makespan + per-task reports under ``hw``.

    HBM bandwidth is shared among the slices *concurrently active in the
    same wave*, not among every slice the plan uses anywhere: a 3-wave
    plan whose waves each run on one slice keeps full bandwidth per task.
    (Charging the whole-plan slice count overcharged multi-wave plans and
    biased the solver toward single-slice assignments.)
    """
    wave_of = topo_waves(fg)
    wave_slices: dict[int, set[int]] = {}
    for t in fg.tasks:
        wave_slices.setdefault(wave_of[t.tid], set()) \
            .add(configs[t.tid].slice_id)
    reports = {
        t.tid: task_report(
            t, configs[t.tid], fg, hw,
            bw_share=hw.bw_share_at(len(wave_slices[wave_of[t.tid]])))
        for t in fg.tasks}
    return dag_latency(fg, configs, reports,
                       dispatch_s=hw.dispatch_s), reports
