"""DEPRECATED shim — code generation moved to :mod:`repro.codegen`.

``core/apply.py`` used to hold a statement-at-a-time executor that honoured
tile sizes only for the one ``(i,k)x(k,j)`` matmul pattern.  The plan-faithful
lowering (arbitrary N-D contractions, plan permutations, fused
init+accumulate kernels, slice-aware dataflow execution) lives in
``repro.codegen``; this module re-exports the public names so existing
imports keep working.

If you landed here looking for a way to *run a JAX function* through the
optimizer, the front door is :mod:`repro.frontend`:
``frontend.trace(fn, *example_inputs)`` captures any callable into a task
graph — no hand-built statements required.
"""
from __future__ import annotations

import warnings

from ..codegen import (PlanExecutable, allclose, assert_close,  # noqa: F401
                       eval_statement, plan_executor, random_inputs,
                       reference_executor)

warnings.warn(
    "repro.core.apply is deprecated: import executors from repro.codegen, "
    "or trace arbitrary JAX functions via repro.frontend.trace",
    DeprecationWarning, stacklevel=2)

# Old private name, kept for any straggler callers.
_eval_statement = eval_statement

__all__ = [
    "PlanExecutable", "plan_executor", "reference_executor",
    "random_inputs", "allclose", "assert_close", "eval_statement",
]
