"""Code generation: ExecutionPlan -> executable JAX (paper §5).

The paper emits HLS-C++ + OpenCL host code from the NLP solution; the TPU
analogue emits a jitted JAX callable per fused task, honouring the plan:

* tile sizes  -> Pallas matmul block shapes (bm, bn, bk) for contraction
  tasks (with the plan's computation padding applied, then sliced back);
* fusion      -> init+accumulate pairs become one einsum/kernel call;
* dataflow    -> tasks execute in topological order, intermediates handed
  off in memory (the single-host analogue of FIFO/shared-buffer edges);
* everything else (buffer levels, overlap) is performance-only and has no
  numerical effect — validated by equivalence with the naive reference.

The generic executor supports the affine statement classes in the paper's
benchmark suite: products contracted over reduction loops ("mul", einsum)
and elementwise sums ("add").  Triangular-density kernels (symm/trmm/...)
are cost-modeled but not executed (their rectangular einsum is not the
same function); the executor raises for them.
"""
from __future__ import annotations

import string
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from .fusion import FusedGraph, fuse
from .plan import ExecutionPlan
from .taskgraph import Statement, TaskGraph
from ..kernels import matmul as tiled_matmul


def reference_executor(graph: TaskGraph) -> Callable[..., dict]:
    """Naive executor: statements in program order via einsum (oracle)."""

    def run(inputs: dict[str, jax.Array]) -> dict[str, jax.Array]:
        env = dict(inputs)
        for stmt in graph.statements:
            env[stmt.writes[0].array] = _eval_statement(stmt, env)
        return {a: env[a] for a in graph.final_outputs()}

    return run


def plan_executor(graph: TaskGraph, plan: ExecutionPlan) \
        -> Callable[..., dict]:
    """Executor honouring the plan's tiling (Pallas blocked matmul for 2D
    contractions, with the plan's padding), fused tasks in topo order."""
    fg = fuse(graph)
    order = fg.topo_order()

    def run(inputs: dict[str, jax.Array]) -> dict[str, jax.Array]:
        env = dict(inputs)
        for tid in order:
            task = fg.tasks[tid]
            cfg = plan.configs[tid]
            for stmt in task.statements:
                if _is_blocked_matmul(stmt):
                    env[stmt.writes[0].array] = _eval_matmul_tiled(
                        stmt, env, cfg)
                else:
                    env[stmt.writes[0].array] = _eval_statement(stmt, env)
        return {a: env[a] for a in graph.final_outputs()}

    return run


# ---------------------------------------------------------------------------
def _eval_statement(stmt: Statement, env: dict) -> jax.Array:
    if stmt.density != 1.0:
        raise NotImplementedError(
            f"{stmt.name}: triangular-density statements are cost-modeled "
            "only (rectangular execution would compute a different function)")
    out_acc = stmt.writes[0]
    reads = [a for a in stmt.reads if a.array != out_acc.array]
    accumulate = any(a.array == out_acc.array for a in stmt.reads)
    out_shape = tuple(stmt.trip_counts[it] for it in out_acc.iters)

    if not reads:
        val = jnp.zeros(out_shape, jnp.float32)
    elif stmt.op == "add":
        letters = {it: string.ascii_letters[i]
                   for i, it in enumerate(stmt.loops)}
        val = None
        for acc in reads:
            spec = "".join(letters[i] for i in acc.iters) + "->" + \
                "".join(letters[i] for i in out_acc.iters)
            term = jnp.einsum(spec, env[acc.array])
            val = term if val is None else val + term
    else:  # "mul": product of reads contracted over reduction loops
        letters = {it: string.ascii_letters[i]
                   for i, it in enumerate(stmt.loops)}
        in_specs = ",".join("".join(letters[i] for i in acc.iters)
                            for acc in reads)
        out_spec = "".join(letters[i] for i in out_acc.iters)
        val = jnp.einsum(f"{in_specs}->{out_spec}",
                         *[env[acc.array] for acc in reads])
    if accumulate and out_acc.array in env:
        val = env[out_acc.array] + val
    return val


def _is_blocked_matmul(stmt: Statement) -> bool:
    """out[i,j] += lhs[i,k] * rhs[k,j] pattern (possibly transposed reads)."""
    if stmt.op != "mul" or stmt.density != 1.0:
        return False
    out = stmt.writes[0]
    reads = [a for a in stmt.reads if a.array != out.array]
    if len(reads) != 2 or len(out.iters) != 2:
        return False
    red = set(stmt.reduction_loops)
    if len(red) != 1:
        return False
    (i, j) = out.iters
    k = next(iter(red))
    pats = {tuple(reads[0].iters), tuple(reads[1].iters)}
    return pats == {(i, k), (k, j)}


def _eval_matmul_tiled(stmt: Statement, env: dict, cfg) -> jax.Array:
    out = stmt.writes[0]
    reads = [a for a in stmt.reads if a.array != out.array]
    (i, j) = out.iters
    k = next(iter(set(stmt.reduction_loops)))
    lhs = next(a for a in reads if tuple(a.iters) == (i, k))
    rhs = next(a for a in reads if tuple(a.iters) == (k, j))
    x, y = env[lhs.array], env[rhs.array]
    bm = cfg.tiles[i].tile if i in cfg.tiles else 128
    bn = cfg.tiles[j].tile if j in cfg.tiles else 128
    bk = cfg.tiles[k].tile if k in cfg.tiles else 128
    val = tiled_matmul(x, y, bm=bm, bn=bn, bk=bk)
    if any(a.array == out.array for a in stmt.reads) and out.array in env:
        val = env[out.array] + val
    return val


def random_inputs(graph: TaskGraph, seed: int = 0) -> dict[str, jax.Array]:
    rng = np.random.default_rng(seed)
    out = {}
    for name in graph.external_inputs():
        arr = graph.arrays[name]
        out[name] = jnp.asarray(
            rng.normal(size=arr.shape).astype(np.float32))
    return out
