"""DEPRECATED shim — code generation moved to :mod:`repro.codegen`.

``core/apply.py`` used to hold a statement-at-a-time executor that honoured
tile sizes only for the one ``(i,k)x(k,j)`` matmul pattern.  The plan-faithful
lowering (arbitrary N-D contractions, plan permutations, fused
init+accumulate kernels, slice-aware dataflow execution) lives in
``repro.codegen``; this module re-exports the public names so existing
imports keep working.
"""
from __future__ import annotations

import warnings

from ..codegen import (PlanExecutable, allclose, assert_close,  # noqa: F401
                       eval_statement, plan_executor, random_inputs,
                       reference_executor)

warnings.warn(
    "repro.core.apply is deprecated; import from repro.codegen instead",
    DeprecationWarning, stacklevel=2)

# Old private name, kept for any straggler callers.
_eval_statement = eval_statement

__all__ = [
    "PlanExecutable", "plan_executor", "reference_executor",
    "random_inputs", "allclose", "assert_close", "eval_statement",
]
