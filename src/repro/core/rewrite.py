"""Traced-graph rewrites that change the *work*, not just the schedule.

The solver's tiling/fusion passes keep every statement's flop count fixed;
the rewrites here run earlier, on the statement list itself, and exploit
freedom the original program never encoded.  First (and currently only)
pass: **matrix-chain reassociation** — a traced ``((a @ b) @ c) @ d``
carries the user's association order, but matrix multiplication is
associative, so the graph may legally re-parenthesize to the cheapest
order (classic interval DP).  ``jax.jit`` executes the chain exactly as
written; on chains with skewed dimensions the optimal order is 10-30%
fewer flops, which is pure headroom for the traced program.

Only exact product contractions participate: ``op == "mul"``, two reads,
one 2-D output, one reduction loop, unit density and no folded
scale/offset.  Intermediates must be single-consumer and must not escape
(final outputs and multi-consumer values keep the user-visible
association, bit-for-bit).  f32 accumulation order changes across a
reassociation — the same rounding freedom XLA's own dot reordering
already claims, well inside the oracle's 2e-4 band.
"""
from __future__ import annotations

from .taskgraph import Access, Array, Statement, intermediate


def _dot_pattern(s: Statement):
    """``(i, k, j)`` iterators when ``s`` is a plain 2-D matmul
    ``out[i, j] += a[i, k] * b[k, j]`` — else ``None``."""
    if s.op != "mul" or s.coeff != 1.0 or s.offset != 0.0:
        return None
    if s.density != 1.0 or len(s.reads) != 2 or len(s.writes) != 1:
        return None
    w = s.writes[0]
    if len(w.iters) != 2 or len(s.loops) != 3 or None in w.iters:
        return None
    i, j = w.iters
    red = [l for l in s.loops if l not in (i, j)]
    if len(red) != 1:
        return None
    k = red[0]
    a, b = s.reads
    if a.iters == (i, k) and b.iters == (k, j):
        return (i, k, j)
    return None


def _chain_order(p: list[int]):
    """Interval DP over dimension vector ``p`` (matrix t is p[t] x p[t+1]).
    Returns (total_macs, split) where split[(lo, hi)] is the optimal last
    multiplication boundary for the product of matrices lo..hi."""
    n = len(p) - 1
    cost = {(t, t): 0 for t in range(n)}
    split: dict[tuple[int, int], int] = {}
    for span in range(1, n):
        for lo in range(n - span):
            hi = lo + span
            best = None
            for m in range(lo, hi):
                c = (cost[(lo, m)] + cost[(m + 1, hi)]
                     + p[lo] * p[m + 1] * p[hi + 1])
                if best is None or c < best:
                    best, split[(lo, hi)] = c, m
            cost[(lo, hi)] = best
    return cost[(0, n - 1)], split


def reassociate_matmul_chains(arrays: dict[str, Array],
                              statements: list[Statement],
                              protected: set[str]) -> int:
    """Re-parenthesize left-associated matmul chains in place.

    ``protected`` holds array names that must keep their exact producing
    statement (graph final outputs).  Returns how many chains were
    rewritten.
    """
    producer: dict[str, int] = {}
    consumers: dict[str, list[tuple[int, int]]] = {}
    for si, s in enumerate(statements):
        for w in s.writes:
            producer[w.array] = si
        for ri, r in enumerate(s.reads):
            consumers.setdefault(r.array, []).append((si, ri))

    dots = {si: pat for si, s in enumerate(statements)
            if (pat := _dot_pattern(s)) is not None}

    rewritten = 0
    chain_heads = []
    for si in sorted(dots):
        s = statements[si]
        lhs = s.reads[0].array
        lp = producer.get(lhs)
        # chain head: the left operand is NOT itself a fusable chain link
        if lp in dots and consumers.get(lhs) == [(si, 0)] \
                and lhs not in protected:
            continue
        chain_heads.append(si)

    for head in chain_heads:
        links = [head]
        while True:
            out = statements[links[-1]].writes[0].array
            if out in protected:
                break
            cons = consumers.get(out)
            if cons is None or len(cons) != 1:
                break
            ci, ri = cons[0]
            if ci not in dots or ri != 0:
                break
            links.append(ci)
        if len(links) < 2:
            continue
        # matrices of the product, left to right
        mats = [statements[links[0]].reads[0].array] + \
               [statements[t].reads[1].array for t in links]
        p = [arrays[mats[0]].shape[0]] + [arrays[m].shape[1] for m in mats]
        left_cost = sum(p[0] * p[t] * p[t + 1] for t in range(1, len(mats)))
        best_cost, split = _chain_order(p)
        if best_cost >= left_cost:
            continue

        final = statements[links[-1]].writes[0].array
        new_stmts: list[Statement] = []
        counter = [0]

        def emit(lo: int, hi: int) -> str:
            if lo == hi:
                return mats[lo]
            m = split[(lo, hi)]
            left, right = emit(lo, m), emit(m + 1, hi)
            top = lo == 0 and hi == len(mats) - 1
            name = f"{final}_ra{counter[0]}"
            counter[0] += 1
            out = final if top else name
            rows, inner, cols = p[lo], p[m + 1], p[hi + 1]
            i, j, k = f"{name}_d0", f"{name}_d1", f"{name}_r0"
            if not top:
                arrays[out] = intermediate(out, (rows, cols))
            new_stmts.append(Statement(
                name=name, loops=(i, j, k),
                trip_counts={i: rows, j: cols, k: inner},
                reads=(Access(left, (i, k)), Access(right, (k, j))),
                writes=(Access(out, (i, j)),),
                flops_per_iter=2.0))
            return out

        emit(0, len(mats) - 1)
        # old intermediates die with their statements
        for t in links[:-1]:
            del arrays[statements[t].writes[0].array]
        keep = set(links)
        insert_at = links[-1]
        rebuilt: list[Statement] = []
        for si2, s2 in enumerate(statements):
            if si2 == insert_at:
                rebuilt.extend(new_stmts)
            if si2 not in keep:
                rebuilt.append(s2)
        statements[:] = rebuilt
        rewritten += 1
        # indices moved: conservatively re-run on the updated list
        if rewritten:
            return rewritten + reassociate_matmul_chains(
                arrays, statements, protected)
    return rewritten
