"""Execution-plan datatypes — the NLP solution vector (paper Table 2/4).

A :class:`TaskConfig` is the per-fused-task slice of the paper's design
variables:

    perm            inter-tile loop order (reduction loops pinned innermost)
    tiles           TC_intra per loop (with the padding that legalised it)
    placements      per-array transfer level t_{a,l}, define level d_{a,l},
                    buffer count N_a, and stream-vs-offchip routing
    slice_id        slr_t — the slice (SLR analogue) executing the task

:class:`ExecutionPlan` aggregates task configs for a fused graph and is the
object handed to code generation (`repro.codegen`) and the benchmark tables.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Mapping

from .padding import TileOption


@dataclasses.dataclass(frozen=True)
class ArrayPlacement:
    """Where an array's tile enters the task and how it is buffered.

    ``transfer_level`` / ``define_level`` index inter-tile loop *levels*:
    0 = before all inter-tile loops, k = just inside the k-th loop of the
    chosen permutation.  Eq. 6: define_level <= transfer_level.
    ``buffers`` is N_a (1 = no overlap, 2 = double, 3 = triple buffering).
    ``stream`` marks FIFO edges from a producer task instead of HBM loads.
    """

    transfer_level: int
    define_level: int
    buffers: int = 2
    stream: bool = False     # FIFO over ICI from a producer on another slice
    onchip: bool = False     # shared VMEM buffer handoff (same-slice edge)

    def __post_init__(self):
        if self.define_level > self.transfer_level:
            raise ValueError("Eq. 6 violated: define after transfer")

    def replace(self, **kw) -> "ArrayPlacement":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class TaskConfig:
    perm: tuple[str, ...]
    tiles: Mapping[str, TileOption]
    placements: Mapping[str, ArrayPlacement]
    slice_id: int = 0

    def tile(self, loop: str) -> TileOption:
        return self.tiles[loop]

    def level_of(self, loop: str) -> int:
        """Level index of a loop: position in perm + 1 (level 0 = pre-loop)."""
        return self.perm.index(loop) + 1

    def to_jsonable(self) -> dict:
        return {
            "perm": list(self.perm),
            "tiles": {l: {"tile": t.tile, "padded_tc": t.padded_tc,
                          "ori_tc": t.ori_tc}
                      for l, t in self.tiles.items()},
            "placements": {a: dataclasses.asdict(p)
                           for a, p in self.placements.items()},
            "slice_id": self.slice_id,
        }

    @staticmethod
    def from_jsonable(d: Mapping) -> "TaskConfig":
        """Inverse of :meth:`to_jsonable`.

        Reconstruction is exact: ``perm`` comes back as a tuple and tile
        counts as ints, so a round-tripped config re-serialises to the
        identical jsonable dict — which is what keeps
        ``plan_fingerprint`` stable through the plan store.
        """
        return TaskConfig(
            perm=tuple(d["perm"]),
            tiles={l: TileOption(tile=int(t["tile"]),
                                 padded_tc=int(t["padded_tc"]),
                                 ori_tc=int(t["ori_tc"]))
                   for l, t in d["tiles"].items()},
            placements={a: ArrayPlacement(**p)
                        for a, p in d["placements"].items()},
            slice_id=int(d["slice_id"]),
        )


@dataclasses.dataclass
class TaskReport:
    """Cost-model output for one task under one config."""

    latency_s: float
    compute_s: float
    load_s: float
    store_s: float
    vmem_bytes: float
    hbm_bytes: float
    stream_bytes: float
    useful_flops: float
    padded_flops: float
    fill_s: float = 0.0

    @property
    def bound(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.load_s + self.store_s}
        return max(terms, key=terms.get)

    def to_jsonable(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_jsonable(d: Mapping) -> "TaskReport":
        return TaskReport(**d)


@dataclasses.dataclass
class ExecutionPlan:
    graph_name: str
    configs: dict[int, TaskConfig]            # tid -> config
    reports: dict[int, TaskReport]
    latency_s: float
    useful_flops: float
    mode: str = "prometheus"
    solver_seconds: float = 0.0
    n_evaluated: int = 0
    space_size: float = 0.0       # raw product-space size (Table 10 story)
    timed_out: bool = False       # exhaustive coverage impossible in budget
    store_hit: bool = False       # served from the persistent plan store
    stale_hw: bool = False        # store hit keyed to an older hw profile

    @property
    def gflops(self) -> float:
        return self.useful_flops / self.latency_s / 1e9 if self.latency_s else 0.0

    def to_jsonable(self) -> dict:
        """Full lossless serialisation (configs + reports) for the plan
        store.  ``store_hit``/``stale_hw`` are runtime provenance flags,
        not plan content, and are deliberately not persisted."""
        return {
            "graph_name": self.graph_name,
            "configs": {str(t): c.to_jsonable() for t, c in self.configs.items()},
            "reports": {str(t): r.to_jsonable() for t, r in self.reports.items()},
            "latency_s": self.latency_s,
            "useful_flops": self.useful_flops,
            "mode": self.mode,
            "solver_seconds": self.solver_seconds,
            "n_evaluated": self.n_evaluated,
            "space_size": self.space_size,
            "timed_out": self.timed_out,
        }

    @staticmethod
    def from_jsonable(d: Mapping) -> "ExecutionPlan":
        return ExecutionPlan(
            graph_name=d["graph_name"],
            configs={int(t): TaskConfig.from_jsonable(c)
                     for t, c in d["configs"].items()},
            reports={int(t): TaskReport.from_jsonable(r)
                     for t, r in d["reports"].items()},
            latency_s=float(d["latency_s"]),
            useful_flops=float(d["useful_flops"]),
            mode=d["mode"],
            solver_seconds=float(d["solver_seconds"]),
            n_evaluated=int(d["n_evaluated"]),
            space_size=float(d["space_size"]),
            timed_out=bool(d["timed_out"]),
        )

    def to_json(self, **extra) -> str:
        return json.dumps({
            "graph": self.graph_name,
            "mode": self.mode,
            "latency_s": self.latency_s,
            "gflops": self.gflops,
            "solver_seconds": self.solver_seconds,
            "n_evaluated": self.n_evaluated,
            "tasks": {str(t): c.to_jsonable() for t, c in self.configs.items()},
            **extra,
        }, indent=2)

    def summary(self) -> str:
        lines = [f"plan[{self.graph_name}|{self.mode}] "
                 f"lat={self.latency_s * 1e6:.2f}us "
                 f"gf={self.gflops:.2f} "
                 f"(solved in {self.solver_seconds:.2f}s, "
                 f"{self.n_evaluated} configs)"]
        for tid, cfg in sorted(self.configs.items()):
            rep = self.reports[tid]
            tiles = ",".join(f"{l}:{t.tile}" +
                             (f"(pad{t.pad})" if t.pad else "")
                             for l, t in cfg.tiles.items())
            lines.append(
                f"  FT{tid} slice={cfg.slice_id} perm={'>'.join(cfg.perm)} "
                f"tiles[{tiles}] lat={rep.latency_s * 1e6:.2f}us "
                f"bound={rep.bound} vmem={rep.vmem_bytes / 2**20:.2f}MiB")
        return "\n".join(lines)
