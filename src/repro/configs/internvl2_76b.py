"""internvl2-76b [vlm] — InternViT + InternLM2 backbone.

80L d_model=8192 64H (GQA kv=8, head_dim=128) d_ff=28672 vocab=128256
[arXiv:2404.16821].  Backbone only: the ViT frontend is a STUB —
input_specs() provides precomputed patch+text embeddings (B, S, d_model).
kv=8 < 16 -> KV replicated across model shards.
"""
from ..models.model import ModelConfig
from .base import register

CONFIG = register(ModelConfig(
    name="internvl2-76b",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=28672, vocab=128256,
    embed_input=False, rope_theta=5e5,
))
