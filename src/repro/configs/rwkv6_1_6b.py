"""rwkv6-1.6b [ssm] — Finch: attention-free, data-dependent decay.

24L d_model=2048 (32 heads x 64) d_ff=7168 vocab=65536 [arXiv:2404.05892].
Attention-sharding aspects of the paper's technique are inapplicable
(attention-free); tiling/fusion/overlap apply to the WKV6 recurrence and
channel-mix matmuls (DESIGN.md §5).  long_500k runs: state is O(1) in S.
"""
from ..models.model import ModelConfig
from .base import register

CONFIG = register(ModelConfig(
    name="rwkv6-1.6b",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=7168, vocab=65536,
    pattern=("rwkv6",), ffn="rwkv_cm",
))
