"""mixtral-8x7b [moe] — 8 experts top-2, sliding-window attention.

32L d_model=4096 32H (GQA kv=8, head_dim=128) d_ff=14336 vocab=32000,
SWA window 4096 [arXiv:2401.04088].  long_500k runs: the SWA KV cache is
bounded by the window.
"""
from ..models.model import ModelConfig
from .base import register

CONFIG = register(ModelConfig(
    name="mixtral-8x7b",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=32000,
    pattern=("swa",), window=4096,
    ffn="moe", n_experts=8, moe_top_k=2, rope_theta=1e6,
))
