"""yi-34b [dense] — llama-arch GQA.

60L d_model=7168 56H (GQA kv=8, head_dim=128) d_ff=20480 vocab=64000
[arXiv:2403.04652].
TP padding: 56 -> 64 q heads (divisible by model=16); kv=8 < 16 -> KV
replicated across excess model shards.
"""
from ..models.model import ModelConfig
from .base import register

CONFIG = register(ModelConfig(
    name="yi-34b",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
    d_ff=20480, vocab=64000,
    rope_theta=5e6, pad_heads_to=64,
))
