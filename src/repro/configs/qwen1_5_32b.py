"""qwen1.5-32b [dense] — QKV bias, MHA (assigned kv=40).

64L d_model=5120 40H (kv=40, head_dim=128) d_ff=27392 vocab=152064
[hf:Qwen/Qwen1.5-32B; assignment specifies kv=40].
TP padding: 40 -> 48 q and kv heads (48 = 3 x 16).
HBM note: the MHA KV cache at decode_32k batch 128 does not fit bf16
(25.8 GB/chip) -> int8 KV cache (12.9 GB) — DESIGN.md §5.
"""
from ..models.model import ModelConfig
from .base import register

CONFIG = register(ModelConfig(
    name="qwen1.5-32b",
    n_layers=64, d_model=5120, n_heads=40, n_kv_heads=40, head_dim=128,
    d_ff=27392, vocab=152064,
    qkv_bias=True, rope_theta=1e6,
    pad_heads_to=48, pad_kv_heads_to=48, kv_cache_dtype="int8",
))
