"""Import every arch module so the registry is populated."""
from . import (recurrentgemma_9b, qwen3_moe_235b_a22b, mixtral_8x7b,
               musicgen_medium, qwen1_5_0_5b, yi_34b, qwen1_5_32b,
               qwen3_0_6b, rwkv6_1_6b, internvl2_76b)  # noqa: F401
