"""Import every arch module so the registry is populated."""
from . import internvl2_76b  # noqa: F401
from . import mixtral_8x7b  # noqa: F401
from . import musicgen_medium  # noqa: F401
from . import qwen1_5_0_5b  # noqa: F401
from . import qwen1_5_32b  # noqa: F401
from . import qwen3_0_6b  # noqa: F401
from . import qwen3_moe_235b_a22b  # noqa: F401
from . import recurrentgemma_9b  # noqa: F401
from . import rwkv6_1_6b  # noqa: F401
from . import yi_34b  # noqa: F401
