"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 1:2 ratio.

38L d_model=4096 16H (MQA kv=1, head_dim=256) d_ff=12288 vocab=256000.
Pattern (rglru, rglru, swa) x 12 + (rglru, rglru) tail — one local-attention
(window 2048) layer per two recurrent layers [arXiv:2402.19427].
TP note: kv=1 < 16 -> KV replicated across model shards (DESIGN.md §5).
"""
from ..models.model import ModelConfig
from .base import register

CONFIG = register(ModelConfig(
    name="recurrentgemma-9b",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1, head_dim=256,
    d_ff=12288, vocab=256000,
    pattern=("rglru", "rglru", "swa"), window=2048, d_rnn=4096,
    ffn="swiglu", rope_theta=1e4,
))
