"""qwen3-moe-235b-a22b [moe] — 128 experts top-8, qk_norm.

94L d_model=4096 64H (GQA kv=4, head_dim=128) expert d_ff=1536
vocab=151936 [hf:Qwen/Qwen3-30B-A3B scaled family].
TP note: experts shard over the model axis (EP=16 -> 8 experts/shard);
kv=4 < 16 -> KV replicated.
"""
from ..models.model import ModelConfig
from .base import register

CONFIG = register(ModelConfig(
    name="qwen3-moe-235b-a22b",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, head_dim=128,
    d_ff=1536, vocab=151936,
    ffn="moe", n_experts=128, moe_top_k=8, qk_norm=True, rope_theta=1e6,
))
