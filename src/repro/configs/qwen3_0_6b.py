"""qwen3-0.6b [dense] — qk_norm, GQA.

28L d_model=1024 16H (GQA kv=8, head_dim=128) d_ff=3072 vocab=151936
[hf:Qwen/Qwen3-0.6B].
"""
from ..models.model import ModelConfig
from .base import register

CONFIG = register(ModelConfig(
    name="qwen3-0.6b",
    n_layers=28, d_model=1024, n_heads=16, n_kv_heads=8, head_dim=128,
    d_ff=3072, vocab=151936,
    qk_norm=True, rope_theta=1e6,
))
