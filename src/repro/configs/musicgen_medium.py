"""musicgen-medium [audio] — decoder-only over EnCodec tokens.

48L d_model=1536 24H (MHA kv=24, head_dim=64) d_ff=6144 vocab=2048
[arXiv:2306.05284].  Backbone only: the EnCodec frontend is a STUB —
input_specs() provides precomputed frame embeddings (B, S, d_model).
Positional encoding: RoPE substitutes the original sinusoidal embedding
(TPU-native choice, noted in DESIGN.md).
TP padding: 24 -> 32 q and kv heads (paper's padding-for-computation).
"""
from ..models.model import ModelConfig
from .base import register

CONFIG = register(ModelConfig(
    name="musicgen-medium",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24, head_dim=64,
    d_ff=6144, vocab=2048,
    ffn="gelu", embed_input=False, rope_theta=1e4,
    pad_heads_to=32, pad_kv_heads_to=32,
))
