"""Assigned-architecture configs (x10) — selectable via --arch <id>."""
from .base import get_config, list_archs, smoke

__all__ = ["get_config", "list_archs", "smoke"]
