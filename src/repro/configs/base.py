"""Config registry + smoke-test reduction.

Each assigned architecture lives in its own module defining ``CONFIG``
(exact published dimensions) — selectable via ``--arch <id>``.  ``smoke()``
shrinks any config to a CPU-runnable size preserving its family structure
(pattern, ffn kind, gqa ratio, biases/norms), used by per-arch smoke tests.

Head-count padding entries implement the paper's padding-for-computation
for tensor-parallel divisibility (DESIGN.md §5); padded heads are real
parameters.
"""
from __future__ import annotations

import dataclasses

from ..models.model import ModelConfig

_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    assert cfg.name not in _REGISTRY, cfg.name
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    from . import _load_all      # noqa: F401  (populate registry)
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> list[str]:
    from . import _load_all      # noqa: F401
    return sorted(_REGISTRY)


def smoke(cfg: ModelConfig, *, seq_friendly: bool = True) -> ModelConfig:
    """Reduced config of the same family for CPU smoke tests."""
    n_pat = len(cfg.pattern)
    layers = n_pat + (1 if cfg.n_layers % n_pat else 0) + n_pat
    heads = max(2, min(4, cfg.n_heads))
    kv = max(1, min(heads, round(heads * cfg.n_kv_heads / cfg.n_heads)))
    head_dim = 16
    d_model = 64
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=layers,
        d_model=d_model,
        n_heads=heads,
        n_kv_heads=kv,
        head_dim=head_dim,
        d_ff=96,
        vocab=128,
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        moe_top_k=min(cfg.moe_top_k, 2) if cfg.moe_top_k else 0,
        window=16 if cfg.window else None,
        d_rnn=d_model if cfg.d_rnn else 0,
        attn_chunk=16,
        loss_chunk=64,
        pad_heads_to=None,
        pad_kv_heads_to=None,
        rope_theta=1e4,
    )
