"""Continuous-batching front door for :class:`~repro.serve.PlanEngine`.

The paper's throughput claim is about balancing computation against data
movement *under concurrency*; the serving analogue is that per-request
dispatch overhead must be amortized across requests.  ``PlanEngine.submit``
is one dispatch per request; this module adds the tier above it — the
JetStream/MaxText pattern of a bounded request queue drained by one
background batcher thread that **coalesces same-fingerprint submits into
one batched program execution**:

* Requests for the same ``register_function`` entry are grouped and padded
  to the next power-of-two **bucket** (``1, 2, 4, ... max_batch``); each
  bucket is served by a lazily registered batched entry — the original
  function re-traced once with a leading batch dimension
  (:meth:`~repro.frontend.TracedFunction.batched`, shared process-wide by
  ``(fingerprint, bucket)``) — so the trace/program caches hold a handful
  of bucket entries, never one per batch size seen.
* A flush happens when a bucket fills, when the oldest request has waited
  ``max_wait_s``, or when the tightest per-request ``deadline_s`` is about
  to expire — whichever comes first.  Requests whose deadline has already
  passed get :class:`~repro.ft.DeadlineExceeded` instead of a stale
  result.
* The steady-state batched call is **one engine submit** (itself one
  compiled-program dispatch): request leaves are stacked by one jitted
  combiner and results are sliced back by one jitted splitter, so a
  bucket-``B`` flush costs three dispatches where the sequential path
  paid ``B``.
* Admission is queue-depth-aware: past ``max_queue`` pending requests the
  caller gets the engine's existing
  :class:`~repro.ft.EngineOverloaded` backpressure signal.

Resilience is inherited, not reimplemented: the batched entry is a normal
engine registration, so PR 7's whole contract — deadlines, NaN guards,
canary validation, per-entry circuit breakers, background re-solve,
plain-jit fallback — applies to the batched execution unchanged.  On top
of it, a batch that *fails outright* (injected via
``ChaosPlan.batch_fail_at``, an evicted bucket entry, or an engine
configured with ``fallback=False``) is re-submitted **per request**
through ``PlanEngine.submit`` so every batchmate passes through its own
breaker/fallback path — one poisoned request cannot fail the others.

Accounting contract (the CI gate's invariant): every enqueued request ends
in exactly one bucket — ``ok + fallbacks + expired + rejected_submits are
raised uncounted`` — concretely, ``ok + fallbacks == completed`` and
``completed + expired + errors == enqueued`` once the queue drains.
"""
from __future__ import annotations

import dataclasses
import logging
import threading
import time
from collections import deque
from concurrent.futures import Future
from typing import Any

import jax
import jax.numpy as jnp

from ..ft.serve import DeadlineExceeded, EngineOverloaded
from ..obs import tracer as _obs_tracer

log = logging.getLogger("repro.serve.batching")

#: Batched entries are registered as ``<name>@b<bucket>``.
BATCH_SEP = "@b"


def bucket_sizes(max_batch: int) -> tuple[int, ...]:
    """Power-of-two bucket ladder up to ``max_batch`` (``8 -> (1,2,4,8)``;
    a non-power-of-two ``max_batch`` rounds down to the last power that
    fits, so the trace/program caches stay at ``log2`` entries)."""
    if max_batch < 1:
        raise ValueError(f"max_batch must be >= 1, got {max_batch}")
    sizes = [1]
    while sizes[-1] * 2 <= max_batch:
        sizes.append(sizes[-1] * 2)
    return tuple(sizes)


@dataclasses.dataclass
class BatchConfig:
    """Knobs of the continuous-batching tier (``ServeConfig.batching``)."""

    #: Largest bucket (requests coalesced per dispatch); the bucket ladder
    #: is every power of two up to this.
    max_batch: int = 8
    #: A partial bucket flushes once its oldest request has waited this
    #: long (the latency/throughput tradeoff dial).
    max_wait_s: float = 0.002
    #: Bounded request queue: submits past this depth are rejected with
    #: ``EngineOverloaded`` (queue-depth-aware admission).
    max_queue: int = 1024
    #: Flush a group early when its tightest per-request deadline is
    #: within this margin — the batch must still execute in time.
    deadline_margin_s: float = 0.005
    #: Latency samples kept for the p50/p99 stats window.
    stats_window: int = 4096
    #: Pre-solve the whole bucket ladder in the background at
    #: ``register_function`` time, so the first coalesced flush never
    #: pays a trace/solve on the request path.  With a warm plan store
    #: the presolve itself is near-free (fingerprint-keyed store hits).
    presolve: bool = True

    def __post_init__(self):
        self.buckets = bucket_sizes(self.max_batch)


class _Request:
    """One queued submit: args + future + its timing budget."""

    __slots__ = ("name", "args", "flat", "future", "t_enqueue",
                 "deadline_at")

    def __init__(self, name: str, args: Any, flat, t_enqueue: float,
                 deadline_at: float | None):
        self.name = name
        self.args = args
        self.flat = flat                # leaves (batchable) or None
        self.future: Future = Future()
        self.t_enqueue = t_enqueue
        self.deadline_at = deadline_at


def _make_stacker(bucket: int):
    """One jitted call stacking ``bucket`` requests' leaves into batched
    leaves (row-major: ``rows[j * n_leaves + i]`` is request j's leaf i)."""

    def stack(*rows):
        n_leaves = len(rows) // bucket
        return tuple(
            jnp.stack([rows[j * n_leaves + i] for j in range(bucket)])
            for i in range(n_leaves))

    return jax.jit(stack)


def _make_splitter(bucket: int):
    """One jitted call slicing batched output leaves back into ``bucket``
    per-request leaf tuples."""

    def split(*leaves):
        return tuple(tuple(v[j] for v in leaves) for j in range(bucket))

    return jax.jit(split)


class Batcher:
    """Bounded request queue + one background thread coalescing submits.

    Created lazily by :meth:`PlanEngine.batcher` when
    ``ServeConfig.batching`` is set; :meth:`PlanEngine.submit_async` is
    the entry point.  One batcher (and one flush thread) per engine.
    """

    def __init__(self, engine, cfg: BatchConfig):
        self._engine = engine
        self.cfg = cfg
        self.buckets = cfg.buckets
        self._cond = threading.Condition(threading.Lock())
        self._pending: dict[str, list[_Request]] = {}
        self._depth = 0
        self._stop = False
        # (name, bucket) -> batched entry name ("" = bucket unavailable:
        # the function is not vmappable / registration raised — serve
        # those requests per-request instead of retrying every flush)
        self._bucket_entries: dict[tuple[str, int], str] = {}
        self._stackers: dict[int, Any] = {}
        self._splitters: dict[int, Any] = {}
        # -- counters (engine's MetricsRegistry: one definition each, the
        # same numbers behind stats() and the Prometheus exposition; the
        # legacy attribute names stay readable as properties) ------------
        m = engine.metrics
        self._tr = _obs_tracer()
        self._c_enqueued = m.counter(
            "repro_batch_enqueued_total", "requests accepted into the queue")
        self._c_completed = m.counter(
            "repro_batch_completed_total", "futures resolved with a result")
        self._c_ok = m.counter(
            "repro_batch_ok_total",
            "served by a batched/solo optimized path")
        self._c_fallbacks = m.counter(
            "repro_batch_fallbacks_total",
            "served by the engine's plain-jit path")
        self._c_expired = m.counter(
            "repro_batch_expired_total", "deadline passed before execution")
        self._c_rejected = m.counter(
            "repro_batch_rejected_total", "queue-depth admission rejections")
        self._c_errors = m.counter(
            "repro_batch_errors_total", "futures resolved with an exception")
        self._c_batch_failures = m.counter(
            "repro_batch_failures_total",
            "whole-batch failures (chaos/evicted)")
        self._c_resubmitted = m.counter(
            "repro_batch_resubmitted_total",
            "requests re-run singly after a batch failure")
        self._c_flushes = m.counter(
            "repro_batch_flushes_total", "bucket flushes", ("bucket",))
        self._c_batched_requests = m.counter(
            "repro_batch_batched_requests_total",
            "live requests served batched", ("bucket",))
        self._h_queue_latency = m.histogram(
            "repro_batch_queue_seconds", "enqueue-to-result latency")
        # the batching accounting closures, asserted in the registry like
        # the engine's (meaningful once the queue drains)
        m.register_invariant(
            "batching: ok+fallbacks==completed",
            lambda: self.ok + self.fallbacks == self.completed)
        m.register_invariant(
            "batching: completed+expired+errors==enqueued (at quiescence)",
            lambda: self.completed + self.expired + self.errors
            == self.enqueued)
        self._lat = deque(maxlen=cfg.stats_window)
        self._t_first: float | None = None
        self._t_last: float | None = None
        self._thread = threading.Thread(
            target=self._loop, daemon=True,
            name=f"repro-batcher-{id(engine):x}")
        self._thread.start()

    # -- legacy counter shims (registry-backed, read-only) -----------------
    @property
    def enqueued(self) -> int:
        return self._c_enqueued.value

    @property
    def completed(self) -> int:
        return self._c_completed.value

    @property
    def ok(self) -> int:
        return self._c_ok.value

    @property
    def fallbacks(self) -> int:
        return self._c_fallbacks.value

    @property
    def expired(self) -> int:
        return self._c_expired.value

    @property
    def rejected(self) -> int:
        return self._c_rejected.value

    @property
    def errors(self) -> int:
        return self._c_errors.value

    @property
    def batch_failures(self) -> int:
        return self._c_batch_failures.value

    @property
    def resubmitted(self) -> int:
        return self._c_resubmitted.value

    @property
    def flushes(self) -> dict[int, int]:
        return {int(k[0]): v for k, v in self._c_flushes.snapshot().items()}

    @property
    def batched_requests(self) -> dict[int, int]:
        return {int(k[0]): v
                for k, v in self._c_batched_requests.snapshot().items()}

    def check_invariants(self) -> list[str]:
        """Violated accounting closures (empty when all hold); meaningful
        once the queue has drained."""
        return self._engine.metrics.check_invariants()

    # -- submission (caller threads) --------------------------------------
    def submit(self, name: str, inputs, *,
               deadline_s: float | None = None) -> Future:
        """Enqueue one request; returns a future resolving to the same
        value ``PlanEngine.submit`` would return.  Raises
        ``EngineOverloaded`` when the bounded queue is full and ``KeyError``
        / ``TypeError`` / ``ValueError`` for caller contract errors
        (unknown entry, wrong pytree/shape/dtype) — uncounted, exactly like
        the synchronous path."""
        eng = self._engine
        with eng._lock:
            if name not in eng._registry:
                raise KeyError(name)
            tf = eng._functions.get(name)
        flat = None
        args = inputs
        if tf is not None and not isinstance(inputs, dict):
            args = tuple(inputs)
            flat, tree = jax.tree_util.tree_flatten(args)
            if tree != tf.in_tree:
                raise TypeError(
                    f"{name}: argument structure {tree} does not match "
                    f"the traced structure {tf.in_tree}")
            flat = [jnp.asarray(v) for v in flat]
            for i, (v, (shape, dtype)) in enumerate(
                    zip(flat, tf.record.in_avals)):
                if tuple(v.shape) != tuple(shape) or v.dtype != dtype:
                    raise ValueError(
                        f"{name}: argument {i} is {v.shape}/{v.dtype}, "
                        f"traced as {shape}/{dtype} — re-trace for new "
                        "shapes/dtypes")
        now = time.monotonic()
        deadline = deadline_s if deadline_s is not None \
            else eng.sc.deadline_s
        req = _Request(name, args, flat, now,
                       None if deadline is None else now + deadline)
        with self._cond:
            if self._depth >= self.cfg.max_queue:
                self._c_rejected.inc()
                raise EngineOverloaded(
                    f"{name}: batching queue full "
                    f"({self._depth}/{self.cfg.max_queue} pending)")
            if self._t_first is None:
                self._t_first = now
            self._pending.setdefault(name, []).append(req)
            self._depth += 1
            self._c_enqueued.inc()
            self._cond.notify()
        return req.future

    # -- the batcher thread -----------------------------------------------
    def _loop(self) -> None:
        while True:
            with self._cond:
                due, wake = self._collect_due(time.monotonic())
                while not due and not self._stop:
                    timeout = None if wake is None \
                        else max(0.0, wake - time.monotonic())
                    self._cond.wait(timeout)
                    due, wake = self._collect_due(time.monotonic())
                if self._stop:
                    # drain everything still queued, then exit
                    for name, group in self._pending.items():
                        if group:
                            due.append((name, group))
                            self._depth -= len(group)
                    self._pending.clear()
            for name, reqs in due:
                try:
                    self._flush(name, reqs)
                except Exception as exc:   # never kill the batcher thread
                    failed = 0
                    for r in reqs:
                        if not r.future.done():
                            r.future.set_exception(exc)
                            failed += 1
                    self._c_errors.inc(failed)
                    log.exception("%s: batch flush failed", name)
            with self._cond:
                if self._stop and self._depth == 0 \
                        and not any(self._pending.values()):
                    return

    def _collect_due(self, now: float):
        """Under the lock: pop every group that must flush now; return the
        groups plus the earliest future flush time (None = nothing queued).
        A group is due when a full bucket is waiting, the oldest request
        has aged ``max_wait_s``, or the tightest deadline minus the safety
        margin has arrived."""
        due: list[tuple[str, list[_Request]]] = []
        wake: float | None = None
        max_b = self.buckets[-1]
        for name, group in self._pending.items():
            while len(group) >= max_b:
                due.append((name, group[:max_b]))
                del group[:max_b]
                self._depth -= max_b
            if not group:
                continue
            due_at = group[0].t_enqueue + self.cfg.max_wait_s
            tightest = min((r.deadline_at for r in group
                            if r.deadline_at is not None), default=None)
            if tightest is not None:
                due_at = min(due_at,
                             tightest - self.cfg.deadline_margin_s)
            if now >= due_at:
                due.append((name, group[:]))
                self._depth -= len(group)
                group.clear()
            else:
                wake = due_at if wake is None else min(wake, due_at)
        return due, wake

    # -- flush path -------------------------------------------------------
    def _flush(self, name: str, reqs: list[_Request]) -> None:
        now = time.monotonic()
        live: list[_Request] = []
        for r in reqs:
            if r.deadline_at is not None and now >= r.deadline_at:
                r.future.set_exception(DeadlineExceeded(
                    f"{name}: deadline expired after "
                    f"{now - r.t_enqueue:.3f}s in the batching queue"))
                self._c_expired.inc()
            else:
                live.append(r)
        if self._tr.enabled:
            # queue-wait spans: enqueue -> flush pick-up, one per request
            base = time.perf_counter()
            for r in live:
                wait = now - r.t_enqueue
                self._tr.record("queue_wait", "request", base - wait, wait,
                                {"entry": name})
        if not live:
            return
        if live[0].flat is None:
            # graph registrations / dict inputs: nothing to coalesce —
            # still async, served per request on this thread
            self._run_singly(name, live, resubmit=False)
            return
        n = len(live)
        bucket = next(b for b in self.buckets if b >= n)
        bname = self._ensure_bucket(name, bucket)
        if not bname:
            self._run_singly(name, live, resubmit=False)
            return
        eng = self._engine
        try:
            chaos = eng.sc.chaos
            if chaos is not None:
                chaos.on_batch(bname)
            with self._tr.span("batch_coalesce", "request", entry=name,
                               bucket=bucket, live=n):
                out = self._run_batched(bname, live, bucket)
        except Exception as exc:
            # the batch itself failed (injected chaos, evicted bucket
            # entry, fallback=False engine): every batchmate goes back
            # through submit() alone so one poisoned request can only
            # fail itself — the per-request breaker path
            self._c_batch_failures.inc()
            with self._cond:
                if (name, bucket) in self._bucket_entries \
                        and isinstance(exc, KeyError):
                    del self._bucket_entries[(name, bucket)]
            log.warning("%s: batch of %d failed (%s: %s); re-submitting "
                        "per request", bname, n, type(exc).__name__, exc)
            self._run_singly(name, live, resubmit=True)
            return
        done = time.monotonic()
        self._c_flushes.labels(bucket).inc()
        self._c_batched_requests.labels(bucket).inc(n)
        for j, r in enumerate(live):
            r.future.set_result(out[j])
            self._finish(r, out.path, done)

    def _run_batched(self, bname: str, live: list[_Request], bucket: int):
        """One engine submit for the whole group: jitted stack -> batched
        entry -> jitted split.  Returns a list-like of per-request results
        with the serving path annotated."""
        eng = self._engine
        with eng._lock:
            btf = eng._functions.get(bname)
        stacker = self._stackers.get(bucket)
        if stacker is None:
            stacker = self._stackers.setdefault(
                bucket, _make_stacker(bucket))
        splitter = self._splitters.get(bucket)
        if splitter is None:
            splitter = self._splitters.setdefault(
                bucket, _make_splitter(bucket))
        rows: list[Any] = []
        for j in range(bucket):
            # pad the partial bucket by repeating the last request's rows;
            # padded results are sliced off below
            rows.extend(live[min(j, len(live) - 1)].flat)
        stacked = stacker(*rows)
        in_tree = btf.in_tree if btf is not None \
            else jax.tree_util.tree_structure(
                tuple(live[0].args))
        args = jax.tree_util.tree_unflatten(in_tree, list(stacked))
        tightest = min((r.deadline_at for r in live
                        if r.deadline_at is not None), default=None)
        budget = None if tightest is None \
            else max(tightest - time.monotonic(), 0.001)
        info: dict = {}
        out = eng.submit(bname, args, deadline_s=budget, _info=info)
        leaves, out_tree = jax.tree_util.tree_flatten(out)
        per_req = splitter(*leaves)

        class _Split(list):
            path = info.get("path", "optimized")

        return _Split(
            jax.tree_util.tree_unflatten(out_tree, list(per_req[j]))
            for j in range(len(live)))

    def _run_singly(self, name: str, live: list[_Request],
                    resubmit: bool) -> None:
        """Serve each request alone through ``PlanEngine.submit`` — the
        uncoalesced (but still resilient) path."""
        eng = self._engine
        if resubmit:
            self._c_resubmitted.inc(len(live))
        for r in live:
            budget = None if r.deadline_at is None \
                else max(r.deadline_at - time.monotonic(), 0.001)
            info: dict = {}
            try:
                out = eng.submit(name, r.args, deadline_s=budget,
                                 _info=info)
            except Exception as exc:
                r.future.set_exception(exc)
                if isinstance(exc, DeadlineExceeded):
                    self._c_expired.inc()
                else:
                    self._c_errors.inc()
            else:
                r.future.set_result(out)
                self._finish(r, info.get("path", "optimized"),
                             time.monotonic())

    def _finish(self, r: _Request, path: str, now: float) -> None:
        self._c_completed.inc()
        if path == "fallback":
            self._c_fallbacks.inc()
        else:
            self._c_ok.inc()
        self._h_queue_latency.observe(now - r.t_enqueue)
        with self._cond:
            self._lat.append(now - r.t_enqueue)
            self._t_last = now

    def _ensure_bucket(self, name: str, bucket: int) -> str:
        """Lazily register the batched entry for (name, bucket): re-trace
        with the leading batch dim and register through the ordinary
        ``register_function`` path, reusing the base entry's solver
        options/hardware.  Returns the batched entry name, or "" when the
        bucket is unavailable (cached so failures don't retry per flush).
        Even a *degraded* registration (trace/solve failed -> plain
        ``jit(vmap(fn))`` fallback) still amortizes dispatch."""
        key = (name, bucket)
        with self._cond:
            bname = self._bucket_entries.get(key)
        if bname is not None:
            return bname
        eng = self._engine
        with eng._lock:
            tf = eng._functions.get(name)
            meta = eng._reg_meta.get(name) or {}
        bname = ""
        if tf is not None:
            full = f"{name}{BATCH_SEP}{bucket}"
            try:
                btf = tf.batched(bucket)
                args = jax.tree_util.tree_unflatten(
                    btf.in_tree, list(btf.example_flat))
                eng.register_function(
                    full, btf.fn, args,
                    solver_opts=meta.get("solver_opts"),
                    hw=meta.get("hw"))
                bname = full
            except Exception as exc:
                log.warning(
                    "%s: bucket %d unavailable (%s: %s); serving those "
                    "requests per-request", name, bucket,
                    type(exc).__name__, exc)
        with self._cond:
            return self._bucket_entries.setdefault(key, bname)

    # -- presolve / warmup / teardown / stats ------------------------------
    def presolve(self, name: str, buckets=None, stop=None) -> int:
        """Register (trace + solve) every bucket entry for ``name`` without
        executing anything — the solve-only half of :meth:`warmup`, cheap
        enough to run at registration time off the flush path.  ``stop``
        (a ``threading.Event``) aborts between buckets so engine shutdown
        is never held behind remaining solves.  Returns the number of
        buckets that became available."""
        n = 0
        for b in (buckets or self.buckets):
            if stop is not None and stop.is_set():
                break
            if self._ensure_bucket(name, b):
                n += 1
        return n

    def warmup(self, name: str, buckets=None) -> float:
        """Pre-register and warm every bucket entry for ``name`` — plus
        the per-bucket stacker/splitter jits — so the first coalesced
        flush pays no trace/solve/compile; returns seconds spent (the
        cold cost)."""
        t0 = time.monotonic()
        eng = self._engine
        with eng._lock:
            tf = eng._functions.get(name)
        for b in (buckets or self.buckets):
            bname = self._ensure_bucket(name, b)
            if not bname:
                continue
            with eng._lock:
                btf = eng._functions.get(bname)
            if btf is not None:
                args = jax.tree_util.tree_unflatten(
                    btf.in_tree, list(btf.example_flat))
                eng.warmup(bname, args)
                out = eng.submit(bname, args)
            elif tf is not None:        # degraded bucket: warm the jit
                flat = [jnp.broadcast_to(jnp.asarray(v),
                                         (b,) + tuple(jnp.shape(v)))
                        for v in tf.example_flat]
                args = jax.tree_util.tree_unflatten(tf.in_tree, flat)
                out = eng.submit(bname, args)
            else:
                continue
            jax.block_until_ready(jax.tree_util.tree_leaves(out))
            if tf is not None:          # compile the flush-path combiners
                stacker = self._stackers.setdefault(b, _make_stacker(b))
                rows: list[Any] = []
                example = [jnp.asarray(v) for v in tf.example_flat]
                for _ in range(b):
                    rows.extend(example)
                jax.block_until_ready(stacker(*rows))
                splitter = self._splitters.setdefault(
                    b, _make_splitter(b))
                leaves = jax.tree_util.tree_leaves(out)
                jax.block_until_ready(
                    jax.tree_util.tree_leaves(splitter(*leaves)))
        return time.monotonic() - t0

    def shutdown(self, timeout: float = 30.0) -> None:
        """Stop the batcher thread, draining the queue first — no enqueued
        future is ever abandoned."""
        with self._cond:
            self._stop = True
            self._cond.notify_all()
        self._thread.join(timeout)

    def stats(self) -> dict:
        """The ``stats()["batching"]`` block: queue depth, accounting
        counters, p50/p99 queue-to-result latency, throughput over the
        busy window, and per-bucket occupancy (how full flushed buckets
        actually were).

        Lock discipline mirrors ``PlanEngine.stats()``: registry counters
        are snapshotted first (family locks only), then ``self._cond``
        covers only the batcher's own plain state."""
        flushes = self.flushes
        batched = self.batched_requests
        completed = self.completed
        counters = {
            "enqueued": self.enqueued,
            "completed": completed,
            "ok": self.ok,
            "fallbacks": self.fallbacks,
            "expired": self.expired,
            "rejected": self.rejected,
            "errors": self.errors,
            "batch_failures": self.batch_failures,
            "resubmitted": self.resubmitted,
        }
        buckets = {}
        for b in self.buckets:
            f = flushes.get(b, 0)
            r = batched.get(b, 0)
            if f:
                buckets[str(b)] = {
                    "flushes": f, "requests": r,
                    "occupancy": round(r / (f * b), 4)}
        with self._cond:
            lat = sorted(self._lat)
            depth = self._depth
            span = None
            if self._t_first is not None and self._t_last is not None:
                span = max(self._t_last - self._t_first, 1e-9)
        return {
            "max_batch": self.buckets[-1],
            "max_wait_ms": self.cfg.max_wait_s * 1e3,
            "queue_depth": depth,
            "max_queue": self.cfg.max_queue,
            **counters,
            "p50_ms": round(_percentile(lat, 0.50) * 1e3, 4),
            "p99_ms": round(_percentile(lat, 0.99) * 1e3, 4),
            "throughput_rps": round(completed / span, 3)
            if span else 0.0,
            "buckets": buckets,
        }


def _percentile(sorted_vals, q: float) -> float:
    if not sorted_vals:
        return 0.0
    idx = min(len(sorted_vals) - 1,
              int(round(q * (len(sorted_vals) - 1))))
    return sorted_vals[idx]
