from ..ft.serve import (DeadlineExceeded, EngineOverloaded, MiscompileError,
                        ServingError)
from .engine import Engine, PlanEngine, ServeConfig, throughput_stats

__all__ = [
    "Engine", "PlanEngine", "ServeConfig", "throughput_stats",
    "ServingError", "EngineOverloaded", "DeadlineExceeded",
    "MiscompileError",
]
