from .engine import Engine, PlanEngine, ServeConfig, throughput_stats

__all__ = ["Engine", "PlanEngine", "ServeConfig", "throughput_stats"]
