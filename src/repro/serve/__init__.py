from ..ft.serve import (DeadlineExceeded, EngineOverloaded, MiscompileError,
                        ServingError)
from .batching import BatchConfig, Batcher, bucket_sizes
from .engine import Engine, PlanEngine, ServeConfig, throughput_stats

__all__ = [
    "Engine", "PlanEngine", "ServeConfig", "throughput_stats",
    "BatchConfig", "Batcher", "bucket_sizes",
    "ServingError", "EngineOverloaded", "DeadlineExceeded",
    "MiscompileError",
]
