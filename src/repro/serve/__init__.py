from .engine import Engine, ServeConfig, throughput_stats

__all__ = ["Engine", "ServeConfig", "throughput_stats"]
