"""Batched serving engine: prefill + greedy/temperature decode.

Static-shape batch engine (the TPU-friendly design): fixed batch slots,
fixed max length, jitted prefill/decode steps.  Continuous batching is
approximated at the slot level — finished sequences are replaced between
decode bursts (slot recycling), which is what production TPU servers do
between jitted macro-steps.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..models import model as M


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 512
    temperature: float = 0.0       # 0 = greedy
    eos_id: int | None = None
    seed: int = 0


class Engine:
    def __init__(self, cfg: M.ModelConfig, params: Any,
                 sc: ServeConfig | None = None):
        self.cfg = cfg
        self.params = params
        self.sc = sc or ServeConfig()
        self._prefill = jax.jit(partial(M.prefill, cfg=self.cfg),
                                static_argnames=("max_len",))
        self._decode = jax.jit(partial(M.decode_step, cfg=self.cfg))

    def _sample(self, logits: jax.Array, key) -> jax.Array:
        if self.sc.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        probs = jax.nn.softmax(logits / self.sc.temperature, axis=-1)
        return jax.random.categorical(key, jnp.log(probs + 1e-9), axis=-1) \
            .astype(jnp.int32)

    def generate(self, prompts: np.ndarray, max_new_tokens: int) \
            -> np.ndarray:
        """prompts (B, P) int32 -> (B, max_new_tokens) int32."""
        b, p = prompts.shape
        assert p + max_new_tokens <= self.sc.max_len, "exceeds max_len"
        key = jax.random.PRNGKey(self.sc.seed)
        logits, cache = self._prefill(
            params=self.params, tokens=jnp.asarray(prompts),
            max_len=self.sc.max_len)
        out = np.zeros((b, max_new_tokens), np.int32)
        done = np.zeros((b,), bool)
        key, sub = jax.random.split(key)
        tok = self._sample(logits, sub)
        for t in range(max_new_tokens):
            out[:, t] = np.where(done, 0, np.asarray(tok))
            if self.sc.eos_id is not None:
                done |= np.asarray(tok) == self.sc.eos_id
                if done.all():
                    break
            logits, cache = self._decode(params=self.params, cache=cache,
                                         tokens=tok)
            key, sub = jax.random.split(key)
            tok = self._sample(logits, sub)
        return out


def throughput_stats(n_tokens: int, seconds: float) -> dict:
    return {"tokens": n_tokens, "seconds": seconds,
            "tokens_per_s": n_tokens / max(seconds, 1e-9)}
