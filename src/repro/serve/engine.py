"""Batched serving engines: LM decode loop + plan-execution serving.

``Engine`` is the static-shape LM batch engine (the TPU-friendly design):
fixed batch slots, fixed max length, jitted prefill/decode steps.
Continuous batching is approximated at the slot level — finished sequences
are replaced between decode bursts (slot recycling), which is what
production TPU servers do between jitted macro-steps.

``PlanEngine`` is the dataflow-plan counterpart: it serves repeated
executions of solved plans through the whole-plan compiled-program cache
(`repro.codegen.program`), so after the first request for a (graph, plan,
impl) triple every subsequent request — including from a *new* PlanEngine —
hits a fully compiled program with zero re-lowering or re-tracing.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..models import model as M


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 512
    temperature: float = 0.0       # 0 = greedy
    eos_id: int | None = None
    seed: int = 0


class Engine:
    def __init__(self, cfg: M.ModelConfig, params: Any,
                 sc: ServeConfig | None = None):
        self.cfg = cfg
        self.params = params
        self.sc = sc or ServeConfig()
        self._prefill = jax.jit(partial(M.prefill, cfg=self.cfg),
                                static_argnames=("max_len",))
        self._decode = jax.jit(partial(M.decode_step, cfg=self.cfg))

    def _sample(self, logits: jax.Array, key) -> jax.Array:
        if self.sc.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        probs = jax.nn.softmax(logits / self.sc.temperature, axis=-1)
        return jax.random.categorical(key, jnp.log(probs + 1e-9), axis=-1) \
            .astype(jnp.int32)

    def generate(self, prompts: np.ndarray, max_new_tokens: int) \
            -> np.ndarray:
        """prompts (B, P) int32 -> (B, max_new_tokens) int32."""
        b, p = prompts.shape
        assert p + max_new_tokens <= self.sc.max_len, "exceeds max_len"
        key = jax.random.PRNGKey(self.sc.seed)
        logits, cache = self._prefill(
            params=self.params, tokens=jnp.asarray(prompts),
            max_len=self.sc.max_len)
        out = np.zeros((b, max_new_tokens), np.int32)
        done = np.zeros((b,), bool)
        key, sub = jax.random.split(key)
        tok = self._sample(logits, sub)
        for t in range(max_new_tokens):
            out[:, t] = np.where(done, 0, np.asarray(tok))
            if self.sc.eos_id is not None:
                done |= np.asarray(tok) == self.sc.eos_id
                if done.all():
                    break
            logits, cache = self._decode(params=self.params, cache=cache,
                                         tokens=tok)
            key, sub = jax.random.split(key)
            tok = self._sample(logits, sub)
        return out


def throughput_stats(n_tokens: int, seconds: float) -> dict:
    return {"tokens": n_tokens, "seconds": seconds,
            "tokens_per_s": n_tokens / max(seconds, 1e-9)}


class PlanEngine:
    """Serve repeated plan executions off the compiled-program cache.

    Register (graph, plan) pairs under a model name, then submit input
    batches against them.  Every request resolves through
    ``repro.codegen.compiled_program`` — the process-wide cache keyed by
    (graph fingerprint, plan fingerprint, impl) — so steady-state requests
    pay one host dispatch of an already-compiled whole-plan program.
    """

    def __init__(self, impl: str | None = None):
        self._impl = impl
        self._registry: dict[str, tuple[Any, Any]] = {}
        # (name, impl) -> PlanProgram: fingerprints are hashed once per
        # registration, not per request — submit() is pure dispatch
        self._resolved: dict[tuple[str, str], Any] = {}
        self.requests = 0

    def register(self, name: str, graph, plan) -> None:
        self._registry[name] = (graph, plan)
        self._resolved = {k: v for k, v in self._resolved.items()
                          if k[0] != name}

    def names(self) -> list[str]:
        return sorted(self._registry)

    def warmup(self, name: str, inputs: dict) -> float:
        """Compile-and-first-run; returns seconds spent (the cold cost the
        cache amortizes away for every later request)."""
        t0 = time.monotonic()
        out = self.submit(name, inputs)
        for v in out.values():
            v.block_until_ready()
        return time.monotonic() - t0

    def submit(self, name: str, inputs: dict) -> dict:
        """Execute one request; hits the whole-plan compiled program."""
        from ..kernels import dispatch
        impl = self._impl or dispatch.current_impl()
        prog = self._resolved.get((name, impl))
        if prog is None:
            from ..codegen import compiled_program
            graph, plan = self._registry[name]
            prog = compiled_program(graph, plan, impl)
            self._resolved[(name, impl)] = prog
        self.requests += 1
        return prog(inputs)

    def stats(self) -> dict:
        from ..codegen import cache_stats
        return {"requests": self.requests,
                "registered": len(self._registry),
                **cache_stats()}
