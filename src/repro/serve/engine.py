"""Batched serving engines: LM decode loop + plan-execution serving.

``Engine`` is the static-shape LM batch engine (the TPU-friendly design):
fixed batch slots, fixed max length, jitted prefill/decode steps.
Continuous batching is approximated at the slot level — finished sequences
are replaced between decode bursts (slot recycling), which is what
production TPU servers do between jitted macro-steps.

``PlanEngine`` is the dataflow-plan counterpart: it serves repeated
executions of solved plans through the whole-plan compiled-program cache
(`repro.codegen.program`), so after the first request for a (graph, plan,
impl) triple every subsequent request — including from a *new* PlanEngine —
hits a fully compiled program with zero re-lowering or re-tracing.

Workloads need not be hand-modeled graphs: ``register_function`` traces an
arbitrary JAX callable through ``repro.frontend``, solves it, and serves it
through the same cache/pool/warmup path — requests for function entries
pass positional-argument tuples instead of array dicts and get the
function's own result pytree back.

With ``ServeConfig.batching`` set, ``submit_async`` adds true continuous
batching *above* ``submit``: a bounded queue drained by one background
batcher thread coalesces same-entry requests into power-of-two buckets
served by batched re-traces (``repro.serve.batching``), so the steady-state
cost of a bucket-``B`` flush is one dispatch instead of ``B``.

Fault tolerance (the ``repro.ft`` contract): the request path never
*assumes* success.  Admission control bounds the in-flight depth
(:class:`~repro.ft.EngineOverloaded` backpressure) and enforces per-submit
deadline budgets; any failure in trace/solve/compile/execute — including
miscompiles caught by sampled canary validation against the plain-jit
oracle and NaN/inf output guards — degrades that request to the plain-jit
fallback path, quarantines the entry behind a per-entry circuit breaker,
and re-solves in the background with exponential backoff.  A
:class:`~repro.ft.ChaosPlan` in ``ServeConfig.chaos`` deterministically
injects every one of those failures for tests and
``benchmarks/bench_chaos.py``.  The happy path stays one dispatch: with a
closed breaker and no chaos configured the additions are a dict lookup
and two branch checks.
"""
from __future__ import annotations

import dataclasses
import logging
import threading
import time
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..ft.serve import (BreakerState, ChaosPlan, CircuitBreaker,
                        DeadlineExceeded, EngineOverloaded, MiscompileError)
from ..ft.straggler import StragglerConfig, StragglerMonitor
from ..models import model as M
from ..obs import (Counter, DriftConfig, DriftDetector, MetricsRegistry,
                   configure_logging)
from ..obs import tracer as _obs_tracer
from .batching import BATCH_SEP, BatchConfig, Batcher

log = logging.getLogger("repro.serve")


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 512
    temperature: float = 0.0       # 0 = greedy
    eos_id: int | None = None
    seed: int = 0
    # -- plan-serving knobs (PlanEngine) ----------------------------------
    # Persistent AOT compilation cache directory: replicas pointed at the
    # same path share lowered XLA artifacts across processes, so a fresh
    # replica's first compile deserializes instead of re-lowering.
    # (env equivalent: REPRO_COMPILATION_CACHE_DIR)
    compilation_cache_dir: str | None = None
    # Persistent plan store directory (repro.store): replicas pointed at
    # the same path share *solved plans* across processes, so a fresh
    # replica's register_function loads a fingerprint-keyed plan instead
    # of running the solver sweep.  A plan priced for an older hardware
    # profile (calibration drift) is still served immediately and
    # re-solved in the background.  (env: REPRO_PLAN_STORE_DIR)
    plan_store_dir: str | None = None
    # Bound of the process-wide compiled-program LRU cache; None keeps the
    # current global setting.  (env equivalent: REPRO_PROGRAM_CACHE_SIZE)
    program_cache_size: int | None = None
    # Round-robin executable-pool size per cached program; None defers to
    # REPRO_PROGRAM_POOL_SIZE (default 1).
    pool_size: int | None = None
    # Admission policy: max (graph, plan) pairs registered at once; the
    # least-recently-used registration is evicted past this.  None = no cap.
    max_plans: int | None = None
    # -- resilience knobs (PlanEngine) ------------------------------------
    # Default per-submit deadline budget in seconds (None = unbounded).  A
    # request that cannot be admitted before its budget expires is rejected
    # with DeadlineExceeded; one that finishes late counts a deadline miss.
    deadline_s: float | None = None
    # Bounded in-flight depth: at most this many submits execute at once;
    # excess callers wait up to admission_timeout_s (backpressure) and are
    # then rejected with EngineOverloaded.  None = unbounded.
    max_inflight: int | None = None
    admission_timeout_s: float = 0.1
    # Sampled canary validation: every Nth optimized execution per entry is
    # synchronously validated against the plain-jit oracle (jax.jit(fn)
    # for function entries, the statement reference oracle for graphs); a
    # mismatch is a miscompile -> immediate quarantine + fallback.  0 = off
    # (the happy path stays one asynchronous dispatch).
    canary_every: int = 0
    # NaN/inf output guard: "canary" checks finiteness on canary-sampled
    # requests, "always" on every request (forces a device sync per
    # submit), "off" never.
    nan_guard: str = "canary"
    # Graceful degradation: failures fall back to the plain-jit path for
    # that request instead of raising.  False re-raises (debugging).
    fallback: bool = True
    # Per-entry circuit breaker: this many consecutive optimized-path
    # failures quarantine the entry (every request falls back); after
    # breaker_reset_s one probe request tries the optimized path again.
    breaker_threshold: int = 3
    breaker_reset_s: float = 30.0
    # Background re-solve backoff schedule for quarantined entries.
    resolve_backoff_s: float = 0.05
    resolve_backoff_mult: float = 2.0
    resolve_backoff_max_s: float = 5.0
    resolve_max_retries: int = 8
    # Deterministic fault injection (repro.ft.ChaosPlan) — tests/benches.
    chaos: ChaosPlan | None = None
    # Per-pool-clone straggler rotation (repro.ft.StragglerConfig): when
    # set, optimized executions are timed per clone and a persistently
    # slow clone is rotated out of round-robin.  Timing implies a device
    # sync per submit, so this is opt-in.
    straggler: StragglerConfig | None = None
    # Continuous batching (repro.serve.batching.BatchConfig): when set,
    # submit_async() routes through a bounded queue drained by one
    # background batcher thread that coalesces same-entry submits into
    # power-of-two buckets served by batched re-traces.  None keeps
    # submit_async() as a thin synchronous wrapper.
    batching: BatchConfig | None = None
    # Cost-model drift detection (repro.obs.drift): one in
    # ``drift.sample_every`` optimized requests is timed (device sync)
    # and folded into a per-entry EMA; when observed/predicted latency
    # leaves the threshold band the entry's plan is re-solved through
    # the background plan-refresh path.  None uses DriftConfig()
    # defaults; DriftConfig(enabled=False) turns it off.
    drift: DriftConfig | None = None


class Engine:
    def __init__(self, cfg: M.ModelConfig, params: Any,
                 sc: ServeConfig | None = None):
        self.cfg = cfg
        self.params = params
        self.sc = sc or ServeConfig()
        self._prefill = jax.jit(partial(M.prefill, cfg=self.cfg),
                                static_argnames=("max_len",))
        self._decode = jax.jit(partial(M.decode_step, cfg=self.cfg))

    def _sample(self, logits: jax.Array, key) -> jax.Array:
        if self.sc.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        probs = jax.nn.softmax(logits / self.sc.temperature, axis=-1)
        return jax.random.categorical(key, jnp.log(probs + 1e-9), axis=-1) \
            .astype(jnp.int32)

    def generate(self, prompts: np.ndarray, max_new_tokens: int) \
            -> np.ndarray:
        """prompts (B, P) int32 -> (B, max_new_tokens) int32."""
        b, p = prompts.shape
        assert p + max_new_tokens <= self.sc.max_len, "exceeds max_len"
        key = jax.random.PRNGKey(self.sc.seed)
        logits, cache = self._prefill(
            params=self.params, tokens=jnp.asarray(prompts),
            max_len=self.sc.max_len)
        out = np.zeros((b, max_new_tokens), np.int32)
        done = np.zeros((b,), bool)
        key, sub = jax.random.split(key)
        tok = self._sample(logits, sub)
        for t in range(max_new_tokens):
            out[:, t] = np.where(done, 0, np.asarray(tok))
            if self.sc.eos_id is not None:
                done |= np.asarray(tok) == self.sc.eos_id
                if done.all():
                    break
            logits, cache = self._decode(params=self.params, cache=cache,
                                         tokens=tok)
            key, sub = jax.random.split(key)
            tok = self._sample(logits, sub)
        return out


def throughput_stats(n_tokens: int, seconds: float) -> dict:
    return {"tokens": n_tokens, "seconds": seconds,
            "tokens_per_s": n_tokens / max(seconds, 1e-9)}


def _rtol_for(dtype) -> float:
    """Canary tolerance per dtype (mirrors the frontend oracle bands)."""
    return 2e-2 if np.dtype(dtype).itemsize <= 2 else 2e-4


# Per-entry counter families: one definition each, labeled by entry name.
# The engine's MetricsRegistry owns them; _EntryHealth holds the labeled
# children so the hot path increments without any engine lock.
_ENTRY_COUNTERS = (
    ("ok", "repro_entry_ok_total", "optimized-path successes"),
    ("failures", "repro_entry_failures_total",
     "optimized-path failures (any site)"),
    ("fallbacks", "repro_entry_fallbacks_total",
     "requests served by the plain-jit path"),
    ("attempts", "repro_entry_attempts_total",
     "optimized-path tries (canary cadence)"),
    ("canaries", "repro_entry_canaries_total", "canary validations run"),
    ("canary_failures", "repro_entry_canary_failures_total",
     "canary validation mismatches"),
    ("deadline_misses", "repro_entry_deadline_misses_total",
     "admitted requests finished past budget"),
    ("resolve_attempts", "repro_entry_resolve_attempts_total",
     "background re-solve tries"),
    ("recovered", "repro_entry_recovered_total",
     "successful background recoveries"),
)


@dataclasses.dataclass
class _EntryHealth:
    """Per-entry resilience state: breaker, counters, recovery plumbing.

    Counter conservation contract (the accounting tests pin it down):
    ``ok + fallbacks == per_name[name]`` — every admitted request ends in
    exactly one bucket, whatever failed along the way.  The counters are
    labeled children of the engine's :class:`MetricsRegistry` families
    (``repro_entry_*_total{entry=...}``), so the same numbers back both
    ``stats()`` and the Prometheus exposition.
    """

    breaker: CircuitBreaker
    ok: Counter
    failures: Counter
    fallbacks: Counter
    attempts: Counter
    canaries: Counter
    canary_failures: Counter
    deadline_misses: Counter
    resolve_attempts: Counter
    recovered: Counter
    recovering: bool = False
    rotated: tuple[int, ...] = ()   # pool clones rotated out (straggler)
    straggler: StragglerMonitor | None = None
    last_error: str | None = None
    recovered_event: threading.Event = dataclasses.field(
        default_factory=threading.Event)
    recovery_thread: threading.Thread | None = None

    def state(self, has_plan: bool) -> str:
        if not has_plan:
            return "fallback"       # registration-time failure: plain jit
        return {BreakerState.CLOSED: "ok",
                BreakerState.OPEN: "quarantined",
                BreakerState.HALF_OPEN: "half_open"}[self.breaker.state]

    def stats(self, has_plan: bool = True) -> dict:
        return {"state": self.state(has_plan),
                "ok": self.ok.value, "failures": self.failures.value,
                "fallbacks": self.fallbacks.value,
                "canaries": self.canaries.value,
                "canary_failures": self.canary_failures.value,
                "deadline_misses": self.deadline_misses.value,
                "resolve_attempts": self.resolve_attempts.value,
                "recovered": self.recovered.value,
                "recovering": self.recovering,
                "rotated_clones": list(self.rotated),
                "breaker": self.breaker.stats(),
                "last_error": self.last_error}


class PlanEngine:
    """Serve repeated plan executions off the compiled-program cache.

    Register (graph, plan) pairs under a model name, then submit input
    batches against them.  Requests resolve through the process-wide
    bounded LRU program cache (``repro.codegen.program_cache``): the
    (graph, plan, impl) fingerprint key is hashed once per registration,
    and every ``submit()`` is an O(1) keyed cache lookup — eviction-aware,
    so the cache's hit/eviction statistics stay the one source of truth.

    ``ServeConfig`` carries the serving knobs: persistent AOT compilation
    cache directory (cross-replica artifact sharing / warm start),
    program-cache bound, executable-pool size, the registration admission
    cap — and the resilience contract (deadlines, bounded in-flight depth,
    canary validation, circuit breakers, background re-solve, chaos
    injection; see the module docstring).

    Thread-safe: N server threads may ``submit`` (and register/unregister)
    against one engine concurrently — registry, key table and request
    counters mutate under an engine lock, the program cache under its own
    lock, and program execution itself runs outside both, so requests for
    warm programs never serialize on each other.
    """

    def __init__(self, impl: str | None = None,
                 sc: ServeConfig | None = None):
        from ..codegen import enable_persistent_cache, set_program_cache_size
        self._impl = impl
        self.sc = sc or ServeConfig()
        if self.sc.compilation_cache_dir:
            enable_persistent_cache(self.sc.compilation_cache_dir)
        if self.sc.plan_store_dir:
            from ..store import set_default_dir
            set_default_dir(self.sc.plan_store_dir)
        if self.sc.program_cache_size is not None:
            set_program_cache_size(self.sc.program_cache_size)
        self._lock = threading.RLock()
        self._registry: dict[str, tuple[Any, Any]] = {}
        # (name, impl) -> program-cache key: fingerprints are hashed once
        # per registration, not per request — submit() is pure dispatch
        self._keys: dict[tuple[str, str], tuple] = {}
        self._last_use: dict[str, float] = {}
        # names registered through register_function: the TracedFunction
        # binds positional args to graph arrays and rebuilds result pytrees
        self._functions: dict[str, Any] = {}
        # -- observability -------------------------------------------------
        # One registry per engine: the single source of truth behind both
        # stats() and the Prometheus exposition (metrics.expose()).  The
        # legacy int attributes (requests, rejected, ...) are read-only
        # property shims over these counters.
        configure_logging()
        self.metrics = MetricsRegistry()
        m = self.metrics
        self._tr = _obs_tracer()
        self._c_requests = m.counter(
            "repro_requests_total", "admitted requests")
        self._c_per_name = m.counter(
            "repro_entry_requests_total", "admitted requests per entry",
            ("entry",))
        self._c_rejected = m.counter(
            "repro_rejected_total", "admission (overload) rejections")
        self._c_deadline_rejected = m.counter(
            "repro_deadline_rejected_total",
            "deadline expired before admission")
        self._c_deadline_misses = m.counter(
            "repro_deadline_misses_total",
            "admitted requests finished past budget")
        self._c_plan_refreshes = m.counter(
            "repro_plan_refreshes_total",
            "stale plans re-solved in background")
        self._c_buckets_presolved = m.counter(
            "repro_buckets_presolved_total",
            "batch buckets pre-solved at register time")
        self._c_drift_triggers = m.counter(
            "repro_drift_triggers_total",
            "cost-model drift events that triggered a plan refresh")
        self._g_inflight = m.gauge(
            "repro_inflight", "requests currently admitted")
        self._h_latency = m.histogram(
            "repro_request_seconds", "submit wall time by serving path",
            ("path",))
        self._entry_families = {
            attr: m.counter(mname, help, ("entry",))
            for attr, mname, help in _ENTRY_COUNTERS
        }
        self._c_breaker_transitions = m.counter(
            "repro_breaker_transitions_total",
            "circuit-breaker state transitions", ("entry", "state"))
        m.register_invariant(
            "ok+fallbacks==requests per entry (at quiescence)",
            self._accounting_closed)
        self._drift = DriftDetector(self.sc.drift or DriftConfig(),
                                    clock=time.monotonic)
        # -- resilience state ---------------------------------------------
        self._health: dict[str, _EntryHealth] = {}
        # entries whose trace/solve failed at registration: served by the
        # plain-jit fallback alone until background re-solve succeeds
        self._fallback_only: dict[str, Any] = {}
        self._fallback_fns: dict[str, Any] = {}     # name -> jit(fn)
        self._reference_fns: dict[str, Any] = {}    # name -> ref executor
        # register_function provenance so background re-solve can retry
        # with the caller's solver budget/hardware
        self._reg_meta: dict[str, dict] = {}
        self._inflight_sem = (
            threading.BoundedSemaphore(self.sc.max_inflight)
            if self.sc.max_inflight else None)
        self._stop = threading.Event()
        self._clock = time.monotonic
        # background plan-refresh / bucket-presolve threads (stale store
        # hits, register-time bucket pre-solving) — joined in shutdown()
        self._bg_threads: list[threading.Thread] = []
        self._refreshing: set[str] = set()   # names with a refresh in flight
        # lazy: the batcher thread only starts on first submit_async()
        self._batcher: Batcher | None = None
        self._batcher_lock = threading.Lock()

    # -- legacy counter shims (registry-backed, read-only) -----------------
    @property
    def requests(self) -> int:
        return self._c_requests.value

    @property
    def per_name(self) -> dict[str, int]:
        return {k[0]: v for k, v in self._c_per_name.snapshot().items()}

    @property
    def rejected(self) -> int:
        return self._c_rejected.value

    @property
    def deadline_rejected(self) -> int:
        return self._c_deadline_rejected.value

    @property
    def deadline_misses(self) -> int:
        return self._c_deadline_misses.value

    @property
    def plan_refreshes(self) -> int:
        return self._c_plan_refreshes.value

    @property
    def buckets_presolved(self) -> int:
        return self._c_buckets_presolved.value

    def _accounting_closed(self) -> bool:
        """The per-entry conservation closure, asserted in one place:
        every admitted request ends in exactly one of ok/fallbacks.
        Holds at quiescence (no requests in flight)."""
        per_name = self.per_name
        with self._lock:
            health = dict(self._health)
        return all(
            h.ok.value + h.fallbacks.value == per_name.get(name, 0)
            for name, h in health.items())

    def check_invariants(self) -> list[str]:
        """Violated accounting invariants (empty when all closures hold).
        The batcher registers its closures in the same registry, so this
        covers both tiers.  Meaningful at quiescence; in-flight requests
        legitimately sit between the 'admitted' and 'resolved' counters."""
        return self.metrics.check_invariants()

    def note_predicted_latency(self, name: str, latency_s: float) -> None:
        """Seed/override the drift detector's predicted latency for an
        entry (benches use this to simulate a miscalibrated cost model)."""
        self._drift.note_predicted(name, latency_s)

    # -- registration -----------------------------------------------------
    def register(self, name: str, graph, plan) -> None:
        """Admit a (graph, plan) pair; past ``sc.max_plans`` registrations
        the least-recently-submitted name is evicted first."""
        with self._lock:
            if self.sc.max_plans is not None and name not in self._registry:
                while len(self._registry) >= max(1, self.sc.max_plans):
                    lru = min(self._registry,
                              key=lambda n: self._last_use.get(n, 0.0))
                    self.unregister(lru)
            self._registry[name] = (graph, plan)
            self._last_use[name] = time.monotonic()
            self._functions.pop(name, None)   # plain graphs shed any old
            self._keys = {k: v for k, v in self._keys.items()  # traced glue
                          if k[0] != name}
            self._health.pop(name, None)      # fresh entry, fresh health
            for fam in self._entry_families.values():
                fam.remove(name)              # ... fresh labeled counters
            for st in BreakerState:
                self._c_breaker_transitions.remove(name, st.value)
            self._fallback_only.pop(name, None)
            self._fallback_fns.pop(name, None)
            self._reference_fns.pop(name, None)
        # fresh plan, fresh drift baseline (resets the observed EMA)
        predicted = getattr(plan, "latency_s", 0.0) if plan is not None else 0.0
        if predicted > 0.0:
            self._drift.note_predicted(name, predicted)
        else:
            self._drift.forget(name)

    def register_function(self, name: str, fn, example_inputs,
                          *, solver_opts=None, hw=None):
        """Trace an arbitrary JAX callable (``repro.frontend``), solve its
        graph and register it for serving under ``name``.

        ``example_inputs`` is the positional-argument tuple fixing shapes
        and dtypes.  Requests for function entries pass the same tuple
        shape to :meth:`submit` (or a dict of graph arrays, as for plain
        registrations).  Returns the :class:`TracedFunction` so callers can
        inspect coverage or validate against the ``jax.jit`` oracle.

        With ``sc.fallback`` (the default), a trace/solve failure does NOT
        raise: the entry is registered in degraded mode — every submit is
        served by plain ``jax.jit(fn)`` — quarantined in :meth:`stats`,
        and re-traced/re-solved in the background with exponential
        backoff.  Returns ``None`` in that case.
        """
        from ..frontend import trace
        try:
            tf = trace(fn, *example_inputs, name=name)
            if not tf.graph.statements:
                raise ValueError(
                    f"{name}: function lowered to an empty graph (pure "
                    "passthrough) — nothing to serve")
            # allow_stale: with a plan store configured, a plan priced for
            # an older hardware profile is accepted here (cold solve off
            # the registration path) and re-solved in the background below
            plan = tf.solve(hw=hw, opts=solver_opts, allow_stale=True)
        except Exception as exc:
            if not self.sc.fallback:
                raise
            log.warning("%s: trace/solve failed (%s); registering the "
                        "plain-jit fallback and re-solving in background",
                        name, exc)
            with self._lock:
                self.register(name, None, None)
                self._fallback_only[name] = jax.jit(fn)
                self._reg_meta[name] = {
                    "fn": fn, "example_inputs": tuple(example_inputs),
                    "solver_opts": solver_opts, "hw": hw}
                health = self._health_for(name)
                health.last_error = f"{type(exc).__name__}: {exc}"
            health.breaker.force_open()
            self._start_recovery(name, self._current_impl())
            return None
        with self._lock:
            # registry entry + function-binding glue must appear atomically:
            # a concurrent positional-tuple submit between the two would see
            # the entry without the binder and hand the raw tuple to the
            # program (the lock is reentrant, register() retakes it)
            self.register(name, tf.graph, plan)
            self._functions[name] = tf
            self._reg_meta[name] = {
                "fn": fn, "example_inputs": tuple(example_inputs),
                "solver_opts": solver_opts, "hw": hw}
        if plan is not None and getattr(plan, "stale_hw", False):
            # serve the drifted plan now; re-solve + store update happen
            # off the request path
            self._start_plan_refresh(name)
        bc = self.sc.batching
        if bc is not None and bc.presolve and BATCH_SEP not in name:
            self._start_bucket_presolve(name)
        return tf

    def _start_plan_refresh(self, name: str) -> None:
        """Background re-solve for a stale-hardware store hit: solve fresh
        (bypassing the store read, updating the store write), recompile,
        revalidate, and atomically swap the entry — requests keep being
        served by the stale plan until the fresh one is proven."""
        impl = self._current_impl()
        with self._lock:
            if name in self._refreshing:
                return                  # one refresh in flight per entry
            self._refreshing.add(name)

        def _loop():
            from ..ft.serve import BackoffPolicy
            policy = BackoffPolicy(
                base_s=self.sc.resolve_backoff_s,
                mult=self.sc.resolve_backoff_mult,
                max_s=self.sc.resolve_backoff_max_s,
                retries=self.sc.resolve_max_retries)
            try:
                for attempt, delay in enumerate(policy.delays(), start=1):
                    if self._stop.wait(delay):
                        return
                    with self._lock:
                        if name not in self._registry:
                            return      # unregistered while refreshing
                    try:
                        chaos = self.sc.chaos
                        if chaos is not None:
                            chaos.on_refresh(name)
                        self._rebuild(name, impl)
                    except Exception as exc:
                        log.info(
                            "plan-refresh entry=%s attempt=%d "
                            "backoff_s=%.3f failed: %s",
                            name, attempt, delay, exc)
                        continue
                    self._c_plan_refreshes.inc()
                    log.info(
                        "plan-refresh entry=%s attempt=%d succeeded: "
                        "stale plan refreshed in background", name, attempt)
                    return
                log.warning("plan-refresh entry=%s gave up after %d "
                            "attempts", name, self.sc.resolve_max_retries)
            finally:
                with self._lock:
                    self._refreshing.discard(name)

        t = threading.Thread(target=_loop, daemon=True,
                             name=f"repro-plan-refresh-{name}")
        with self._lock:
            self._bg_threads.append(t)
        t.start()

    def _start_bucket_presolve(self, name: str) -> None:
        """Pre-solve the continuous-batching bucket ladder for ``name`` at
        registration time, so the first coalesced flush pays no trace or
        solve (with a warm plan store it pays neither even cold)."""

        def _loop():
            try:
                n = self.batcher().presolve(name, stop=self._stop)
            except Exception as exc:
                log.info("bucket-presolve entry=%s failed: %s", name, exc)
                return
            self._c_buckets_presolved.inc(n)
            log.info("bucket-presolve entry=%s buckets=%d done", name, n)

        t = threading.Thread(target=_loop, daemon=True,
                             name=f"repro-presolve-{name}")
        with self._lock:
            self._bg_threads.append(t)
        t.start()

    def unregister(self, name: str) -> None:
        self._c_per_name.remove(name)
        for fam in self._entry_families.values():
            fam.remove(name)
        for st in BreakerState:
            self._c_breaker_transitions.remove(name, st.value)
        self._drift.forget(name)
        with self._lock:
            self._registry.pop(name, None)
            self._last_use.pop(name, None)
            self._functions.pop(name, None)
            self._keys = {k: v for k, v in self._keys.items()
                          if k[0] != name}
            self._health.pop(name, None)
            self._fallback_only.pop(name, None)
            self._fallback_fns.pop(name, None)
            self._reference_fns.pop(name, None)
            self._reg_meta.pop(name, None)

    def names(self) -> list[str]:
        with self._lock:
            return sorted(self._registry)

    def shutdown(self, timeout: float = 60.0) -> None:
        """Stop background recovery threads and wait for any in-flight
        re-solve to finish (an attempt mid-solve cannot be interrupted,
        only not-followed-by-another).  Daemon threads also die with the
        process — this is for tests and orderly replica teardown, so a
        stopped engine leaves the process-wide program cache alone.  The
        batching tier (if started) drains its queue first: no enqueued
        future is abandoned."""
        with self._batcher_lock:
            batcher = self._batcher
        if batcher is not None:
            batcher.shutdown(timeout)
        self._stop.set()
        with self._lock:
            threads = [h.recovery_thread for h in self._health.values()
                       if h.recovery_thread is not None]
            threads += self._bg_threads
        for t in threads:
            t.join(timeout)

    # -- warmup -----------------------------------------------------------
    def warmup(self, name: str, inputs: dict) -> float:
        """Compile-and-first-run; returns seconds spent (the cold cost the
        cache amortizes away for every later request).

        Warms **every** pool clone, not just clone 0 — otherwise the first
        ``pool_size - 1`` concurrent requests after warmup each pay a
        first-call trace on a cold clone.  Every warmup execution flows
        through :meth:`submit`, so per-entry hit counters, LRU recency and
        ``per_name`` accounting all see the warmup (a just-warmed plan is
        MRU, never the next eviction victim).  With a persistent
        compilation cache configured, a replica warming a program another
        replica already compiled deserializes the artifact instead of
        re-lowering — the warm-start path."""
        from ..codegen import program_cache
        t0 = time.monotonic()
        out = self.submit(name, inputs)
        for v in jax.tree_util.tree_leaves(out):
            v.block_until_ready()
        impl = self._current_impl()
        if self.sc.pool_size is not None:
            # the engine's own pool contract — valid even if the entry was
            # already evicted again by a concurrent replica
            clones = self.sc.pool_size
        else:
            with self._lock:
                key = self._keys.get((name, impl))
            entry = program_cache().entry(key) if key is not None else None
            clones = entry.program.pool_size if entry is not None else 1
        for _ in range(clones - 1):
            out = self.submit(name, inputs)
            for v in jax.tree_util.tree_leaves(out):
                v.block_until_ready()
        return time.monotonic() - t0

    # -- request path -----------------------------------------------------
    def _current_impl(self) -> str:
        from ..kernels import dispatch
        return self._impl or dispatch.current_impl()

    def _health_for(self, name: str) -> _EntryHealth:
        with self._lock:
            health = self._health.get(name)
            if health is None:
                trans = self._c_breaker_transitions
                health = self._health[name] = _EntryHealth(
                    breaker=CircuitBreaker(
                        self.sc.breaker_threshold,
                        self.sc.breaker_reset_s, clock=self._clock,
                        on_transition=lambda state, _n=name:
                            trans.labels(_n, state).inc()),
                    **{attr: fam.labels(name)
                       for attr, fam in self._entry_families.items()})
            return health

    def _resolve(self, name: str, impl: str):
        from ..codegen import compiled_program, program_cache, program_key
        with self._lock:
            key = self._keys.get((name, impl))
            if key is None:
                graph, plan = self._registry[name]
                key = program_key(graph, plan, impl)
                self._keys[(name, impl)] = key
            else:
                graph, plan = self._registry[name]
        # fast path: an O(1) keyed hit honouring this engine's pool
        # contract (a pool-mismatched entry is NOT counted as a hit —
        # compiled_program rebuilds and re-admits it below)
        prog = program_cache().get_if(key, self.sc.pool_size)
        if prog is not None:
            return prog
        # miss or evicted or foreign pool: build once (per-key build lock
        # inside compiled_program), re-admitted as MRU
        return compiled_program(graph, plan, impl,
                                pool_size=self.sc.pool_size)

    def batcher(self) -> Batcher:
        """The engine's continuous-batching front door (lazily started on
        first use).  Requires ``sc.batching``; raises otherwise."""
        if self.sc.batching is None:
            raise RuntimeError(
                "continuous batching is not configured — set "
                "ServeConfig.batching = BatchConfig(...)")
        with self._batcher_lock:
            if self._batcher is None:
                self._batcher = Batcher(self, self.sc.batching)
            return self._batcher

    def submit_async(self, name: str, inputs, *,
                     deadline_s: float | None = None):
        """Asynchronous submit: returns a ``concurrent.futures.Future``
        resolving to the same value :meth:`submit` would return.

        With ``sc.batching`` configured the request enters the bounded
        batching queue, where same-entry submits are coalesced into one
        batched program execution (see :mod:`repro.serve.batching`);
        admission rejections (``EngineOverloaded``) and caller contract
        errors still raise synchronously, while execution-time failures
        (including ``DeadlineExceeded``) resolve the future.  Without
        batching this is a thin synchronous wrapper — the request runs
        inline and the returned future is already done — so callers can
        target either engine flavor uniformly.
        """
        if self.sc.batching is not None:
            return self.batcher().submit(name, inputs,
                                         deadline_s=deadline_s)
        from concurrent.futures import Future
        fut: Future = Future()
        try:
            fut.set_result(self.submit(name, inputs,
                                       deadline_s=deadline_s))
        except Exception as exc:
            fut.set_exception(exc)
        return fut

    def submit(self, name: str, inputs, *,
               deadline_s: float | None = None, _info: dict | None = None) \
            -> Any:
        """Execute one request; hits the compiled program for ``name``.

        ``inputs`` is a dict of graph arrays for plain registrations.  For
        ``register_function`` entries it may also be a tuple/list of
        positional arguments matching the traced signature — the request is
        bound through the TracedFunction and returns the function's result
        pytree instead of a raw array dict.

        ``deadline_s`` overrides ``sc.deadline_s`` for this request.
        Raises :class:`~repro.ft.EngineOverloaded` when the bounded
        in-flight depth stays full past the admission timeout, and
        :class:`~repro.ft.DeadlineExceeded` when the budget expires before
        admission; any post-admission failure degrades to the plain-jit
        fallback (``sc.fallback``) instead of raising.

        ``_info`` (internal, used by the batching tier's accounting) is
        annotated with ``{"path": "optimized" | "fallback"}`` for the path
        that served the request.
        """
        t0 = time.monotonic()
        deadline = deadline_s if deadline_s is not None \
            else self.sc.deadline_s
        sem = self._inflight_sem
        if sem is not None:
            timeout = self.sc.admission_timeout_s
            if deadline is not None:
                timeout = min(timeout, deadline)
            with self._tr.span("admission", "request", entry=name):
                admitted = sem.acquire(timeout=max(0.0, timeout))
            if not admitted:
                if deadline is not None \
                        and time.monotonic() - t0 >= deadline:
                    self._c_deadline_rejected.inc()
                    raise DeadlineExceeded(
                        f"{name}: deadline {deadline:.3f}s expired before "
                        "admission (engine at max_inflight="
                        f"{self.sc.max_inflight})")
                self._c_rejected.inc()
                raise EngineOverloaded(
                    f"{name}: {self.sc.max_inflight} requests in flight; "
                    f"none drained within {timeout:.3f}s")
        try:
            self._g_inflight.inc()
            return self._submit_admitted(name, inputs, t0, deadline,
                                         _info)
        finally:
            self._g_inflight.dec()
            if sem is not None:
                sem.release()

    def _submit_admitted(self, name: str, inputs, t0: float,
                         deadline: float | None,
                         _info: dict | None = None) -> Any:
        impl = self._current_impl()
        with self._lock:
            if name not in self._registry:
                raise KeyError(name)
            tf = self._functions.get(name)
            has_plan = self._registry[name][1] is not None
        health = self._health_for(name)
        env = None
        if tf is not None and not isinstance(inputs, dict):
            # argument-contract errors (bad pytree/shape/dtype) are caller
            # bugs: they raise before the request is counted and never
            # touch the breaker
            env = tf.bind_args(tuple(inputs))
        self._c_requests.inc()
        self._c_per_name.labels(name).inc()
        with self._lock:
            self._last_use[name] = time.monotonic()
        if has_plan and health.breaker.allow():
            try:
                out = self._run_optimized(
                    name, impl, tf, env if env is not None else inputs,
                    health)
            except Exception as exc:
                self._note_failure(name, impl, health, exc)
                if not self.sc.fallback:
                    raise
            else:
                health.ok.inc()
                health.breaker.record_success()
                self._note_deadline(t0, deadline, health)
                self._h_latency.labels("optimized").observe(
                    time.monotonic() - t0)
                if _info is not None:
                    _info["path"] = "optimized"
                if env is not None:
                    return tf.unbind(out, env)
                return out
        with self._tr.span("fallback", "request", entry=name):
            out = self._run_fallback(name, tf, env, inputs, health)
        self._note_deadline(t0, deadline, health)
        self._h_latency.labels("fallback").observe(time.monotonic() - t0)
        if _info is not None:
            _info["path"] = "fallback"
        return out

    def _run_optimized(self, name: str, impl: str, tf, env: dict,
                       health: _EntryHealth) -> dict:
        """The one-dispatch path; raises on any failure (compile, execute,
        injected chaos, NaN guard, canary mismatch)."""
        chaos = self.sc.chaos
        if chaos is not None:
            chaos.on_compile(name)
        prog = self._resolve(name, impl)
        if chaos is not None:
            chaos.on_execute(name)
        attempt = health.attempts.inc() - 1
        canary = self.sc.canary_every > 0 \
            and attempt % self.sc.canary_every == 0
        # one in drift.sample_every optimized runs is timed (device sync)
        # to feed the cost-model drift EMA; sampling keeps the sync off
        # the steady-state path
        drift_sample = self._drift.config.enabled \
            and self._drift.should_sample(name)
        timed = canary or (self.sc.straggler is not None
                           and prog.pool_size > 1) \
            or self.sc.nan_guard == "always" or drift_sample
        t_run = time.monotonic()
        with self._tr.span("execute", "request", entry=name) as sp:
            out, clone = prog.run(env)
            if chaos is not None:
                delay = chaos.execute_delay(name, clone)
                if delay > 0.0:
                    time.sleep(delay)
                out = chaos.corrupt_outputs(name, out)
            if timed:
                jax.block_until_ready(list(out.values()))
            sp.set(clone=clone, timed=timed)
        elapsed = time.monotonic() - t_run
        if drift_sample:
            self._note_drift(name, elapsed)
        if self.sc.straggler is not None and prog.pool_size > 1:
            self._observe_clone(name, health, prog, clone, elapsed)
        guard_nan = self.sc.nan_guard == "always" \
            or (canary and self.sc.nan_guard == "canary")
        if canary:
            health.canaries.inc()
        if guard_nan:
            self._guard_finite(name, out)
        if canary:
            with self._tr.span("canary", "request", entry=name):
                self._validate_canary(name, tf, env, out, health)
        return out

    def _note_drift(self, name: str, elapsed: float) -> None:
        """Fold one observed optimized-path latency into the drift EMA;
        a threshold crossing re-prices the plan through the background
        refresh path (the cost model drifted from reality)."""
        ev = self._drift.observe(name, elapsed)
        if ev is None:
            return
        self._c_drift_triggers.inc()
        log.warning(
            "drift entry=%s predicted_s=%.3g observed_ema_s=%.3g "
            "ratio=%.2f samples=%d — re-solving in background",
            ev.name, ev.predicted_s, ev.observed_ema_s, ev.ratio,
            ev.samples)
        self._start_plan_refresh(name)

    def _guard_finite(self, name: str, out: dict) -> None:
        for k, v in out.items():
            if jnp.issubdtype(v.dtype, jnp.floating) \
                    and not bool(jnp.all(jnp.isfinite(v))):
                raise MiscompileError(
                    f"{name}: output {k!r} contains NaN/inf — optimized "
                    "path quarantined")

    def _validate_canary(self, name: str, tf, env: dict, out: dict,
                         health: _EntryHealth) -> None:
        """Compare the optimized outputs against the plain-jit oracle;
        a mismatch is a miscompile (wrong kernel output) — the entry is
        quarantined and this request re-served by the oracle path."""
        from ..codegen import allclose
        try:
            if tf is not None:
                got = tf.unbind(out, env)
                flat = [env[n] for n in tf.record.in_names]
                args = jax.tree_util.tree_unflatten(tf.in_tree, list(flat))
                expect = self._fallback_fn(name, tf)(*args)
                g_flat = jax.tree_util.tree_leaves(got)
                e_flat = jax.tree_util.tree_leaves(expect)
                bad = len(g_flat) != len(e_flat) or any(
                    not allclose(g, e, rtol=_rtol_for(e.dtype))
                    for g, e in zip(g_flat, e_flat))
            else:
                expect = self._reference_fn(name)(env)
                bad = any(not allclose(out[k], expect[k],
                                       rtol=_rtol_for(expect[k].dtype))
                          for k in expect)
        except MiscompileError:
            raise
        except Exception as exc:
            # the oracle itself failing is an engine problem, not proof of
            # a miscompile; treat as an optimized-path failure all the same
            raise MiscompileError(
                f"{name}: canary oracle execution failed: {exc}") from exc
        if bad:
            health.canary_failures.inc()
            raise MiscompileError(
                f"{name}: canary validation mismatch vs the plain-jit "
                "oracle — corrupted kernel output")

    def _fallback_fn(self, name: str, tf):
        with self._lock:
            fn = self._fallback_fns.get(name)
            if fn is None:
                fn = self._fallback_fns[name] = jax.jit(tf.fn)
            return fn

    def _reference_fn(self, name: str):
        from ..codegen import reference_executor
        with self._lock:
            fn = self._reference_fns.get(name)
            if fn is None:
                graph, _ = self._registry[name]
                fn = self._reference_fns[name] = reference_executor(graph)
            return fn

    def _run_fallback(self, name: str, tf, env, inputs,
                      health: _EntryHealth) -> Any:
        """Serve the request on the plain-jit path (guaranteed-correct
        baseline): ``jax.jit(fn)`` for function entries, the statement
        reference oracle for graph registrations."""
        health.fallbacks.inc()
        with self._lock:
            fb = self._fallback_only.get(name)
        if fb is not None:
            return fb(*tuple(inputs))
        if tf is not None:
            fn = self._fallback_fn(name, tf)
            if env is not None:
                return fn(*tuple(inputs))
            flat = [inputs[n] for n in tf.record.in_names]
            args = jax.tree_util.tree_unflatten(tf.in_tree, list(flat))
            return fn(*args)
        return self._reference_fn(name)(inputs)

    def _note_deadline(self, t0: float, deadline: float | None,
                       health: _EntryHealth) -> None:
        if deadline is not None and time.monotonic() - t0 > deadline:
            self._c_deadline_misses.inc()
            health.deadline_misses.inc()

    def _observe_clone(self, name: str, health: _EntryHealth, prog,
                       clone: int, elapsed: float) -> None:
        with self._lock:
            mon = health.straggler
            if mon is None or mon.n_hosts != prog.pool_size:
                mon = health.straggler = StragglerMonitor(
                    prog.pool_size, self.sc.straggler)
            flagged = mon.observe_one(clone, elapsed)
            if flagged and clone not in mon.reassigned:
                if prog.disable_clone(clone):
                    mon.demote(clone)
                    health.rotated = tuple(
                        sorted(set(health.rotated) | {clone}))
                    log.warning(
                        "%s: pool clone %d persistently slow "
                        "(%.1fms) — rotated out of round-robin",
                        name, clone, elapsed * 1e3)

    # -- quarantine + background re-solve ---------------------------------
    def _note_failure(self, name: str, impl: str, health: _EntryHealth,
                      exc: Exception) -> None:
        health.failures.inc()
        with self._lock:
            health.last_error = f"{type(exc).__name__}: {exc}"
        if isinstance(exc, MiscompileError):
            # wrong values are never a transient: quarantine immediately
            health.breaker.force_open()
            opened = True
        else:
            opened = health.breaker.record_failure()
        if health.breaker.state is BreakerState.OPEN:
            # the quarantined program must not be served again on recovery:
            # drop it from the process-wide cache so re-solve starts clean
            from ..codegen import program_cache
            with self._lock:
                key = self._keys.pop((name, impl), None)
            if key is not None:
                program_cache().invalidate(key)
        if opened:
            log.warning("%s: optimized path quarantined (%s); serving "
                        "plain-jit fallback, re-solving in background",
                        name, health.last_error)
            self._start_recovery(name, impl)

    def _start_recovery(self, name: str, impl: str) -> None:
        health = self._health_for(name)
        with self._lock:
            if health.recovering or self._stop.is_set():
                return
            health.recovering = True
            health.recovered_event.clear()
        t = threading.Thread(target=self._recovery_loop, args=(name, impl),
                             daemon=True, name=f"repro-resolve-{name}")
        with self._lock:
            health.recovery_thread = t
        t.start()

    def _recovery_loop(self, name: str, impl: str) -> None:
        from ..ft.serve import BackoffPolicy
        health = self._health_for(name)
        policy = BackoffPolicy(
            base_s=self.sc.resolve_backoff_s,
            mult=self.sc.resolve_backoff_mult,
            max_s=self.sc.resolve_backoff_max_s,
            retries=self.sc.resolve_max_retries)
        for attempt, delay in enumerate(policy.delays(), start=1):
            if self._stop.wait(delay):
                break
            with self._lock:
                if name not in self._registry:
                    break               # unregistered while quarantined
            health.resolve_attempts.inc()
            try:
                self._rebuild(name, impl)
            except Exception as exc:
                with self._lock:
                    health.last_error = f"{type(exc).__name__}: {exc}"
                log.info(
                    "re-solve entry=%s attempt=%d backoff_s=%.3f "
                    "failed: %s", name, attempt, delay, exc)
                continue
            health.breaker.record_success()     # closes: next submit is
            health.recovered.inc()              # optimized again
            with self._lock:
                health.recovering = False
            health.recovered_event.set()
            log.info("re-solve entry=%s attempt=%d succeeded; breaker "
                     "closed", name, attempt)
            return
        else:
            log.warning("re-solve entry=%s gave up after %d attempts; "
                        "entry stays on the fallback path",
                        name, self.sc.resolve_max_retries)
        with self._lock:
            health.recovering = False

    def _rebuild(self, name: str, impl: str) -> None:
        """One recovery attempt: re-trace/re-solve as needed, compile the
        program eagerly, and validate it against the plain-jit oracle on
        probe inputs before the breaker may close."""
        from ..codegen import (allclose, compiled_program, program_key,
                               random_inputs, reference_executor)
        with self._lock:
            meta = self._reg_meta.get(name)
            graph, plan = self._registry.get(name, (None, None))
            tf = self._functions.get(name)
            fallback_only = name in self._fallback_only
        if fallback_only or (tf is None and graph is None):
            # registration never succeeded: retry the full trace + solve
            from ..frontend import trace
            tf = trace(meta["fn"], *meta["example_inputs"], name=name)
            if not tf.graph.statements:
                raise ValueError(f"{name}: still lowers to an empty graph")
            plan = tf.solve(hw=meta["hw"], opts=meta["solver_opts"])
            graph = tf.graph
        elif tf is not None:
            # quarantined traced entry: re-solve fresh (calibration may
            # have drifted; the old plan produced the failure).  refresh
            # bypasses the plan-store read — a stored plan is exactly what
            # must not be trusted here — but still writes the result back,
            # so the store converges to the re-solved plan for every
            # replica
            from ..core.solver import SolverOptions, solve
            opts = (meta or {}).get("solver_opts") \
                or SolverOptions(time_budget_s=20.0)
            plan = solve(graph, (meta or {}).get("hw"), opts,
                         refresh=True)
        # graph-only entries keep their externally supplied plan: the
        # rebuild recompiles and revalidates the program
        prog = compiled_program(graph, plan, impl,
                                pool_size=self.sc.pool_size)
        if tf is not None:
            env = tf.bind(list(tf.example_flat))
            out = prog(env)
            got = jax.tree_util.tree_leaves(tf.unbind(out, env))
            args = jax.tree_util.tree_unflatten(tf.in_tree,
                                                list(tf.example_flat))
            expect = jax.tree_util.tree_leaves(jax.jit(tf.fn)(*args))
            if len(got) != len(expect) or any(
                    not allclose(g, e, rtol=_rtol_for(e.dtype))
                    for g, e in zip(got, expect)):
                raise MiscompileError(
                    f"{name}: rebuilt program still fails oracle "
                    "validation")
        else:
            env = random_inputs(graph, seed=0)
            out = prog(env)
            expect = reference_executor(graph)(env)
            if any(not allclose(out[k], expect[k]) for k in expect):
                raise MiscompileError(
                    f"{name}: rebuilt program still fails oracle "
                    "validation")
        with self._lock:
            self._registry[name] = (graph, plan)
            self._keys = {k: v for k, v in self._keys.items()
                          if k[0] != name}
            self._keys[(name, impl)] = program_key(graph, plan, impl)
            if tf is not None:
                self._functions[name] = tf
                self._fallback_only.pop(name, None)
            self._reference_fns.pop(name, None)
        # the re-solved plan is the new drift baseline (EMA resets)
        predicted = getattr(plan, "latency_s", 0.0) if plan is not None else 0.0
        if predicted > 0.0:
            self._drift.note_predicted(name, predicted)

    # -- statistics -------------------------------------------------------
    def stats(self) -> dict:
        """Serving statistics: engine request counts, the global program
        cache (size/capacity, hits/misses/evictions, per-entry detail),
        per-pool occupancy of every program this engine serves, the
        frontend trace cache (hits, size, per-entry coverage) feeding
        ``register_function`` entries, the ``resilience`` block —
        admission rejections, deadline accounting, and per-entry health
        (breaker state, fallbacks, canary results, recovery progress) —
        and the ``drift`` block (cost-model predicted vs. observed
        latency per entry).

        Lock discipline: the metrics-registry and drift snapshots come
        first (their own locks only), then the engine lock covers a
        plain-data copy; every sub-object that takes its own lock
        (breakers, batcher, program cache, trace cache) is consulted
        with NO engine lock held — ``stats()`` can never deadlock
        against a concurrent ``submit`` storm."""
        from ..codegen import cache_stats, persistent_cache_dir, program_cache
        from ..frontend import trace_cache_stats
        cache = program_cache()
        # 1) registry-backed counters + drift: no engine lock, no nesting
        requests = self._c_requests.value
        per_name = self.per_name
        drift = self._drift.stats()
        plan_store = {
            "dir": self.sc.plan_store_dir,
            "refreshes": self._c_plan_refreshes.value,
            "buckets_presolved": self._c_buckets_presolved.value,
        }
        # 2) engine lock: copy plain data only — no sub-object calls
        with self._lock:
            keys = dict(self._keys)
            registered = len(self._registry)
            functions = sorted(self._functions)
            health_refs = {
                name: (h, self._registry.get(name, (None, None))[1]
                       is not None)
                for name, h in self._health.items()}
        # 3) sub-objects with their own locks, engine lock released
        health = {name: h.stats(has_plan)
                  for name, (h, has_plan) in health_refs.items()}
        resilience = {
            "rejected": self._c_rejected.value,
            "deadline_rejected": self._c_deadline_rejected.value,
            "deadline_misses": self._c_deadline_misses.value,
            "inflight": self._g_inflight.value,
            "max_inflight": self.sc.max_inflight,
            "entries": health,
        }
        pools = {}
        for (name, impl), key in keys.items():
            entry = cache.entry(key)
            if entry is not None:
                p = entry.program
                pools[f"{name}/{impl}"] = {
                    "pool_size": p.pool_size,
                    "next": p.calls % p.pool_size,
                    "calls": p.calls,
                    "n_segments": p.n_segments,
                    "disabled_clones": list(p.disabled_clones),
                }
        with self._batcher_lock:
            batcher = self._batcher
        batching = batcher.stats() if batcher is not None else None
        s = cache_stats(detail=True)
        hit_rate = s["hits"] / max(1, s["hits"] + s["misses"])
        return {"requests": requests,
                "batching": batching,
                "registered": registered,
                "functions": functions,
                "per_name": per_name,
                "hit_rate": round(hit_rate, 4),
                "pools": pools,
                "persistent_cache_dir": persistent_cache_dir(),
                "trace_cache": trace_cache_stats(),
                "plan_store": plan_store,
                "resilience": resilience,
                "drift": drift,
                **s}
