"""Batched serving engines: LM decode loop + plan-execution serving.

``Engine`` is the static-shape LM batch engine (the TPU-friendly design):
fixed batch slots, fixed max length, jitted prefill/decode steps.
Continuous batching is approximated at the slot level — finished sequences
are replaced between decode bursts (slot recycling), which is what
production TPU servers do between jitted macro-steps.

``PlanEngine`` is the dataflow-plan counterpart: it serves repeated
executions of solved plans through the whole-plan compiled-program cache
(`repro.codegen.program`), so after the first request for a (graph, plan,
impl) triple every subsequent request — including from a *new* PlanEngine —
hits a fully compiled program with zero re-lowering or re-tracing.

Workloads need not be hand-modeled graphs: ``register_function`` traces an
arbitrary JAX callable through ``repro.frontend``, solves it, and serves it
through the same cache/pool/warmup path — requests for function entries
pass positional-argument tuples instead of array dicts and get the
function's own result pytree back.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..models import model as M


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 512
    temperature: float = 0.0       # 0 = greedy
    eos_id: int | None = None
    seed: int = 0
    # -- plan-serving knobs (PlanEngine) ----------------------------------
    # Persistent AOT compilation cache directory: replicas pointed at the
    # same path share lowered XLA artifacts across processes, so a fresh
    # replica's first compile deserializes instead of re-lowering.
    # (env equivalent: REPRO_COMPILATION_CACHE_DIR)
    compilation_cache_dir: str | None = None
    # Bound of the process-wide compiled-program LRU cache; None keeps the
    # current global setting.  (env equivalent: REPRO_PROGRAM_CACHE_SIZE)
    program_cache_size: int | None = None
    # Round-robin executable-pool size per cached program; None defers to
    # REPRO_PROGRAM_POOL_SIZE (default 1).
    pool_size: int | None = None
    # Admission policy: max (graph, plan) pairs registered at once; the
    # least-recently-used registration is evicted past this.  None = no cap.
    max_plans: int | None = None


class Engine:
    def __init__(self, cfg: M.ModelConfig, params: Any,
                 sc: ServeConfig | None = None):
        self.cfg = cfg
        self.params = params
        self.sc = sc or ServeConfig()
        self._prefill = jax.jit(partial(M.prefill, cfg=self.cfg),
                                static_argnames=("max_len",))
        self._decode = jax.jit(partial(M.decode_step, cfg=self.cfg))

    def _sample(self, logits: jax.Array, key) -> jax.Array:
        if self.sc.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        probs = jax.nn.softmax(logits / self.sc.temperature, axis=-1)
        return jax.random.categorical(key, jnp.log(probs + 1e-9), axis=-1) \
            .astype(jnp.int32)

    def generate(self, prompts: np.ndarray, max_new_tokens: int) \
            -> np.ndarray:
        """prompts (B, P) int32 -> (B, max_new_tokens) int32."""
        b, p = prompts.shape
        assert p + max_new_tokens <= self.sc.max_len, "exceeds max_len"
        key = jax.random.PRNGKey(self.sc.seed)
        logits, cache = self._prefill(
            params=self.params, tokens=jnp.asarray(prompts),
            max_len=self.sc.max_len)
        out = np.zeros((b, max_new_tokens), np.int32)
        done = np.zeros((b,), bool)
        key, sub = jax.random.split(key)
        tok = self._sample(logits, sub)
        for t in range(max_new_tokens):
            out[:, t] = np.where(done, 0, np.asarray(tok))
            if self.sc.eos_id is not None:
                done |= np.asarray(tok) == self.sc.eos_id
                if done.all():
                    break
            logits, cache = self._decode(params=self.params, cache=cache,
                                         tokens=tok)
            key, sub = jax.random.split(key)
            tok = self._sample(logits, sub)
        return out


def throughput_stats(n_tokens: int, seconds: float) -> dict:
    return {"tokens": n_tokens, "seconds": seconds,
            "tokens_per_s": n_tokens / max(seconds, 1e-9)}


class PlanEngine:
    """Serve repeated plan executions off the compiled-program cache.

    Register (graph, plan) pairs under a model name, then submit input
    batches against them.  Requests resolve through the process-wide
    bounded LRU program cache (``repro.codegen.program_cache``): the
    (graph, plan, impl) fingerprint key is hashed once per registration,
    and every ``submit()`` is an O(1) keyed cache lookup — eviction-aware,
    so the cache's hit/eviction statistics stay the one source of truth.

    ``ServeConfig`` carries the serving knobs: persistent AOT compilation
    cache directory (cross-replica artifact sharing / warm start),
    program-cache bound, executable-pool size, and the registration
    admission cap.

    Thread-safe: N server threads may ``submit`` (and register/unregister)
    against one engine concurrently — registry, key table and request
    counters mutate under an engine lock, the program cache under its own
    lock, and program execution itself runs outside both, so requests for
    warm programs never serialize on each other.
    """

    def __init__(self, impl: str | None = None,
                 sc: ServeConfig | None = None):
        from ..codegen import enable_persistent_cache, set_program_cache_size
        self._impl = impl
        self.sc = sc or ServeConfig()
        if self.sc.compilation_cache_dir:
            enable_persistent_cache(self.sc.compilation_cache_dir)
        if self.sc.program_cache_size is not None:
            set_program_cache_size(self.sc.program_cache_size)
        self._lock = threading.RLock()
        self._registry: dict[str, tuple[Any, Any]] = {}
        # (name, impl) -> program-cache key: fingerprints are hashed once
        # per registration, not per request — submit() is pure dispatch
        self._keys: dict[tuple[str, str], tuple] = {}
        self._last_use: dict[str, float] = {}
        # names registered through register_function: the TracedFunction
        # binds positional args to graph arrays and rebuilds result pytrees
        self._functions: dict[str, Any] = {}
        self.requests = 0
        self.per_name: dict[str, int] = {}

    def register(self, name: str, graph, plan) -> None:
        """Admit a (graph, plan) pair; past ``sc.max_plans`` registrations
        the least-recently-submitted name is evicted first."""
        with self._lock:
            if self.sc.max_plans is not None and name not in self._registry:
                while len(self._registry) >= max(1, self.sc.max_plans):
                    lru = min(self._registry,
                              key=lambda n: self._last_use.get(n, 0.0))
                    self.unregister(lru)
            self._registry[name] = (graph, plan)
            self._last_use[name] = time.monotonic()
            self._functions.pop(name, None)   # plain graphs shed any old
            self._keys = {k: v for k, v in self._keys.items()  # traced glue
                          if k[0] != name}

    def register_function(self, name: str, fn, example_inputs,
                          *, solver_opts=None, hw=None):
        """Trace an arbitrary JAX callable (``repro.frontend``), solve its
        graph and register it for serving under ``name``.

        ``example_inputs`` is the positional-argument tuple fixing shapes
        and dtypes.  Requests for function entries pass the same tuple
        shape to :meth:`submit` (or a dict of graph arrays, as for plain
        registrations).  Returns the :class:`TracedFunction` so callers can
        inspect coverage or validate against the ``jax.jit`` oracle.
        """
        from ..frontend import trace
        tf = trace(fn, *example_inputs, name=name)
        if not tf.graph.statements:
            raise ValueError(
                f"{name}: function lowered to an empty graph (pure "
                "passthrough) — nothing to serve")
        plan = tf.solve(hw=hw, opts=solver_opts)
        with self._lock:
            # registry entry + function-binding glue must appear atomically:
            # a concurrent positional-tuple submit between the two would see
            # the entry without the binder and hand the raw tuple to the
            # program (the lock is reentrant, register() retakes it)
            self.register(name, tf.graph, plan)
            self._functions[name] = tf
        return tf

    def unregister(self, name: str) -> None:
        with self._lock:
            self._registry.pop(name, None)
            self._last_use.pop(name, None)
            self.per_name.pop(name, None)
            self._functions.pop(name, None)
            self._keys = {k: v for k, v in self._keys.items()
                          if k[0] != name}

    def names(self) -> list[str]:
        with self._lock:
            return sorted(self._registry)

    def warmup(self, name: str, inputs: dict) -> float:
        """Compile-and-first-run; returns seconds spent (the cold cost the
        cache amortizes away for every later request).

        Warms **every** pool clone, not just clone 0 — otherwise the first
        ``pool_size - 1`` concurrent requests after warmup each pay a
        first-call trace on a cold clone.  Every warmup execution flows
        through :meth:`submit`, so per-entry hit counters, LRU recency and
        ``per_name`` accounting all see the warmup (a just-warmed plan is
        MRU, never the next eviction victim).  With a persistent
        compilation cache configured, a replica warming a program another
        replica already compiled deserializes the artifact instead of
        re-lowering — the warm-start path."""
        from ..codegen import program_cache
        from ..kernels import dispatch
        t0 = time.monotonic()
        out = self.submit(name, inputs)
        for v in jax.tree_util.tree_leaves(out):
            v.block_until_ready()
        impl = self._impl or dispatch.current_impl()
        if self.sc.pool_size is not None:
            # the engine's own pool contract — valid even if the entry was
            # already evicted again by a concurrent replica
            clones = self.sc.pool_size
        else:
            with self._lock:
                key = self._keys.get((name, impl))
            entry = program_cache().entry(key) if key is not None else None
            clones = entry.program.pool_size if entry is not None else 1
        for _ in range(clones - 1):
            out = self.submit(name, inputs)
            for v in jax.tree_util.tree_leaves(out):
                v.block_until_ready()
        return time.monotonic() - t0

    def _resolve(self, name: str, impl: str):
        from ..codegen import compiled_program, program_cache, program_key
        with self._lock:
            key = self._keys.get((name, impl))
            if key is None:
                graph, plan = self._registry[name]
                key = program_key(graph, plan, impl)
                self._keys[(name, impl)] = key
            else:
                graph, plan = self._registry[name]
        # fast path: an O(1) keyed hit honouring this engine's pool
        # contract (a pool-mismatched entry is NOT counted as a hit —
        # compiled_program rebuilds and re-admits it below)
        prog = program_cache().get_if(key, self.sc.pool_size)
        if prog is not None:
            return prog
        # miss or evicted or foreign pool: build once (per-key build lock
        # inside compiled_program), re-admitted as MRU
        return compiled_program(graph, plan, impl,
                                pool_size=self.sc.pool_size)

    def submit(self, name: str, inputs) -> Any:
        """Execute one request; hits the compiled program for ``name``.

        ``inputs`` is a dict of graph arrays for plain registrations.  For
        ``register_function`` entries it may also be a tuple/list of
        positional arguments matching the traced signature — the request is
        bound through the TracedFunction and returns the function's result
        pytree instead of a raw array dict.
        """
        from ..kernels import dispatch
        impl = self._impl or dispatch.current_impl()
        with self._lock:
            tf = self._functions.get(name)
        env = None
        if tf is not None and not isinstance(inputs, dict):
            env = tf.bind_args(tuple(inputs))
        prog = self._resolve(name, impl)
        with self._lock:
            self.requests += 1
            self.per_name[name] = self.per_name.get(name, 0) + 1
            self._last_use[name] = time.monotonic()
        if env is not None:
            return tf.unbind(prog(env), env)
        return prog(inputs)

    def stats(self) -> dict:
        """Serving statistics: engine request counts, the global program
        cache (size/capacity, hits/misses/evictions, per-entry detail),
        per-pool occupancy of every program this engine serves, and the
        frontend trace cache (hits, size, per-entry coverage) feeding
        ``register_function`` entries."""
        from ..codegen import cache_stats, persistent_cache_dir, program_cache
        from ..frontend import trace_cache_stats
        cache = program_cache()
        with self._lock:
            keys = dict(self._keys)
            requests = self.requests
            registered = len(self._registry)
            per_name = dict(self.per_name)
            functions = sorted(self._functions)
        pools = {}
        for (name, impl), key in keys.items():
            entry = cache.entry(key)
            if entry is not None:
                p = entry.program
                pools[f"{name}/{impl}"] = {
                    "pool_size": p.pool_size,
                    "next": p.calls % p.pool_size,
                    "calls": p.calls,
                    "n_segments": p.n_segments,
                }
        s = cache_stats(detail=True)
        hit_rate = s["hits"] / max(1, s["hits"] + s["misses"])
        return {"requests": requests,
                "registered": registered,
                "functions": functions,
                "per_name": per_name,
                "hit_rate": round(hit_rate, 4),
                "pools": pools,
                "persistent_cache_dir": persistent_cache_dir(),
                "trace_cache": trace_cache_stats(),
                **s}
