"""Optimize + execute the full executable PolyBench suite.

    PYTHONPATH=src python examples/polybench_suite.py [--scale N]

For each kernel: solve the Prometheus NLP, generate the tiled JAX
executable, validate against the reference, and report model GF/s.
"""
import argparse

import numpy as np

from repro.core import THREE_SLICE, SolverOptions, polybench, solve
from repro.core.apply import (plan_executor, random_inputs,
                              reference_executor)

EXECUTABLE = ["3mm", "2mm", "gemm", "atax", "bicg", "mvt", "gesummv",
              "gemver", "madd", "2-madd", "3-madd"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=1,
                    help="dataset scale (1 = paper medium)")
    ap.add_argument("--budget", type=float, default=10.0)
    args = ap.parse_args()

    print(f"{'kernel':10s} {'GF/s(model)':>12s} {'solver_s':>9s} "
          f"{'validated':>9s}")
    for name in EXECUTABLE:
        g = polybench.build(name, scale=args.scale)
        plan = solve(g, THREE_SLICE,
                     SolverOptions(time_budget_s=args.budget))
        ok = "-"
        if args.scale == 1:          # numeric validation at medium sizes
            ins = random_inputs(g, seed=0)
            ref = reference_executor(g)(ins)
            out = plan_executor(g, plan)(ins)
            ok = all(np.allclose(np.asarray(out[k]), np.asarray(ref[k]),
                                 rtol=2e-4, atol=2e-4) for k in ref)
        print(f"{name:10s} {plan.gflops:12.1f} "
              f"{plan.solver_seconds:9.2f} {str(ok):>9s}")


if __name__ == "__main__":
    main()
