"""Optimize + execute the full executable PolyBench suite.

    PYTHONPATH=src python examples/polybench_suite.py [--scale N] [--impl I]

For each kernel: solve the Prometheus NLP, lower the plan through the
codegen subsystem (one fused Pallas kernel per task), validate against the
reference oracle, and report model GF/s plus measured wall time.
"""
import argparse
import time

from repro.codegen import (allclose, plan_executor, random_inputs,
                           reference_executor)
from repro.core import THREE_SLICE, SolverOptions, polybench, solve

EXECUTABLE = ["3mm", "2mm", "gemm", "atax", "bicg", "mvt", "gesummv",
              "gemver", "madd", "2-madd", "3-madd"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=1,
                    help="dataset scale (1 = paper medium)")
    ap.add_argument("--budget", type=float, default=10.0)
    ap.add_argument("--impl", default=None,
                    choices=("xla", "pallas_interpret", "pallas"),
                    help="kernel implementation (default: auto)")
    args = ap.parse_args()

    print(f"{'kernel':10s} {'GF/s(model)':>12s} {'solver_s':>9s} "
          f"{'exec_ms':>8s} {'lowered':>12s} {'validated':>9s}")
    for name in EXECUTABLE:
        g = polybench.build(name, scale=args.scale)
        plan = solve(g, THREE_SLICE,
                     SolverOptions(time_budget_s=args.budget))
        exe = plan_executor(g, plan, impl=args.impl)
        ins = random_inputs(g, seed=0)
        out = exe(ins)                          # compile + warm up
        for v in out.values():
            v.block_until_ready()               # drain async dispatch
        t0 = time.monotonic()
        out = exe(ins)
        for v in out.values():
            v.block_until_ready()
        exec_ms = (time.monotonic() - t0) * 1e3
        kinds = {lw.kind for lw in exe.lowerings().values()}
        lowered = "+".join(sorted(kinds))
        ok = "-"
        if args.scale == 1:          # numeric validation at medium sizes
            ref = reference_executor(g)(ins)
            ok = all(allclose(out[k], ref[k]) for k in ref)
        print(f"{name:10s} {plan.gflops:12.1f} "
              f"{plan.solver_seconds:9.2f} {exec_ms:8.2f} {lowered:>12s} "
              f"{str(ok):>9s}")


if __name__ == "__main__":
    main()
