"""End-to-end LM training driver (deliverable b): fault-tolerant loop with
checkpointing, deterministic data, any assigned --arch at a reduced depth.

    # ~15M-param model, 300 steps (CPU-feasible):
    PYTHONPATH=src python examples/train_lm.py --steps 300

    # ~100M-param qwen-family model (larger budget):
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300

    # smoke (CI): PYTHONPATH=src python examples/train_lm.py --steps 8 \
    #     --preset tiny

The same loop, step function and sharding rules the 512-chip dry-run
lowers — here jitted on the local device mesh.
"""
import argparse
import dataclasses

from repro.configs import get_config, list_archs
from repro.configs.base import smoke
from repro.ft import FailurePlan
from repro.train.loop import TrainConfig, train
from repro.train.optimizer import AdamWConfig

PRESETS = {
    # name: (n_layers, d_model, heads, kv, d_ff, vocab) — ~param count
    "tiny": (2, 64, 2, 1, 128, 512),             # ~0.2M
    "15m": (4, 256, 4, 2, 1024, 8192),           # ~15M
    "100m": (8, 640, 10, 5, 2560, 16384),        # ~100M
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b", choices=list_archs())
    ap.add_argument("--preset", default="15m", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--inject-failure-at", type=int, default=None,
                    help="test checkpoint-restart by failing at this step")
    args = ap.parse_args()

    L, d, h, kv, ff, v = PRESETS[args.preset]
    base = smoke(get_config(args.arch))
    n_pat = len(base.pattern)
    cfg = dataclasses.replace(
        base, name=f"{args.arch}-{args.preset}",
        n_layers=max(n_pat, (L // n_pat) * n_pat), d_model=d, n_heads=h,
        n_kv_heads=kv, head_dim=d // h, d_ff=ff, vocab=v,
        d_rnn=d if base.d_rnn else 0, loss_chunk=args.batch * args.seq)
    tc = TrainConfig(total_steps=args.steps,
                     checkpoint_every=args.ckpt_every,
                     checkpoint_dir=args.ckpt_dir,
                     global_batch=args.batch, seq_len=args.seq,
                     log_every=10)
    opt = AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps // 10),
                      total_steps=args.steps)
    plan = FailurePlan(at_steps=(args.inject_failure_at,)) \
        if args.inject_failure_at is not None else None

    import logging
    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")
    from repro.models.model import init_params, param_count
    import jax
    n_params = param_count(init_params(cfg, jax.random.PRNGKey(0)))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"steps={args.steps} batch={args.batch}x{args.seq}")

    state, history, stats = train(cfg, tc, opt_cfg=opt,
                                  failure_plan=plan)
    first = history[0][1]
    last = min(l for _, l in history[-10:])
    print(f"loss {first:.4f} -> {last:.4f} over {len(history)} steps "
          f"({stats.restarts} restarts, {stats.replayed_steps} replayed)")
    assert last < first, "training must reduce loss"
    print("train_lm OK")


if __name__ == "__main__":
    main()
