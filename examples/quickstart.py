"""Quickstart: the paper's 3mm walkthrough (§2.4) end-to-end.

    PYTHONPATH=src python examples/quickstart.py

1. Build the 3mm affine task graph (Listing 4).
2. Maximal distribution + output-stationary fusion (Fig. 3 -> Listing 6).
3. Solve the unified NLP (tiling x permutation x padding x buffering x
   concurrency x slice placement) in all four solver modes.
4. Generate JAX code from the winning plan and validate it bit-for-bit
   against the naive reference executor.
5. The new front door: trace an *arbitrary JAX function* (a 2-layer MLP —
   never hand-modeled) into the same pipeline via ``repro.frontend``.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import frontend
from repro.codegen import (allclose, plan_executor, random_inputs,
                           reference_executor)
from repro.core import (ONE_SLICE, THREE_SLICE, SolverOptions, polybench,
                        solve)
from repro.core.fusion import fuse


def main() -> None:
    g = polybench.build("3mm")
    print(f"== task graph: {g.name} ==")
    print(f"statements: {[s.name for s in g.statements]}")
    print(f"inputs: {g.external_inputs()}  outputs: {g.final_outputs()}")

    fg = fuse(g)
    print(f"\n== fused dataflow graph (paper Fig. 3) ==")
    for t in fg.tasks:
        print(f"  {t.name}: {[s.name for s in t.statements]} "
              f"-> {t.output_array}")
    print(f"  edges: {fg.edges}")

    print("\n== NLP solve, all modes (TPU-scale datasets) ==")
    gtpu = polybench.build("3mm", scale=polybench.TPU_SCALE)
    plans = {}
    for mode in ("prometheus", "sisyphus", "streamhls", "autodse"):
        hw = THREE_SLICE if mode == "prometheus" else ONE_SLICE
        plan = solve(gtpu, hw, SolverOptions(mode=mode, time_budget_s=15))
        plans[mode] = plan
        print(f"  {mode:11s} {plan.gflops:10.1f} GF/s  "
              f"(solved in {plan.solver_seconds:5.2f}s, "
              f"{plan.n_evaluated} configs, "
              f"space {plan.space_size:.1e}"
              f"{', TIMEOUT' if plan.timed_out else ''})")

    best = plans["prometheus"]
    print("\n== winning plan ==")
    print(best.summary())

    print("\n== codegen + validation (paper-exact medium sizes) ==")
    plan_m = solve(g, THREE_SLICE, SolverOptions(time_budget_s=10))
    exe = plan_executor(g, plan_m)
    for tid, lw in sorted(exe.lowerings("xla").items()):
        print(f"  {lw.name}: kind={lw.kind} grid={lw.grid} "
              f"slice={lw.slice_id} inputs={list(lw.in_arrays)} "
              f"-> {lw.out_array}")
    ins = random_inputs(g, seed=0)
    ref = reference_executor(g)(ins)
    out = exe(ins)
    for k in ref:
        ok = allclose(out[k], ref[k])
        print(f"  {k}: allclose={ok}")
        assert ok

    print("\n== frontend: trace an arbitrary JAX function ==")

    def mlp(params, x):
        """2-layer MLP nobody hand-modeled: the frontend's job."""
        h = jnp.tanh(x @ params["w1"] + params["b1"])
        return h @ params["w2"] + params["b2"]

    rng = np.random.default_rng(0)
    params = {
        "w1": jnp.asarray(rng.normal(size=(64, 128)).astype(np.float32)),
        "b1": jnp.asarray(rng.normal(size=(128,)).astype(np.float32)),
        "w2": jnp.asarray(rng.normal(size=(128, 32)).astype(np.float32)),
        "b2": jnp.asarray(rng.normal(size=(32,)).astype(np.float32)),
    }
    x = jnp.asarray(rng.normal(size=(16, 64)).astype(np.float32))

    tf = frontend.trace(mlp, params, x)
    cov = tf.coverage
    print(f"  {tf!r}")
    print(f"  coverage: {cov.n_supported}/{cov.n_eqns} equations "
          f"supported ({cov.flop_ratio:.0%} of est. FLOPs); the tanh "
          "lowers through the unary pointwise family")
    plan_t = tf.solve(opts=SolverOptions(time_budget_s=10))
    print(f"  solved: {plan_t.latency_s * 1e6:.2f}us model latency, "
          f"{len(plan_t.configs)} tasks")
    exe = tf.executable(plan=plan_t)          # whole-plan compiled program
    got = exe(params, x)
    want = jax.jit(mlp)(params, x)
    ok = allclose(got, want)
    print(f"  traced program vs jax.jit oracle: allclose={ok}")
    assert ok
    print("quickstart OK")


if __name__ == "__main__":
    main()
