"""Batched LM serving driver (deliverable b): prefill + decode engine with
slot-recycled batching, any assigned --arch at a reduced size.

    PYTHONPATH=src python examples/serve_lm.py --arch mixtral-8x7b \
        --batch 4 --new-tokens 32
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, list_archs
from repro.configs.base import smoke
from repro.models import model as M
from repro.serve.engine import Engine, ServeConfig, throughput_stats


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b", choices=list_archs())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--kv-dtype", default="bfloat16",
                    choices=["bfloat16", "int8", "float32"])
    args = ap.parse_args()

    import dataclasses
    cfg = dataclasses.replace(smoke(get_config(args.arch)),
                              kv_cache_dtype=args.kv_dtype)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params,
                 ServeConfig(max_len=args.prompt_len + args.new_tokens,
                             temperature=args.temperature))

    rng = np.random.default_rng(0)
    prompts = rng.integers(
        0, cfg.vocab, size=(args.batch, args.prompt_len)).astype(np.int32)
    # warmup (compile)
    eng.generate(prompts, max_new_tokens=2)
    t0 = time.monotonic()
    out = eng.generate(prompts, max_new_tokens=args.new_tokens)
    dt = time.monotonic() - t0
    stats = throughput_stats(args.batch * args.new_tokens, dt)
    print(f"arch={args.arch} kv={args.kv_dtype} "
          f"batch={args.batch} prompt={args.prompt_len} "
          f"new={args.new_tokens}")
    print(f"generated {out.shape} in {dt:.2f}s "
          f"-> {stats['tokens_per_s']:.1f} tok/s (CPU interpret)")
    print("sample:", out[0, :16].tolist())
    print("serve_lm OK")


if __name__ == "__main__":
    main()
