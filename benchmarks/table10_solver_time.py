"""Table 10 analogue: NLP solve time — Prometheus decomposition vs the
Sisyphus shared-buffer JOINT formulation.

The paper's story: dataflow decouples tasks, so Prometheus' effective
search is a SUM of per-task spaces; the shared-buffer formulation couples
them into a PRODUCT that times out on 3mm (4 h).  We report wall time,
the raw product-space size, and whether exhaustive coverage was possible
within the budget (the timeout condition).
"""
from __future__ import annotations

from .common import Table, solve_kernel

KERNELS = ["2mm", "3mm", "atax", "bicg", "gemm", "gesummv", "mvt",
           "symm", "syr2k", "syrk", "trmm"]


def run(budget: float = 20.0) -> Table:
    t = Table("Table 10 — solver time (s) and joint-space blowup",
              ["kernel", "prometheus_s", "pro_space", "sisyphus_s",
               "sis_space", "sis_covered"])
    for name in KERNELS:
        pro = solve_kernel(name, "prometheus", budget=budget)
        sis = solve_kernel(name, "sisyphus", budget=budget)
        t.add(name, f"{pro.solver_seconds:.2f}", f"{pro.space_size:.1e}",
              f"{sis.solver_seconds:.2f}", f"{sis.space_size:.1e}",
              "no(TIMEOUT)" if sis.timed_out else "yes")
    return t


if __name__ == "__main__":
    run().show()
