"""Table 10 analogue: NLP solve time — Prometheus decomposition vs the
Sisyphus shared-buffer JOINT formulation.

The paper's story: dataflow decouples tasks, so Prometheus' effective
search is a SUM of per-task spaces; the shared-buffer formulation couples
them into a PRODUCT that times out on 3mm (4 h).  We report wall time,
the raw product-space size, and whether exhaustive coverage was possible
within the budget (the timeout condition).

``--bench-out`` additionally measures the cold-solve path this repo's
serving tier actually pays — and the two mechanisms that take it off the
request path (BENCH_solver.json, gated by ``scripts/bench_compare.py
--solver-fresh``):

* serial vs parallel sweep (``SolverOptions.workers``) on the largest
  benchmarked graph, same seed — the parallel plan must be at least as
  good and arrive materially faster (process pool + cost-model pruning);
* a warm plan-store hit (``repro.store``) — the same solve answered from
  disk with **zero** solver evaluations, in milliseconds;
* engine ``register_function`` cold vs warm against the same store —
  the replica-restart scenario.
"""
from __future__ import annotations

import json
import os
import tempfile
import time

from .common import Table, solve_kernel

KERNELS = ["2mm", "3mm", "atax", "bicg", "gemm", "gesummv", "mvt",
           "symm", "syr2k", "syrk", "trmm"]

#: The largest benchmarked graph (most tasks x biggest per-task space):
#: the kernel the parallel-sweep gate measures.
BENCH_KERNEL = "3mm"


def run(budget: float = 20.0) -> Table:
    t = Table("Table 10 — solver time (s) and joint-space blowup",
              ["kernel", "prometheus_s", "pro_space", "sisyphus_s",
               "sis_space", "sis_covered"])
    for name in KERNELS:
        pro = solve_kernel(name, "prometheus", budget=budget)
        sis = solve_kernel(name, "sisyphus", budget=budget)
        t.add(name, f"{pro.solver_seconds:.2f}", f"{pro.space_size:.1e}",
              f"{sis.solver_seconds:.2f}", f"{sis.space_size:.1e}",
              "no(TIMEOUT)" if sis.timed_out else "yes")
    return t


def _plan_summary(plan) -> dict:
    from repro.core.fingerprint import plan_fingerprint
    return {
        "solver_s": plan.solver_seconds,
        "latency_s": plan.latency_s,
        "n_evaluated": plan.n_evaluated,
        "timed_out": plan.timed_out,
        "plan_fp": plan_fingerprint(plan),
    }


def bench(budget: float = 60.0, workers: int | None = None,
          kernel: str = BENCH_KERNEL) -> dict:
    """The gated benchmark.  Solve order matters: the serial/parallel/warm
    solves run *before* anything imports jax, so the worker pool can use
    fork (cheap workers) exactly as a solver-only replica would."""
    from repro.store import PlanStore

    if workers is None:
        # at least 2 even on a 1-core host: chunked workers still apply
        # the shared-bound pruning the serial sweep cannot
        workers = max(2, (os.cpu_count() or 2) - 1)
    store_dir = tempfile.mkdtemp(prefix="repro-plan-store-bench-")
    st = PlanStore(store_dir)

    serial = solve_kernel(kernel, "prometheus", budget=budget, workers=1,
                          store=None)
    # refresh=True: measure the full parallel solve (no store read) while
    # still seeding the store for the warm measurement below
    parallel = solve_kernel(kernel, "prometheus", budget=budget,
                            workers=workers, store=st, refresh=True)
    t0 = time.monotonic()
    warm = solve_kernel(kernel, "prometheus", budget=budget,
                        workers=workers, store=st)
    warm_s = time.monotonic() - t0

    engine = _bench_engine(store_dir)

    import jax
    result = {
        "benchmark": "solver_parallel_store",
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "cpu_count": os.cpu_count(),
        "kernel": kernel,
        "budget_s": budget,
        "workers": workers,
        "serial": _plan_summary(serial),
        "parallel": _plan_summary(parallel),
        "speedup": round(serial.solver_seconds
                         / max(parallel.solver_seconds, 1e-9), 3),
        "warm": {**_plan_summary(warm), "solver_s": warm_s,
                 "store_hit": warm.store_hit},
        "engine": engine,
        "store": st.stats(),
    }
    return result


def _bench_engine(store_dir: str) -> dict:
    """Replica-restart scenario: ``register_function`` cold (full trace +
    solve, seeding the store) vs warm (same store, trace-record plan
    cache cleared to simulate a fresh process)."""
    import numpy as np
    import jax.numpy as jnp

    from repro.serve.engine import PlanEngine, ServeConfig
    from repro.store import set_default_dir

    def mlp(x, w1, w2):
        return jnp.tanh(x @ w1) @ w2

    rng = np.random.default_rng(0)
    args = (jnp.asarray(rng.normal(size=(8, 64)), jnp.float32),
            jnp.asarray(rng.normal(size=(64, 64)), jnp.float32),
            jnp.asarray(rng.normal(size=(64, 32)), jnp.float32))
    try:
        eng = PlanEngine(sc=ServeConfig(plan_store_dir=store_dir))
        t0 = time.monotonic()
        tf = eng.register_function("mlp", mlp, args)
        cold_s = time.monotonic() - t0
        _, cold_plan = eng._registry["mlp"]
        eng.shutdown()

        tf.record.plan_cache.clear()        # fresh-replica stand-in
        eng2 = PlanEngine(sc=ServeConfig(plan_store_dir=store_dir))
        t0 = time.monotonic()
        eng2.register_function("mlp", mlp, args)
        warm_s = time.monotonic() - t0
        _, warm_plan = eng2._registry["mlp"]
        eng2.shutdown()
    finally:
        set_default_dir(None)
    return {
        "cold_register_s": cold_s,
        "cold_evals": cold_plan.n_evaluated,
        "warm_register_s": warm_s,
        "warm_evals": warm_plan.n_evaluated,
        "warm_store_hit": bool(warm_plan.store_hit),
    }


def emit(path: str, **kw) -> dict:
    result = bench(**kw)
    with open(path, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    return result


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--budget", type=float, default=None,
                    help="solver budget per solve (default: 20 for the "
                         "table, 60 for --bench-out)")
    ap.add_argument("--workers", type=int, default=None,
                    help="parallel sweep width for --bench-out "
                         "(default: max(2, cpu_count - 1))")
    ap.add_argument("--bench-out", default=None,
                    help="emit the parallel-sweep + plan-store benchmark "
                         "(BENCH_solver.json) instead of the table")
    args = ap.parse_args()
    if args.bench_out:
        r = emit(args.bench_out, budget=args.budget or 60.0,
                 workers=args.workers)
        print(json.dumps(r, indent=2))
    else:
        run(budget=args.budget or 20.0).show()
