"""BENCH_obs.json emitter: what observability costs, and that it works.

Three sections, matching the CI gate (``bench_compare.py --obs-fresh``):

* ``overhead`` — steady-state per-call seconds of the ``PlanEngine``
  submit path with observability ON (span tracing enabled + drift
  sampling at its default cadence) vs OFF (tracing disabled, drift
  disabled), sampled in ALTERNATING windows like ``bench_frontend`` so
  host drift cancels out of the ratio.  ``overhead_ratio`` is the median
  of per-window-pair on/off ratios; the gate holds it ≤ 1.03 (3% p50
  budget, retryable — it is a perf number on a shared runner).
* ``drift`` — a deliberately miscalibrated profile: the entry's
  predicted latency is forced absurdly low via the
  ``note_predicted_latency`` seam, so the observed EMA must cross the
  ratio threshold, fire a drift trigger, and drive the existing
  background re-solve + plan-store refresh path to completion.
  Correctness-tagged in the gate: drift that cannot fire means the
  feedback loop is dead.
* ``export`` — the Prometheus text exposition and the Chrome-trace
  export both validate structurally (every sample line parses, ``le``
  buckets are cumulative and end at ``+Inf == _count``, every trace
  event is a complete event with µs timestamps).  Also
  correctness-tagged.

Usage:
    PYTHONPATH=src python -m benchmarks.bench_obs --out BENCH_obs.json
"""
from __future__ import annotations

import argparse
import json
import time


# ---------------------------------------------------------------------------
# Export validators (shared with scripts/obs_dump.py)
# ---------------------------------------------------------------------------
def validate_exposition(text: str) -> list[str]:
    """Structural check of Prometheus text-format output; [] when valid."""
    problems: list[str] = []
    typed: dict[str, str] = {}
    samples: dict[str, float] = {}
    for ln in text.strip().split("\n"):
        if not ln:
            problems.append("blank line in exposition")
            continue
        if ln.startswith("# TYPE "):
            _, _, rest = ln.partition("# TYPE ")
            name, _, kind = rest.partition(" ")
            if kind not in ("counter", "gauge", "histogram", "untyped"):
                problems.append(f"unknown TYPE {kind!r} for {name}")
            typed[name] = kind
            continue
        if ln.startswith("#"):
            continue
        try:
            key, value = ln.rsplit(" ", 1)
            samples[key] = float(value)
        except ValueError:
            problems.append(f"unparseable sample line {ln!r}")
            continue
    if not typed:
        problems.append("no TYPE lines")
    if not samples:
        problems.append("no sample lines")
    for name, kind in typed.items():
        if kind != "histogram":
            continue
        # cumulative buckets: the +Inf bucket must equal _count
        for key, v in samples.items():
            if key.startswith(f"{name}_bucket") and 'le="+Inf"' in key:
                count_key = _strip_le(key, name)
                if samples.get(count_key) != v:
                    problems.append(
                        f"{name}: +Inf bucket {v} != _count "
                        f"{samples.get(count_key)}")
    return problems


def _strip_le(bucket_key: str, name: str) -> str:
    """``name_bucket{a="b",le="+Inf"}`` -> the matching ``name_count`` key."""
    labels = bucket_key[len(name) + len("_bucket"):]
    if labels.startswith("{"):
        pairs = [p for p in labels[1:-1].split(",")
                 if not p.startswith("le=")]
        labels = "{" + ",".join(pairs) + "}" if pairs else ""
    return f"{name}_count{labels}"


def validate_chrome_trace(doc: dict) -> list[str]:
    """Structural check of a Chrome-trace JSON object; [] when valid."""
    problems: list[str] = []
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        return ["traceEvents missing or empty"]
    for ev in events:
        if ev.get("ph") != "X":
            problems.append(f"non-complete event ph={ev.get('ph')!r}")
        for field in ("name", "cat", "ts", "dur", "pid", "tid"):
            if field not in ev:
                problems.append(f"event missing {field!r}: {ev}")
                break
        if ev.get("ts", -1) < 0 or ev.get("dur", -1) < 0:
            problems.append(f"negative ts/dur: {ev}")
    return problems


# ---------------------------------------------------------------------------
# Sections
# ---------------------------------------------------------------------------
def _workload(seed: int = 0):
    import jax.numpy as jnp
    import numpy as np

    rng = np.random.default_rng(seed)
    w1 = jnp.asarray(rng.standard_normal((128, 256)).astype(np.float32) * .05)
    w2 = jnp.asarray(rng.standard_normal((256, 128)).astype(np.float32) * .05)
    x = jnp.asarray(rng.standard_normal((32, 128)).astype(np.float32))

    def mlp(v, a, b):
        return jnp.maximum(v @ a, 0.0) @ b

    return mlp, (x, w1, w2)


def bench_overhead(*, budget: float, batch: int, samples: int,
                   seed: int) -> dict:
    import jax

    from repro.core.solver import SolverOptions
    from repro.obs import DriftConfig
    from repro.obs import configure as configure_tracing
    from repro.obs import tracer
    from repro.serve import PlanEngine, ServeConfig

    fn, args = _workload(seed)
    opts = SolverOptions(time_budget_s=budget)
    # One engine, one compiled program.  Drift sampling is disabled: a
    # drift-sampled call syncs the device to measure wall time — a
    # by-design 1-in-16 cost priced by the ``drift`` section, not
    # hot-path overhead.  This section prices exactly what the gate
    # budgets — span tracing + the registry-backed counters, on vs off —
    # by alternating the tracer toggle CALL BY CALL, so host-contention
    # drift hits adjacent off/on calls alike and the median per-pair
    # ratio isolates the obs cost from runner noise.
    eng = PlanEngine(sc=ServeConfig(drift=DriftConfig(enabled=False)))
    assert eng.register_function("w", fn, args, solver_opts=opts)

    def timed_submit(enabled: bool) -> float:
        configure_tracing(enabled=enabled)
        t0 = time.perf_counter()
        out = eng.submit("w", args)
        dt = time.perf_counter() - t0
        jax.block_until_ready(list(out.values()) if isinstance(out, dict)
                              else out)
        return dt

    n = batch * samples
    for _ in range(20):                 # compile + warm both toggles
        timed_submit(False)
        timed_submit(True)
    off_t: list[float] = []
    on_t: list[float] = []
    for _ in range(n):
        off_t.append(timed_submit(False))
        on_t.append(timed_submit(True))
    configure_tracing(enabled=False)
    pair_ratios = sorted(o / f for o, f in zip(on_t, off_t))
    ratio = pair_ratios[len(pair_ratios) // 2]
    spans = tracer().stats()
    eng.shutdown()
    off_s, on_s = sorted(off_t), sorted(on_t)
    return {
        "off_p50_s": off_s[len(off_s) // 2],
        "on_p50_s": on_s[len(on_s) // 2],
        "overhead_ratio": round(ratio, 4),
        "pair_ratio_p10": round(pair_ratios[len(pair_ratios) // 10], 4),
        "pair_ratio_p90": round(pair_ratios[9 * len(pair_ratios) // 10], 4),
        "pairs": n,
        "spans_recorded": spans["recorded"],
    }


def bench_drift(*, budget: float, seed: int, timeout_s: float = 120.0) -> dict:
    from repro.core.solver import SolverOptions
    from repro.obs import DriftConfig
    from repro.serve import PlanEngine, ServeConfig

    fn, args = _workload(seed)
    sc = ServeConfig(drift=DriftConfig(sample_every=1, min_samples=3,
                                       ratio_threshold=2.0,
                                       cooldown_s=3600.0))
    eng = PlanEngine(sc=sc)
    assert eng.register_function(
        "w", fn, args, solver_opts=SolverOptions(time_budget_s=budget))
    predicted = eng.stats()["drift"]["entries"]["w"]["predicted_s"]
    # the deliberately miscalibrated profile: a prediction no real
    # dispatch can meet, so the observed EMA must cross the band
    eng.note_predicted_latency("w", 1e-12)
    for _ in range(8):
        eng.submit("w", args)
    st = eng.stats()["drift"]
    triggered = st["triggers"] >= 1
    # snapshot the entry BEFORE the background refresh lands: a completed
    # refresh re-notes the fresh plan's prediction, resetting the EMA
    entry = st["entries"]["w"]
    refreshed = False
    deadline = time.monotonic() + timeout_s
    while time.monotonic() < deadline:
        if eng.plan_refreshes >= 1:
            refreshed = True
            break
        time.sleep(0.05)
    invariant_failures = eng.check_invariants()
    eng.shutdown()
    return {
        "solver_predicted_s": predicted,
        "seeded_predicted_s": 1e-12,
        "observed_ema_s": entry["observed_ema_s"],
        "ratio": entry["ratio"],
        "triggered": triggered,
        "refresh_completed": refreshed,
        "triggers": st["triggers"],
        "invariant_failures": invariant_failures,
    }


def bench_export(*, budget: float, seed: int) -> dict:
    from repro.core.solver import SolverOptions
    from repro.obs import chrome_trace
    from repro.obs import configure as configure_tracing
    from repro.obs import tracer
    from repro.serve import PlanEngine, ServeConfig

    fn, args = _workload(seed)
    tracer().clear()
    configure_tracing(enabled=True)
    try:
        eng = PlanEngine(sc=ServeConfig())
        assert eng.register_function(
            "w", fn, args, solver_opts=SolverOptions(time_budget_s=budget))
        for _ in range(4):
            eng.submit("w", args)
        spans = tracer().snapshot()
        doc = json.loads(json.dumps(chrome_trace(spans)))
        trace_problems = validate_chrome_trace(doc)
        text = eng.metrics.expose()
        expo_problems = validate_exposition(text)
        cats = sorted({f"{s.cat}/{s.name.split('/')[0]}" for s in spans})
        eng.shutdown()
    finally:
        configure_tracing(enabled=False)
    return {
        "n_spans": len(spans),
        "span_categories": cats,
        "trace_valid": not trace_problems,
        "trace_problems": trace_problems,
        "exposition_valid": not expo_problems,
        "exposition_problems": expo_problems,
        "exposition_lines": len(text.strip().split("\n")),
    }


def bench(*, budget: float = 2.0, batch: int = 30, samples: int = 9,
          seed: int = 0) -> dict:
    import jax
    return {
        "benchmark": "obs",
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "overhead": bench_overhead(budget=budget, batch=batch,
                                   samples=samples, seed=seed),
        "drift": bench_drift(budget=budget, seed=seed),
        "export": bench_export(budget=budget, seed=seed),
    }


def emit(path: str, **kw) -> dict:
    result = bench(**kw)
    with open(path, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    return result


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--budget", type=float, default=2.0)
    ap.add_argument("--batch", type=int, default=30)
    ap.add_argument("--samples", type=int, default=9)
    ap.add_argument("--out", default="BENCH_obs.json")
    args = ap.parse_args()
    result = emit(args.out, budget=args.budget, batch=args.batch,
                  samples=args.samples)
    ov, dr, ex = result["overhead"], result["drift"], result["export"]
    print(f"overhead: off={ov['off_p50_s'] * 1e6:8.1f}us "
          f"on={ov['on_p50_s'] * 1e6:8.1f}us "
          f"ratio={ov['overhead_ratio']:.4f} "
          f"(spans recorded: {ov['spans_recorded']})")
    print(f"drift:    triggered={dr['triggered']} "
          f"refresh_completed={dr['refresh_completed']} "
          f"ratio={dr['ratio'] or 0:.3g}")
    print(f"export:   spans={ex['n_spans']} "
          f"trace_valid={ex['trace_valid']} "
          f"exposition_valid={ex['exposition_valid']} "
          f"({ex['exposition_lines']} lines)")
    print(f"-> {args.out}")


if __name__ == "__main__":
    main()
