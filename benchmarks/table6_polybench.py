"""Table 6 analogue: PolyBench throughput (GF/s) across solver modes.

The paper's RTL-sim comparison (Prometheus vs Sisyphus vs ScaleHLS vs Allo
vs AutoDSE vs Stream-HLS) becomes: the SAME NLP engine restricted to each
framework's design space (solver modes, Table 1 feature matrix).  Datasets
are TPU-scaled (DESIGN.md §2: restores the paper's arithmetic-intensity
regime); the medium-size (paper-exact) numbers are reported by --medium.

Expected qualitative reproduction:
  prometheus >= sisyphus > {streamhls, autodse} on compute-bound kernels;
  the gap collapses on memory-bound kernels (atax/bicg/mvt...).
"""
from __future__ import annotations

from .common import MODES, Table, build_graph, measure_plan, solve_kernel

KERNELS = ["2mm", "3mm", "atax", "bicg", "gemm", "gesummv", "mvt",
           "symm", "syr2k", "syrk", "trmm"]


def run(scale: int | None = None, budget: float = 12.0,
        measure: bool = False, kernels: list[str] | None = None,
        bench_out: str | None = None) -> Table:
    from repro.core.polybench import TPU_SCALE
    scale = scale or TPU_SCALE
    kernels = kernels or KERNELS
    header = ["kernel"] + list(MODES) + ["PI_vs_sisyphus"]
    if measure:
        header += ["measured_GF/s", "measured_ok"]
    t = Table(f"Table 6 — PolyBench GF/s by solver mode (scale x{scale})",
              header)
    gmean_ratio = []
    prometheus_plans = {}
    for name in kernels:
        row = [name]
        gf = {}
        plans = {}
        for mode in MODES:
            plan = solve_kernel(name, mode, scale=scale, budget=budget)
            gf[mode] = plan.gflops
            plans[mode] = plan
            row.append(f"{plan.gflops:.1f}")
        pi = gf["prometheus"] / max(gf["sisyphus"], 1e-9)
        gmean_ratio.append(pi)
        row.append(f"{pi:.2f}x")
        prometheus_plans[name] = plans["prometheus"]
        if measure:
            # Wall-clock execution of the prometheus plan through the
            # whole-plan compiled program — the "real hardware" counterpart
            # of the model prediction.
            try:
                _, mgf, ok = measure_plan(name, plans["prometheus"],
                                          graph=build_graph(name, scale),
                                          scale=scale,
                                          validate=(scale == 1))
                row += [f"{mgf:.1f}", str(ok) if scale == 1 else "-"]
            except NotImplementedError:
                row += ["-", "-"]       # triangular-density: model-only
        t.add(*row)
    g = 1.0
    for r in gmean_ratio:
        g *= r
    g **= 1.0 / len(gmean_ratio)
    t.add("gmean_PI", "", "", "", "", f"{g:.2f}x")
    if bench_out:
        # Steady-state program-vs-per-task dispatch benchmark on the same
        # prometheus plans (no re-solving) -> BENCH_codegen.json
        from .bench_codegen import emit
        emit(bench_out, kernels=tuple(kernels), scale=scale, budget=budget,
             plans=prometheus_plans)
    return t


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--medium", action="store_true",
                    help="paper-exact medium sizes (scale=1)")
    ap.add_argument("--measure", action="store_true",
                    help="also execute the prometheus plan and report "
                         "measured GF/s (use with --medium on CPU)")
    ap.add_argument("--budget", type=float, default=12.0)
    ap.add_argument("--kernels", nargs="+", default=None,
                    help="kernel subset (default: all 11)")
    ap.add_argument("--bench-out", default=None,
                    help="also emit the steady-state dispatch benchmark "
                         "(BENCH_codegen.json) for the measured kernels")
    args = ap.parse_args()
    run(scale=1 if args.medium else None, budget=args.budget,
        measure=args.measure, kernels=args.kernels,
        bench_out=args.bench_out).show()
