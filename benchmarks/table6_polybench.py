"""Table 6 analogue: PolyBench throughput (GF/s) across solver modes.

The paper's RTL-sim comparison (Prometheus vs Sisyphus vs ScaleHLS vs Allo
vs AutoDSE vs Stream-HLS) becomes: the SAME NLP engine restricted to each
framework's design space (solver modes, Table 1 feature matrix).  Datasets
are TPU-scaled (DESIGN.md §2: restores the paper's arithmetic-intensity
regime); the medium-size (paper-exact) numbers are reported by --medium.

Expected qualitative reproduction:
  prometheus >= sisyphus > {streamhls, autodse} on compute-bound kernels;
  the gap collapses on memory-bound kernels (atax/bicg/mvt...).
"""
from __future__ import annotations

from .common import MODES, Table, solve_kernel

KERNELS = ["2mm", "3mm", "atax", "bicg", "gemm", "gesummv", "mvt",
           "symm", "syr2k", "syrk", "trmm"]


def run(scale: int | None = None, budget: float = 12.0) -> Table:
    from repro.core.polybench import TPU_SCALE
    scale = scale or TPU_SCALE
    t = Table(f"Table 6 — PolyBench GF/s by solver mode (scale x{scale})",
              ["kernel"] + list(MODES) + ["PI_vs_sisyphus"])
    gmean_ratio = []
    for name in KERNELS:
        row = [name]
        gf = {}
        for mode in MODES:
            plan = solve_kernel(name, mode, scale=scale, budget=budget)
            gf[mode] = plan.gflops
            row.append(f"{plan.gflops:.1f}")
        pi = gf["prometheus"] / max(gf["sisyphus"], 1e-9)
        gmean_ratio.append(pi)
        row.append(f"{pi:.2f}x")
        t.add(*row)
    g = 1.0
    for r in gmean_ratio:
        g *= r
    g **= 1.0 / len(gmean_ratio)
    t.add("gmean_PI", "", "", "", "", f"{g:.2f}x")
    return t


if __name__ == "__main__":
    run().show()
