"""Table 7 analogue: performance + resource utilisation, Prometheus vs
Sisyphus-mode.

FPGA resource columns map to the TPU budget terms the NLP constrains:
  DSP%   -> compute occupancy (padded FLOPs over the plan's compute window)
  BRAM%  -> peak VMEM occupancy across tasks (buffers x footprints)
  pad%   -> padded-vs-useful FLOP overhead (padding-for-computation cost)
Double buffering shows up exactly as the paper observes: Prometheus uses
MORE on-chip memory (ping-pong buffers) to buy overlap.
"""
from __future__ import annotations

from repro.core.resources import VMEM_BYTES

from .common import Table, solve_kernel

KERNELS = ["madd", "2-madd", "3-madd", "2mm", "3mm", "gemm", "gemver",
           "mvt"]


def _resources(plan) -> dict:
    vmem_peak = max(r.vmem_bytes for r in plan.reports.values())
    compute_s = sum(r.compute_s for r in plan.reports.values())
    pad = sum(r.padded_flops for r in plan.reports.values()) / \
        max(sum(r.useful_flops for r in plan.reports.values()), 1e-9)
    return {
        "vmem_pct": 100.0 * vmem_peak / VMEM_BYTES,
        "compute_occ_pct": 100.0 * compute_s / max(plan.latency_s, 1e-12)
        / max(len({c.slice_id for c in plan.configs.values()}), 1),
        "pad_overhead_pct": 100.0 * (pad - 1.0),
    }


def run(budget: float = 12.0) -> Table:
    t = Table("Table 7 — resources: Prometheus vs Sisyphus-mode",
              ["kernel",
               "pro_GF/s", "pro_vmem%", "pro_occ%", "pro_pad%",
               "sis_GF/s", "sis_vmem%", "sis_occ%", "sis_pad%"])
    for name in KERNELS:
        pro = solve_kernel(name, "prometheus", budget=budget)
        sis = solve_kernel(name, "sisyphus", budget=budget)
        rp, rs = _resources(pro), _resources(sis)
        t.add(name,
              f"{pro.gflops:.1f}", f"{rp['vmem_pct']:.1f}",
              f"{rp['compute_occ_pct']:.0f}",
              f"{rp['pad_overhead_pct']:.2f}",
              f"{sis.gflops:.1f}", f"{rs['vmem_pct']:.1f}",
              f"{rs['compute_occ_pct']:.0f}",
              f"{rs['pad_overhead_pct']:.2f}")
    return t


if __name__ == "__main__":
    run().show()
