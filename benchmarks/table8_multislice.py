"""Table 8 analogue: on-board 1-slice vs 3-slice evaluation.

The paper's on-board scenario: 60% utilisation per SLR, 1 SLR vs all 3.
Expected reproduction (paper §6.3): compute-bound 2mm/3mm gain from three
slices; memory-bound atax/bicg don't (the DRAM system is shared — the
slice model's bandwidth pool, resources.py).
"""
from __future__ import annotations

from repro.core.resources import ONE_SLICE_60, THREE_SLICE_60

from .common import Table, solve_kernel

KERNELS = ["2mm", "3mm", "atax", "bicg"]


def run(budget: float = 12.0) -> Table:
    t = Table("Table 8 — 1-slice vs 3-slice (60% budget per slice)",
              ["kernel", "1slr_GF/s", "3slr_GF/s", "speedup",
               "3slr_slices_used"])
    for name in KERNELS:
        one = solve_kernel(name, "prometheus", budget=budget,
                           hw=ONE_SLICE_60)
        three = solve_kernel(name, "prometheus", budget=budget,
                             hw=THREE_SLICE_60)
        used = len({c.slice_id for c in three.configs.values()})
        t.add(name, f"{one.gflops:.1f}", f"{three.gflops:.1f}",
              f"{one.latency_s / three.latency_s:.2f}x", used)
    return t


if __name__ == "__main__":
    run().show()
