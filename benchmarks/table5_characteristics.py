"""Table 5 analogue: benchmark kernel characteristics derived from the
task-graph IR — ops, memory footprint, reuse order, inter-task traffic.

Everything is computed from the graphs (not hard-coded), so this doubles
as a structural audit of the PolyBench builders against the paper.
"""
from __future__ import annotations


from repro.core import polybench
from repro.core.fusion import fuse

from .common import Table

KERNELS = ["bicg", "madd", "mvt", "atax", "gesummv", "2-madd", "3-madd",
           "gemver", "2mm", "gemm", "syr2k", "syrk", "trmm", "3mm", "symm"]


def run() -> Table:
    t = Table("Table 5 — kernel characteristics (from the task-graph IR)",
              ["kernel", "flops", "io_bytes", "reuse_order",
               "comm_between_tasks_elems", "n_fused_tasks"])
    for name in KERNELS:
        g = polybench.build(name)
        fg = fuse(g)
        flops = g.total_flops()
        io = g.io_bytes()
        # arithmetic intensity vs problem scale: O(N) reuse iff ai >> 1
        ai = flops / max(io / 4.0, 1)
        reuse = "O(N)" if ai > 8 else "O(1)"
        t.add(name, f"{flops:.3e}", f"{io:.3e}", reuse,
              int(fg.comm_between_tasks_elems()), len(fg.tasks))
    return t


if __name__ == "__main__":
    run().show()
