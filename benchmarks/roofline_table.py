"""Roofline table (deliverable g): per (arch x shape x mesh) three-term
roofline from the dry-run artifacts in experiments/dryrun/.

Reads the JSON the 512-device dry-run wrote; does not itself need fake
devices.  Run ``python -m repro.launch.dryrun --all --mesh both`` first.
"""
from __future__ import annotations

import json
import os

from .common import Table

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN_DIR", "experiments/dryrun")


def load_cells(mesh: str = "single", tag: str = "") -> list[dict]:
    cells = []
    if not os.path.isdir(DRYRUN_DIR):
        return cells
    for fn in sorted(os.listdir(DRYRUN_DIR)):
        if not fn.endswith(f"_{mesh}{('_' + tag) if tag else ''}.json"):
            continue
        with open(os.path.join(DRYRUN_DIR, fn)) as f:
            cells.append(json.load(f))
    return [c for c in cells if not tag or c.get("tag") == tag]


def run(mesh: str = "single") -> Table:
    t = Table(f"Roofline — per (arch x shape), {mesh}-pod mesh "
              f"({256 if mesh == 'single' else 512} chips)",
              ["arch", "shape", "t_compute_s", "t_memory_s",
               "t_collective_s", "bound", "useful_ratio",
               "roofline_fraction"])
    cells = load_cells(mesh)
    if not cells:
        t.add("(no dry-run artifacts found — run "
              "python -m repro.launch.dryrun --all --mesh both)", "",
              "", "", "", "", "", "")
        return t
    for c in cells:
        t.add(c["arch"], c["shape"],
              f"{c['t_compute_s']:.3e}", f"{c['t_memory_s']:.3e}",
              f"{c['t_collective_s']:.3e}", c["bound"],
              f"{c['useful_ratio']:.3f}",
              f"{c['roofline_fraction']:.4f}")
    return t


if __name__ == "__main__":
    run().show()
