"""BENCH_concurrent.json emitter: ``PlanEngine.submit`` under thread load.

The executable pool exists for multi-threaded servers (N callers
round-robin onto N cloned executables), but until now only single-caller
steady state was ever measured (ROADMAP open item).  This benchmark drives
one shared ``PlanEngine`` from ``--threads`` OS threads, each submitting
``--requests`` back-to-back requests (block per request — a request is
done when its outputs are ready), against pool sizes {1, 2, 4}, and
records throughput and p50/p99 latency per pool size — the measured
answer to "does pool > 1 pay, and what should the default be?".

Every pool's section also doubles as a served-under-load correctness
check: the last response is validated against the reference oracle and the
engine/cache counters are checked for lost updates (the thread-safety
stress signal the CI gate reads).

Usage:
    PYTHONPATH=src python -m benchmarks.bench_concurrent \
        --kernel 3-madd --threads 4 --pools 1 2 4 --requests 40 \
        --out BENCH_concurrent.json
"""
from __future__ import annotations

import argparse
import json
import os
import threading
import time

from .common import build_graph, solve_kernel

DEFAULT_POOLS = (1, 2, 4)


def _percentile(sorted_vals: list[float], q: float) -> float:
    if not sorted_vals:
        return 0.0
    idx = min(len(sorted_vals) - 1, int(round(q * (len(sorted_vals) - 1))))
    return sorted_vals[idx]


def _drive(eng, name: str, ins, *, threads: int, requests: int):
    """N threads x M blocking submits against one engine; returns
    (wall_seconds, per-request latencies, worker errors)."""
    import jax

    latencies: list[list[float]] = [[] for _ in range(threads)]
    errors: list[str] = []
    barrier = threading.Barrier(threads + 1)

    def worker(i: int) -> None:
        try:
            barrier.wait()
            for _ in range(requests):
                t0 = time.perf_counter()
                out = eng.submit(name, ins)
                jax.block_until_ready(list(out.values()))
                latencies[i].append(time.perf_counter() - t0)
        except Exception as e:                          # lost update / race
            errors.append(f"thread {i}: {type(e).__name__}: {e}")

    workers = [threading.Thread(target=worker, args=(i,))
               for i in range(threads)]
    for w in workers:
        w.start()
    barrier.wait()
    t0 = time.perf_counter()
    for w in workers:
        w.join()
    wall = time.perf_counter() - t0
    return wall, sorted(lat for per in latencies for lat in per), errors


def bench(kernel: str = "3-madd", *, pool_sizes=DEFAULT_POOLS,
          threads: int = 4, requests: int = 40, scale: int = 1,
          budget: float = 4.0, impl: str = "xla") -> dict:
    """Measure concurrent serving throughput per pool size."""
    import jax

    from repro.codegen import (allclose, cache_stats, clear_program_cache,
                               random_inputs, reference_executor)
    from repro.serve import PlanEngine, ServeConfig

    g = build_graph(kernel, scale)
    plan = solve_kernel(kernel, "prometheus", scale=scale, budget=budget)
    ins = random_inputs(g, seed=0)
    ref = reference_executor(g)(ins)

    pools: dict[str, dict] = {}
    for pool in pool_sizes:
        clear_program_cache()
        eng = PlanEngine(impl=impl, sc=ServeConfig(pool_size=pool))
        eng.register(kernel, g, plan)
        eng.warmup(kernel, ins)                 # warms every pool clone
        warm_requests = eng.requests
        wall, lat, errors = _drive(eng, kernel, ins, threads=threads,
                                   requests=requests)
        out = eng.submit(kernel, ins)           # served-state validation
        ok = all(allclose(out[k], ref[k]) for k in ref)
        stats = eng.stats()
        served = stats["requests"] - warm_requests - 1   # minus validation
        # completed = requests that actually finished (one latency sample
        # each).  lost_updates compares the engine's accounting against
        # COMPLETED work, so a worker dying early (reported via `errors`)
        # is not misdiagnosed as a counter race; throughput likewise only
        # counts completed requests.
        completed = len(lat)
        cs = cache_stats()
        pools[str(pool)] = {
            "pool_size": pool,
            "wall_s": round(wall, 6),
            "throughput_rps": round(completed / wall, 3) if wall else 0.0,
            "p50_ms": round(_percentile(lat, 0.50) * 1e3, 4),
            "p99_ms": round(_percentile(lat, 0.99) * 1e3, 4),
            "completed": completed,
            "served": served,
            "lost_updates": max(completed - served, 0),
            "errors": errors,
            "validated": bool(ok and not errors),
            "cache_misses": cs["misses"],
            "cache_hits": cs["hits"],
        }

    base = pools.get(str(pool_sizes[0]), {}).get("throughput_rps", 0.0)
    for p in pools.values():
        p["scaling_vs_first"] = round(p["throughput_rps"] / base, 4) \
            if base else 0.0
    best = max(pools, key=lambda k: pools[k]["throughput_rps"]) \
        if pools else None
    return {
        "benchmark": "concurrent_serving",
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "cpu_count": os.cpu_count(),
        "impl": impl,
        "kernel": kernel,
        "scale": scale,
        "threads": threads,
        "requests_per_thread": requests,
        "scaling_baseline_pool": str(pool_sizes[0]),
        "pools": pools,
        "best_pool": best,
    }


def emit(path: str, **kw) -> dict:
    result = bench(**kw)
    with open(path, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    return result


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--kernel", default="3-madd")
    ap.add_argument("--pools", type=int, nargs="+",
                    default=list(DEFAULT_POOLS))
    ap.add_argument("--threads", type=int, default=4)
    ap.add_argument("--requests", type=int, default=40,
                    help="requests per thread")
    ap.add_argument("--scale", type=int, default=1)
    ap.add_argument("--budget", type=float, default=4.0)
    ap.add_argument("--impl", default="xla")
    ap.add_argument("--out", default="BENCH_concurrent.json")
    args = ap.parse_args()
    result = emit(args.out, kernel=args.kernel,
                  pool_sizes=tuple(args.pools), threads=args.threads,
                  requests=args.requests, scale=args.scale,
                  budget=args.budget, impl=args.impl)
    for k, p in result["pools"].items():
        print(f"pool={k}: {p['throughput_rps']:8.1f} req/s "
              f"p50={p['p50_ms']:7.2f}ms p99={p['p99_ms']:7.2f}ms "
              f"served={p['served']} lost={p['lost_updates']} "
              f"validated={p['validated']}")
    print(f"best_pool={result['best_pool']} -> {args.out}")


if __name__ == "__main__":
    main()
