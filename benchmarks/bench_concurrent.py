"""BENCH_concurrent.json emitter: ``PlanEngine`` under thread load.

Two experiments share this emitter:

**Closed loop** (the ``pools`` section): the executable pool exists for
multi-threaded servers (N callers round-robin onto N cloned executables).
This part drives one shared ``PlanEngine`` from ``--threads`` OS threads,
each submitting ``--requests`` back-to-back requests (block per request —
a request is done when its outputs are ready), against pool sizes
{1, 2, 4}, and records throughput and p50/p99 latency per pool size — the
measured answer to "does pool > 1 pay, and what should the default be?".
Every pool's section also doubles as a served-under-load correctness
check: the last response is validated against the reference oracle and the
engine/cache counters are checked for lost updates (the thread-safety
stress signal the CI gate reads).

**Open loop** (the ``open_loop`` section): requests arrive on a
deterministic Poisson-like schedule (:func:`arrival_schedule` — seeded
exponential inter-arrival gaps), *independent of completions*, at offered
rates derived from the measured sequential capacity.  Each rate is served
two ways — ``sequential`` (a thread pool of blocking ``submit`` calls: one
dispatch per request) and ``batched`` (``submit_async`` through the
continuous-batching tier: same-entry requests coalesced into power-of-two
buckets, one dispatch per bucket) — and the section records
per-rate throughput, p50/p99 latency (scheduled arrival → result ready),
full request accounting (``ok + fallbacks + expired + rejected + errors
== issued``, the CI gate's correctness invariant) and the
``batched_vs_sequential`` throughput ratio the gate's ``>= 1.2x`` floor
reads at the overload rate.

Usage:
    PYTHONPATH=src python -m benchmarks.bench_concurrent \
        --kernel 3-madd --threads 4 --pools 1 2 4 --requests 40 \
        --open-loop-requests 200 --out BENCH_concurrent.json
"""
from __future__ import annotations

import argparse
import json
import os
import threading
import time
from concurrent.futures import ThreadPoolExecutor

from .common import build_graph, solve_kernel

DEFAULT_POOLS = (1, 2, 4)
#: Offered open-loop rates as multipliers of measured sequential capacity:
#: comfortable (0.8x) and overloaded (2.0x — where coalescing must pay).
DEFAULT_RATE_MULTS = (0.8, 2.0)
#: The rate the CI gate reads the batched/sequential ratio at.
GATE_RATE = "2.0x"


def _percentile(sorted_vals: list[float], q: float) -> float:
    if not sorted_vals:
        return 0.0
    idx = min(len(sorted_vals) - 1, int(round(q * (len(sorted_vals) - 1))))
    return sorted_vals[idx]


def _drive(eng, name: str, ins, *, threads: int, requests: int):
    """N threads x M blocking submits against one engine; returns
    (wall_seconds, per-request latencies, worker errors)."""
    import jax

    latencies: list[list[float]] = [[] for _ in range(threads)]
    errors: list[str] = []
    barrier = threading.Barrier(threads + 1)

    def worker(i: int) -> None:
        try:
            barrier.wait()
            for _ in range(requests):
                t0 = time.perf_counter()
                out = eng.submit(name, ins)
                jax.block_until_ready(list(out.values()))
                latencies[i].append(time.perf_counter() - t0)
        except Exception as e:                          # lost update / race
            errors.append(f"thread {i}: {type(e).__name__}: {e}")

    workers = [threading.Thread(target=worker, args=(i,))
               for i in range(threads)]
    for w in workers:
        w.start()
    barrier.wait()
    t0 = time.perf_counter()
    for w in workers:
        w.join()
    wall = time.perf_counter() - t0
    return wall, sorted(lat for per in latencies for lat in per), errors


def bench(kernel: str = "3-madd", *, pool_sizes=DEFAULT_POOLS,
          threads: int = 4, requests: int = 40, scale: int = 1,
          budget: float = 4.0, impl: str = "xla") -> dict:
    """Measure concurrent serving throughput per pool size."""
    import jax

    from repro.codegen import (allclose, cache_stats, clear_program_cache,
                               random_inputs, reference_executor)
    from repro.serve import PlanEngine, ServeConfig

    g = build_graph(kernel, scale)
    plan = solve_kernel(kernel, "prometheus", scale=scale, budget=budget)
    ins = random_inputs(g, seed=0)
    ref = reference_executor(g)(ins)

    pools: dict[str, dict] = {}
    for pool in pool_sizes:
        clear_program_cache()
        eng = PlanEngine(impl=impl, sc=ServeConfig(pool_size=pool))
        eng.register(kernel, g, plan)
        eng.warmup(kernel, ins)                 # warms every pool clone
        warm_requests = eng.requests
        wall, lat, errors = _drive(eng, kernel, ins, threads=threads,
                                   requests=requests)
        out = eng.submit(kernel, ins)           # served-state validation
        ok = all(allclose(out[k], ref[k]) for k in ref)
        stats = eng.stats()
        served = stats["requests"] - warm_requests - 1   # minus validation
        # completed = requests that actually finished (one latency sample
        # each).  lost_updates compares the engine's accounting against
        # COMPLETED work, so a worker dying early (reported via `errors`)
        # is not misdiagnosed as a counter race; throughput likewise only
        # counts completed requests.
        completed = len(lat)
        cs = cache_stats()
        pools[str(pool)] = {
            "pool_size": pool,
            "wall_s": round(wall, 6),
            "throughput_rps": round(completed / wall, 3) if wall else 0.0,
            "p50_ms": round(_percentile(lat, 0.50) * 1e3, 4),
            "p99_ms": round(_percentile(lat, 0.99) * 1e3, 4),
            "completed": completed,
            "served": served,
            "lost_updates": max(completed - served, 0),
            "errors": errors,
            "validated": bool(ok and not errors),
            "cache_misses": cs["misses"],
            "cache_hits": cs["hits"],
        }

    base = pools.get(str(pool_sizes[0]), {}).get("throughput_rps", 0.0)
    for p in pools.values():
        p["scaling_vs_first"] = round(p["throughput_rps"] / base, 4) \
            if base else 0.0
    best = max(pools, key=lambda k: pools[k]["throughput_rps"]) \
        if pools else None
    return {
        "benchmark": "concurrent_serving",
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "cpu_count": os.cpu_count(),
        "impl": impl,
        "kernel": kernel,
        "scale": scale,
        "threads": threads,
        "requests_per_thread": requests,
        "scaling_baseline_pool": str(pool_sizes[0]),
        "pools": pools,
        "best_pool": best,
    }


# ---------------------------------------------------------------------------
# Open-loop offered-load sweep: batched vs sequential serving
# ---------------------------------------------------------------------------
def arrival_schedule(n: int, rate_rps: float, seed: int = 0):
    """Deterministic Poisson-like arrival offsets: ``n`` cumulative
    exponential inter-arrival gaps at mean rate ``rate_rps``, from a
    seeded generator — the same (n, rate, seed) always yields the same
    schedule, so open-loop runs are reproducible bit-for-bit."""
    import numpy as np

    if n < 0:
        raise ValueError(f"n must be >= 0, got {n}")
    if rate_rps <= 0.0:
        raise ValueError(f"rate_rps must be > 0, got {rate_rps}")
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / rate_rps, size=n))


def _mlp_workload(seed: int = 0, n_inputs: int = 8):
    """The open-loop serving workload: a small residual fan-out network
    (traced as a function entry) plus ``n_inputs`` cycling input batches.

    Each block's input feeds two matmuls — a multi-consumer producer, so
    the compiled plan program splits at those boundaries into several
    segments (several dispatches per request).  Small per-request compute
    with real per-request dispatch/host overhead is exactly the regime
    continuous batching exists for: a coalesced bucket pays the per-flush
    overhead once instead of once per request."""
    import numpy as np
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    ws = [jnp.asarray(rng.standard_normal((128, 128), dtype=np.float32)
                      * 0.05) for _ in range(8)]

    def mlp(x):
        for w_a, w_b in zip(ws[0::2], ws[1::2]):
            x = (x @ w_a) * (x @ w_b) + x
        return x

    xs = [jnp.asarray(rng.standard_normal((16, 128), dtype=np.float32))
          for _ in range(n_inputs)]
    return mlp, xs


def _measure_capacity(eng, name: str, xs, *, threads: int,
                      requests: int) -> float:
    """Closed-loop sequential capacity (requests/s) of the plain blocking
    ``submit`` path — the anchor the offered open-loop rates scale from,
    so the sweep adapts to however fast this runner actually is."""
    import jax

    done = [0] * threads
    barrier = threading.Barrier(threads + 1)

    def worker(i: int) -> None:
        barrier.wait()
        for k in range(requests):
            out = eng.submit(name, (xs[k % len(xs)],))
            jax.block_until_ready(out)
            done[i] += 1

    workers = [threading.Thread(target=worker, args=(i,))
               for i in range(threads)]
    for w in workers:
        w.start()
    barrier.wait()
    t0 = time.perf_counter()
    for w in workers:
        w.join()
    wall = time.perf_counter() - t0
    return sum(done) / max(wall, 1e-9)


def _open_loop_drive(eng, name: str, xs, schedule, *, mode: str,
                     threads: int, deadline_s: float) -> dict:
    """Issue one request per schedule offset (sleeping to the schedule,
    never waiting on completions) and account for every one of them.

    ``sequential`` serves each request with a blocking ``submit`` on a
    ``threads``-wide pool (one dispatch per request); ``batched`` enqueues
    through ``submit_async`` (the continuous-batching tier).  Latency is
    scheduled arrival -> result ready, so driver lateness and queueing
    both count against the server — the open-loop contract.
    """
    import jax

    from repro.ft import DeadlineExceeded, EngineOverloaded

    lock = threading.Lock()
    counts = {"ok": 0, "fallbacks": 0, "expired": 0, "rejected": 0,
              "errors": 0}
    latencies: list[float] = []
    done_at: list[float] = []

    def record(kind: str, sched: float) -> None:
        with lock:
            counts[kind] += 1
            if kind in ("ok", "fallbacks"):
                now = time.perf_counter()
                latencies.append(now - sched)
                done_at.append(now)

    def run_blocking(i: int, sched: float) -> None:
        info: dict = {}
        try:
            out = eng.submit(name, (xs[i % len(xs)],),
                             deadline_s=deadline_s, _info=info)
            jax.block_until_ready(out)
        except DeadlineExceeded:
            record("expired", sched)
        except EngineOverloaded:
            record("rejected", sched)
        except Exception:
            record("errors", sched)
        else:
            record("fallbacks" if info.get("path") == "fallback"
                   else "ok", sched)

    def on_done(sched: float):
        # done-callback, runs on the batcher thread the instant the future
        # resolves: stamping here (instead of a pool of waiter threads
        # each blocking per request) keeps the measurement machinery off
        # the GIL during the run.  Stamps are future-resolution times;
        # device completion is synced in bulk below, so throughput error
        # is bounded by one flush's device time.
        def cb(fut) -> None:
            now = time.perf_counter()
            try:
                fut.result()
            except DeadlineExceeded:
                record("expired", sched)
            except Exception:
                record("errors", sched)
            else:
                with lock:
                    counts["ok"] += 1    # ok/fallback split refined below
                    latencies.append(now - sched)
                    done_at.append(now)
        return cb

    workers = ThreadPoolExecutor(max_workers=max(threads, 1)) \
        if mode == "sequential" else None
    pending = []
    max_late = 0.0
    t0 = time.perf_counter()
    for i, offset in enumerate(schedule):
        target = t0 + float(offset)
        delay = target - time.perf_counter()
        if delay > 0:
            time.sleep(delay)
        max_late = max(max_late, time.perf_counter() - target)
        if mode == "sequential":
            pending.append(workers.submit(run_blocking, i, target))
        else:
            try:
                fut = eng.submit_async(name, (xs[i % len(xs)],),
                                       deadline_s=deadline_s)
            except EngineOverloaded:
                record("rejected", target)
            else:
                fut.add_done_callback(on_done(target))
                pending.append(fut)
    outs = []
    for p in pending:
        try:
            outs.append(p.result())
        except Exception:
            pass                        # already counted by the callback
    jax.block_until_ready(outs)
    if workers is not None:
        workers.shutdown()
    issued = len(schedule)
    if mode == "batched":
        # the batcher's own accounting knows which completed requests were
        # served by the optimized vs the plain-jit path; totals must agree
        # with what the driver observed
        bs = eng.batcher().stats()
        counts["ok"] = bs["ok"]
        counts["fallbacks"] = bs["fallbacks"]
    lat = sorted(latencies)
    span = (max(done_at) - t0) if done_at else 0.0
    completed = counts["ok"] + counts["fallbacks"]
    return {
        "mode": mode,
        "issued": issued,
        "throughput_rps": round(completed / span, 3) if span else 0.0,
        "p50_ms": round(_percentile(lat, 0.50) * 1e3, 4),
        "p99_ms": round(_percentile(lat, 0.99) * 1e3, 4),
        "max_driver_lateness_ms": round(max_late * 1e3, 4),
        **counts,
    }


def bench_open_loop(*, requests: int = 200, threads: int = 4,
                    max_batch: int = 16, max_wait_ms: float = 2.0,
                    deadline_ms: float = 2000.0, seed: int = 0,
                    budget: float = 3.0,
                    rate_mults=DEFAULT_RATE_MULTS) -> dict:
    """The ``open_loop`` section: offered-load sweep of batched vs
    sequential serving of one traced workload, plus full accounting."""
    import numpy as np
    import jax

    from repro.codegen import clear_program_cache
    from repro.core.solver import SolverOptions
    from repro.serve import BatchConfig, PlanEngine, ServeConfig

    fn, xs = _mlp_workload(seed)
    oracle = jax.jit(fn)
    opts = SolverOptions(time_budget_s=budget)
    deadline_s = deadline_ms / 1e3

    def validate(eng) -> bool:
        out = eng.submit("mlp", (xs[0],))
        return bool(np.allclose(np.asarray(out),
                                np.asarray(oracle(xs[0])),
                                rtol=2e-4, atol=1e-5))

    # -- anchor: closed-loop sequential capacity on a plain engine --------
    clear_program_cache()
    seq_probe = PlanEngine(sc=ServeConfig())
    seq_probe.register_function("mlp", fn, (xs[0],), solver_opts=opts)
    seq_probe.warmup("mlp", (xs[0],))
    capacity = _measure_capacity(seq_probe, "mlp", xs, threads=threads,
                                 requests=max(8, requests // (4 * threads)))
    seq_probe.shutdown()

    rates: dict[str, dict] = {}
    for mult in rate_mults:
        rate = capacity * mult
        schedule = arrival_schedule(requests, rate, seed)
        per_rate: dict[str, object] = {
            "offered_rps": round(rate, 3),
            "rate_multiplier": mult,
        }
        for mode in ("sequential", "batched"):
            clear_program_cache()
            cfg = ServeConfig()
            if mode == "batched":
                cfg = ServeConfig(batching=BatchConfig(
                    max_batch=max_batch, max_wait_s=max_wait_ms / 1e3))
            eng = PlanEngine(sc=cfg)
            eng.register_function("mlp", fn, (xs[0],), solver_opts=opts)
            eng.warmup("mlp", (xs[0],))
            if mode == "batched":
                eng.batcher().warmup("mlp")
            res = _open_loop_drive(eng, "mlp", xs, schedule, mode=mode,
                                   threads=threads, deadline_s=deadline_s)
            res["validated"] = validate(eng)
            if mode == "batched":
                bs = eng.batcher().stats()
                res["batch_failures"] = bs["batch_failures"]
                res["flushes"] = sum(
                    b["flushes"] for b in bs["buckets"].values())
                occ = [b["occupancy"] * b["flushes"]
                       for b in bs["buckets"].values()]
                res["bucket_occupancy"] = round(
                    sum(occ) / max(res["flushes"], 1), 4)
            eng.shutdown()
            per_rate[mode] = res
        seq_rps = per_rate["sequential"]["throughput_rps"]
        bat_rps = per_rate["batched"]["throughput_rps"]
        per_rate["batched_vs_sequential"] = \
            round(bat_rps / seq_rps, 4) if seq_rps else 0.0
        rates[f"{mult:.1f}x"] = per_rate
    return {
        "seed": seed,
        "requests": requests,
        "threads": threads,
        "max_batch": max_batch,
        "max_wait_ms": max_wait_ms,
        "deadline_ms": deadline_ms,
        "capacity_rps": round(capacity, 3),
        "gate_rate": GATE_RATE,
        "rates": rates,
    }


def emit(path: str, *, open_loop_requests: int = 0, max_batch: int = 16,
         max_wait_ms: float = 2.0, deadline_ms: float = 2000.0,
         seed: int = 0, **kw) -> dict:
    if kw.get("pool_sizes"):
        result = bench(**kw)
    else:                       # open-loop-only run (e.g. the CI gate job)
        import jax

        result = {
            "benchmark": "concurrent_serving",
            "jax": jax.__version__,
            "backend": jax.default_backend(),
            "cpu_count": os.cpu_count(),
            "pools": {},
        }
    if open_loop_requests:
        result["open_loop"] = bench_open_loop(
            requests=open_loop_requests,
            threads=kw.get("threads", 4),
            max_batch=max_batch, max_wait_ms=max_wait_ms,
            deadline_ms=deadline_ms, seed=seed,
            budget=kw.get("budget", 3.0))
    with open(path, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    return result


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--kernel", default="3-madd")
    ap.add_argument("--pools", type=int, nargs="*",
                    default=list(DEFAULT_POOLS),
                    help="closed-loop pool sizes (empty = skip)")
    ap.add_argument("--threads", type=int, default=4)
    ap.add_argument("--requests", type=int, default=40,
                    help="requests per thread (closed loop)")
    ap.add_argument("--scale", type=int, default=1)
    ap.add_argument("--budget", type=float, default=4.0)
    ap.add_argument("--impl", default="xla")
    ap.add_argument("--open-loop-requests", type=int, default=0,
                    help="open-loop sweep request count (0 = skip)")
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    ap.add_argument("--deadline-ms", type=float, default=2000.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_concurrent.json")
    args = ap.parse_args()
    result = emit(args.out, kernel=args.kernel,
                  pool_sizes=tuple(args.pools), threads=args.threads,
                  requests=args.requests, scale=args.scale,
                  budget=args.budget, impl=args.impl,
                  open_loop_requests=args.open_loop_requests,
                  max_batch=args.max_batch, max_wait_ms=args.max_wait_ms,
                  deadline_ms=args.deadline_ms, seed=args.seed)
    for k, p in result["pools"].items():
        print(f"pool={k}: {p['throughput_rps']:8.1f} req/s "
              f"p50={p['p50_ms']:7.2f}ms p99={p['p99_ms']:7.2f}ms "
              f"served={p['served']} lost={p['lost_updates']} "
              f"validated={p['validated']}")
    if result["pools"]:
        print(f"best_pool={result['best_pool']}")
    ol = result.get("open_loop")
    if ol:
        print(f"open loop: capacity={ol['capacity_rps']:.1f} req/s "
              f"(gate rate {ol['gate_rate']})")
        for rk, r in ol["rates"].items():
            s, b = r["sequential"], r["batched"]
            print(f"  rate={rk} offered={r['offered_rps']:7.1f}: "
                  f"seq={s['throughput_rps']:7.1f} "
                  f"bat={b['throughput_rps']:7.1f} req/s "
                  f"ratio={r['batched_vs_sequential']:.2f} "
                  f"bat_p99={b['p99_ms']:.1f}ms "
                  f"occ={b.get('bucket_occupancy', 0):.2f}")
    print(f"-> {args.out}")


if __name__ == "__main__":
    main()
