"""BENCH_chaos.json emitter: availability and p99 under injected faults.

The resilient request path (``repro.serve.PlanEngine`` + ``repro.ft``)
claims that a broken optimized path never becomes a wrong or dropped
answer: failures degrade to the plain-jit fallback, miscompiles are caught
by canary validation, quarantined entries re-solve in the background, and
corrupted persistent artifacts are discarded and regenerated.  This
benchmark *measures* that claim.  Two scenarios drive the same engine with
the same thread load:

* ``clean``   — no injected faults (the baseline request path);
* ``faulted`` — a :class:`repro.ft.ChaosPlan` injects a compile failure,
  runtime execute failures and a silent miscompile (NaN-corrupted kernel
  outputs, caught only by the per-request canary) mid-run, plus a
  corrupted persistent calibration artifact exercised through the real
  load/quarantine/regenerate path.

Every response in both scenarios is validated against the reference
oracle; **availability** is the fraction of submits that returned a
correct answer (an exception or a wrong value both count against it).
The CI gate (``scripts/bench_compare.py --chaos-fresh``) holds the
faulted scenario to availability >= 0.99 with the breaker closed again
after background re-solve.

Usage:
    PYTHONPATH=src python -m benchmarks.bench_chaos \
        --kernel 3-madd --threads 2 --requests 30 --out BENCH_chaos.json
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
import threading
import time

from .common import build_graph, solve_kernel


def _percentile(sorted_vals: list[float], q: float) -> float:
    if not sorted_vals:
        return 0.0
    idx = min(len(sorted_vals) - 1, int(round(q * (len(sorted_vals) - 1))))
    return sorted_vals[idx]


def _chaos_plan(name: str):
    from repro.ft import ChaosPlan
    return ChaosPlan(
        compile_fail_at=(1,),           # re-resolve blows up mid-run
        execute_fail_at=(3, 7),         # runtime faults (device-loss-ish)
        corrupt_at=(5,),                # silent miscompile: NaN outputs
        only=name,
    )


def _artifact_round_trip() -> dict:
    """Corrupt a persistent calibration profile on disk and prove the
    loader quarantines + regenerates instead of crashing (fault 3)."""
    from repro.calibrate import CalibratedHardware, cached_profile
    from repro.ft import ChaosPlan
    profile = CalibratedHardware(
        backend="bench", n_devices=1, cpu_count=os.cpu_count() or 1,
        dispatch_s=5e-5, ici_bw=8e9, hbm_bw=12e9,
        hbm_share=(1.0, 0.7, 0.55),
        gflops={"small": 20.0, "medium": 40.0, "large": 60.0})
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "bench-profile.json")
        profile.save(path)
        ChaosPlan.corrupt_file(path)
        survived = cached_profile(path=path) is None    # no crash, no lie
        quarantined = os.path.exists(path + ".corrupt")
        profile.save(path)                              # regenerate
        regenerated = cached_profile(path=path) is not None
    return {"survived_corrupt_load": bool(survived),
            "quarantined": bool(quarantined),
            "regenerated": bool(regenerated)}


def _drive(eng, name: str, ins, ref, *, threads: int, requests: int):
    """N threads x M blocking submits, validating EVERY response; returns
    (latencies, correct_count, error_strings)."""
    import jax

    from repro.codegen import allclose

    latencies: list[list[float]] = [[] for _ in range(threads)]
    correct = [0] * threads
    errors: list[str] = []
    barrier = threading.Barrier(threads)

    def worker(i: int) -> None:
        barrier.wait()
        for _ in range(requests):
            t0 = time.perf_counter()
            try:
                out = eng.submit(name, ins)
                jax.block_until_ready(list(out.values()))
            except Exception as e:          # dropped request: unavailable
                errors.append(f"thread {i}: {type(e).__name__}: {e}")
                continue
            latencies[i].append(time.perf_counter() - t0)
            if all(allclose(out[k], ref[k]) for k in ref):
                correct[i] += 1
            else:                           # wrong answer: worse than none
                errors.append(f"thread {i}: response failed validation")

    workers = [threading.Thread(target=worker, args=(i,))
               for i in range(threads)]
    for w in workers:
        w.start()
    for w in workers:
        w.join()
    return (sorted(t for per in latencies for t in per), sum(correct),
            errors)


def bench(kernel: str = "3-madd", *, threads: int = 2, requests: int = 30,
          scale: int = 1, budget: float = 4.0, impl: str = "xla") -> dict:
    """Measure serving availability/latency with and without chaos."""
    import jax

    from repro.codegen import (clear_program_cache, random_inputs,
                               reference_executor)
    from repro.serve import PlanEngine, ServeConfig

    g = build_graph(kernel, scale)
    plan = solve_kernel(kernel, "prometheus", scale=scale, budget=budget)
    ins = random_inputs(g, seed=0)
    ref = reference_executor(g)(ins)

    scenarios: dict[str, dict] = {}
    for label in ("clean", "faulted"):
        clear_program_cache()
        chaos = _chaos_plan(kernel) if label == "faulted" else None
        eng = PlanEngine(impl=impl, sc=ServeConfig(
            pool_size=2, chaos=chaos,
            canary_every=1, nan_guard="canary",     # catch miscompiles
            breaker_threshold=2, breaker_reset_s=1e9,
            resolve_backoff_s=0.05, resolve_backoff_mult=2.0,
            resolve_max_retries=6))
        eng.register(kernel, g, plan)
        eng.warmup(kernel, ins)
        lat, correct, errors = _drive(eng, kernel, ins, ref,
                                      threads=threads, requests=requests)
        total = threads * requests
        health = eng._health_for(kernel)
        recovered = True
        if health.breaker.stats()["state"] != "closed":
            # injected faults opened the breaker: wait for the background
            # re-solve to close it (bounded by the backoff schedule)
            recovered = health.recovered_event.wait(120.0)
        s = eng.stats()
        h = s["resilience"]["entries"].get(kernel, {})
        scenarios[label] = {
            "requests": total,
            "correct": correct,
            "availability": round(correct / max(1, total), 4),
            "p50_ms": round(_percentile(lat, 0.50) * 1e3, 4),
            "p99_ms": round(_percentile(lat, 0.99) * 1e3, 4),
            "errors": errors[:10],
            "injected": sorted(chaos.events) if chaos else [],
            "failures": h.get("failures", 0),
            "fallbacks": h.get("fallbacks", 0),
            "canaries": h.get("canaries", 0),
            "recovered": h.get("recovered", 0),
            "breaker_closed_after_recovery": bool(
                recovered
                and eng._health_for(kernel).breaker.stats()["state"]
                == "closed"),
            "final_state": eng.stats()["resilience"]["entries"]
                           [kernel]["state"],
        }
        eng.shutdown()

    clean, faulted = scenarios["clean"], scenarios["faulted"]
    return {
        "benchmark": "chaos_serving",
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "cpu_count": os.cpu_count(),
        "impl": impl,
        "kernel": kernel,
        "scale": scale,
        "threads": threads,
        "requests_per_thread": requests,
        "scenarios": scenarios,
        "artifact_recovery": _artifact_round_trip(),
        "p99_ratio_faulted_vs_clean": round(
            faulted["p99_ms"] / clean["p99_ms"], 4)
        if clean["p99_ms"] else 0.0,
    }


def emit(path: str, **kw) -> dict:
    result = bench(**kw)
    with open(path, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    return result


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--kernel", default="3-madd")
    ap.add_argument("--threads", type=int, default=2)
    ap.add_argument("--requests", type=int, default=30,
                    help="requests per thread")
    ap.add_argument("--scale", type=int, default=1)
    ap.add_argument("--budget", type=float, default=4.0)
    ap.add_argument("--impl", default="xla")
    ap.add_argument("--out", default="BENCH_chaos.json")
    args = ap.parse_args()
    result = emit(args.out, kernel=args.kernel, threads=args.threads,
                  requests=args.requests, scale=args.scale,
                  budget=args.budget, impl=args.impl)
    for label, s in result["scenarios"].items():
        print(f"{label:8s}: availability={s['availability']:.4f} "
              f"p50={s['p50_ms']:7.2f}ms p99={s['p99_ms']:7.2f}ms "
              f"failures={s['failures']} fallbacks={s['fallbacks']} "
              f"state={s['final_state']}")
    print(f"artifact_recovery={result['artifact_recovery']} "
          f"-> {args.out}")


if __name__ == "__main__":
    main()
