"""BENCH_codegen.json emitter: steady-state wall-clock of the plan engines.

Measures repeated execution of solved plans through BOTH executor modes —
the whole-plan compiled program (segmented ``jax.jit`` programs resolved
through the serving cache/pool) and the per-task host-dispatch debug path —
and records the dispatch-overhead speedup per kernel.  This is the
perf-trajectory datapoint the model predictions in Table 6 never provided:
actual wall-clock on this host, and the series the CI bench gate
(`scripts/bench_compare.py`) regresses against.

Methodology: each sample times a *batch* of back-to-back calls (steady-state
serving behaviour — async dispatch pipelines inside a batch, one block at
the end) and the metric is the best per-call time across samples.  The two
modes' samples are taken ALTERNATELY (per_task batch, program batch,
per_task batch, ...), so slow drift on a contended host — CPU frequency,
noisy neighbours — hits both modes equally and the speedup ratio stays
meaningful even when absolute times wander.

Usage:
    PYTHONPATH=src python -m benchmarks.bench_codegen \
        --kernels 3mm 3-madd gesummv --out BENCH_codegen.json
"""
from __future__ import annotations

import argparse
import json
import time

from .common import build_graph, solve_kernel

# Multi-task graphs where whole-plan compilation pays: matmul chains
# (concurrent waves), add trees (cross-task elementwise fusion), and
# small-vector kernels (dispatch-bound).
DEFAULT_KERNELS = ("3mm", "2mm", "gemver", "3-madd", "gesummv")


def paired_steady_state_s(exes, ins, *, batch: int = 10,
                          samples: int = 7) -> list[float]:
    """Best per-call seconds for each executable in ``exes``, sampled
    alternately (exe0 batch, exe1 batch, exe0 batch, ...) so host drift
    cancels out of the ratio between them."""
    import jax
    for exe in exes:                            # compile + warm up
        jax.block_until_ready(list(exe(ins).values()))
    best = [float("inf")] * len(exes)
    for _ in range(samples):
        for i, exe in enumerate(exes):
            t0 = time.perf_counter()
            for _ in range(batch):
                out = exe(ins)
            jax.block_until_ready(list(out.values()))
            best[i] = min(best[i], (time.perf_counter() - t0) / batch)
    return best


def bench(kernels=DEFAULT_KERNELS, *, scale: int = 1, budget: float = 6.0,
          impl: str = "xla", batch: int = 10, samples: int = 7,
          pool_size: int | None = None, plans: dict | None = None) -> dict:
    """Benchmark program-mode vs per-task-mode execution of solved plans."""
    import jax

    from repro.codegen import (allclose, plan_executor, random_inputs,
                               reference_executor)

    entries = {}
    speedups = []
    for name in kernels:
        g = build_graph(name, scale)
        plan = (plans or {}).get(name) or solve_kernel(
            name, "prometheus", scale=scale, budget=budget)
        try:
            ins = random_inputs(g, seed=0)
            per = plan_executor(g, plan, impl=impl, mode="per_task")
            prog = plan_executor(g, plan, impl=impl, mode="program",
                                 pool_size=pool_size)
            per_s, prog_s = paired_steady_state_s(
                (per, prog), ins, batch=batch, samples=samples)
            ref = reference_executor(g)(ins)
            out = prog(ins)
            ok = all(allclose(out[k], ref[k]) for k in ref)
        except NotImplementedError:
            continue                    # triangular-density: model-only
        sched = prog.schedule
        program = prog.program(impl)
        speedup = per_s / prog_s if prog_s else 0.0
        speedups.append(speedup)
        entries[name] = {
            "n_tasks": len(plan.configs),
            "n_waves": len(sched.waves),
            "max_wave_width": sched.max_width,
            "cross_slice_transfers": len(sched.transfers),
            "n_segments": program.n_segments,
            "pool_size": program.pool_size,
            "per_task_s": per_s,
            "program_s": prog_s,
            "speedup": round(speedup, 3),
            "program_gflops": round(g.total_flops() / prog_s / 1e9, 3)
            if prog_s else 0.0,
            "model_latency_s": plan.latency_s,
            "validated": bool(ok),
        }
    gmean = 1.0
    for s in speedups:
        gmean *= s
    gmean = gmean ** (1.0 / len(speedups)) if speedups else 0.0
    return {
        "benchmark": "codegen_whole_plan",
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "impl": impl,
        "scale": scale,
        "batch": batch,
        "samples": samples,
        "kernels": entries,
        "gmean_speedup": round(gmean, 3),
    }


def emit(path: str, **kw) -> dict:
    result = bench(**kw)
    with open(path, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    return result


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--kernels", nargs="+", default=list(DEFAULT_KERNELS))
    ap.add_argument("--scale", type=int, default=1)
    ap.add_argument("--budget", type=float, default=6.0)
    ap.add_argument("--impl", default="xla")
    ap.add_argument("--batch", type=int, default=10)
    ap.add_argument("--samples", type=int, default=7)
    ap.add_argument("--pool", type=int, default=None,
                    help="executable-pool size for program mode "
                         "(default: REPRO_PROGRAM_POOL_SIZE or 1)")
    ap.add_argument("--out", default="BENCH_codegen.json")
    args = ap.parse_args()
    result = emit(args.out, kernels=tuple(args.kernels), scale=args.scale,
                  budget=args.budget, impl=args.impl, batch=args.batch,
                  samples=args.samples, pool_size=args.pool)
    for name, e in result["kernels"].items():
        print(f"{name:10s} per_task={e['per_task_s'] * 1e6:9.1f}us "
              f"program={e['program_s'] * 1e6:9.1f}us "
              f"speedup={e['speedup']:5.2f}x segs={e['n_segments']} "
              f"validated={e['validated']}")
    print(f"gmean_speedup={result['gmean_speedup']:.2f}x -> {args.out}")


if __name__ == "__main__":
    main()
