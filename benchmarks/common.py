"""Shared benchmark helpers: solve-and-report across solver modes.

Every table prints CSV to stdout and returns rows so ``benchmarks.run``
can aggregate into bench_output.txt.  GF/s figures are model-predicted
throughput (useful FLOPs / plan latency) on the TPU hardware model — the
analogue of the paper's RTL-simulated GF/s.
"""
from __future__ import annotations

import contextlib
import functools
import io
import sys
import time

from repro.core import (ONE_SLICE, THREE_SLICE, SolverOptions, polybench,
                        solve)
from repro.core.resources import ONE_SLICE_60, THREE_SLICE_60

MODES = ("prometheus", "sisyphus", "streamhls", "autodse")

# Hardware per mode for the RTL-sim analogue (Table 6): every framework may
# use the whole board, but only SLR-aware Prometheus can span multiple
# slices (the paper: "they are constrained to a single SLR").
def hw_for(mode: str):
    return THREE_SLICE if mode == "prometheus" else ONE_SLICE


@functools.lru_cache(maxsize=None)
def build_graph(name: str, scale: int):
    """One build per (kernel, scale) — solving and measuring the same kernel
    share the graph instead of rebuilding it.  Treat the result read-only."""
    return polybench.build(name, scale=scale)


def solve_kernel(name: str, mode: str, *, scale: int = polybench.TPU_SCALE,
                 budget: float = 12.0, hw=None, seed: int = 0):
    g = build_graph(name, scale)
    opts = SolverOptions(mode=mode, time_budget_s=budget, seed=seed)
    t0 = time.monotonic()
    plan = solve(g, hw if hw is not None else hw_for(mode), opts)
    plan.solver_seconds = time.monotonic() - t0
    return plan


def steady_state_s(exe, ins, *, batch: int = 10, samples: int = 7) -> float:
    """Best per-call seconds over ``samples`` timed batches of ``batch``
    back-to-back calls (one block at the batch end).  The ONE timing
    methodology every benchmark uses: batching amortizes scheduler noise on
    contended hosts far better than single-call timings, and best-of
    filters the remaining interference."""
    out = exe(ins)                              # compile + warm up
    for v in out.values():
        v.block_until_ready()                   # drain async dispatch
    best = float("inf")
    for _ in range(samples):
        t0 = time.perf_counter()
        for _ in range(batch):
            out = exe(ins)
        for v in out.values():
            v.block_until_ready()
        best = min(best, (time.perf_counter() - t0) / batch)
    return best


def measure_plan(name: str, plan, *, graph=None, scale: int = 1,
                 impl: str | None = None, repeats: int = 3,
                 validate: bool = True, mode: str = "program"):
    """Execute a plan through the codegen subsystem and time it.

    Returns ``(seconds, gflops, validated)`` — the measured counterpart of
    the model-predicted GF/s, timed with :func:`steady_state_s` (``repeats``
    = samples).  ``mode="program"`` runs the whole-plan compiled program
    (one jit over the full DAG); ``mode="per_task"`` runs the host-driven
    per-task dispatch for comparison.  ``graph`` lets callers pass the
    already-built graph (``build_graph`` otherwise caches the rebuild).
    Triangular-density kernels are not executable; callers should catch
    ``NotImplementedError``.
    """
    from repro.codegen import (allclose, plan_executor, random_inputs,
                               reference_executor)
    g = graph if graph is not None else build_graph(name, scale)
    exe = plan_executor(g, plan, impl=impl, mode=mode)
    ins = random_inputs(g, seed=0)
    best = steady_state_s(exe, ins, samples=repeats)
    ok = True
    if validate:
        ref = reference_executor(g)(ins)
        out = exe(ins)
        ok = all(allclose(out[k], ref[k]) for k in ref)
    gflops = g.total_flops() / best / 1e9 if best else 0.0
    return best, gflops, ok


def fmt_row(cells) -> str:
    return ",".join(str(c) for c in cells)


class Table:
    def __init__(self, title: str, header: list[str]):
        self.title = title
        self.header = header
        self.rows: list[list] = []

    def add(self, *cells):
        self.rows.append(list(cells))

    def render(self) -> str:
        out = [f"# {self.title}", fmt_row(self.header)]
        out += [fmt_row(r) for r in self.rows]
        return "\n".join(out) + "\n"

    def show(self):
        print(self.render(), flush=True)
        return self
