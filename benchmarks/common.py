"""Shared benchmark helpers: solve-and-report across solver modes.

Every table prints CSV to stdout and returns rows so ``benchmarks.run``
can aggregate into bench_output.txt.  GF/s figures are model-predicted
throughput (useful FLOPs / plan latency) on the TPU hardware model — the
analogue of the paper's RTL-simulated GF/s.
"""
from __future__ import annotations

import time

from repro.core import (ONE_SLICE, THREE_SLICE, SolverOptions, polybench,
                        solve)
# Measurement lives in the core solver now, so solve-time validation and
# serve-time execution resolve through one program cache + executable pool;
# re-exported here because every benchmark table imports them from common.
from repro.core.solver import build_graph, measure_plan, steady_state_s

__all__ = ["MODES", "Table", "build_graph", "fmt_row", "hw_for",
           "measure_plan", "solve_kernel", "steady_state_s"]

MODES = ("prometheus", "sisyphus", "streamhls", "autodse")

# Hardware per mode for the RTL-sim analogue (Table 6): every framework may
# use the whole board, but only SLR-aware Prometheus can span multiple
# slices (the paper: "they are constrained to a single SLR").
def hw_for(mode: str):
    return THREE_SLICE if mode == "prometheus" else ONE_SLICE


def solve_kernel(name: str, mode: str, *, scale: int = polybench.TPU_SCALE,
                 budget: float = 12.0, hw=None, seed: int = 0,
                 workers: int | None = 1, store=None, refresh: bool = False):
    """One benchmark solve.  Defaults pin the seed behavior every table
    depends on: serial sweep (``workers=1``) and no plan store (so a
    configured ``REPRO_PLAN_STORE_DIR`` cannot short-circuit a table's
    measurement); ``table10_solver_time --bench-out`` opts into both."""
    g = build_graph(name, scale)
    opts = SolverOptions(mode=mode, time_budget_s=budget, seed=seed,
                         workers=workers)
    t0 = time.monotonic()
    plan = solve(g, hw if hw is not None else hw_for(mode), opts,
                 store=store, refresh=refresh)
    plan.solver_seconds = time.monotonic() - t0
    return plan


def fmt_row(cells) -> str:
    return ",".join(str(c) for c in cells)


class Table:
    def __init__(self, title: str, header: list[str]):
        self.title = title
        self.header = header
        self.rows: list[list] = []

    def add(self, *cells):
        self.rows.append(list(cells))

    def render(self) -> str:
        out = [f"# {self.title}", fmt_row(self.header)]
        out += [fmt_row(r) for r in self.rows]
        return "\n".join(out) + "\n"

    def show(self):
        print(self.render(), flush=True)
        return self
