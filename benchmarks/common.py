"""Shared benchmark helpers: solve-and-report across solver modes.

Every table prints CSV to stdout and returns rows so ``benchmarks.run``
can aggregate into bench_output.txt.  GF/s figures are model-predicted
throughput (useful FLOPs / plan latency) on the TPU hardware model — the
analogue of the paper's RTL-simulated GF/s.
"""
from __future__ import annotations

import contextlib
import io
import sys
import time

from repro.core import (ONE_SLICE, THREE_SLICE, SolverOptions, polybench,
                        solve)
from repro.core.resources import ONE_SLICE_60, THREE_SLICE_60

MODES = ("prometheus", "sisyphus", "streamhls", "autodse")

# Hardware per mode for the RTL-sim analogue (Table 6): every framework may
# use the whole board, but only SLR-aware Prometheus can span multiple
# slices (the paper: "they are constrained to a single SLR").
def hw_for(mode: str):
    return THREE_SLICE if mode == "prometheus" else ONE_SLICE


def solve_kernel(name: str, mode: str, *, scale: int = polybench.TPU_SCALE,
                 budget: float = 12.0, hw=None, seed: int = 0):
    g = polybench.build(name, scale=scale)
    opts = SolverOptions(mode=mode, time_budget_s=budget, seed=seed)
    t0 = time.monotonic()
    plan = solve(g, hw if hw is not None else hw_for(mode), opts)
    plan.solver_seconds = time.monotonic() - t0
    return plan


def measure_plan(name: str, plan, *, scale: int = 1, impl: str | None = None,
                 repeats: int = 3, validate: bool = True):
    """Execute a plan through the codegen subsystem and time it.

    Returns ``(seconds, gflops, validated)`` — the measured counterpart of
    the model-predicted GF/s, using the plan-lowered executor (one fused
    kernel per task, slice-aware dispatch).  Triangular-density kernels are
    not executable; callers should catch ``NotImplementedError``.
    """
    from repro.codegen import (allclose, plan_executor, random_inputs,
                               reference_executor)
    g = polybench.build(name, scale=scale)
    exe = plan_executor(g, plan, impl=impl)
    ins = random_inputs(g, seed=0)
    out = exe(ins)                              # compile + warm up
    for v in out.values():
        v.block_until_ready()                   # drain async dispatch
    best = float("inf")
    for _ in range(repeats):
        t0 = time.monotonic()
        out = exe(ins)
        for v in out.values():
            v.block_until_ready()
        best = min(best, time.monotonic() - t0)
    ok = True
    if validate:
        ref = reference_executor(g)(ins)
        ok = all(allclose(out[k], ref[k]) for k in ref)
    gflops = g.total_flops() / best / 1e9 if best else 0.0
    return best, gflops, ok


def fmt_row(cells) -> str:
    return ",".join(str(c) for c in cells)


class Table:
    def __init__(self, title: str, header: list[str]):
        self.title = title
        self.header = header
        self.rows: list[list] = []

    def add(self, *cells):
        self.rows.append(list(cells))

    def render(self) -> str:
        out = [f"# {self.title}", fmt_row(self.header)]
        out += [fmt_row(r) for r in self.rows]
        return "\n".join(out) + "\n"

    def show(self):
        print(self.render(), flush=True)
        return self
