"""BENCH_frontend.json emitter: traced whole-plan programs vs plain jax.jit.

The frontend's promise is that *arbitrary* JAX functions flow through the
solver/codegen pipeline; this benchmark prices that promise on two
workloads nobody hand-modeled:

* ``gemm_chain`` — a 3-matmul chain (the pure affine case: 100% coverage);
* ``mlp_block``  — a float32 SwiGLU FFN block from ``repro.models``
  (partial coverage: the silu ``logistic`` runs as an opaque segment).

For each workload it records the steady-state per-call seconds of the
traced plan program (resolved through the serving program cache, exactly
what ``PlanEngine`` would execute) against ``jax.jit(fn)`` — sampled
ALTERNATELY like ``bench_codegen`` so host drift cancels out of the ratio —
plus the trace coverage, the program's unit census and a scale-aware
validation of the traced outputs against the jit oracle.

``ratio`` is jit seconds over program seconds (>1 means the traced program
beats plain jit).  On XLA:CPU the ratio hovers near parity — XLA already
fuses these chains well — and the CI gate regresses the *same-run ratio*
and the coverage fractions, not absolute runner speed.

Usage:
    PYTHONPATH=src python -m benchmarks.bench_frontend \
        --out BENCH_frontend.json
"""
from __future__ import annotations

import argparse
import json
import time


def _workloads(seed: int = 0):
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.models import ffn

    rng = np.random.default_rng(seed)

    def arr(*shape):
        return jnp.asarray(rng.normal(size=shape).astype(np.float32))

    def chain(a, b, c, d):
        return ((a @ b) @ c) @ d

    chain_args = (arr(160, 192), arr(192, 144), arr(144, 176),
                  arr(176, 128))

    params = ffn.init_swiglu(jax.random.PRNGKey(seed), 128, 256)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (32, 128),
                          jnp.float32)

    def mlp_block(p, v):
        return ffn.swiglu(p, v, compute_dtype=jnp.float32)

    return {
        "gemm_chain": (chain, chain_args),
        "mlp_block": (mlp_block, (params, x)),
    }


def paired_steady_state_s(fns, *, batch: int = 10,
                          samples: int = 7) -> list[float]:
    """Best per-call seconds for each thunk in ``fns``, sampled alternately
    (fn0 batch, fn1 batch, fn0 batch, ...) so drift cancels out of ratios."""
    import jax
    for fn in fns:                               # compile + warm up
        jax.block_until_ready(fn())
    best = [float("inf")] * len(fns)
    for _ in range(samples):
        for i, fn in enumerate(fns):
            t0 = time.perf_counter()
            for _ in range(batch):
                out = fn()
            jax.block_until_ready(out)
            best[i] = min(best[i], (time.perf_counter() - t0) / batch)
    return best


def bench(*, budget: float = 8.0, impl: str = "xla", batch: int = 10,
          samples: int = 7, seed: int = 0) -> dict:
    import jax

    from repro import frontend
    from repro.codegen import allclose
    from repro.core.solver import SolverOptions

    entries = {}
    ratios = []
    for name, (fn, args) in _workloads(seed).items():
        tf = frontend.trace(fn, *args, name=name)
        plan = tf.solve(opts=SolverOptions(time_budget_s=budget))
        exe = tf.executable(plan=plan, impl=impl)
        jit_fn = jax.jit(fn)
        jit_s, prog_s = paired_steady_state_s(
            (lambda: jit_fn(*args), lambda: exe(*args)),
            batch=batch, samples=samples)
        got = jax.tree_util.tree_leaves(exe(*args))
        want = jax.tree_util.tree_leaves(jit_fn(*args))
        ok = len(got) == len(want) and all(
            allclose(g, w) for g, w in zip(got, want))
        program = exe.executor.program(impl)
        ratio = jit_s / prog_s if prog_s else 0.0
        ratios.append(ratio)
        cov = tf.coverage
        entries[name] = {
            "n_eqns": cov.n_eqns,
            "n_supported": cov.n_supported,
            "coverage_eqns": round(cov.eqn_ratio, 4),
            "coverage_flops": round(cov.flop_ratio, 4),
            "n_tasks": len(plan.configs),
            "unit_kinds": program.unit_kinds(),
            "n_segments": program.n_segments,
            "jit_s": jit_s,
            "program_s": prog_s,
            "ratio": round(ratio, 3),
            "model_latency_s": plan.latency_s,
            "validated": bool(ok),
        }
    gmean = 1.0
    for r in ratios:
        gmean *= r
    gmean = gmean ** (1.0 / len(ratios)) if ratios else 0.0
    return {
        "benchmark": "frontend_trace",
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "impl": impl,
        "batch": batch,
        "samples": samples,
        "workloads": entries,
        "gmean_ratio": round(gmean, 3),
    }


def emit(path: str, **kw) -> dict:
    result = bench(**kw)
    with open(path, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    return result


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--budget", type=float, default=8.0)
    ap.add_argument("--impl", default="xla")
    ap.add_argument("--batch", type=int, default=10)
    ap.add_argument("--samples", type=int, default=7)
    ap.add_argument("--out", default="BENCH_frontend.json")
    args = ap.parse_args()
    result = emit(args.out, budget=args.budget, impl=args.impl,
                  batch=args.batch, samples=args.samples)
    for name, e in result["workloads"].items():
        print(f"{name:12s} jit={e['jit_s'] * 1e6:9.1f}us "
              f"program={e['program_s'] * 1e6:9.1f}us "
              f"ratio={e['ratio']:5.2f}x "
              f"coverage={e['n_supported']}/{e['n_eqns']} "
              f"({e['coverage_flops']:.0%} flops) "
              f"validated={e['validated']}")
    print(f"gmean_ratio={result['gmean_ratio']:.2f}x -> {args.out}")


if __name__ == "__main__":
    main()
