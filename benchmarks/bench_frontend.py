"""BENCH_frontend.json emitter: traced whole-plan programs vs plain jax.jit.

The frontend's promise is that *arbitrary* JAX functions flow through the
solver/codegen pipeline; this benchmark prices that promise on two
workloads nobody hand-modeled:

* ``gemm_chain`` — a 3-matmul chain (the pure affine case: 100% coverage);
* ``mlp_block``  — a float32 SwiGLU FFN block from ``repro.models`` (the
  silu chain lowers through the unary/pointwise statement families and
  fuses into the producing dot's task);
* ``gelu_mlp``   — a float32 GeLU FFN block (tanh/integer_pow/scalar-folding
  coverage; the gelu tail fuses like silu);
* ``bf16_chain`` — a 2-matmul bf16 chain with f32 accumulation
  (``convert_element_type`` coverage: the converts alias away in the traced
  program while plain jit executes them).

For each workload it records the steady-state per-call seconds of the
traced plan program (resolved through the serving program cache, exactly
what ``PlanEngine`` would execute) against ``jax.jit(fn)`` — sampled
ALTERNATELY like ``bench_codegen`` so host drift cancels out of the ratio —
plus the trace coverage, the program's unit census and a scale-aware
validation of the traced outputs against the jit oracle.

``ratio`` is jit seconds over program seconds (>1 means the traced program
beats plain jit), computed as the *median of per-sample-pair ratios* so a
contended host's drift and outlier windows cancel; ``jit_s``/``program_s``
report the best windows.  The CI gate regresses the same-run ratio and the
coverage fractions, not absolute runner speed.

Usage:
    PYTHONPATH=src python -m benchmarks.bench_frontend \
        --out BENCH_frontend.json
"""
from __future__ import annotations

import argparse
import json
import time


def _workloads(seed: int = 0):
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.models import ffn

    rng = np.random.default_rng(seed)

    def arr(*shape):
        return jnp.asarray(rng.normal(size=shape).astype(np.float32))

    def chain(a, b, c, d):
        return ((a @ b) @ c) @ d

    chain_args = (arr(160, 192), arr(192, 144), arr(144, 176),
                  arr(176, 128))

    params = ffn.init_swiglu(jax.random.PRNGKey(seed), 128, 256)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (32, 128),
                          jnp.float32)

    def mlp_block(p, v):
        return ffn.swiglu(p, v, compute_dtype=jnp.float32)

    gparams = ffn.init_gelu(jax.random.PRNGKey(seed + 2), 128, 256)

    def gelu_mlp(p, v):
        return ffn.gelu_mlp(p, v, compute_dtype=jnp.float32)

    def barr(*shape):
        return jnp.asarray(rng.normal(size=shape).astype(np.float32),
                           dtype=jnp.bfloat16)

    def bf16_chain(a, b, c):
        h = jnp.dot(a, b,
                    preferred_element_type=jnp.float32).astype(jnp.bfloat16)
        return jnp.dot(h, c,
                       preferred_element_type=jnp.float32) \
            .astype(jnp.bfloat16)

    bf16_args = (barr(160, 192), barr(192, 144), barr(144, 128))

    return {
        "gemm_chain": (chain, chain_args),
        "mlp_block": (mlp_block, (params, x)),
        "gelu_mlp": (gelu_mlp, (gparams, x)),
        "bf16_chain": (bf16_chain, bf16_args),
    }


def paired_steady_state_s(fns, *, batch: int = 10,
                          samples: int = 7) -> list[list[float]]:
    """Per-sample per-call seconds for each thunk in ``fns``, sampled
    alternately (fn0 batch, fn1 batch, fn0 batch, ...) so slow host drift
    hits adjacent windows of both thunks alike.  Callers take the best for
    absolute numbers and the *median of per-sample ratios* for gates — a
    contended host swings +-20% between windows, and best-vs-best lets one
    lucky window of either side dominate the ratio."""
    import jax
    for fn in fns:                               # compile + warm up
        jax.block_until_ready(fn())
    times: list[list[float]] = [[] for _ in fns]
    for _ in range(samples):
        for i, fn in enumerate(fns):
            t0 = time.perf_counter()
            for _ in range(batch):
                out = fn()
            jax.block_until_ready(out)
            times[i].append((time.perf_counter() - t0) / batch)
    return times


def bench(*, budget: float = 8.0, impl: str = "xla", batch: int = 10,
          samples: int = 7, seed: int = 0) -> dict:
    import jax

    from repro import frontend
    from repro.codegen import allclose
    from repro.core.solver import SolverOptions

    entries = {}
    ratios = []
    for name, (fn, args) in _workloads(seed).items():
        tf = frontend.trace(fn, *args, name=name)
        plan = tf.solve(opts=SolverOptions(time_budget_s=budget))
        exe = tf.executable(plan=plan, impl=impl)
        jit_fn = jax.jit(fn)
        jit_t, prog_t = paired_steady_state_s(
            (lambda: jit_fn(*args), lambda: exe(*args)),
            batch=batch, samples=samples)
        jit_s, prog_s = min(jit_t), min(prog_t)
        pair_ratios = sorted(j / p for j, p in zip(jit_t, prog_t))
        ratio = pair_ratios[len(pair_ratios) // 2]
        got = jax.tree_util.tree_leaves(exe(*args))
        want = jax.tree_util.tree_leaves(jit_fn(*args))
        # half-precision graphs compare in the half-precision band (the
        # oracle itself rounds at bf16 resolution between the dots)
        rtol = 2e-2 if tf.record.precision_bytes <= 2 else 2e-4
        ok = len(got) == len(want) and all(
            allclose(g, w, rtol=rtol) for g, w in zip(got, want))
        program = exe.executor.program(impl)
        ratios.append(ratio)
        cov = tf.coverage
        entries[name] = {
            "n_eqns": cov.n_eqns,
            "n_supported": cov.n_supported,
            "coverage_eqns": round(cov.eqn_ratio, 4),
            "coverage_flops": round(cov.flop_ratio, 4),
            "n_tasks": len(plan.configs),
            "unit_kinds": program.unit_kinds(),
            "n_segments": program.n_segments,
            "jit_s": jit_s,
            "program_s": prog_s,
            "ratio": round(ratio, 3),
            "model_latency_s": plan.latency_s,
            # model-predicted over measured: the cost-model sanity band the
            # unit tests assert on covered workloads
            "model_ratio": round(plan.latency_s / prog_s, 3) if prog_s
            else 0.0,
            "validated": bool(ok),
        }
    gmean = 1.0
    for r in ratios:
        gmean *= r
    gmean = gmean ** (1.0 / len(ratios)) if ratios else 0.0
    return {
        "benchmark": "frontend_trace",
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "impl": impl,
        "batch": batch,
        "samples": samples,
        "workloads": entries,
        "gmean_ratio": round(gmean, 3),
    }


def emit(path: str, **kw) -> dict:
    result = bench(**kw)
    with open(path, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    return result


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--budget", type=float, default=8.0)
    ap.add_argument("--impl", default="xla")
    ap.add_argument("--batch", type=int, default=10)
    ap.add_argument("--samples", type=int, default=7)
    ap.add_argument("--out", default="BENCH_frontend.json")
    args = ap.parse_args()
    result = emit(args.out, budget=args.budget, impl=args.impl,
                  batch=args.batch, samples=args.samples)
    for name, e in result["workloads"].items():
        print(f"{name:12s} jit={e['jit_s'] * 1e6:9.1f}us "
              f"program={e['program_s'] * 1e6:9.1f}us "
              f"ratio={e['ratio']:5.2f}x "
              f"coverage={e['n_supported']}/{e['n_eqns']} "
              f"({e['coverage_flops']:.0%} flops) "
              f"validated={e['validated']}")
    print(f"gmean_ratio={result['gmean_ratio']:.2f}x -> {args.out}")


if __name__ == "__main__":
    main()
