"""Benchmark driver: one table per paper table + the roofline table.

    PYTHONPATH=src python -m benchmarks.run            # all tables
    PYTHONPATH=src python -m benchmarks.run table6     # one table
    PYTHONPATH=src python -m benchmarks.run --fast     # smaller budgets
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    args = [a for a in sys.argv[1:]]
    fast = "--fast" in args
    args = [a for a in args if not a.startswith("--")]
    budget = 6.0 if fast else 12.0

    from . import (roofline_table, table5_characteristics,
                   table6_polybench, table7_resources, table8_multislice,
                   table9_plans, table10_solver_time)
    jobs = {
        "table5": lambda: table5_characteristics.run(),
        "table6": lambda: table6_polybench.run(budget=budget),
        "table7": lambda: table7_resources.run(budget=budget),
        "table8": lambda: table8_multislice.run(budget=budget),
        "table9": lambda: table9_plans.run(budget=budget),
        "table10": lambda: table10_solver_time.run(
            budget=10.0 if fast else 20.0),
        "roofline": lambda: roofline_table.run("single"),
        "roofline_multi": lambda: roofline_table.run("multi"),
    }
    selected = args or list(jobs)
    t_all = time.monotonic()
    for name in selected:
        if name not in jobs:
            raise SystemExit(f"unknown table {name!r}; have {list(jobs)}")
        t0 = time.monotonic()
        jobs[name]().show()
        print(f"[{name} done in {time.monotonic() - t0:.1f}s]\n",
              flush=True)
    print(f"[all benchmarks done in {time.monotonic() - t_all:.1f}s]")


if __name__ == "__main__":
    main()
