"""Table 9 analogue: the NLP's chosen fusion, loop order and data-tile
sizes for the on-board kernels (1 slice)."""
from __future__ import annotations

from repro.core.costmodel import footprint_elems
from repro.core.fusion import fuse
from repro.core import polybench
from repro.core.resources import ONE_SLICE_60

from .common import Table, solve_kernel

KERNELS = ["2mm", "3mm", "atax", "bicg"]


def run(budget: float = 12.0) -> Table:
    t = Table("Table 9 — NLP-chosen plans (fusion / loop order / tiles)",
              ["kernel", "task", "fused_stmts", "loop_order", "tiles",
               "data_tiles(elems)"])
    for name in KERNELS:
        plan = solve_kernel(name, "prometheus", budget=budget,
                            hw=ONE_SLICE_60)
        fg = fuse(polybench.build(name, scale=polybench.TPU_SCALE))
        for task in fg.tasks:
            cfg = plan.configs[task.tid]
            stmts = "+".join(s.name for s in task.statements)
            order = ">".join(cfg.perm)
            tiles = " ".join(
                f"{l}:{ti.tile}" + (f"(pad{ti.pad})" if ti.pad else "")
                for l, ti in cfg.tiles.items())
            fps = " ".join(
                f"{a}:{footprint_elems(cfg, task, a, cfg.placements[a].transfer_level)}"
                for a in task.read_arrays() + [task.output_array]
                if a in cfg.placements)
            t.add(name, f"FT{task.tid}", stmts, order, tiles, fps)
    return t


if __name__ == "__main__":
    run().show()
