"""Thread-safety of the serving layer: concurrent submit, single-build
misses, pool round-robin balance, warmup accounting.

The failure modes these pin down (seen as races on the pre-lock code):
OrderedDict mutation during concurrent get/put, lost hit/request counter
updates, duplicate compilation of one cold program, and a warmup that left
``pool_size - 1`` clones cold.
"""
from __future__ import annotations

import threading

from repro.codegen import (allclose, cache_stats, clear_program_cache,
                           compiled_program, program_cache, program_key,
                           random_inputs, reference_executor)
from repro.codegen.program import ProgramCache
from repro.core import SolverOptions, THREE_SLICE, polybench, solve
from repro.serve import PlanEngine, ServeConfig

N_THREADS = 8
N_SUBMITS = 12


def _solved(name: str, budget: float = 1.0):
    g = polybench.build(name)
    plan = solve(g, THREE_SLICE, SolverOptions(time_budget_s=budget))
    return g, plan


def _run_threads(n, target):
    barrier = threading.Barrier(n)
    errors: list[BaseException] = []

    def wrapped(i):
        try:
            barrier.wait()
            target(i)
        except BaseException as e:          # surface into the test
            errors.append(e)

    threads = [threading.Thread(target=wrapped, args=(i,)) for i in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    if errors:
        raise errors[0]
    return errors


# ---------------------------------------------------------------------------
# Engine-level stress: the harness the pool benchmark runs, as a test
# ---------------------------------------------------------------------------
def test_concurrent_submit_stress_no_lost_updates():
    clear_program_cache()
    g, plan = _solved("2-madd")
    ins = random_inputs(g, seed=0)
    ref = reference_executor(g)(ins)
    eng = PlanEngine(impl="xla", sc=ServeConfig(pool_size=2))
    eng.register("m", g, plan)
    eng.warmup("m", ins)
    warm = eng.requests
    results: dict[int, list] = {i: [] for i in range(N_THREADS)}

    def worker(i):
        for _ in range(N_SUBMITS):
            results[i].append(eng.submit("m", ins))

    _run_threads(N_THREADS, worker)

    total = N_THREADS * N_SUBMITS
    # results match the oracle — no torn env/pool state under load
    for outs in results.values():
        for out in outs:
            assert all(allclose(out[k], ref[k]) for k in ref)
    # no lost counter updates anywhere in the accounting chain
    assert eng.requests == warm + total
    assert eng.per_name["m"] == warm + total
    key = program_key(g, plan, "xla")
    entry = program_cache().entry(key)
    assert entry.program.calls == warm + total
    assert entry.hits == warm + total - 1       # all but the build
    s = cache_stats()
    assert s["misses"] == 1 and s["hits"] == warm + total - 1
    # round-robin stayed balanced: every clone traced exactly once (the
    # warmup), none re-traced under concurrency
    assert entry.program.trace_count == 2 * entry.program.n_segments
    assert entry.program.pool_size == 2


def test_concurrent_cold_misses_compile_once():
    """N threads racing the same cold (graph, plan, impl) key must yield
    ONE compiled program and one recorded miss."""
    clear_program_cache()
    g, plan = _solved("2-madd")
    got: list = [None] * N_THREADS

    def worker(i):
        got[i] = compiled_program(g, plan, "xla")

    _run_threads(N_THREADS, worker)
    assert all(p is got[0] for p in got)
    s = cache_stats()
    assert s["misses"] == 1 and len(program_cache()) == 1


def test_concurrent_register_submit_unregister_distinct_names():
    """Registry churn from one thread while others submit elsewhere."""
    clear_program_cache()
    g, plan = _solved("2-madd")
    g2, plan2 = _solved("3-madd")
    ins = random_inputs(g, seed=0)
    eng = PlanEngine(impl="xla")
    eng.register("serve", g, plan)
    eng.warmup("serve", ins)

    def worker(i):
        if i == 0:
            for r in range(10):
                eng.register(f"churn{r}", g2, plan2)
                eng.unregister(f"churn{r}")
        else:
            for _ in range(10):
                eng.submit("serve", ins)

    _run_threads(4, worker)
    assert eng.names() == ["serve"]
    assert eng.per_name["serve"] == 1 + 3 * 10


# ---------------------------------------------------------------------------
# Cache-level fuzz (no compilation: fake programs)
# ---------------------------------------------------------------------------
class _Fake:
    pool_size = 1
    n_segments = 1
    calls = 0

    def est_bytes(self):
        return 1


def test_program_cache_concurrent_fuzz():
    cache = ProgramCache(capacity=8)
    keys = [(f"k{i}",) for i in range(24)]

    def worker(i):
        for r in range(300):
            k = keys[(i * 7 + r) % len(keys)]
            if cache.get(k) is None:
                cache.put(k, _Fake())
            if r % 50 == 0:
                cache.stats(detail=True)
                cache.keys()

    _run_threads(6, worker)
    s = cache.stats()
    assert s["size"] <= 8 and s["size"] == len(cache.keys())
    # conservation: every successful put beyond capacity evicted exactly one
    assert s["evictions"] >= len(keys) - 8
    # hit accounting still works after the storm (the fuzz itself may see
    # zero hits: 6 lockstep threads striding 24 keys never revisit one
    # inside an 8-entry LRU window)
    cache.put(("solo",), _Fake())
    assert cache.get(("solo",)) is not None
    assert cache.stats()["hits"] == s["hits"] + 1


def test_program_cache_concurrent_resize_and_clear():
    cache = ProgramCache(capacity=16)

    def worker(i):
        for r in range(200):
            k = (f"{i}-{r % 10}",)
            if cache.get(k) is None:
                cache.put(k, _Fake())
            if r % 67 == 0:
                cache.resize(4 + (r % 3))
            if i == 0 and r % 97 == 0:
                cache.clear()

    _run_threads(4, worker)
    assert len(cache) <= cache.capacity


# ---------------------------------------------------------------------------
# Warmup accounting (the under-reported-stats / cold-clone bug)
# ---------------------------------------------------------------------------
def test_warmup_warms_every_pool_clone_and_counts_as_usage():
    clear_program_cache()
    g, plan = _solved("2-madd")
    ins = random_inputs(g, seed=0)
    eng = PlanEngine(impl="xla", sc=ServeConfig(pool_size=3))
    eng.register("m", g, plan)
    eng.warmup("m", ins)
    key = program_key(g, plan, "xla")
    entry = program_cache().entry(key)
    # every clone traced by warmup: later (concurrent) callers never pay a
    # first-call trace
    assert entry.program.trace_count == 3 * entry.program.n_segments
    assert entry.program.calls == 3
    # warmup flows through submit: usage is accounted, not bypassed
    assert eng.requests == 3 and eng.per_name["m"] == 3
    assert entry.hits == 2                      # 3 submits - 1 build miss
    before = entry.program.trace_count
    eng.submit("m", ins)
    assert program_cache().entry(key).program.trace_count == before


def test_warmed_plan_is_mru_not_eviction_victim():
    """A just-warmed plan must be the LAST eviction candidate."""
    from repro.codegen import set_program_cache_size
    clear_program_cache()
    old = program_cache().capacity
    try:
        set_program_cache_size(2)
        g1, p1 = _solved("2-madd")
        g2, p2 = _solved("3-madd")
        eng = PlanEngine(impl="xla", sc=ServeConfig(pool_size=2))
        eng.register("a", g1, p1)
        eng.register("b", g2, p2)
        eng.warmup("a", random_inputs(g1, seed=0))
        eng.warmup("b", random_inputs(g2, seed=0))
        # "a" is now LRU; admitting a third program evicts it, not "b"
        g3 = polybench.build("gesummv")
        p3 = solve(g3, THREE_SLICE, SolverOptions(time_budget_s=1.0))
        compiled_program(g3, p3, "xla")
        assert program_key(g2, p2, "xla") in program_cache()
        assert program_key(g1, p1, "xla") not in program_cache()
    finally:
        set_program_cache_size(old)
        clear_program_cache()


def test_concurrent_submits_with_injected_failures_keep_accounting():
    """Failures mid-submit under concurrency must not corrupt engine
    accounting: request totals, per-entry ok/fallback buckets and pool
    cursors all stay conservation-clean, and every caller still gets a
    correct answer (failed optimized executions fall back, they do not
    raise or return garbage)."""
    from repro.ft import ChaosPlan

    clear_program_cache()
    g, plan = _solved("2-madd")
    ins = random_inputs(g, seed=0)
    ref = reference_executor(g)(ins)
    chaos = ChaosPlan(execute_fail_at=tuple(range(3, 60, 7)))
    eng = PlanEngine(impl="xla", sc=ServeConfig(
        pool_size=2, chaos=chaos, breaker_threshold=1_000_000))
    eng.register("m", g, plan)
    warm = eng.stats()["requests"]

    def worker(_):
        for _ in range(N_SUBMITS):
            out = eng.submit("m", ins)
            assert all(allclose(out[k], ref[k]) for k in ref)

    _run_threads(N_THREADS, worker)
    s = eng.stats()
    total = N_THREADS * N_SUBMITS
    assert s["requests"] == warm + total
    assert s["per_name"]["m"] == warm + total
    h = s["resilience"]["entries"]["m"]
    # conservation: every admitted request in exactly one bucket, every
    # injected fault matched by exactly one fallback
    assert h["ok"] + h["fallbacks"] == warm + total
    assert h["failures"] == len(chaos.events) > 0
    assert h["fallbacks"] == h["failures"]
    # the pool cursor advanced once per completed optimized execution
    # (injected execute faults fire before the kernel dispatches)
    assert s["pools"]["m/xla"]["calls"] == h["ok"]
