"""Integration tests: persistent plan store (repro/store) + parallel
solver sweep (SolverOptions.workers) — the cold-solve-off-the-request-path
PR."""
from __future__ import annotations

import os

import pytest

from repro.core import THREE_SLICE, Hardware, SolverOptions, polybench, solve
from repro.core.fingerprint import (graph_fingerprint, hardware_fingerprint,
                                    plan_fingerprint,
                                    solver_options_fingerprint)
from repro.core.plan import ExecutionPlan
from repro.store import PlanStore, default_store, set_default_dir

FAST = SolverOptions(time_budget_s=10.0)


@pytest.fixture(scope="module")
def atax_plan():
    g = polybench.build("atax")
    return g, solve(g, THREE_SLICE, FAST, store=None)


# ---------------------------------------------------------------------------
# Plan serialization round-trip
# ---------------------------------------------------------------------------
def test_plan_jsonable_round_trip_is_exact(atax_plan):
    g, plan = atax_plan
    back = ExecutionPlan.from_jsonable(plan.to_jsonable())
    assert back.graph_name == plan.graph_name
    assert back.latency_s == plan.latency_s
    assert back.useful_flops == plan.useful_flops
    assert set(back.configs) == set(plan.configs)
    for tid, cfg in plan.configs.items():
        b = back.configs[tid]
        assert b.perm == cfg.perm
        assert b.slice_id == cfg.slice_id
        assert {k: t.tile for k, t in b.tiles.items()} == \
            {k: t.tile for k, t in cfg.tiles.items()}
        assert b.placements == cfg.placements
        assert b.to_jsonable() == cfg.to_jsonable()
    for tid, rep in plan.reports.items():
        assert back.reports[tid] == rep
    # fingerprints are content hashes: the round-tripped plan is the
    # same plan
    assert plan_fingerprint(back) == plan_fingerprint(plan)
    # provenance flags are runtime-only, never persisted
    assert "store_hit" not in plan.to_jsonable()
    assert back.store_hit is False and back.stale_hw is False


def test_fingerprints_are_stable_and_discriminating(atax_plan):
    g, _ = atax_plan
    assert graph_fingerprint(g) == graph_fingerprint(polybench.build("atax"))
    assert graph_fingerprint(g) != graph_fingerprint(polybench.build("bicg"))
    assert hardware_fingerprint(THREE_SLICE) != hardware_fingerprint(
        Hardware.make(n_slices=3, dispatch_s=1e-6))
    a = solver_options_fingerprint(FAST)
    assert a == solver_options_fingerprint(SolverOptions(time_budget_s=10.0))
    assert a != solver_options_fingerprint(
        SolverOptions(time_budget_s=10.0, seed=7))
    # worker count must NOT key the store: replicas with different core
    # counts share entries
    assert a == solver_options_fingerprint(
        SolverOptions(time_budget_s=10.0, workers=4))


# ---------------------------------------------------------------------------
# Store hit / miss / refresh
# ---------------------------------------------------------------------------
def test_store_hit_skips_the_sweep(tmp_path, atax_plan):
    g, cold = atax_plan
    st = PlanStore(str(tmp_path))
    st.save(g, THREE_SLICE, FAST, cold)
    warm = solve(g, THREE_SLICE, FAST, store=st)
    assert warm.store_hit and not warm.stale_hw
    assert warm.n_evaluated == 0           # no sweep ran
    assert warm.latency_s == cold.latency_s
    assert {t: c.to_jsonable() for t, c in warm.configs.items()} == \
        {t: c.to_jsonable() for t, c in cold.configs.items()}
    assert st.stats()["hits"] == 1


def test_refresh_bypasses_load_but_updates_store(tmp_path, atax_plan):
    g, cold = atax_plan
    st = PlanStore(str(tmp_path))
    st.save(g, THREE_SLICE, FAST, cold)
    fresh = solve(g, THREE_SLICE, FAST, store=st, refresh=True)
    assert not fresh.store_hit and fresh.n_evaluated > 0
    assert st.stats()["writes"] == 2       # seed + refreshed entry


def test_corrupt_entry_is_quarantined_and_resolved(tmp_path, atax_plan):
    g, cold = atax_plan
    st = PlanStore(str(tmp_path))
    path = st.save(g, THREE_SLICE, FAST, cold)
    with open(path, "w") as f:
        f.write('{"schema": 1, "plan": tru')      # torn write
    plan = solve(g, THREE_SLICE, FAST, store=st)
    assert not plan.store_hit and plan.n_evaluated > 0   # re-solved
    assert os.path.exists(path + ".corrupt")             # quarantined
    assert st.stats()["corrupt"] == 1
    # the re-solve overwrote the slot: next load hits again
    assert solve(g, THREE_SLICE, FAST, store=st).store_hit


def test_stale_hardware_hit_requires_allow_stale(tmp_path, atax_plan):
    g, cold = atax_plan
    st = PlanStore(str(tmp_path))
    st.save(g, THREE_SLICE, FAST, cold)
    drifted = Hardware.make(n_slices=3, dispatch_s=1e-6)
    miss = solve(g, drifted, FAST, store=st)
    assert not miss.store_hit              # exact key: drift is a miss
    st2 = PlanStore(str(tmp_path))         # fresh counters; drifted entry
    hit = st2.load(g, Hardware.make(n_slices=3, dispatch_s=2e-6),
                   FAST, allow_stale=True)
    assert hit is not None and hit.stale_hw and hit.n_evaluated == 0


def test_store_is_bounded_by_mtime_eviction(tmp_path, atax_plan):
    g, plan = atax_plan
    st = PlanStore(str(tmp_path), max_entries=2)
    for i, seed in enumerate((1, 2, 3)):
        st.save(g, THREE_SLICE, SolverOptions(time_budget_s=10.0,
                                              seed=seed), plan)
        os.utime(st._path(*st.key(g, THREE_SLICE,
                                  SolverOptions(time_budget_s=10.0,
                                                seed=seed))),
                 (i, i))                   # deterministic mtime order
    assert len(st) == 2
    # the oldest (seed=1) was evicted
    assert st.load(g, THREE_SLICE,
                   SolverOptions(time_budget_s=10.0, seed=1)) is None
    assert st.load(g, THREE_SLICE,
                   SolverOptions(time_budget_s=10.0, seed=3)) is not None


def test_default_store_is_env_gated(tmp_path, monkeypatch):
    monkeypatch.delenv("REPRO_PLAN_STORE_DIR", raising=False)
    set_default_dir(None)
    assert default_store() is None         # disabled: seed behavior
    monkeypatch.setenv("REPRO_PLAN_STORE_DIR", str(tmp_path))
    st = default_store()
    assert st is not None and st.root == str(tmp_path)
    set_default_dir(str(tmp_path / "override"))
    assert default_store().root == str(tmp_path / "override")
    set_default_dir(None)


# ---------------------------------------------------------------------------
# Parallel sweep (SolverOptions.workers)
# ---------------------------------------------------------------------------
def test_parallel_sweep_latency_no_worse_than_serial():
    g = polybench.build("2mm")
    opts_ser = SolverOptions(time_budget_s=30.0, workers=1)
    opts_par = SolverOptions(time_budget_s=30.0, workers=2)
    serial = solve(g, THREE_SLICE, opts_ser, store=None)
    par = solve(g, THREE_SLICE, opts_par, store=None)
    # pruning only discards candidates whose lower bound cannot win, so
    # the parallel plan is never worse on the same seed
    assert par.latency_s <= serial.latency_s * (1 + 1e-12)
    assert par.configs and not par.timed_out


def test_workers_do_not_change_the_store_key():
    g = polybench.build("atax")
    k1 = PlanStore.key(g, THREE_SLICE, SolverOptions(workers=1))
    k2 = PlanStore.key(g, THREE_SLICE, SolverOptions(workers=8))
    assert k1 == k2


# ---------------------------------------------------------------------------
# Deadline accounting (solve() includes fusion + enumeration)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mode", ["prometheus", "sisyphus"])
def test_tiny_budget_returns_best_feasible_not_raise(mode):
    g = polybench.build("3mm")
    plan = solve(g, THREE_SLICE,
                 SolverOptions(mode=mode, time_budget_s=0.05), store=None)
    assert plan.configs                    # feasible, not an exception
    assert plan.latency_s > 0
    assert plan.timed_out                  # and honest about it
    # solver_seconds covers the whole call (fusion + enumeration +
    # search), so it cannot be simultaneously timed-out and near-zero
    assert plan.solver_seconds >= 0.04
