"""Unit + property tests: analytic cost model (core/costmodel.py, Eqs. 12-16)."""
from __future__ import annotations

import math

import pytest
from _hypothesis_compat import given, settings, st

from repro.core import polybench
from repro.core.costmodel import (footprint_elems, n_transfers,
                                  plan_latency, task_report)
from repro.core.fusion import fuse
from repro.core.padding import TileOption
from repro.core.plan import ArrayPlacement, TaskConfig
from repro.core.resources import (ONE_SLICE, THREE_SLICE,
                                  alignment_efficiency, packing_efficiency)


def _gemm_cfg(bm=20, bn=22, bk=24, perm=("i0", "j0", "k0"),
              levels=None, buffers=2, slice_id=0):
    """TaskConfig for the gemm fused task (I=200, J=220, K=240)."""
    tiles = {"i0": TileOption(bm, 200, 200),
             "j0": TileOption(bn, 220, 220),
             "k0": TileOption(bk, 240, 240)}
    levels = levels or {}
    placements = {}
    for arr, dflt in (("A", (2, 2)), ("B", (2, 2)), ("Cout", (2, 2))):
        tl, dl = levels.get(arr, dflt)
        placements[arr] = ArrayPlacement(tl, dl, buffers=buffers)
    return TaskConfig(perm=perm, tiles=tiles, placements=placements,
                      slice_id=slice_id)


@pytest.fixture(scope="module")
def gemm_fg():
    return fuse(polybench.build("gemm"))


def test_footprints_follow_transfer_level(gemm_fg):
    task = gemm_fg.tasks[0]
    cfg = _gemm_cfg()
    # At level 3 (inside all loops) A's tile is (bm, bk)
    assert footprint_elems(cfg, task, "A", 3) == 20 * 24
    # At level 1 (inside i0 only): A covers (bm, K_full)
    assert footprint_elems(cfg, task, "A", 1) == 20 * 240
    # At level 0 (before loops): whole array
    assert footprint_elems(cfg, task, "A", 0) == 200 * 240
    # B at level 1 does not depend on i0 -> full (K, J)
    assert footprint_elems(cfg, task, "B", 1) == 240 * 220


def test_n_transfers_reuse_semantics(gemm_fg):
    """Paper d_{a,l}: a loop not indexing the array multiplies transfers
    only if the buffer is (re)defined under it."""
    task = gemm_fg.tasks[0]
    cfg = _gemm_cfg()
    # B indexed by (k0, j0); transfer at level 3, define at 3:
    # loop i0 (10 tiles) does NOT index B but define_level=3 >= 1 -> reload
    pl = ArrayPlacement(3, 3)
    assert n_transfers(cfg, task, "B", pl) == 10 * 10 * 10
    # define at level 1 (under i0): B reused across i0? define_level=1
    # means defined under i0 -> still reloaded per i0 iteration
    pl = ArrayPlacement(3, 1)
    assert n_transfers(cfg, task, "B", pl) == 10 * 10 * 10
    # define at level 0 (before loops): reused across i0 -> only j0,k0 tiles
    pl = ArrayPlacement(3, 0)
    assert n_transfers(cfg, task, "B", pl) == 10 * 10
    # transfer everything up-front: one transfer
    pl = ArrayPlacement(0, 0)
    assert n_transfers(cfg, task, "B", pl) == 1


def test_alignment_efficiency_bounds():
    assert alignment_efficiency((128, 128)) == 1.0
    assert alignment_efficiency((8, 128)) == 1.0
    # paper's 190 example: 190/256 lanes used
    assert alignment_efficiency((8, 190)) == pytest.approx(190 / 256)
    assert alignment_efficiency((5, 128)) == pytest.approx(5 / 8)
    assert 0 < alignment_efficiency((1, 1)) <= 1.0


def test_packing_efficiency_monotone_in_alignment():
    full = packing_efficiency(128, 4)
    assert full == 1.0
    assert packing_efficiency(64, 4) == pytest.approx(0.5)
    assert packing_efficiency(190, 4) == pytest.approx(190 / 256)


def test_task_report_terms_positive(gemm_fg):
    task = gemm_fg.tasks[0]
    rep = task_report(task, _gemm_cfg(), gemm_fg, ONE_SLICE)
    assert rep.latency_s > 0
    assert rep.compute_s > 0
    assert rep.load_s > 0
    assert rep.vmem_bytes > 0
    assert rep.useful_flops == task.flops
    assert rep.padded_flops >= rep.useful_flops
    # latency covers at least the pure-compute time and the serial fill
    assert rep.latency_s >= rep.fill_s


def test_overlap_beats_no_overlap(gemm_fg):
    """Eq. 14: double buffering (max) <= serial (sum), with fill terms."""
    task = gemm_fg.tasks[0]
    rep2 = task_report(task, _gemm_cfg(buffers=2), gemm_fg, ONE_SLICE)
    rep1 = task_report(task, _gemm_cfg(buffers=1), gemm_fg, ONE_SLICE)
    assert rep2.latency_s <= rep1.latency_s
    # identical traffic, only scheduling differs
    assert rep2.hbm_bytes == rep1.hbm_bytes


def test_bigger_tiles_fewer_transfers_more_vmem(gemm_fg):
    task = gemm_fg.tasks[0]
    small = task_report(task, _gemm_cfg(10, 11, 12), gemm_fg, ONE_SLICE)
    big = task_report(task, _gemm_cfg(40, 44, 48), gemm_fg, ONE_SLICE)
    assert big.vmem_bytes > small.vmem_bytes
    assert big.hbm_bytes < small.hbm_bytes


def test_padding_costs_padded_flops(gemm_fg):
    task = gemm_fg.tasks[0]
    tiles = {"i0": TileOption(32, 224, 200),       # padded 200 -> 224
             "j0": TileOption(22, 220, 220),
             "k0": TileOption(24, 240, 240)}
    cfg = TaskConfig(perm=("i0", "j0", "k0"), tiles=tiles,
                     placements={a: ArrayPlacement(2, 2)
                                 for a in ("A", "B", "Cout")})
    rep = task_report(task, cfg, gemm_fg, ONE_SLICE)
    assert rep.padded_flops == pytest.approx(task.flops * 224 / 200)


def test_dag_latency_3mm_concurrency():
    """Independent FT0/FT1 on different slices overlap; same slice
    serializes (a slice runs one task at a time)."""
    fg = fuse(polybench.build("3mm"))
    cfgs = {}
    for t in fg.tasks:
        tiles = {l: TileOption(10, t.trip_counts[l], t.trip_counts[l])
                 for l in t.loops}
        placements = {a: ArrayPlacement(1, 1)
                      for a in t.read_arrays() + [t.output_array]}
        cfgs[t.tid] = TaskConfig(perm=tuple(t.loops), tiles=tiles,
                                 placements=placements, slice_id=0)
    lat_serial, _ = plan_latency(fg, cfgs, ONE_SLICE)
    cfgs_par = {tid: c if tid != 1 else
                TaskConfig(c.perm, c.tiles, c.placements, slice_id=1)
                for tid, c in cfgs.items()}
    lat_par, _ = plan_latency(fg, cfgs_par, THREE_SLICE)
    assert lat_par < lat_serial


def test_streaming_shift_reduces_latency():
    """Eq. 12 shift: an order-compatible streamed edge lets the consumer
    start after the first tile instead of after the producer finishes."""
    fg = fuse(polybench.build("2mm"))
    # tasks: FT0 (tmp), FT1 (D). Edge tmp: FT0 -> FT1.
    def mk(t, slice_id, stream_tmp):
        tiles = {l: TileOption(10, t.trip_counts[l], t.trip_counts[l])
                 for l in t.loops}
        placements = {}
        for a in t.read_arrays() + [t.output_array]:
            st_flag = stream_tmp and a == "tmp"
            placements[a] = ArrayPlacement(1, 1, buffers=2,
                                           stream=st_flag)
        return TaskConfig(perm=tuple(t.loops), tiles=tiles,
                          placements=placements, slice_id=slice_id)

    cfg_stream = {t.tid: mk(t, t.tid, True) for t in fg.tasks}
    cfg_block = {t.tid: mk(t, t.tid, False) for t in fg.tasks}
    lat_stream, _ = plan_latency(fg, cfg_stream, THREE_SLICE)
    lat_block, _ = plan_latency(fg, cfg_block, THREE_SLICE)
    assert lat_stream <= lat_block


@settings(max_examples=30, deadline=None)
@given(bm=st.sampled_from([5, 10, 20, 25, 40, 50, 100]),
       bn=st.sampled_from([5, 10, 11, 20, 22, 44, 55]),
       bk=st.sampled_from([5, 8, 10, 12, 24, 40, 60]))
def test_report_invariants_random_tiles(bm, bn, bk):
    fg = fuse(polybench.build("gemm"))
    task = fg.tasks[0]
    rep = task_report(task, _gemm_cfg(bm, bn, bk), fg, ONE_SLICE)
    assert rep.latency_s > 0 and math.isfinite(rep.latency_s)
    assert rep.hbm_bytes >= 4 * (200 * 240 + 240 * 220 + 200 * 220) * 0.99
    assert rep.useful_flops == task.flops
