"""Multi-device tests (subprocess with fake CPU devices): sharding specs,
pipeline parallelism, gradient compression, dry-run calibration fidelity."""
from __future__ import annotations

import textwrap


from conftest import run_subprocess


def _run(code: str, n_devices: int = 8, timeout: int = 560):
    r = run_subprocess(textwrap.dedent(code), n_devices, timeout)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_sharded_train_step_matches_single_device():
    """The pjit'd train step on a (2,4) mesh computes the same loss and
    parameter update as an unsharded run — sharding is semantics-free."""
    out = _run("""
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.configs.base import smoke
        from repro.models import model as M
        from repro.train.optimizer import AdamWConfig, init_opt_state
        from repro.train.train_step import make_train_step, train_step
        cfg = dataclasses.replace(smoke(get_config('qwen1.5-0.5b')),
                                  n_layers=2, remat=False,
                                  compute_dtype='float32')
        mesh = jax.make_mesh((2, 4), ('data', 'model'))
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        opt = init_opt_state(params)
        shapes = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
        opt_cfg = AdamWConfig(warmup_steps=1, total_steps=10)
        fn, _ = make_train_step(mesh, cfg, opt_cfg, shapes, 8, 32)
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab)
        labels = jax.random.randint(jax.random.PRNGKey(2), (8, 32), 0, cfg.vocab)
        p2, o2, m2 = fn(params, opt, toks, labels)
        # reference: plain single-device step
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        p1, o1, m1 = train_step(params, init_opt_state(params), toks, labels,
                                cfg=cfg, opt_cfg=opt_cfg)
        assert abs(float(m1['loss']) - float(m2['loss'])) < 1e-4, \\
            (float(m1['loss']), float(m2['loss']))
        d = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
        assert d < 2e-4, d
        print('OK sharded==single')
    """)
    assert "OK sharded==single" in out


def test_all_archs_shard_on_test_mesh():
    """Every arch's full-size param tree gets a valid NamedSharding on a
    (2,4) mesh (abstract — eval_shape only, no allocation)."""
    out = _run("""
        import functools, jax
        from repro.configs import get_config, list_archs
        from repro.distributed import sharding as sh
        from repro.models import model as M
        mesh = jax.make_mesh((2, 4), ('data', 'model'))
        for arch in list_archs():
            cfg = get_config(arch)
            params = jax.eval_shape(
                functools.partial(M.init_params, cfg), jax.random.PRNGKey(0))
            specs = sh.shard_params(mesh, params)
            flat_p = jax.tree.leaves(params)
            flat_s = jax.tree.leaves(specs, is_leaf=lambda x: hasattr(x, 'spec'))
            assert len(flat_p) == len(flat_s)
            for p, s in zip(flat_p, flat_s):
                # every sharded dim must divide
                for dim, axes in zip(p.shape, s.spec):
                    if axes is None: continue
                    size = sh.axis_size(mesh, axes)
                    assert dim % size == 0, (arch, p.shape, s.spec)
        print('OK all archs shard')
    """)
    assert "OK all archs shard" in out


def test_pipeline_parallel_equals_sequential():
    """GPipe shard_map pipeline over 4 stages == sequential layer stack."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.pipeline import pipeline_apply
        n_stages, m, mb, d = 4, 8, 2, 16
        key = jax.random.PRNGKey(0)
        ws = jax.random.normal(key, (n_stages, d, d)) / d ** 0.5
        def stage_fn(p, x):
            return jnp.tanh(x @ p['w'])
        mesh = jax.make_mesh((4,), ('stage',))
        x = jax.random.normal(jax.random.PRNGKey(1), (m, mb, d))
        out = pipeline_apply(stage_fn, mesh, 'stage', {'w': ws}, x)
        ref = x
        for s in range(n_stages):
            ref = jnp.tanh(ref @ ws[s])
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)
        print('OK pipeline==sequential')
    """, n_devices=4)
    assert "OK pipeline==sequential" in out


def test_gradient_compression_roundtrip():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from repro.distributed import compression as C, shard_map_compat
        mesh = jax.make_mesh((4,), ('dp',))
        g = jax.random.normal(jax.random.PRNGKey(0), (4, 16, 32))
        def run(fn):
            return shard_map_compat(fn, mesh=mesh, in_specs=P('dp'),
                                    out_specs=P())(g)
        mean_ref = np.asarray(jnp.mean(g, 0))
        out32 = run(lambda x: C.allreduce_mean({'g': x[0]}, 'dp')['g'])
        # psum may associate the 4-way sum differently than jnp.mean
        np.testing.assert_allclose(np.asarray(out32), mean_ref,
                                   rtol=1e-6, atol=1e-6)
        out16 = run(lambda x: C.allreduce_mean_bf16({'g': x[0]}, 'dp')['g'])
        assert np.abs(np.asarray(out16) - mean_ref).max() < 0.02
        def int8_fn(x):
            e = C.zeros_like_errors({'g': x[0]})
            m, e2 = C.allreduce_mean_int8_ef({'g': x[0]}, e, 'dp')
            return m['g']
        out8 = run(int8_fn)
        assert np.abs(np.asarray(out8) - mean_ref).max() < 0.05
        # wire accounting
        assert C.compressed_bytes({'g': g[0]}, 'int8') < \\
            C.compressed_bytes({'g': g[0]}, 'fp32') // 3
        print('OK compression')
    """, n_devices=4)
    assert "OK compression" in out


def test_error_feedback_reduces_bias():
    """With error feedback, repeated compressed reductions of a CONSTANT
    gradient converge to the true mean (bias telescopes)."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.distributed import compression as C, shard_map_compat
        mesh = jax.make_mesh((4,), ('dp',))
        g = jax.random.normal(jax.random.PRNGKey(3), (4, 8, 8)) * \\
            jnp.logspace(-3, 0, 8)[None, None, :]   # ill-scaled rows
        mean_ref = np.asarray(jnp.mean(g, 0))
        def run(x):
            def fn(xs):
                e = C.zeros_like_errors({'g': xs[0]})
                acc = jnp.zeros_like(xs[0])
                for _ in range(8):
                    m, e = C.allreduce_mean_int8_ef({'g': xs[0]}, e, 'dp')
                    acc = acc + m['g']
                return acc / 8
            return shard_map_compat(fn, mesh=mesh, in_specs=P('dp'),
                                    out_specs=P())(x)
        avg8 = np.asarray(run(g))
        one = np.asarray(run(g))  # deterministic
        err_avg = np.abs(avg8 - mean_ref).max()
        assert err_avg < 0.02, err_avg
        print('OK error feedback')
    """, n_devices=4)
    assert "OK error feedback" in out


def test_dryrun_calibration_matches_full_unroll():
    """The 1g/2g affine extrapolation (scan-cost fix) reproduces the
    full-unroll HLO flop count within 2% on a small arch."""
    out = _run("""
        import dataclasses, jax
        from repro.launch import dryrun
        from repro.configs import get_config
        # shrink the shape so the full unroll compiles quickly
        dryrun.SHAPES['train_4k'] = dict(kind='train', seq=512, batch=8)
        cfg = dataclasses.replace(
            get_config('qwen3-0.6b'), n_layers=8, vocab=4096,
            attn_chunk=128, loss_chunk=512)
        mesh = jax.make_mesh((2, 4), ('data', 'model'))
        corrected = dryrun.calibrated_cost(cfg, 'train_4k', mesh)
        full_cfg = dataclasses.replace(cfg, unroll_layers=True,
                                       loss_chunk=1 << 30)
        lowered, _ = dryrun.lower_cell(full_cfg, 'train_4k', mesh)
        full = dryrun._measure(lowered.compile())
        # flops are affine-exact in group count; 'bytes accessed' is a
        # fusion-dependent proxy (XLA fuses 2-layer and 8-layer programs
        # slightly differently) — hold it to 15%.
        for k, tol in (('flops', 0.02), ('bytes', 0.15)):
            rel = abs(corrected[k] - full[k]) / max(full[k], 1)
            assert rel < tol, (k, corrected[k], full[k], rel)
        print('OK calibration flops=%.3e vs full=%.3e' %
              (corrected['flops'], full['flops']))
    """, n_devices=8)
    assert "OK calibration" in out


def test_lower_cell_all_kinds_on_test_mesh():
    """train / prefill / decode lowerings succeed on a small mesh for a
    reduced arch (structure identical to the 512-device dry-run)."""
    out = _run("""
        import dataclasses, jax
        from repro.launch import dryrun
        from repro.configs import get_config
        dryrun.SHAPES.update(
            train_4k=dict(kind='train', seq=256, batch=8),
            prefill_32k=dict(kind='prefill', seq=512, batch=8),
            decode_32k=dict(kind='decode', seq=512, batch=8))
        mesh = jax.make_mesh((2, 4), ('data', 'model'))
        for arch in ('qwen3-0.6b', 'rwkv6-1.6b', 'recurrentgemma-9b'):
            cfg = dataclasses.replace(get_config(arch), n_layers=4,
                                      vocab=4096, attn_chunk=128)
            if arch == 'recurrentgemma-9b':
                cfg = dataclasses.replace(cfg, n_layers=6)
            for shape in ('train_4k', 'prefill_32k', 'decode_32k'):
                lowered, aux = dryrun.lower_cell(cfg, shape, mesh)
                lowered.compile()
        print('OK lower all kinds')
    """, n_devices=8)
    assert "OK lower all kinds" in out
