"""Observability layer (``repro.obs``) + its serving integration.

What these pin down:

* the metrics registry is the single source of truth — ``stats()``,
  ``expose()`` and the legacy counter attributes all read the same
  numbers, and the accounting closures (``ok+fallbacks == completed``,
  ``completed+expired+errors == enqueued``) hold under threaded chaos;
* the span tracer is bounded (ring buffer drops, never grows) and its
  Chrome-trace export is loadable JSON with microsecond complete events;
* drift detection is deterministic on an injected clock: min-samples,
  threshold band (both directions), cooldown, and EMA reset on re-plan;
* ``stats()`` never deadlocks against a concurrent submit storm — the
  lock-ordering regression test for the nested-lock assembly bug.
"""
from __future__ import annotations

import json
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SolverOptions
from repro.ft import ChaosPlan
from repro.obs import (DriftConfig, DriftDetector, MetricsRegistry,
                       ProgramProfiler, Tracer, chrome_trace)
from repro.serve import BatchConfig, PlanEngine, ServeConfig

_RNG = np.random.default_rng(0)
_WA = jnp.asarray(_RNG.standard_normal((16, 16)).astype(np.float32) * 0.1)
_X = jnp.asarray(_RNG.standard_normal((8, 16)).astype(np.float32))


def _mm(x):
    return x @ _WA


def _engine(sc: ServeConfig | None = None, name: str = "f") -> PlanEngine:
    eng = PlanEngine(sc=sc or ServeConfig())
    tf = eng.register_function(name, _mm, (_X,),
                               solver_opts=SolverOptions(time_budget_s=0.5))
    assert tf is not None
    return eng


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------
def test_counter_inc_returns_new_value_and_snapshot():
    m = MetricsRegistry()
    c = m.counter("t_total", "help")
    assert c.inc() == 1
    assert c.inc(4) == 5
    assert c.value == 5
    assert c.snapshot() == {(): 5}


def test_registry_get_or_create_and_type_mismatch():
    m = MetricsRegistry()
    a = m.counter("x_total")
    b = m.counter("x_total")
    assert a is b
    with pytest.raises(TypeError):
        m.gauge("x_total")


def test_labeled_children_and_remove():
    m = MetricsRegistry()
    c = m.counter("per_entry_total", labelnames=("entry",))
    c.labels("a").inc(3)
    c.labels("b").inc()
    assert m.value("per_entry_total", "a") == 3
    assert c.snapshot() == {("a",): 3, ("b",): 1}
    c.remove("a")
    assert c.snapshot() == {("b",): 1}
    assert m.value("per_entry_total", "a") == 0     # never-touched => 0
    with pytest.raises(ValueError):
        c.labels("a", "too-many")


def test_gauge_set_inc_dec_and_fn_backed():
    m = MetricsRegistry()
    g = m.gauge("depth")
    g.set(7)
    g.inc()
    g.dec(3)
    assert g.value == 5
    f = m.gauge("live", fn=lambda: 42)
    assert f.value == 42


def test_histogram_buckets_count_sum_quantile():
    m = MetricsRegistry()
    h = m.histogram("lat_seconds", buckets=(0.01, 0.1, 1.0))
    for v in (0.005, 0.05, 0.05, 0.5, 2.0):
        h.observe(v)
    snap = h.snapshot()[()]
    assert snap["count"] == 5
    assert snap["sum"] == pytest.approx(2.605)
    assert snap["counts"] == [1, 2, 1, 1]       # last is the +Inf tail
    assert h.quantile(0.5) == 0.1               # upper-bound interpolation


def test_expose_prometheus_text_format():
    m = MetricsRegistry()
    m.counter("req_total", "requests").inc(3)
    m.counter("per_total", "per entry", ("entry",)).labels('a"\\b').inc()
    m.gauge("inflight", "in flight").set(2)
    h = m.histogram("rt_seconds", "latency", buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(5.0)
    text = m.expose()
    lines = text.strip().split("\n")
    assert "# HELP req_total requests" in lines
    assert "# TYPE req_total counter" in lines
    assert "req_total 3" in lines
    # label values escaped per the text format
    assert 'per_total{entry="a\\"\\\\b"} 1' in lines
    assert "inflight 2" in lines
    # histogram: cumulative buckets ending at +Inf == _count
    assert 'rt_seconds_bucket{le="0.1"} 1' in lines
    assert 'rt_seconds_bucket{le="1"} 1' in lines
    assert 'rt_seconds_bucket{le="+Inf"} 2' in lines
    assert "rt_seconds_count 2" in lines
    # every sample line is "name{...} value" with a numeric value
    for ln in lines:
        if ln.startswith("#"):
            continue
        float(ln.rsplit(" ", 1)[1])


def test_invariants_checked_from_registry():
    m = MetricsRegistry()
    a = m.counter("a_total")
    b = m.counter("b_total")
    m.register_invariant("a==b", lambda: a.value == b.value)
    assert m.check_invariants() == []
    a.inc()
    assert m.check_invariants() == ["a==b"]
    b.inc()
    assert m.check_invariants() == []


# ---------------------------------------------------------------------------
# Span tracer
# ---------------------------------------------------------------------------
def test_tracer_ring_buffer_bounds_and_drop_count():
    t = Tracer(capacity=4, enabled=True)
    for i in range(10):
        t.record("s", "test", float(i), 0.001, {"i": i})
    st = t.stats()
    assert st["buffered"] == 4 and st["recorded"] == 10
    assert st["dropped"] == 6
    names = [s.args["i"] for s in t.snapshot()]
    assert names == [6, 7, 8, 9]                # oldest evicted first


def test_disabled_tracer_is_noop():
    t = Tracer(capacity=4, enabled=False)
    with t.span("x", "test", entry="e") as sp:
        sp.set(more=1)                          # null span accepts set()
    t.record("y", "test", 0.0, 1.0)
    assert t.snapshot() == []
    assert t.stats()["recorded"] == 0


def test_live_span_times_block_and_records_error_class():
    t = Tracer(capacity=16, enabled=True)
    with t.span("ok", "test", entry="e") as sp:
        time.sleep(0.01)
        sp.set(extra=7)
    with pytest.raises(ValueError):
        with t.span("boom", "test"):
            raise ValueError("injected")
    ok, boom = t.snapshot()
    assert ok.name == "ok" and ok.dur_s >= 0.009
    assert ok.args == {"entry": "e", "extra": 7}
    assert boom.args["error"] == "ValueError"


def test_chrome_trace_export_round_trips():
    t = Tracer(capacity=16, enabled=True)
    with t.span("a", "request", entry="e"):
        time.sleep(0.002)
    t.record("b", "solver", 100.0, 0.5, {"k": 1})
    doc = json.loads(json.dumps(chrome_trace(t.snapshot())))
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    assert len(evs) == 2
    for ev in evs:
        assert ev["ph"] == "X"
        assert ev["ts"] >= 0 and ev["dur"] > 0
        assert set(ev) >= {"name", "cat", "pid", "tid", "args"}
    # timestamps are rebased to the earliest span
    assert min(ev["ts"] for ev in evs) == 0


# ---------------------------------------------------------------------------
# Drift detection (fake clock)
# ---------------------------------------------------------------------------
def _detector(**kw):
    clock = {"t": 0.0}
    cfg = DriftConfig(**{"sample_every": 1, "min_samples": 3,
                         "ratio_threshold": 2.0, "cooldown_s": 10.0, **kw})
    return DriftDetector(cfg, clock=lambda: clock["t"]), clock


def test_drift_needs_min_samples_and_prediction():
    det, _ = _detector()
    for _ in range(5):
        assert det.observe("m", 1.0) is None    # no prediction yet
    det.note_predicted("m", 0.1)                # resets the EMA
    assert det.observe("m", 1.0) is None        # samples 1, 2 < min
    assert det.observe("m", 1.0) is None
    ev = det.observe("m", 1.0)
    assert ev is not None and ev.ratio > 2.0 and ev.samples == 3


def test_drift_fires_both_directions_and_cooldown():
    det, clock = _detector()
    det.note_predicted("m", 1.0)
    for _ in range(3):
        assert det.observe("m", 1.0) is None    # ratio 1.0: in band
    # 10x slower than predicted: fires once, then cooldown suppresses
    assert det.observe("m", 30.0) is not None
    assert det.observe("m", 30.0) is None
    clock["t"] += 11.0                          # past cooldown: re-fires
    assert det.observe("m", 30.0) is not None
    # 10x faster also counts as drift (stale pessimistic plan)
    det.note_predicted("m", 1.0)
    det.note_predicted("m", 100.0)              # changed => EMA reset
    clock["t"] += 11.0
    for _ in range(2):
        det.observe("m", 1.0)
    ev = det.observe("m", 1.0)
    assert ev is not None and ev.ratio < 0.5


def test_note_predicted_same_value_keeps_ema():
    det, _ = _detector()
    det.note_predicted("m", 1.0)
    det.observe("m", 5.0)
    det.note_predicted("m", 1.0)                # unchanged: no reset
    assert det.stats()["entries"]["m"]["samples"] == 1
    det.note_predicted("m", 2.0)                # changed: reset
    assert det.stats()["entries"]["m"]["samples"] == 0
    det.forget("m")
    assert det.stats()["entries"] == {}


def test_drift_stats_shape():
    det, _ = _detector()
    det.note_predicted("m", 1.0)
    for _ in range(3):
        det.observe("m", 4.0)
    st = det.stats()
    assert st["triggers"] == 1
    e = st["entries"]["m"]
    assert e["drifted"] is True
    assert e["ratio"] == pytest.approx(4.0)
    assert e["predicted_s"] == 1.0


# ---------------------------------------------------------------------------
# Program profiler
# ---------------------------------------------------------------------------
def test_profiler_sampling_cadence_and_aggregation():
    p = ProgramProfiler(sample_every=3)
    assert p.enabled
    hits = [p.should_sample("prog") for _ in range(9)]
    assert hits == [False, False, True] * 3     # one in three, per key
    p.record_segment("prog", "xla", 0, 0.5, n_tasks=2, waves=(1, 1))
    p.record_segment("prog", "xla", 0, 1.5, n_tasks=2, waves=(1, 1))
    seg = p.stats()["programs"]["prog"]["xla"][0]
    assert seg["count"] == 2
    assert seg["mean_s"] == pytest.approx(1.0)
    assert seg["min_s"] == 0.5 and seg["max_s"] == 1.5
    p.clear()
    assert p.stats()["programs"] == {}
    assert not ProgramProfiler(sample_every=0).should_sample("prog")


# ---------------------------------------------------------------------------
# Engine integration: registry is the single source of truth
# ---------------------------------------------------------------------------
def test_engine_stats_exposition_and_invariants_agree():
    eng = _engine()
    try:
        for _ in range(5):
            eng.submit("f", (_X,))
        st = eng.stats()
        assert st["requests"] == 5 == eng.requests
        assert st["per_name"]["f"] == 5
        assert eng.metrics.value("repro_requests_total") == 5
        assert eng.metrics.value("repro_entry_ok_total", "f") == 5
        text = eng.metrics.expose()
        assert "repro_requests_total 5" in text
        assert 'repro_entry_requests_total{entry="f"} 5' in text
        assert "# TYPE repro_request_seconds histogram" in text
        assert eng.check_invariants() == []
        assert st["drift"]["entries"]["f"]["predicted_s"] > 0
    finally:
        eng.shutdown()


def test_unregister_drops_labeled_children():
    eng = _engine()
    try:
        eng.submit("f", (_X,))
        assert eng.per_name == {"f": 1}
        eng.unregister("f")
        assert eng.per_name == {}
        assert 'entry="f"' not in eng.metrics.expose()
    finally:
        eng.shutdown()


def test_drift_triggers_background_plan_refresh():
    """An absurd predicted latency must fire drift and kick the existing
    background re-solve + store-refresh path (the PR's closing loop)."""
    sc = ServeConfig(drift=DriftConfig(sample_every=1, min_samples=3,
                                       ratio_threshold=2.0, cooldown_s=3600.0))
    eng = _engine(sc=sc)
    try:
        eng.note_predicted_latency("f", 1e-12)  # everything looks drifted
        for _ in range(6):
            eng.submit("f", (_X,))
        st = eng.stats()
        assert st["drift"]["triggers"] >= 1
        assert st["drift"]["entries"]["f"]["drifted"] is True
        # the refresh lands asynchronously (backoff before first attempt)
        deadline = time.monotonic() + 60.0
        while time.monotonic() < deadline:
            if eng.plan_refreshes >= 1:
                break
            time.sleep(0.05)
        assert eng.stats()["plan_store"]["refreshes"] >= 1
        assert eng.metrics.value("repro_drift_triggers_total") >= 1
        # serving continued throughout: accounting still closes
        assert eng.check_invariants() == []
    finally:
        eng.shutdown()


# ---------------------------------------------------------------------------
# Threaded chaos stress: accounting closures under injected faults
# ---------------------------------------------------------------------------
def test_threaded_chaos_accounting_closes():
    cp = ChaosPlan(batch_fail_at=(0,), execute_fail_at=(3, 7))
    sc = ServeConfig(chaos=cp,
                     batching=BatchConfig(max_batch=4, max_wait_s=0.001))
    eng = _engine(sc=sc)
    try:
        n_threads, per_thread = 6, 8
        barrier = threading.Barrier(n_threads)
        futures: list = []
        flock = threading.Lock()
        errors: list[BaseException] = []

        def worker():
            try:
                barrier.wait()
                mine = [eng.submit_async("f", (_X,))
                        for _ in range(per_thread)]
                with flock:
                    futures.extend(mine)
            except BaseException as e:
                errors.append(e)

        threads = [threading.Thread(target=worker)
                   for _ in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=120)
        assert not errors
        for f in futures:
            f.result(timeout=120)               # no dropped futures
        st = eng.stats()["batching"]
        total = n_threads * per_thread
        assert st["enqueued"] == total
        assert st["ok"] + st["fallbacks"] == st["completed"]
        assert (st["completed"] + st["expired"] + st["errors"]
                == st["enqueued"])
        assert st["completed"] == total and st["errors"] == 0
        # the same closures, asserted where they live: the registry
        assert eng.check_invariants() == []
        # chaos really fired (the closures held under faults, not calm)
        resil = eng.stats()["resilience"]["entries"]
        assert st["batch_failures"] >= 1 or any(
            e["fallbacks"] >= 1 for e in resil.values())
    finally:
        eng.shutdown()


# ---------------------------------------------------------------------------
# Deadlock regression: stats() vs. a concurrent submit storm
# ---------------------------------------------------------------------------
def test_stats_never_deadlocks_against_submit_storm():
    """The old ``stats()`` assembled nested output while holding the
    engine lock and calling into sub-objects that take their own locks
    (breaker, batcher, program cache) — one inverted acquisition away
    from deadlock.  The rewrite snapshots the registry first and holds
    the engine lock only over plain-data copies; this pins it with a
    storm of submits racing stats()/expose() readers under a watchdog."""
    sc = ServeConfig(batching=BatchConfig(max_batch=4, max_wait_s=0.001))
    eng = _engine(sc=sc)
    try:
        stop = threading.Event()
        errors: list[BaseException] = []

        def submitter():
            try:
                while not stop.is_set():
                    eng.submit("f", (_X,))
                    eng.submit_async("f", (_X,)).result(timeout=60)
            except BaseException as e:
                errors.append(e)

        def reader():
            try:
                while not stop.is_set():
                    st = eng.stats()
                    assert "drift" in st and "requests" in st
                    eng.metrics.expose()
                    eng.check_invariants()
            except BaseException as e:
                errors.append(e)

        threads = ([threading.Thread(target=submitter) for _ in range(3)]
                   + [threading.Thread(target=reader) for _ in range(3)])
        for t in threads:
            t.start()
        time.sleep(2.0)
        stop.set()
        for t in threads:
            t.join(timeout=60)
        stuck = [t for t in threads if t.is_alive()]
        assert not stuck, f"deadlocked threads: {stuck}"
        assert not errors
        # the storm really exercised both paths
        assert eng.requests > 0
    finally:
        eng.shutdown()
