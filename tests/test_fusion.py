"""Unit tests: output-stationary fusion (core/fusion.py) — paper §3.1."""
from __future__ import annotations

import pytest

from repro.core import polybench
from repro.core.fusion import fuse
from repro.core.taskgraph import Access, Array, Statement, TaskGraph


def test_3mm_fuses_to_three_tasks_like_paper():
    """Listing 6: FT0 = {S0,S1} (E), FT1 = {S2,S3} (F), FT2 = {S4,S5} (G)."""
    fg = fuse(polybench.build("3mm"))
    assert len(fg.tasks) == 3
    outs = [t.output_array for t in fg.tasks]
    assert outs == ["E", "F", "G"]
    for t in fg.tasks:
        assert len(t.statements) == 2          # init + mac
    # dataflow edges E->G and F->G (paper Fig. 3 after fusion)
    assert set(fg.edges) == {(0, 2, "E"), (1, 2, "F")}


def test_3mm_topo_order_and_sinks():
    fg = fuse(polybench.build("3mm"))
    order = fg.topo_order()
    assert order.index(0) < order.index(2)
    assert order.index(1) < order.index(2)
    assert fg.sinks() == [2]


def test_fused_task_loops_and_trip_counts():
    fg = fuse(polybench.build("3mm"))
    ft0 = fg.tasks[0]
    assert ft0.main.name == "E_mac"            # dominant statement
    assert set(ft0.loops) == {"i0", "j0", "k0"}
    assert ft0.trip_counts == {"i0": 180, "j0": 190, "k0": 200}
    # accumulator reads of own output are not transfers
    assert sorted(ft0.read_arrays()) == ["A", "B"]


def test_no_fusion_across_intervening_reader():
    """A statement consuming the array between writers blocks fusion."""
    arrays = {k: Array(k, (8,)) for k in ("A", "B", "C")}
    stmts = [
        Statement("w1", ("i",), {"i": 8}, (), (Access("A", ("i",)),), 0.0),
        Statement("r", ("i",), {"i": 8}, (Access("A", ("i",)),),
                  (Access("B", ("i",)),), 1.0),
        Statement("w2", ("i",), {"i": 8},
                  (Access("A", ("i",)), Access("C", ("i",))),
                  (Access("A", ("i",)),), 1.0),
    ]
    fg = fuse(TaskGraph("g", arrays, stmts))
    assert len(fg.tasks) == 3                  # w2 NOT fused into w1


def test_atax_fusion_matches_paper_table9():
    """Table 9 atax: FT0 = {tmp_init, tmp_mac}, FT1 = {y_init, y_mac}."""
    fg = fuse(polybench.build("atax"))
    assert len(fg.tasks) == 2
    assert [t.output_array for t in fg.tasks] == ["tmp", "y"]
    assert set(fg.edges) == {(0, 1, "tmp")}


@pytest.mark.parametrize("name", sorted(polybench.BUILDERS))
def test_fusion_preserves_flops_and_is_acyclic(name):
    g = polybench.build(name)
    fg = fuse(g)
    assert sum(t.flops for t in fg.tasks) == g.total_flops()
    fg.topo_order()                            # raises on cycles
    # every edge joins distinct tasks, array is written by the producer
    for (u, v, arr) in fg.edges:
        assert u != v
        assert arr in {w.array for s in fg.tasks[u].statements
                       for w in s.writes}
