"""Shared fixtures.  NOTE: no XLA_FLAGS here — unit/smoke tests must see
the real single CPU device; multi-device tests spawn subprocesses that set
``--xla_force_host_platform_device_count`` themselves."""
from __future__ import annotations

import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def run_subprocess(code: str, n_devices: int = 8, timeout: int = 600) \
        -> subprocess.CompletedProcess:
    """Run python code in a fresh process with N fake CPU devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = \
        f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    return subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)
