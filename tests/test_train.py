"""Training substrate: optimizer, data pipeline, checkpointing,
fault-tolerant loop (checkpoint-restart), straggler policy."""
from __future__ import annotations

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.checkpoint import io as ckpt_io
from repro.configs import get_config
from repro.configs.base import smoke
from repro.data import DataConfig, PrefetchLoader, SyntheticLM
from repro.ft import FailurePlan, InjectedFailure, run_with_restarts
from repro.ft.straggler import StragglerConfig, StragglerMonitor
from repro.train.loop import TrainConfig, train
from repro.train.optimizer import (AdamWConfig, adamw_update,
                                   init_opt_state, lr_schedule)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------
def test_adamw_matches_manual_single_param():
    cfg = AdamWConfig(lr=1e-2, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0,
                      grad_clip=1e9, warmup_steps=0, total_steps=10**9,
                      min_lr_frac=1.0)
    p = {"w": jnp.array([[1.0, 2.0]])}
    g = {"w": jnp.array([[0.1, -0.2]])}
    st = init_opt_state(p)
    new_p, st2, _ = adamw_update(cfg, p, g, st)
    m = 0.1 * np.array([0.1, -0.2])
    v = 0.01 * np.array([0.1, -0.2]) ** 2
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.99)
    expect = np.array([1.0, 2.0]) - 1e-2 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(np.asarray(new_p["w"])[0], expect, rtol=1e-6)
    assert int(st2.step) == 1


def test_grad_clip_scales_update():
    cfg = AdamWConfig(lr=1.0, grad_clip=1.0, warmup_steps=0,
                      weight_decay=0.0, min_lr_frac=1.0)
    p = {"w": jnp.ones((4, 4))}
    g = {"w": jnp.full((4, 4), 100.0)}           # norm = 400
    _, st, metrics = adamw_update(cfg, p, g, init_opt_state(p))
    assert float(metrics["grad_norm"]) == pytest.approx(400.0)
    # clipped: effective grad norm 1.0 -> m = 0.1 * g_clipped
    np.testing.assert_allclose(np.asarray(st.m["w"]),
                               0.1 * 100.0 / 400.0, rtol=1e-5)


def test_lr_schedule_warmup_and_cosine():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110,
                      min_lr_frac=0.1)
    assert float(lr_schedule(cfg, jnp.asarray(0))) == 0.0
    assert float(lr_schedule(cfg, jnp.asarray(5))) == pytest.approx(0.5)
    assert float(lr_schedule(cfg, jnp.asarray(10))) == pytest.approx(1.0)
    end = float(lr_schedule(cfg, jnp.asarray(110)))
    assert end == pytest.approx(0.1, rel=1e-3)
    mid = float(lr_schedule(cfg, jnp.asarray(60)))
    assert 0.1 < mid < 1.0


def test_weight_decay_skips_1d_params():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.5, warmup_steps=0,
                      min_lr_frac=1.0, grad_clip=1e9)
    p = {"w2d": jnp.ones((2, 2)), "norm": jnp.ones((2,))}
    g = jax.tree.map(jnp.zeros_like, p)
    new_p, _, _ = adamw_update(cfg, p, g, init_opt_state(p))
    assert float(new_p["w2d"][0, 0]) == pytest.approx(1 - 0.1 * 0.5)
    assert float(new_p["norm"][0]) == 1.0


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------
def test_synthetic_data_deterministic_per_step():
    cfg = DataConfig(vocab=64, seq_len=32, global_batch=4, seed=1)
    d1, d2 = SyntheticLM(cfg), SyntheticLM(cfg)
    for step in (0, 3, 17):
        a, la = d1.batch(step)
        b, lb = d2.batch(step)
        np.testing.assert_array_equal(a, b)
        np.testing.assert_array_equal(la, lb)
    a0, _ = d1.batch(0)
    a1, _ = d1.batch(1)
    assert not np.array_equal(a0, a1)


def test_synthetic_data_labels_are_shifted_tokens():
    cfg = DataConfig(vocab=64, seq_len=32, global_batch=2, seed=0)
    toks, labels = SyntheticLM(cfg).batch(0)
    np.testing.assert_array_equal(toks[:, 1:], labels[:, :-1])


def test_prefetch_loader_order_and_seek():
    cfg = DataConfig(vocab=64, seq_len=8, global_batch=2, seed=0)
    src = SyntheticLM(cfg)
    loader = PrefetchLoader(src)
    try:
        a0 = loader.next()
        a1 = loader.next()
        np.testing.assert_array_equal(a0[0], src.batch(0)[0])
        np.testing.assert_array_equal(a1[0], src.batch(1)[0])
        loader.seek(10)
        a10 = loader.next()
        np.testing.assert_array_equal(a10[0], src.batch(10)[0])
    finally:
        loader.close()


def test_host_sharded_batches_disjoint():
    h0 = SyntheticLM(DataConfig(vocab=32, seq_len=8, global_batch=4,
                                seed=5, n_hosts=2, host_id=0))
    h1 = SyntheticLM(DataConfig(vocab=32, seq_len=8, global_batch=4,
                                seed=5, n_hosts=2, host_id=1))
    assert h0.cfg.host_batch == 2
    a, _ = h0.batch(0)
    b, _ = h1.batch(0)
    assert a.shape == (2, 8)
    assert not np.array_equal(a, b)


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "nest": {"b": jnp.ones((4,), jnp.bfloat16)},
            "step": jnp.asarray(7, jnp.int32)}
    ckpt_io.save(str(tmp_path), 3, tree)
    like = jax.tree.map(lambda x: np.zeros(x.shape, x.dtype), tree)
    back = ckpt_io.restore(str(tmp_path), 3, like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomicity_no_commit_is_invisible(tmp_path):
    tree = {"a": jnp.ones((2,))}
    path = ckpt_io.save(str(tmp_path), 1, tree)
    os.remove(os.path.join(path, "COMMIT"))
    assert ckpt_io.latest_step(str(tmp_path)) is None


def test_checkpoint_manager_rotation(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for step in (1, 2, 3, 4):
        mgr.save(step, {"x": jnp.asarray([step])})
    assert mgr.latest_step() == 4
    kept = sorted(os.listdir(tmp_path))
    assert kept == ["step_00000003", "step_00000004"]


def test_checkpoint_async_save_visible_after_wait(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=True)
    mgr.save(5, {"x": jnp.arange(3)})
    mgr.wait()
    back, step = mgr.restore({"x": np.zeros(3, np.int32)})
    assert step == 5
    np.testing.assert_array_equal(np.asarray(back["x"]), [0, 1, 2])


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------
def test_run_with_restarts_replays_from_checkpoint():
    saves: dict[int, int] = {}

    def step_fn(state, step):
        plan.maybe_fail(step)
        return state + 1

    def save_fn(state, step):
        saves[step] = state

    def restore_fn():
        if not saves:
            return None, None
        step = max(saves)
        return saves[step], step

    plan = FailurePlan(at_steps=(7,))
    final, stats = run_with_restarts(
        total_steps=10, state=0, step_fn=step_fn, save_fn=save_fn,
        restore_fn=restore_fn, checkpoint_every=5, max_restarts=3,
        failure_plan=plan)
    assert final == 10                       # every step executed
    assert stats.restarts == 1
    assert stats.replayed_steps == 2         # steps 5,6 replayed


def test_run_with_restarts_budget_exhausted():
    def step_fn(state, step):
        raise InjectedFailure("always")

    with pytest.raises(InjectedFailure):
        run_with_restarts(
            total_steps=3, state=0, step_fn=step_fn,
            save_fn=lambda s, t: None, restore_fn=lambda: (None, None),
            checkpoint_every=1, max_restarts=2)


def test_training_loop_end_to_end_with_injected_failure(tmp_path):
    """Loss decreases AND an injected mid-run failure is absorbed by
    checkpoint-restart with identical final history (determinism)."""
    cfg = dataclasses.replace(smoke(get_config("qwen1.5-0.5b")),
                              n_layers=2, remat=False)
    tc = TrainConfig(total_steps=16, checkpoint_every=5,
                     checkpoint_dir=str(tmp_path / "ck1"),
                     global_batch=4, seq_len=32, log_every=100)
    opt_cfg = AdamWConfig(lr=5e-3, warmup_steps=2, total_steps=16)
    _, hist_clean, stats_clean = train(cfg, tc, opt_cfg=opt_cfg)
    assert stats_clean.restarts == 0
    losses = [l for _, l in hist_clean]
    assert min(losses[-4:]) < losses[0], "loss must decrease"

    tc2 = dataclasses.replace(tc, checkpoint_dir=str(tmp_path / "ck2"))
    _, hist_fail, stats_fail = train(
        cfg, tc2, opt_cfg=opt_cfg, failure_plan=FailurePlan(at_steps=(7,)))
    assert stats_fail.restarts == 1
    # deterministic replay: the last executed step matches the clean run
    clean = dict(hist_clean)
    fail = dict(hist_fail)
    assert fail[15] == pytest.approx(clean[15], rel=1e-5)


# ---------------------------------------------------------------------------
# straggler policy
# ---------------------------------------------------------------------------
def test_straggler_flagged_after_patience():
    mon = StragglerMonitor(4, StragglerConfig(threshold=1.5, patience=3,
                                              min_steps=2))
    flagged = []
    for step in range(10):
        times = [1.0, 1.0, 1.0, 1.0]
        if step >= 4:
            times[2] = 3.0                   # host 2 goes slow
        flagged = mon.observe(times)
        if flagged:
            break
    assert flagged == [2]
    shares = mon.demote(2)
    assert set(shares) == {0, 1, 3}
    assert sum(shares.values()) == pytest.approx(1.0)


def test_straggler_transient_blip_not_flagged():
    mon = StragglerMonitor(2, StragglerConfig(threshold=1.5, patience=3,
                                              min_steps=2))
    for step in range(10):
        times = [1.0, 3.0 if step == 5 else 1.0]   # single blip
        assert mon.observe(times) == []
