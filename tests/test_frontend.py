"""repro.frontend: jaxpr capture -> TaskGraph -> solved whole-plan program.

Coverage contract:
* every supported primitive round-trips against the ``jax.jit`` oracle;
* a function containing unsupported primitives still executes end-to-end
  through opaque fallback partitioning (with coverage < 1);
* the trace cache shares lowerings (and graphs) across identical traces;
* a ``repro.models`` FFN block and a >=3-matmul chain execute correctly on
  both the ``xla`` and ``pallas_interpret`` impls (the acceptance bar);
* traced workloads serve through ``PlanEngine.register_function``.
"""
from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import frontend
from repro.codegen import OPAQUE_PREFIX
from repro.core.solver import SolverOptions, build_graph

OPTS = SolverOptions(time_budget_s=6.0)


def _arr(shape, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


def _roundtrip(fn, *args, impl=None, full_coverage=True, opts=OPTS):
    tf = frontend.trace(fn, *args)
    if full_coverage:
        assert tf.coverage.eqn_ratio == 1.0, tf.coverage.to_jsonable()
    tf.validate(impl=impl, plan=tf.solve(opts=opts))
    return tf


# ---------------------------------------------------------------------------
# Per-primitive round trips vs the jax.jit oracle
# ---------------------------------------------------------------------------
def test_dot_general_plain():
    _roundtrip(lambda a, b: a @ b, _arr((17, 23)), _arr((23, 11), 1))


def test_dot_general_batched():
    f = lambda a, b: jnp.einsum("bik,bkj->bij", a, b)   # noqa: E731
    _roundtrip(f, _arr((3, 8, 12)), _arr((3, 12, 6), 1))


def test_dot_general_multi_contract():
    f = lambda a, b: jnp.einsum("ikl,klj->ij", a, b)    # noqa: E731
    _roundtrip(f, _arr((7, 5, 6)), _arr((5, 6, 9), 1))


def test_elementwise_add_mul_sub():
    f = lambda a, b: (a + b) * a - b                    # noqa: E731
    _roundtrip(f, _arr((9, 14)), _arr((9, 14), 1))


def test_elementwise_scalar_and_neg():
    f = lambda a: -(a * 2.0) + 1.5                      # noqa: E731
    _roundtrip(f, _arr((6, 10)))


def test_broadcast_in_dim_vector_bias():
    f = lambda a, b: a + b                              # noqa: E731
    _roundtrip(f, _arr((12, 7)), _arr((7,), 1))


def test_broadcast_size1_dim():
    f = lambda a, b: a * b                              # noqa: E731
    _roundtrip(f, _arr((5, 8)), _arr((1, 8), 1))


def test_transpose():
    f = lambda a: a.T @ a                               # noqa: E731
    _roundtrip(f, _arr((13, 9)))


def test_transpose_3d():
    f = lambda a: jnp.transpose(a, (2, 0, 1))           # noqa: E731
    _roundtrip(f, _arr((4, 5, 6)))


def test_reduce_sum_axis():
    f = lambda a: a.sum(axis=0)                         # noqa: E731
    _roundtrip(f, _arr((11, 15)))


def test_reduce_sum_multi_axis():
    f = lambda a: a.sum(axis=(0, 2))                    # noqa: E731
    _roundtrip(f, _arr((5, 7, 6)))


def test_reduce_sum_to_scalar_goes_opaque():
    tf = frontend.trace(lambda a: a.sum() * a, _arr((6, 7)))
    assert tf.coverage.eqn_ratio < 1.0      # rank-0 result + its consumer
    tf.validate(plan=tf.solve(opts=OPTS))


def test_pjit_inlining_sees_through_jax_nn():
    x = _arr((8, 16))
    tf = frontend.trace(jax.nn.silu, x)
    # silu = x * logistic(x): the mul is supported, logistic is opaque
    assert tf.coverage.n_supported >= 1
    assert 0.0 < tf.coverage.eqn_ratio < 1.0
    tf.validate(plan=tf.solve(opts=OPTS))


# ---------------------------------------------------------------------------
# Fallback partitioning around unsupported primitives
# ---------------------------------------------------------------------------
def test_unsupported_primitive_fallback_partition():
    def fn(a, b):
        h = a @ b                 # supported
        h = jnp.tanh(h)           # opaque
        return h @ b.T            # supported again

    a, b = _arr((10, 12)), _arr((12, 8), 1)
    tf = frontend.trace(fn, a, b)
    cov = tf.coverage
    assert cov.n_supported == 3 and cov.n_eqns == 4
    ops = [s.op for s in tf.graph.statements]
    assert any(op.startswith(OPAQUE_PREFIX) for op in ops)
    assert sum(op == "mul" for op in ops) == 2
    tf.validate(plan=tf.solve(opts=OPTS))


def test_fully_opaque_function_still_runs():
    fn = lambda a: jnp.sort(jnp.abs(a), axis=0)         # noqa: E731
    tf = frontend.trace(fn, _arr((6, 4)))
    assert tf.coverage.eqn_ratio == 0.0
    tf.validate(plan=tf.solve(opts=OPTS))


def test_non_f32_dtypes_go_opaque_but_execute():
    def fn(a):
        h = a.astype(jnp.bfloat16)
        return (h @ h.T).astype(jnp.float32)

    tf = frontend.trace(fn, _arr((6, 9)))
    assert tf.coverage.eqn_ratio == 0.0     # bf16 dot is outside the subset
    tf.validate(plan=tf.solve(opts=OPTS))


def test_output_consumed_downstream_is_still_returned():
    def fn(a, b):
        e = a @ b
        return e, e @ b.T         # e is both an output and consumed

    tf = frontend.trace(fn, _arr((7, 5)), _arr((5, 9), 1))
    tf.validate(plan=tf.solve(opts=OPTS))


def test_passthrough_and_constant_outputs():
    def fn(a):
        return a, jnp.float32(3.0), a @ a.T

    tf = frontend.trace(fn, _arr((5, 5)))
    out = tf.executable(opts=OPTS)(_arr((5, 5)))
    ref = jax.jit(fn)(_arr((5, 5)))
    for o, r in zip(jax.tree_util.tree_leaves(out),
                    jax.tree_util.tree_leaves(ref)):
        np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                                   rtol=2e-4, atol=2e-3)


def test_closure_consts_are_hoisted_and_bound_per_trace():
    w1 = _arr((6, 8), 3)
    w2 = _arr((6, 8), 4)

    def make(w):
        return lambda x: x @ (w * 1.0)

    tf1 = frontend.trace(make(w1), _arr((4, 6)))
    tf2 = frontend.trace(make(w2), _arr((4, 6)))
    # same structure -> same record/graph, different bound const values
    assert tf1.record is tf2.record
    tf1.validate(plan=tf1.solve(opts=OPTS))
    tf2.validate(plan=tf2.solve(opts=OPTS))
    x = _arr((4, 6), 5)
    o1 = tf1.executable(opts=OPTS)(x)
    o2 = tf2.executable(opts=OPTS)(x)
    assert not np.allclose(np.asarray(o1), np.asarray(o2))


# ---------------------------------------------------------------------------
# Trace cache
# ---------------------------------------------------------------------------
def test_trace_cache_identity_and_stats():
    frontend.clear_trace_cache()
    fn = lambda a, b: a @ b + b.sum(axis=0)             # noqa: E731
    args = (_arr((6, 7)), _arr((7, 9), 1))
    t1 = frontend.trace(fn, *args)
    before = frontend.trace_cache_stats()
    t2 = frontend.trace(fn, *args)
    after = frontend.trace_cache_stats()
    assert t1.record is t2.record and t1.graph is t2.graph
    assert after["hits"] == before["hits"] + 1
    # different shapes -> different fingerprint -> new record
    t3 = frontend.trace(fn, _arr((3, 7)), _arr((7, 9), 1))
    assert t3.record is not t1.record
    assert t3.graph.name != t1.graph.name


def test_trace_cache_shares_solved_plan():
    fn = lambda a: a @ a.T                              # noqa: E731
    t1 = frontend.trace(fn, _arr((8, 6)))
    p1 = t1.solve()
    t2 = frontend.trace(fn, _arr((8, 6)))
    assert t2.solve() is p1


def test_trace_cache_eviction_releases_opaque_registry():
    from repro.codegen.reference import opaque_fn
    frontend.clear_trace_cache()
    cache = frontend.trace_cache()
    old_cap = cache.capacity
    try:
        cache.resize(1)
        t1 = frontend.trace(lambda a: jnp.tanh(a) @ a, _arr((5, 5)))
        ops = t1.record.opaque_ops
        assert ops and all(opaque_fn(op) for op in ops)
        # a second distinct trace evicts the first record -> its opaque
        # callables leave the registry with it
        frontend.trace(lambda a: jnp.sin(a) @ a, _arr((5, 5)))
        with pytest.raises(KeyError, match="re-trace"):
            opaque_fn(ops[0])
        # re-tracing re-registers identical semantics
        t3 = frontend.trace(lambda a: jnp.tanh(a) @ a, _arr((5, 5)))
        assert t3.record.opaque_ops == ops
        assert all(opaque_fn(op) for op in ops)
    finally:
        cache.resize(old_cap)


def test_build_graph_resolves_traced_names():
    fn = lambda a: a @ a.T                              # noqa: E731
    tf = frontend.trace(fn, _arr((8, 6)))
    assert build_graph(tf.graph.name) is tf.graph
    with pytest.raises(KeyError):
        frontend.traced_graph("traced:0000000000000000")


def test_argument_contract_errors():
    fn = lambda a, b: a @ b                             # noqa: E731
    tf = frontend.trace(fn, _arr((6, 7)), _arr((7, 9), 1))
    exe = tf.executable(opts=OPTS)
    with pytest.raises(ValueError, match="re-trace"):
        exe(_arr((5, 7)), _arr((7, 9)))
    with pytest.raises(TypeError):
        exe(_arr((6, 7)))


# ---------------------------------------------------------------------------
# Acceptance: FFN block + >=3-matmul chain on both impls
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("impl", ["xla", "pallas_interpret"])
def test_matmul_chain_both_impls(impl):
    def chain(a, b, c, d):
        return ((a @ b) @ c) @ d

    args = (_arr((24, 32)), _arr((32, 20), 1), _arr((20, 28), 2),
            _arr((28, 16), 3))
    tf = frontend.trace(chain, *args)
    assert tf.coverage.eqn_ratio == 1.0
    plan = tf.solve(opts=OPTS)
    tf.validate(*args, impl=impl, plan=plan)


@pytest.mark.parametrize("impl", ["xla", "pallas_interpret"])
def test_models_ffn_block_both_impls(impl):
    from repro.models import ffn
    params = ffn.init_swiglu(jax.random.PRNGKey(0), 32, 64)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 32), jnp.float32)

    def block(p, v):
        return ffn.swiglu(p, v, compute_dtype=jnp.float32)

    tf = frontend.trace(block, params, x)
    # the three projection matmuls and the gating mul are owned by the
    # solver; silu's logistic stays opaque
    assert tf.coverage.n_supported >= 4
    assert tf.coverage.flop_ratio > 0.9
    plan = tf.solve(opts=OPTS)
    tf.validate(impl=impl, plan=plan)


def test_models_gelu_mlp_block():
    from repro.models import ffn
    params = ffn.init_gelu(jax.random.PRNGKey(0), 24, 48)
    x = jax.random.normal(jax.random.PRNGKey(1), (6, 24), jnp.float32)

    def block(p, v):
        return ffn.gelu_mlp(p, v, compute_dtype=jnp.float32)

    tf = frontend.trace(block, params, x)
    assert tf.coverage.flop_ratio > 0.9
    tf.validate(plan=tf.solve(opts=OPTS))


# ---------------------------------------------------------------------------
# Serving integration
# ---------------------------------------------------------------------------
def test_plan_engine_register_function_serves_and_warms():
    from repro.serve import PlanEngine

    a, b = _arr((16, 24)), _arr((24, 12), 1)

    def fn(x, y):
        return jnp.tanh(x @ y) @ y.T

    eng = PlanEngine(impl="xla")
    tf = eng.register_function("fn", fn, (a, b), solver_opts=OPTS)
    assert "fn" in eng.names()
    eng.warmup("fn", (a, b))
    out = eng.submit("fn", (a, b))
    ref = jax.jit(fn)(a, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-3)
    st = eng.stats()
    assert st["functions"] == ["fn"]
    assert st["per_name"]["fn"] >= 2
    # dict-of-arrays submission still works for function entries
    env = tf.bind_args((a, b))
    raw = eng.submit("fn", env)
    assert set(raw) == set(tf.graph.final_outputs())
    eng.unregister("fn")
    assert eng.stats()["functions"] == []


def test_register_function_rejects_empty_graph():
    from repro.serve import PlanEngine
    eng = PlanEngine(impl="xla")
    with pytest.raises(ValueError, match="empty graph"):
        eng.register_function("id", lambda x: x, (_arr((4, 4)),))
